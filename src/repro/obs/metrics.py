"""Process-wide metrics registry with Prometheus text exposition.

Design goals, in priority order:

1. **Near-zero cost when disabled.** Serving code declares its metrics at
   module import time and calls ``inc()`` / ``observe()`` unconditionally
   from hot paths (the decode step, the scheduler tick).  Every mutator
   starts with a single module-global read — the same discipline as
   ``faults.fault_point()`` — and returns immediately when collection is
   off.  Nothing is allocated, no label tuple is built, no lock is taken.

2. **Bounded label cardinality.** Prometheus outages are almost always
   cardinality explosions (a request id or a hash smuggled into a label).
   Every metric carries a hard cap on the number of distinct label sets
   (default ``MAX_LABEL_SETS``); exceeding it raises
   :class:`LabelCardinalityError` at the call site instead of silently
   growing without bound.

3. **One source of truth.** The legacy report dataclasses
   (``LoadReport``, ``FleetReport``, ...) and the registry are fed from
   the *same* measurement at the same code point, so the numbers cannot
   disagree; ``fig18_observability`` asserts the equality.

The module is intentionally dependency-free (stdlib only) and must not
import anything from the rest of ``repro`` — it sits below every layer
that uses it.
"""
from __future__ import annotations

import math
import re
import threading
from bisect import bisect_left
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "LabelCardinalityError",
    "MetricsRegistry",
    "REGISTRY",
    "counter",
    "gauge",
    "histogram",
    "enable",
    "disable",
    "enabled",
    "enabled_scope",
    "render",
    "reset",
    "value",
    "lint_exposition",
]

# One global read on the hot path.  Flipped only by enable()/disable().
_ENABLED = False

#: default hard cap on distinct label sets per metric
MAX_LABEL_SETS = 64

#: default histogram buckets — spans µs-scale decode steps up to
#: minute-scale cold starts (seconds).
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def enable() -> None:
    """Turn collection on (mutators start recording)."""
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    """Turn collection off (mutators become one-global-read no-ops)."""
    global _ENABLED
    _ENABLED = False


def enabled() -> bool:
    return _ENABLED


@contextmanager
def enabled_scope(on: bool = True) -> Iterator[None]:
    """Temporarily enable (or disable) collection; restores on exit."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = on
    try:
        yield
    finally:
        _ENABLED = prev


class LabelCardinalityError(RuntimeError):
    """A metric exceeded its cap on distinct label sets.

    Raised at the offending call site: an unbounded label value (request
    id, blob hash, timestamp) is a bug in the instrumentation, not a
    runtime condition to tolerate.
    """


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _escape_help(v: str) -> str:
    return v.replace("\\", "\\\\").replace("\n", "\\n")


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if isinstance(v, float) and v.is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


class _Metric:
    """Common labeled-children machinery for the three metric kinds."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Sequence[str] = (),
                 max_label_sets: int = MAX_LABEL_SETS):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r} on {name}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self.max_label_sets = max_label_sets
        self._lock = threading.Lock()
        # label-values tuple -> child state (kind-specific)
        self._children: Dict[Tuple[str, ...], object] = {}

    def _key(self, labels: Dict[str, str]) -> Tuple[str, ...]:
        if tuple(sorted(labels)) != tuple(sorted(self.labelnames)):
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared "
                f"{sorted(self.labelnames)}")
        return tuple(str(labels[ln]) for ln in self.labelnames)

    def _child(self, key: Tuple[str, ...]):
        # caller holds self._lock
        child = self._children.get(key)
        if child is None:
            if len(self._children) >= self.max_label_sets:
                raise LabelCardinalityError(
                    f"metric {self.name}: more than {self.max_label_sets} "
                    f"distinct label sets (latest: "
                    f"{dict(zip(self.labelnames, key))}) — a label value is "
                    f"probably unbounded (request id, hash, timestamp)")
            child = self._new_child()
            self._children[key] = child
        return child

    def _new_child(self):
        raise NotImplementedError

    def clear(self) -> None:
        with self._lock:
            self._children.clear()

    def samples(self) -> List[Tuple[str, Tuple[Tuple[str, str], ...], float]]:
        """Flat (sample_name, ((label, value), ...), value) list."""
        raise NotImplementedError


class Counter(_Metric):
    kind = "counter"

    def _new_child(self):
        return [0.0]

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        if not _ENABLED:
            return
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative increment")
        key = self._key(labels)
        with self._lock:
            self._child(key)[0] += amount

    def value(self, **labels: str) -> float:
        key = self._key(labels)
        with self._lock:
            child = self._children.get(key)
            return child[0] if child else 0.0

    def samples(self):
        with self._lock:
            return [(self.name, tuple(zip(self.labelnames, key)), c[0])
                    for key, c in sorted(self._children.items())]


class Gauge(_Metric):
    kind = "gauge"

    def _new_child(self):
        return [0.0]

    def set(self, v: float, **labels: str) -> None:
        if not _ENABLED:
            return
        key = self._key(labels)
        with self._lock:
            self._child(key)[0] = float(v)

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        if not _ENABLED:
            return
        key = self._key(labels)
        with self._lock:
            self._child(key)[0] += amount

    def dec(self, amount: float = 1.0, **labels: str) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels: str) -> float:
        key = self._key(labels)
        with self._lock:
            child = self._children.get(key)
            return child[0] if child else 0.0

    def samples(self):
        with self._lock:
            return [(self.name, tuple(zip(self.labelnames, key)), c[0])
                    for key, c in sorted(self._children.items())]


class _HistChild:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, nbuckets: int):
        self.counts = [0] * (nbuckets + 1)  # +1 for the +Inf bucket
        self.sum = 0.0
        self.count = 0


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str, labelnames: Sequence[str] = (),
                 buckets: Optional[Sequence[float]] = None,
                 max_label_sets: int = MAX_LABEL_SETS):
        super().__init__(name, help, labelnames, max_label_sets)
        bs = tuple(sorted(buckets if buckets is not None else DEFAULT_BUCKETS))
        if not bs or any(b2 <= b1 for b1, b2 in zip(bs, bs[1:])):
            raise ValueError(f"histogram {name}: buckets must be distinct")
        self.buckets = bs

    def _new_child(self):
        return _HistChild(len(self.buckets))

    def observe(self, v: float, **labels: str) -> None:
        if not _ENABLED:
            return
        key = self._key(labels)
        i = bisect_left(self.buckets, v)
        with self._lock:
            child = self._child(key)
            child.counts[i] += 1
            child.sum += v
            child.count += 1

    def snapshot(self, **labels: str) -> Tuple[List[int], float, int]:
        """(cumulative bucket counts incl. +Inf, sum, count)."""
        key = self._key(labels)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                return [0] * (len(self.buckets) + 1), 0.0, 0
            cum, acc = [], 0
            for c in child.counts:
                acc += c
                cum.append(acc)
            return cum, child.sum, child.count

    def samples(self):
        out = []
        with self._lock:
            items = sorted(self._children.items())
            for key, child in items:
                base = tuple(zip(self.labelnames, key))
                acc = 0
                for b, c in zip(self.buckets, child.counts):
                    acc += c
                    out.append((self.name + "_bucket",
                                base + (("le", _fmt(b)),), float(acc)))
                acc += child.counts[-1]
                out.append((self.name + "_bucket", base + (("le", "+Inf"),),
                            float(acc)))
                out.append((self.name + "_sum", base, child.sum))
                out.append((self.name + "_count", base, float(child.count)))
        return out


class MetricsRegistry:
    """Name -> metric map with idempotent get-or-create declaration."""

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: Dict[str, _Metric] = {}

    def _declare(self, cls, name: str, help: str, labelnames, **kw):
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if (type(existing) is not cls
                        or existing.labelnames != tuple(labelnames)):
                    raise ValueError(
                        f"metric {name} re-declared with different "
                        f"kind/labels")
                return existing
            m = cls(name, help, labelnames, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str,
                labelnames: Sequence[str] = (), **kw) -> Counter:
        return self._declare(Counter, name, help, labelnames, **kw)

    def gauge(self, name: str, help: str,
              labelnames: Sequence[str] = (), **kw) -> Gauge:
        return self._declare(Gauge, name, help, labelnames, **kw)

    def histogram(self, name: str, help: str,
                  labelnames: Sequence[str] = (), **kw) -> Histogram:
        return self._declare(Histogram, name, help, labelnames, **kw)

    def get(self, name: str) -> Optional[_Metric]:
        with self._lock:
            return self._metrics.get(name)

    def metrics(self) -> List[_Metric]:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def reset(self) -> None:
        """Zero every metric (keeps the declarations)."""
        for m in self.metrics():
            m.clear()

    def value(self, name: str, labels: Optional[Dict[str, str]] = None) -> float:
        """Convenience accessor for counters/gauges (0.0 if never touched)."""
        m = self.get(name)
        if m is None:
            raise KeyError(name)
        return m.value(**(labels or {}))  # type: ignore[union-attr]

    def render(self) -> str:
        """Prometheus text exposition (format 0.0.4)."""
        lines: List[str] = []
        for m in self.metrics():
            lines.append(f"# HELP {m.name} {_escape_help(m.help)}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for sname, labels, v in m.samples():
                if labels:
                    lbl = ",".join(f'{k}="{_escape_label(str(val))}"'
                                   for k, val in labels)
                    lines.append(f"{sname}{{{lbl}}} {_fmt(v)}")
                else:
                    lines.append(f"{sname} {_fmt(v)}")
        return "\n".join(lines) + "\n"


#: the default process-wide registry
REGISTRY = MetricsRegistry()


def counter(name: str, help: str, labelnames: Sequence[str] = (), **kw) -> Counter:
    return REGISTRY.counter(name, help, labelnames, **kw)


def gauge(name: str, help: str, labelnames: Sequence[str] = (), **kw) -> Gauge:
    return REGISTRY.gauge(name, help, labelnames, **kw)


def histogram(name: str, help: str, labelnames: Sequence[str] = (), **kw) -> Histogram:
    return REGISTRY.histogram(name, help, labelnames, **kw)


def render() -> str:
    return REGISTRY.render()


def reset() -> None:
    REGISTRY.reset()


def value(name: str, labels: Optional[Dict[str, str]] = None) -> float:
    return REGISTRY.value(name, labels)


# ---------------------------------------------------------------------------
# exposition lint — shared by fig18, tests, and .github/analysis_gate.py
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r" (?P<value>[^ ]+)(?: (?P<ts>-?\d+))?$")
_LABEL_PAIR_RE = re.compile(
    r'^(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>(?:[^"\\]|\\.)*)"$')


def _split_labels(raw: str) -> Optional[List[Tuple[str, str]]]:
    """Split 'a="x",b="y"' respecting escaped quotes; None if malformed."""
    pairs: List[Tuple[str, str]] = []
    buf, depth_in_str, prev_backslash = [], False, False
    items: List[str] = []
    for ch in raw:
        if depth_in_str:
            buf.append(ch)
            if ch == '"' and not prev_backslash:
                depth_in_str = False
            prev_backslash = (ch == "\\" and not prev_backslash)
            continue
        if ch == '"':
            depth_in_str = True
            buf.append(ch)
            prev_backslash = False
        elif ch == ",":
            items.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    if buf:
        items.append("".join(buf))
    if depth_in_str:
        return None
    for item in items:
        m = _LABEL_PAIR_RE.match(item.strip())
        if not m:
            return None
        pairs.append((m.group("k"), m.group("v")))
    return pairs


def _parse_value(s: str) -> Optional[float]:
    if s == "+Inf":
        return math.inf
    if s == "-Inf":
        return -math.inf
    if s == "NaN":
        return math.nan
    try:
        return float(s)
    except ValueError:
        return None


def lint_exposition(text: str) -> List[str]:
    """Validate Prometheus text exposition; return a list of problems.

    Checks: line grammar, HELP/TYPE placement (at most one each, before
    any sample of the family), samples grouped under their TYPE,
    duplicate series, and histogram structure (``le`` parses, ``+Inf``
    bucket present, cumulative counts non-decreasing, ``_count`` equals
    the ``+Inf`` bucket, ``_sum``/``_count`` present).
    """
    problems: List[str] = []
    typed: Dict[str, str] = {}
    helped: Dict[str, bool] = {}
    seen_sample_of: Dict[str, bool] = {}
    seen_series: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], int] = {}
    # family -> labelset(excl. le) -> [(le, cum_count)]
    hist_buckets: Dict[str, Dict[Tuple[Tuple[str, str], ...], List[Tuple[float, float]]]] = {}
    hist_sum: Dict[str, Dict[Tuple[Tuple[str, str], ...], float]] = {}
    hist_count: Dict[str, Dict[Tuple[Tuple[str, str], ...], float]] = {}

    def family_of(name: str) -> str:
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[: -len(suffix)] in typed:
                base = name[: -len(suffix)]
                break
        return base

    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            parts = line.split(" ", 3)
            if len(parts) < 3 or not _NAME_RE.match(parts[2]):
                problems.append(f"line {lineno}: malformed HELP")
                continue
            name = parts[2]
            if helped.get(name):
                problems.append(f"line {lineno}: duplicate HELP for {name}")
            if seen_sample_of.get(name):
                problems.append(
                    f"line {lineno}: HELP for {name} after its samples")
            helped[name] = True
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ")
            if len(parts) != 4 or not _NAME_RE.match(parts[2]) or \
                    parts[3] not in ("counter", "gauge", "histogram",
                                     "summary", "untyped"):
                problems.append(f"line {lineno}: malformed TYPE")
                continue
            name = parts[2]
            if name in typed:
                problems.append(f"line {lineno}: duplicate TYPE for {name}")
            if seen_sample_of.get(name):
                problems.append(
                    f"line {lineno}: TYPE for {name} after its samples")
            typed[name] = parts[3]
            continue
        if line.startswith("#"):
            continue  # plain comment
        m = _SAMPLE_RE.match(line)
        if not m:
            problems.append(f"line {lineno}: unparseable sample: {line!r}")
            continue
        name = m.group("name")
        val = _parse_value(m.group("value"))
        if val is None:
            problems.append(f"line {lineno}: bad value {m.group('value')!r}")
            continue
        labels = _split_labels(m.group("labels")) if m.group("labels") else []
        if labels is None:
            problems.append(f"line {lineno}: malformed labels")
            continue
        fam = family_of(name)
        seen_sample_of[fam] = True
        series = (name, tuple(sorted(labels)))
        if series in seen_series:
            problems.append(
                f"line {lineno}: duplicate series {name}{dict(labels)} "
                f"(first at line {seen_series[series]})")
        seen_series[series] = lineno
        if typed.get(fam) == "histogram":
            rest = tuple(sorted((k, v) for k, v in labels if k != "le"))
            if name == fam + "_bucket":
                le = dict(labels).get("le")
                lev = _parse_value(le) if le is not None else None
                if lev is None:
                    problems.append(f"line {lineno}: histogram bucket "
                                    f"without parseable le")
                else:
                    hist_buckets.setdefault(fam, {}).setdefault(
                        rest, []).append((lev, val))
            elif name == fam + "_sum":
                hist_sum.setdefault(fam, {})[rest] = val
            elif name == fam + "_count":
                hist_count.setdefault(fam, {})[rest] = val
            elif name == fam:
                problems.append(
                    f"line {lineno}: bare sample for histogram {fam}")

    for fam, per_labels in hist_buckets.items():
        for rest, entries in per_labels.items():
            entries.sort(key=lambda e: e[0])
            les = [le for le, _ in entries]
            counts = [c for _, c in entries]
            if not les or les[-1] != math.inf:
                problems.append(f"{fam}{dict(rest)}: missing +Inf bucket")
                continue
            if any(c2 < c1 for c1, c2 in zip(counts, counts[1:])):
                problems.append(
                    f"{fam}{dict(rest)}: bucket counts not cumulative")
            cnt = hist_count.get(fam, {}).get(rest)
            if cnt is None:
                problems.append(f"{fam}{dict(rest)}: missing _count")
            elif cnt != counts[-1]:
                problems.append(
                    f"{fam}{dict(rest)}: _count {cnt} != +Inf bucket "
                    f"{counts[-1]}")
            if rest not in hist_sum.get(fam, {}):
                problems.append(f"{fam}{dict(rest)}: missing _sum")
    for fam, t in typed.items():
        if t == "histogram" and seen_sample_of.get(fam) and \
                fam not in hist_buckets:
            problems.append(f"{fam}: histogram with samples but no buckets")
    return problems

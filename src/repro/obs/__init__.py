"""Unified telemetry for the repro stack.

Three small, dependency-free pieces:

- :mod:`repro.obs.metrics` — process-wide registry of labeled counters,
  gauges, and histograms with Prometheus text exposition and a
  one-global-read disabled path (off by default).
- :mod:`repro.obs.trace` — structured spans with thread attribution,
  exported as Chrome/Perfetto trace-event JSON (off by default).
- :func:`configure_logging` — one-call console logging for the
  ``repro.*`` logger namespace used across the package.

Serving code declares metrics at import time and instruments hot paths
unconditionally; until ``metrics.enable()`` / ``trace.start()`` is
called, every hook is a single module-global read.  See
``docs/architecture.md`` §13 for the metric catalog and span taxonomy.
"""
from __future__ import annotations

import logging

from repro.obs import metrics, trace
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    LabelCardinalityError,
    MetricsRegistry,
    REGISTRY,
    lint_exposition,
)
from repro.obs.trace import TraceCollector, instant, span, validate_trace

__all__ = [
    "metrics",
    "trace",
    "Counter",
    "Gauge",
    "Histogram",
    "LabelCardinalityError",
    "MetricsRegistry",
    "REGISTRY",
    "TraceCollector",
    "span",
    "instant",
    "lint_exposition",
    "validate_trace",
    "configure_logging",
]


def configure_logging(level: int = logging.INFO,
                      stream=None, force: bool = False) -> logging.Logger:
    """Attach a console handler to the ``repro`` logger namespace.

    Idempotent: if the ``repro`` logger already has handlers (or a
    handler is installed on the root logger) it only adjusts the level,
    unless ``force=True``.  Scoped to the ``repro`` logger rather than
    the root so embedding applications keep control of their own logging.
    """
    log = logging.getLogger("repro")
    log.setLevel(level)
    has_root = logging.getLogger().handlers
    if force or (not log.handlers and not has_root):
        handler = logging.StreamHandler(stream)
        handler.setFormatter(logging.Formatter("[%(name)s] %(message)s"))
        log.addHandler(handler)
        log.propagate = False
    return log

"""Structured trace spans exported as Chrome/Perfetto trace-event JSON.

The LOAD pipeline runs fetch, deserialize, and install on three distinct
threads (``restore._TemplatePipeline``); a reshard overlaps a DUAL window
with live serving.  Wall-clock reports cannot show *where* that time
overlaps — a timeline can.  This module collects spans with explicit
thread attribution and writes the Chrome trace-event JSON object format
(``{"traceEvents": [...]}``) that both ``chrome://tracing`` and
https://ui.perfetto.dev load directly.

Discipline mirrors :mod:`repro.obs.metrics`: a single module-global read
(``_TRACING``) gates every emission, so instrumented code can leave span
context managers in place permanently.  :class:`span` *always* measures
its duration (callers such as ``restore.foundry_load`` reuse
``span.seconds`` to fill the legacy report dataclasses — one measurement,
two consumers) but only records an event when tracing is on.

Event vocabulary used here (a small, valid subset of the format):

- ``"X"`` complete events — spans with ``ts``/``dur`` in microseconds
- ``"i"`` instant events — crashes, cutovers, shed decisions
- ``"M"`` metadata events — ``thread_name`` / ``process_name``

Stdlib only; must not import from the rest of ``repro``.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Union

__all__ = [
    "TraceCollector",
    "span",
    "instant",
    "complete",
    "set_thread_name",
    "start",
    "stop",
    "active",
    "collector",
    "save",
    "validate_trace",
]

# One global read on the hot path.  Flipped only by start()/stop().
_TRACING = False

#: default cap on buffered events; beyond it events are counted as
#: dropped rather than growing memory without bound
MAX_EVENTS = 500_000

_VALID_PHASES = {"X", "B", "E", "i", "I", "M", "C"}


class TraceCollector:
    """Bounded, thread-safe buffer of Chrome trace events.

    Timestamps are ``time.perf_counter()`` seconds rebased to the
    collector's epoch and converted to microseconds, so events recorded
    from any thread share one clock.
    """

    def __init__(self, max_events: int = MAX_EVENTS):
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._named_tids: Dict[int, str] = {}
        self.max_events = max_events
        self.dropped = 0
        self.epoch = time.perf_counter()
        self.pid = os.getpid()

    def _ts_us(self, t: float) -> float:
        return (t - self.epoch) * 1e6

    def _append(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._events) >= self.max_events:
                self.dropped += 1
                return
            self._events.append(ev)

    def set_thread_name(self, name: str, tid: Optional[int] = None) -> None:
        tid = threading.get_ident() if tid is None else tid
        with self._lock:
            if self._named_tids.get(tid) == name:
                return
            self._named_tids[tid] = name
            if len(self._events) >= self.max_events:
                self.dropped += 1
                return
            self._events.append({
                "name": "thread_name", "ph": "M", "pid": self.pid,
                "tid": tid, "args": {"name": name},
            })

    def add_complete(self, name: str, cat: str, t0: float, dur_s: float,
                     args: Optional[Dict[str, Any]] = None,
                     tid: Optional[int] = None) -> None:
        """Record a finished span; ``t0`` is a perf_counter timestamp."""
        ev: Dict[str, Any] = {
            "name": name, "cat": cat or "default", "ph": "X",
            "ts": self._ts_us(t0), "dur": max(dur_s, 0.0) * 1e6,
            "pid": self.pid,
            "tid": threading.get_ident() if tid is None else tid,
        }
        if args:
            ev["args"] = args
        self._append(ev)

    def add_instant(self, name: str, cat: str,
                    args: Optional[Dict[str, Any]] = None,
                    t: Optional[float] = None) -> None:
        ev: Dict[str, Any] = {
            "name": name, "cat": cat or "default", "ph": "i",
            "ts": self._ts_us(time.perf_counter() if t is None else t),
            "pid": self.pid, "tid": threading.get_ident(), "s": "t",
        }
        if args:
            ev["args"] = args
        self._append(ev)

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.obs.trace",
                          "dropped_events": self.dropped},
        }

    def save(self, path: str) -> str:
        doc = self.to_dict()
        tmp = path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(doc, f)
        os.replace(tmp, path)
        return path


_COLLECTOR = TraceCollector()


def collector() -> TraceCollector:
    return _COLLECTOR


def start(max_events: int = MAX_EVENTS, fresh: bool = True) -> TraceCollector:
    """Begin tracing; by default into a fresh collector."""
    global _TRACING, _COLLECTOR
    if fresh or not isinstance(_COLLECTOR, TraceCollector):
        _COLLECTOR = TraceCollector(max_events=max_events)
    _TRACING = True
    return _COLLECTOR


def stop() -> TraceCollector:
    """Stop tracing; the collector (and its events) remain readable."""
    global _TRACING
    _TRACING = False
    return _COLLECTOR


def active() -> bool:
    return _TRACING


def save(path: str) -> str:
    return _COLLECTOR.save(path)


def set_thread_name(name: str) -> None:
    if not _TRACING:
        return
    _COLLECTOR.set_thread_name(name)


def instant(name: str, cat: str = "", **args: Any) -> None:
    if not _TRACING:
        return
    _COLLECTOR.add_instant(name, cat, args or None)


def complete(name: str, cat: str, t0: float, t1: float, **args: Any) -> None:
    """Record a span from two perf_counter timestamps (for windows whose
    endpoints are observed at different call sites, e.g. reshard DUAL)."""
    if not _TRACING:
        return
    _COLLECTOR.add_complete(name, cat, t0, t1 - t0, args or None)


class span:
    """Context manager that times a block and records it when tracing.

    ``seconds`` is always populated on exit, so call sites can feed the
    same measurement into legacy reports and histograms::

        with span("load.parse", cat="load") as sp:
            manifest = archive.manifest
        rep.phases["parse_s"] = sp.seconds
    """

    __slots__ = ("name", "cat", "args", "seconds", "_t0")

    def __init__(self, name: str, cat: str = "", **args: Any):
        self.name = name
        self.cat = cat
        self.args = args or None
        self.seconds = 0.0
        self._t0 = 0.0

    def __enter__(self) -> "span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.seconds = time.perf_counter() - self._t0
        if _TRACING:
            args = self.args
            if exc_type is not None:
                args = dict(args or {})
                args["error"] = exc_type.__name__
            _COLLECTOR.add_complete(self.name, self.cat, self._t0,
                                    self.seconds, args)
        return False


# ---------------------------------------------------------------------------
# schema check — shared by fig18, tests, and .github/analysis_gate.py
# ---------------------------------------------------------------------------

def validate_trace(doc: Union[Dict[str, Any], List[Any]]) -> List[str]:
    """Validate Chrome trace-event JSON; return a list of problems.

    Accepts both the object format (``{"traceEvents": [...]}``) and the
    bare array format.  Checks per-event structure: known phase, string
    name, numeric non-negative ``ts``, integral ``pid``/``tid``, ``dur``
    present and non-negative on ``"X"`` events, and well-formed
    ``thread_name``/``process_name`` metadata events.
    """
    problems: List[str] = []
    if isinstance(doc, dict):
        events = doc.get("traceEvents")
        if not isinstance(events, list):
            return ["traceEvents missing or not a list"]
    elif isinstance(doc, list):
        events = doc
    else:
        return ["trace document is neither an object nor an array"]
    if not events:
        problems.append("trace contains no events")
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _VALID_PHASES:
            problems.append(f"{where}: unknown phase {ph!r}")
            continue
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            problems.append(f"{where}: missing name")
        for fld in ("pid", "tid"):
            if not isinstance(ev.get(fld), int):
                problems.append(f"{where} ({name}): {fld} not an int")
        if ph == "M":
            if name not in ("thread_name", "process_name",
                            "thread_sort_index", "process_sort_index"):
                problems.append(f"{where}: unknown metadata event {name!r}")
            elif name in ("thread_name", "process_name") and not isinstance(
                    (ev.get("args") or {}).get("name"), str):
                problems.append(f"{where} ({name}): args.name missing")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            problems.append(f"{where} ({name}): ts not a number")
        elif ts < 0:
            problems.append(f"{where} ({name}): negative ts {ts}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)):
                problems.append(f"{where} ({name}): X event without dur")
            elif dur < 0:
                problems.append(f"{where} ({name}): negative dur {dur}")
    return problems


def spans_named(doc: Union[Dict[str, Any], List[Any]], name: str
                ) -> List[Dict[str, Any]]:
    """All ``"X"`` events with the given name (fig18/test helper)."""
    events = doc.get("traceEvents", []) if isinstance(doc, dict) else doc
    return [e for e in events
            if isinstance(e, dict) and e.get("ph") == "X"
            and e.get("name") == name]


def overlapping(a: Dict[str, Any], b: Dict[str, Any]) -> bool:
    """True if two ``"X"`` events overlap in time."""
    return (a["ts"] < b["ts"] + b["dur"]) and (b["ts"] < a["ts"] + a["dur"])

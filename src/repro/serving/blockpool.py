"""Block-table paged KV pool + radix prefix cache for the serving engine.

Replaces the slot pool's per-request contiguous KV rows (kvcache.py) with
fixed-size KV *blocks* shared across requests:

  * ``BlockAllocator`` — ref-counted free list over ``n_blocks`` blocks.
    Block 0 is a reserved scratch block: padded/inactive batch rows point
    every block-table entry at it, so their in-graph writes and gathers are
    harmless (decode masks positions past ``lengths`` before softmax).
  * ``RadixPrefixCache`` — a radix tree over *block-sized token chunks*.
    Each node owns exactly one block (one tree reference in the allocator);
    a request whose prompt prefixes a cached chain reuses those blocks
    instead of re-prefilling, diverging tails fork copy-on-write, and
    unreferenced nodes evict LRU when the allocator runs dry.
  * ``PagedKVCachePool`` — the engine-facing pool. Device state is the
    donated decode-cache pytree ``{"k","v","block_tables","lengths"}``: the
    k/v pools are batch-invariant ``[L, NB, bs, Hkv, Dh]`` buffers (every
    bucket's captured program takes the *same* pools; only block_tables and
    lengths carry the batch dim), so templates group across buckets exactly
    as the slot layout's did. Host-side metadata (per-slot block tables and
    lengths) is the source of truth; scheduling events mark it dirty and
    ``sync`` rebuilds the small device tables wholesale before dispatch.

Slot compaction becomes pure host bookkeeping — releasing a request moves
its *table*, never its KV bytes (the slot pool's O(cache) device row move
disappears). Construction registers the pool's deterministic extents with
the MemoryPlan exactly like the slot pool (paper §5.4), and
``export_rows``/``import_rows`` speak the same dense RowBundle interchange
format as ``KVCachePool`` so live reshard (§8) migrates KV across layouts
and meshes.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.memory_plan import MemoryPlan
from repro.obs import metrics as obs_metrics
from repro.serving.rowbundle import (RowBundle, check_export_slots,
                                     check_import, reshard_rows)

# Mirrors RadixPrefixCache.stats — both fed at the same code points so the
# exposition and the dict can never disagree (docs/architecture.md §13).
_M_RADIX = obs_metrics.counter(
    "kv_radix_events_total",
    "Radix prefix-cache events (hit/miss/eviction/dedup/cow_fork).",
    labelnames=("event",))


class BlockAllocator:
    """Ref-counted allocator over ``n_blocks`` fixed-size KV blocks.

    Block 0 is the reserved scratch block: its refcount is pinned and it is
    never handed out, so zeroed block-table entries always alias a block no
    live request reads through its length mask."""

    SCRATCH = 0

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ValueError(f"need >= 2 blocks (scratch + 1), got {n_blocks}")
        self.n_blocks = n_blocks
        self.refs = [0] * n_blocks
        self.refs[self.SCRATCH] = 1
        # pop() yields ascending block ids — deterministic layouts for tests
        self._free = list(range(n_blocks - 1, 0, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_usable(self) -> int:
        """Blocks a single request could ever hold (everything but scratch)."""
        return self.n_blocks - 1

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("kv block pool exhausted")
        b = self._free.pop()
        self.refs[b] = 1
        return b

    def ref(self, block: int) -> int:
        return self.refs[block]

    def incref(self, block: int):
        if self.refs[block] <= 0:
            raise ValueError(f"incref of free block {block}")
        self.refs[block] += 1

    def decref(self, block: int):
        if block == self.SCRATCH:
            return
        if self.refs[block] <= 0:
            raise ValueError(f"decref of free block {block}")
        self.refs[block] -= 1
        if self.refs[block] == 0:
            self._free.append(block)


class _RadixNode:
    __slots__ = ("chunk", "block", "children", "parent", "tick")

    def __init__(self, chunk, block, parent):
        self.chunk = chunk          # tuple of block_size token ids
        self.block = block          # allocator block backing this chunk's KV
        self.children: Dict[tuple, "_RadixNode"] = {}
        self.parent = parent
        self.tick = 0


class RadixPrefixCache:
    """Radix tree over block-sized token chunks; one block per node.

    The tree holds one allocator reference per node, so a cached block
    outlives the request that produced it and is reclaimed only by LRU
    eviction (``evict_lru``) once no live request references it."""

    def __init__(self, allocator: BlockAllocator, block_size: int):
        self.allocator = allocator
        self.block_size = block_size
        self.root = _RadixNode(None, None, None)
        self._tick = 0
        self.stats = {"hits": 0, "misses": 0, "evictions": 0, "dedup": 0}

    # ------------------------------------------------------------------
    def _chunks(self, tokens):
        bs = self.block_size
        for i in range(len(tokens) // bs):
            yield tuple(tokens[i * bs:(i + 1) * bs])

    def _touch(self, node: _RadixNode):
        self._tick += 1
        node.tick = self._tick

    def match(self, tokens) -> List[_RadixNode]:
        """Longest chain of cached full-block nodes prefixing ``tokens``.
        Read-only on the allocator: callers take their own references."""
        node, out = self.root, []
        for chunk in self._chunks(tokens):
            child = node.children.get(chunk)
            if child is None:
                break
            self._touch(child)
            out.append(child)
            node = child
        return out

    def partial_child(self, node: _RadixNode,
                      rest) -> Tuple[Optional[_RadixNode], int]:
        """Child of ``node`` sharing the longest strict token prefix with
        ``rest``: the copy-on-write fork point (0 < k < block_size slots of
        the child's block are reusable; the caller copies them into a fresh
        private block)."""
        best, best_k = None, 0
        for chunk, child in node.children.items():
            k = 0
            for a, b in zip(chunk, rest):
                if a != b:
                    break
                k += 1
            if k > best_k:
                best, best_k = child, k
        return best, best_k

    def insert(self, tokens, table: List[int]) -> List[Tuple[int, int]]:
        """Record ``tokens``' full blocks in the tree, backed by ``table``.

        New chunks take a tree reference on the slot's block. Chunks already
        cached under a *different* block dedupe: the return value lists
        ``(table_index, cached_block)`` swaps for the caller to apply
        (retarget its table at the cached block and drop its private copy —
        KV content at a position is a pure function of the token prefix, so
        the blocks are interchangeable)."""
        node, swaps = self.root, []
        for i, chunk in enumerate(self._chunks(tokens)):
            child = node.children.get(chunk)
            if child is None:
                child = _RadixNode(chunk, table[i], node)
                node.children[chunk] = child
                self.allocator.incref(table[i])
            elif child.block != table[i]:
                swaps.append((i, child.block))
                self.stats["dedup"] += 1
                _M_RADIX.inc(event="dedup")
            self._touch(child)
            node = child
        return swaps

    # ------------------------------------------------------------------
    def evictable(self) -> List[_RadixNode]:
        """Leaf nodes whose block only the tree still references — the only
        nodes eviction may free. An interior node's block stays pinned while
        descendants exist (a child's KV attends into it), and a block a live
        request's table references has allocator refcount > 1."""
        out, stack = [], list(self.root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            elif self.allocator.ref(n.block) == 1:
                out.append(n)
        return out

    def reclaimable_count(self, exclude=frozenset()) -> int:
        """Blocks iterated LRU eviction could eventually return to the
        allocator. A node is reclaimable iff only the tree references its
        block AND its whole subtree is reclaimable (eviction is leaf-first:
        a pinned descendant keeps every ancestor interior forever). Counting
        only current leaves would under-report chains and wedge admission.
        ``exclude``: blocks to treat as pinned — an admission probe passes
        the chain the candidate itself would adopt, since those blocks stop
        being evictable the moment it is admitted."""
        def walk(n):
            total, clean = 0, True
            for c in n.children.values():
                t, ok = walk(c)
                total += t
                clean = clean and ok
            if (clean and n.block not in exclude
                    and self.allocator.ref(n.block) == 1):
                return total + 1, True
            return total, False

        return sum(walk(c)[0] for c in self.root.children.values())

    def evict_lru(self) -> bool:
        """Drop the least-recently-hit evictable leaf, freeing its block
        back to the allocator. Returns False when nothing can be evicted."""
        cands = self.evictable()
        if not cands:
            return False
        victim = min(cands, key=lambda n: n.tick)
        del victim.parent.children[victim.chunk]
        self.allocator.decref(victim.block)
        self.stats["evictions"] += 1
        _M_RADIX.inc(event="eviction")
        return True

    @property
    def n_nodes(self) -> int:
        n, stack = 0, list(self.root.children.values())
        while stack:
            node = stack.pop()
            n += 1
            stack.extend(node.children.values())
        return n


class PagedKVCachePool:
    """Engine-facing paged pool; interface-compatible with ``KVCachePool``
    (slots/acquire/release/export/import and the same guard errors) plus the
    paged lifecycle hooks the decode-fill engine loop drives:

        begin_sequence   radix-match the prompt, adopt cached blocks (+COW)
        ensure_step_capacity   allocate this step's write block per slot
        sync             rebuild device block_tables/lengths when dirty
        note_step        mirror the in-graph ``lengths + 1`` on the host
        commit_prefix    insert a finished fill's full blocks into the tree
    """

    def __init__(self, model, max_batch: int, max_seq: int, bucket_of,
                 memory_plan: Optional[MemoryPlan] = None,
                 block_size: int = 16, n_blocks: Optional[int] = None):
        self.model = model
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.bucket_of = bucket_of
        self.block_size = block_size
        self.blocks_per_seq = -(-max_seq // block_size)
        # default: every request can hold a full table, plus scratch
        self.n_blocks = n_blocks or max_batch * self.blocks_per_seq + 1
        self.allocator = BlockAllocator(self.n_blocks)
        self.prefix = RadixPrefixCache(self.allocator, block_size)
        self.cur_bucket = bucket_of(1)
        self.slots: List[Optional[int]] = [None] * self.cur_bucket
        self.tables: List[List[int]] = [[] for _ in range(self.cur_bucket)]
        self.host_len: List[int] = [0] * self.cur_bucket
        self.dirty = True
        self.cache = self._init_device_state(self.cur_bucket)
        if memory_plan is not None:
            # paged extents are bucket-invariant (pools carry no batch dim);
            # registered rank-relative like the slot pool so stamped LOADs
            # re-derive per-rank buffer sizes from a 1-rank capture (§4.3)
            specs = model.paged_cache_specs(max_batch, max_seq,
                                            self.n_blocks, block_size)
            for path, sd in jax.tree_util.tree_flatten_with_path(specs)[0]:
                memory_plan.alloc(
                    "kv_paged" + jax.tree_util.keystr(path),
                    int(np.prod(sd.shape)) * jnp.dtype(sd.dtype).itemsize,
                    scope="per_rank")

    # ------------------------------------------------------------------
    def _specs(self, bucket: int):
        return self.model.paged_cache_specs(bucket, self.max_seq,
                                            self.n_blocks, self.block_size)

    def _init_device_state(self, bucket: int):
        def mk(sd):
            z = jnp.zeros(sd.shape, sd.dtype)
            return jax.device_put(z, sd.sharding) if sd.sharding is not None else z
        return jax.tree.map(mk, self._specs(bucket))

    def _apply_shardings(self):
        if self.model.ctx.mesh is None:
            return
        specs = self._specs(self.cur_bucket)
        self.cache = jax.tree.map(
            lambda x, sd: (jax.device_put(x, sd.sharding)
                           if sd.sharding is not None else x),
            self.cache, specs)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    # ------------------------------------------------------------------
    # slot lifecycle (KVCachePool-compatible)
    # ------------------------------------------------------------------
    def acquire(self, req_id: int) -> int:
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = req_id
                self.tables[i] = []
                self.host_len[i] = 0
                self.dirty = True
                return i
        n = self.n_active + 1
        if n > self.max_batch:
            raise RuntimeError("pool exhausted")
        self._resize(self.bucket_of(n))
        return self.acquire(req_id)

    def release(self, slot: int):
        """Free a slot: drop its table's block references (radix-cached
        blocks survive on the tree's reference) and compact by moving the
        last active slot's *metadata* into the hole — no device KV moves."""
        if not (0 <= slot < len(self.slots)):
            raise ValueError(
                f"release of slot {slot}: out of range for bucket "
                f"{self.cur_bucket} (valid slots 0..{len(self.slots) - 1})")
        if self.slots[slot] is None:
            raise ValueError(
                f"release of slot {slot}: not an active slot "
                f"({'pool is empty' if self.n_active == 0 else 'double release'}"
                f") — compacting would corrupt a live row")
        for b in self.tables[slot]:
            self.allocator.decref(b)
        self.tables[slot] = []
        self.host_len[slot] = 0
        last = max(i for i, s in enumerate(self.slots) if s is not None)
        if last != slot:
            self.slots[slot] = self.slots[last]
            self.tables[slot] = self.tables[last]
            self.host_len[slot] = self.host_len[last]
            self.tables[last] = []
            self.host_len[last] = 0
        self.slots[last] = None
        self.dirty = True
        want = self.bucket_of(max(1, self.n_active))
        if want < self.cur_bucket and self.bucket_of(self.n_active + 1) < self.cur_bucket:
            self._resize(want)

    def moved_request(self, slot: int) -> Optional[int]:
        return self.slots[slot]

    def reset_slot(self, slot: int):
        """Drop a slot's blocks so a fresh fill can repopulate it."""
        for b in self.tables[slot]:
            self.allocator.decref(b)
        self.tables[slot] = []
        self.host_len[slot] = 0
        self.dirty = True

    def _resize(self, new_bucket: int):
        """Pad/slice the batch-dim device leaves (block_tables, lengths) and
        the host metadata; the k/v pools are bucket-invariant."""
        old = self.cur_bucket
        for name in ("block_tables", "lengths"):
            x = self.cache[name]
            if new_bucket > old:
                pad = [(0, new_bucket - old)] + [(0, 0)] * (x.ndim - 1)
                self.cache[name] = jnp.pad(x, pad)
            elif new_bucket < old:
                self.cache[name] = x[:new_bucket]
        self.slots = (self.slots + [None] * new_bucket)[:new_bucket]
        self.tables = (self.tables + [[] for _ in range(new_bucket)])[:new_bucket]
        self.host_len = (self.host_len + [0] * new_bucket)[:new_bucket]
        self.cur_bucket = new_bucket
        self._apply_shardings()

    # ------------------------------------------------------------------
    # block budget + prefix lifecycle
    # ------------------------------------------------------------------
    def _alloc_block(self) -> int:
        """Allocate a block, evicting LRU radix leaves when the free list is
        dry. Raises RuntimeError when nothing is evictable either."""
        while True:
            try:
                return self.allocator.alloc()
            except RuntimeError:
                if not self.prefix.evict_lru():
                    raise

    def match_blocks(self, tokens) -> int:
        """Full cached blocks a fill of ``tokens`` would reuse (peek, no
        references taken). Capped so the last token is always re-processed —
        the fill step that feeds it produces the first sampled token, and
        serving it from cache would change the sampling computation."""
        cap = max(0, len(tokens) - 1)
        return len(self.prefix.match(list(tokens)[:cap]))

    def blocks_needed(self, plen: int, max_new: int) -> int:
        """Table size a request needs end-of-life: prompt + generation
        budget, clamped to the engine's max_seq position capacity."""
        return -(-min(plen + max_new, self.max_seq) // self.block_size)

    def free_and_evictable(self) -> int:
        return self.allocator.n_free + self.prefix.reclaimable_count()

    def begin_sequence(self, slot: int, tokens) -> int:
        """Attach the radix-cached prefix of ``tokens`` to ``slot``: adopt
        matched full blocks by reference, then fork the best partially
        matching child copy-on-write (device-copy its first k positions into
        a fresh private block). Returns the number of cached positions —
        the fill loop starts there instead of at 0."""
        toks = list(tokens)
        bs = self.block_size
        cap = max(0, len(toks) - 1)  # always re-process the last token
        matched = self.prefix.match(toks[:cap])
        table = self.tables[slot]
        for node in matched:
            self.allocator.incref(node.block)
            table.append(node.block)
        cached = len(matched) * bs
        parent = matched[-1] if matched else self.prefix.root
        child, k = self.prefix.partial_child(parent, toks[cached:cap])
        if child is not None and k > 0:
            fresh = self._alloc_block()
            for leaf in ("k", "v"):
                src = self.cache[leaf][:, child.block, :k]
                self.cache[leaf] = self.cache[leaf].at[:, fresh, :k].set(src)
            self.prefix._touch(child)
            table.append(fresh)
            cached += k
            self._apply_shardings()
            _M_RADIX.inc(event="cow_fork")
        self.host_len[slot] = cached
        self.dirty = True
        self.prefix.stats["hits" if cached else "misses"] += 1
        _M_RADIX.inc(event="hit" if cached else "miss")
        return cached

    def ensure_step_capacity(self) -> Optional[int]:
        """Make every active slot's table cover its next write position
        (``host_len``). Returns None on success, or the first slot whose
        block allocation failed (the engine preempts it and retries)."""
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            need_idx = self.host_len[i] // self.block_size
            while len(self.tables[i]) <= need_idx:
                try:
                    self.tables[i].append(self._alloc_block())
                except RuntimeError:
                    return i
                self.dirty = True
        return None

    def sync(self) -> int:
        """Rebuild the device block_tables/lengths from host metadata when
        dirty. Returns bytes moved host->device (0 on the clean fast path —
        steady-state decode advances lengths in-graph and never syncs)."""
        if not self.dirty:
            return 0
        B, MB = self.cur_bucket, self.blocks_per_seq
        bt = np.zeros((B, MB), np.int32)
        ln = np.zeros((B,), np.int32)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            t = self.tables[i]
            bt[i, :len(t)] = t
            ln[i] = self.host_len[i]
        self.cache["block_tables"] = jnp.asarray(bt)
        self.cache["lengths"] = jnp.asarray(ln)
        self._apply_shardings()
        self.dirty = False
        return bt.nbytes + ln.nbytes

    def note_step(self):
        """Mirror the captured step's ``lengths + 1`` on the host."""
        for i, s in enumerate(self.slots):
            if s is not None:
                self.host_len[i] += 1

    def commit_prefix(self, slot: int, tokens):
        """Insert a completed fill's full blocks into the radix tree. Chunks
        another request cached first dedupe: this slot's table retargets at
        the cached block and the private duplicate is freed."""
        swaps = self.prefix.insert(list(tokens), self.tables[slot])
        for idx, shared in swaps:
            self.allocator.incref(shared)
            self.allocator.decref(self.tables[slot][idx])
            self.tables[slot][idx] = shared
        if swaps:
            self.dirty = True

    # ------------------------------------------------------------------
    # uniform row accessors (layout-neutral seams for tests/tools)
    # ------------------------------------------------------------------
    def row_length(self, slot: int) -> int:
        return self.host_len[slot]

    def seed_length(self, slot: int, n: int):
        """Force a slot's length to ``n``, backing it with blocks."""
        self.reset_slot(slot)
        for _ in range(-(-n // self.block_size)):
            self.tables[slot].append(self._alloc_block())
        self.host_len[slot] = n
        self.dirty = True

    # ------------------------------------------------------------------
    # cross-pool row migration (live reshard, serving/fleet.py)
    # ------------------------------------------------------------------
    def export_rows(self, slots: List[int]) -> RowBundle:
        """Gather the given slots' blocks into dense per-request rows in the
        slot-layout interchange format ([L,n,S,Hkv,Dh] k rows, [n] lengths,
        v rows) so either pool layout can import them."""
        check_export_slots(slots, self.slots)
        MB, bs = self.blocks_per_seq, self.block_size
        tbl = np.zeros((len(slots), MB), np.int32)
        lens = np.zeros((len(slots),), np.int32)
        for j, s in enumerate(slots):
            t = self.tables[s]
            tbl[j, :len(t)] = t
            lens[j] = self.host_len[s]
        idx = jnp.asarray(tbl)

        def dense(pool):  # [L, NB, bs, Hkv, Dh] -> [L, n, S, Hkv, Dh]
            g = pool[:, idx]  # [L, n, MB, bs, Hkv, Dh]
            L, n = g.shape[0], g.shape[1]
            g = g.reshape((L, n, MB * bs) + g.shape[4:])
            return g[:, :, :self.max_seq]

        rows = [dense(self.cache["k"]), jnp.asarray(lens),
                dense(self.cache["v"])]
        return RowBundle(rows, [1, 0, 1], len(slots))

    def import_rows(self, bundle: RowBundle, req_ids: List[int]) -> List[int]:
        """Adopt dense interchange rows: per request, allocate blocks for
        its length, reshard the row onto this pool's mesh, and scatter it
        block-by-block into the pools. Imported rows are private (no radix
        attachment — the migrated request may be mid-stream)."""
        check_import(bundle, req_ids, self.n_active, self.max_batch)
        k_rows, lens, v_rows = bundle.rows
        lens = np.asarray(lens)
        bs = self.block_size
        specs = self._specs(self.cur_bucket)
        mesh = self.model.ctx.mesh
        slots = []
        for j, rid in enumerate(req_ids):
            slot = self.acquire(rid)
            slots.append(slot)
            ln = int(lens[j])
            nb = -(-ln // bs)
            blocks = [self._alloc_block() for _ in range(nb)]
            self.tables[slot] = blocks
            self.host_len[slot] = ln
            if nb == 0:
                continue
            bidx = jnp.asarray(blocks, jnp.int32)
            for name, rows in (("k", k_rows), ("v", v_rows)):
                row = jax.lax.slice_in_dim(rows, j, j + 1, axis=1)[:, 0]
                row = reshard_rows(row, specs[name], mesh)  # [L, S, Hkv, Dh]
                S = row.shape[1]
                if S < nb * bs:
                    pad = [(0, 0), (0, nb * bs - S), (0, 0), (0, 0)]
                    row = jnp.pad(row, pad)
                row = row[:, :nb * bs].reshape(
                    (row.shape[0], nb, bs) + row.shape[2:])
                pool = self.cache[name]
                self.cache[name] = pool.at[:, bidx].set(row.astype(pool.dtype))
        self.dirty = True
        self._apply_shardings()
        return slots

"""Autoscaling replica fleet: phase-aware replica pools cold-starting
against ONE shared Foundry archive while traffic is in flight (paper §1-2).

This is the paper's motivating scenario made executable: a load spike
arrives, the autoscaler spins up replicas, and every second a replica spends
in cold start is a second of queue growth ("Breaking the Ice"; HydraServe's
serverless scale-out framing). The fleet makes the cold-start path the
measured quantity: one ``Archive`` object is shared by every replica LOAD
(the lazy v2 blob store parses the manifest once and decompresses each blob
at most once fleet-wide), replicas provision on background threads while the
fleet keeps dispatching, and serving steps run cooperatively on the fleet's
own thread so scale-up/scale-down behavior is deterministic enough to
unit-test.

A fleet is now a SET OF POOLS (``serving/pool.py``; docs/architecture.md
§14). The default is one colocated pool of phase "serve" — the historical
behavior, byte for byte. Passing ``pools=[PoolSpec("prefill", ...),
PoolSpec("decode", ...)]`` phase-disaggregates it (HydraServe / ParaServe,
PAPERS.md): prefill replicas provision on a wide mesh via the rank-stamped
LOAD of the SAME archive (§4.3 — one capture, many topologies), run the
captured decode-fill prefill to completion, and the fleet hands each request
off per-request onto a decode replica through ``export_requests ->
RowBundle -> adopt_inflight``::

    submit ──▶ prefill pool (wide mesh)          decode pool (narrow mesh)
               │  decode-fill to plen            │  steady-state decode
               └─▶ export_requests ── RowBundle ─▶ adopt_inflight ──▶ done
                        (kv.handoff fault site; a failed handoff
                         requeues onto the decode pool, prefix kept)

Token streams stay byte-identical across the handoff (the adopter re-derives
a one-step-left fill target, which degenerates to the steady-state feeding
rule), no fallback compiles, and radix prefix-cache hits survive (the
prefill pool's tree serves later prompts; the exported rows carry the KV).
Each pool keeps its own ``AutoscalePolicy``, mesh, and reshard trigger —
``Fleet.reshard(..., pool="prefill")`` switches one pool's topology while
the other keeps serving. Autoscaling, crash salvage (cross-pool: a crashed
prefill replica's mid-fill rows can land on decode replicas), and the
degradation ladder all live in ``ReplicaPool``; the fleet owns request
identity, admission shedding, the handoff, and fleet-wide accounting.
"""
from __future__ import annotations

import itertools
import logging
import random
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence

from repro.core import Archive
from repro.launch.mesh import describe_mesh, resolve_mesh
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving.engine import ServingEngine
from repro.serving.faults import fault_point
from repro.serving.pool import (AutoscalePolicy, PoolSpec, Replica,
                                ReplicaPool, ReplicaState, ReplicaStats,
                                ReshardReport, _ReshardOp)
from repro.serving.scheduler import Request, ReqState, Scheduler

log = logging.getLogger("repro.serving.fleet")

# docs/architecture.md §13 has the full metric catalog (per-pool gauges and
# the replica lifecycle counters are declared in serving/pool.py)
_M_SHED = obs_metrics.counter(
    "fleet_shed_requests_total",
    "Requests rejected at admission by a terminally degraded fleet.")
_M_HANDOFFS = obs_metrics.counter(
    "fleet_handoffs_total",
    "Prefill->decode KV handoffs by outcome (adopted/requeued).",
    ("outcome",))
_M_HANDOFF_WAIT = obs_metrics.histogram(
    "serving_handoff_seconds",
    "Prefill-exit -> decode-adopt handoff latency (adopted path).")


@dataclass
class FleetReport:
    """Fleet-wide outcome of a trace replay (see Fleet.report)."""
    mode: str
    ticks: int
    wall_s: float
    peak_alive: int
    replicas: List[ReplicaStats] = field(default_factory=list)
    ttfts: List[float] = field(default_factory=list)
    tpots: List[float] = field(default_factory=list)
    # queueing share of TTFT (arrival -> first admission; scheduler.Request
    # .queue_wait_s) — TTFT additionally bundles cold start + prefill
    queue_waits: List[float] = field(default_factory=list)
    n_done: int = 0
    n_failed: int = 0
    reshards: List[Dict[str, object]] = field(default_factory=list)
    # supervision accounting (mid-serving failures; docs §12)
    crashes: int = 0
    respawns: int = 0
    salvaged_requests: int = 0        # KV rows migrated off crashed replicas
    crash_requeued_requests: int = 0  # retried from kept prefixes instead
    shed_requests: int = 0            # rejected at admission while degraded
    verify_degraded_loads: int = 0    # respawns that fell back to non-strict
    degraded: bool = False            # currently below min_replicas
    degraded_ticks: int = 0           # ticks spent below the floor
    # phase disaggregation (docs §14)
    handoffs: int = 0                 # prefill->decode adoptions
    handoff_requeued: int = 0         # handoffs requeued with prefix kept
    handoff_waits: List[float] = field(default_factory=list)
    phase_queue_waits: Dict[str, List[float]] = field(default_factory=dict)
    pools: List[Dict[str, object]] = field(default_factory=list)

    @staticmethod
    def _pct(xs: List[float], q: float) -> Optional[float]:
        if not xs:
            return None
        xs = sorted(xs)
        return xs[min(len(xs) - 1, int(q * (len(xs) - 1) + 0.5))]

    def summary(self) -> Dict[str, object]:
        cold = [r.cold_start_to_first_token_s for r in self.replicas
                if r.cold_start_to_first_token_s is not None]
        return {
            "mode": self.mode,
            "ticks": self.ticks,
            "wall_s": self.wall_s,
            "n_done": self.n_done,
            "n_failed": self.n_failed,
            "peak_alive": self.peak_alive,
            "replicas_spawned": len(self.replicas),
            "ttft_p50_s": self._pct(self.ttfts, 0.50),
            "ttft_p95_s": self._pct(self.ttfts, 0.95),
            "queue_wait_p50_s": self._pct(self.queue_waits, 0.50),
            "queue_wait_p95_s": self._pct(self.queue_waits, 0.95),
            "tpot_mean_s": (sum(self.tpots) / len(self.tpots)
                            if self.tpots else None),
            "cold_start_to_first_token_s": cold,
            "cold_start_to_first_token_max_s": max(cold) if cold else None,
            "fallback_compiles": sum(r.fallback_compiles
                                     for r in self.replicas),
            "background_errors": sum(r.background_errors
                                     for r in self.replicas),
            "reshards": list(self.reshards),
            "crashes": self.crashes,
            "respawns": self.respawns,
            "salvaged_requests": self.salvaged_requests,
            "crash_requeued_requests": self.crash_requeued_requests,
            "shed_requests": self.shed_requests,
            "verify_degraded_loads": self.verify_degraded_loads,
            "degraded": self.degraded,
            "degraded_ticks": self.degraded_ticks,
            "handoffs": self.handoffs,
            "handoff_requeued": self.handoff_requeued,
            "handoff_wait_p50_s": self._pct(self.handoff_waits, 0.50),
            "handoff_wait_p95_s": self._pct(self.handoff_waits, 0.95),
            "phase_queue_wait_p50_s": {
                ph: self._pct(ws, 0.50)
                for ph, ws in sorted(self.phase_queue_waits.items())},
            "pools": list(self.pools),
        }


def spike_trace(warm_ticks: int = 10, spike_ticks: int = 25,
                cool_ticks: int = 30, base_rate: int = 1,
                spike_rate: int = 6) -> List[int]:
    """Synthetic arrivals-per-tick trace: steady base load, a hard spike
    (the autoscaling trigger), then a cool-down tail for scale-down."""
    return ([base_rate] * warm_ticks + [spike_rate] * spike_ticks
            + [base_rate if t % 2 == 0 else 0 for t in range(cool_ticks)])


class Fleet:
    """Phase-aware replica pools behind one shared request front door.

    ``mode`` picks the replica cold-start path: "vanilla" | "eager" |
    "foundry" (LOAD ``archive``; reported as "foundry-stamped" automatically
    when the archive was captured on a different, shape-compatible mesh).
    ``pools`` disaggregates the fleet into named phases (module docstring);
    omitted, the fleet is one colocated pool of phase "serve" built from the
    legacy ``policy``/``mesh`` arguments. ``factory_for_mesh`` is the
    mesh-parameterized engine factory a resharding or multi-mesh fleet needs
    (the zero-arg ``engine_factory`` then becomes optional): replicas are
    built with ``factory_for_mesh(pool_mesh)``.
    """

    def __init__(self, engine_factory: Optional[Callable[[], ServingEngine]] = None, *,
                 mode: str = "foundry", archive: Optional[Archive] = None,
                 policy: Optional[AutoscalePolicy] = None,
                 allow_stamping: bool = True, background_exact: bool = True,
                 mesh=None,
                 factory_for_mesh: Optional[Callable] = None,
                 pools: Optional[Sequence[PoolSpec]] = None,
                 verbose: bool = False,
                 name: str = "fleet",
                 trace_path: Optional[str] = None):
        if mode == "foundry" and archive is None:
            raise ValueError("foundry fleet needs the shared archive")
        if mode not in ("foundry", "vanilla", "eager"):
            raise ValueError(f"unknown fleet mode {mode!r}")
        if engine_factory is None and factory_for_mesh is None:
            raise ValueError("Fleet needs engine_factory or factory_for_mesh")
        self.engine_factory = engine_factory
        self.factory_for_mesh = factory_for_mesh
        self.mode = mode
        self.archive = archive
        self.allow_stamping = allow_stamping
        self.background_exact = background_exact
        self.verbose = verbose
        self.requests: List[Request] = []
        self.peak_alive = 0
        self.verify_degraded_loads = 0
        # prefill->decode handoff accounting (docs §14)
        self.handoffs = 0
        self.handoff_requeued = 0
        # admission-shed scheduler (reuses Scheduler.reject for terminal
        # bookkeeping — no KV touched)
        self._shed = Scheduler()
        self._ids = itertools.count()
        self._rids = itertools.count()  # shared: replica ids unique fleet-wide
        self._tick = 0
        self._t0: Optional[float] = None
        self.name = name
        # pool topology: legacy single "serve" pool unless specs are given
        specs = (list(pools) if pools
                 else [PoolSpec("serve", policy or AutoscalePolicy(), mesh)])
        self.pools: Dict[str, ReplicaPool] = {}
        for spec in specs:
            if spec.phase in self.pools:
                raise ValueError(f"duplicate pool phase {spec.phase!r}")
            self.pools[spec.phase] = ReplicaPool(
                spec.phase, policy=spec.policy, mesh=spec.mesh,
                engine_factory=engine_factory,
                factory_for_mesh=factory_for_mesh,
                cold_start=self._cold_start,
                respawn_cold_start=(self._respawn_cold_start
                                    if mode == "foundry" else None),
                salvage_targets=self._salvage_targets,
                tick_fn=self.tick, rid_source=self._rids, fleet_name=name)
        # requests enter through the prefill pool when one exists
        self._entry = self.pools.get("prefill") or next(iter(self.pools.values()))
        self.disaggregated = ("prefill" in self.pools
                              and "decode" in self.pools)
        # telemetry identity + optional Chrome/Perfetto trace file: gauges
        # are labeled by (`name`, pool), and `trace_path` starts tracing now
        # and writes the file at report()
        self.trace_path = trace_path
        self._trace_started_here = False
        if trace_path is not None and not obs_trace.active():
            obs_trace.start()
            self._trace_started_here = True
        if verbose:
            # CLI convenience (launch/serve.py --fleet): surface the fleet's
            # INFO events without requiring callers to configure logging
            from repro.obs import configure_logging
            configure_logging()

    # -- cold-start wiring (shared by every pool) ------------------------
    def _cold_start(self, eng: ServingEngine, warm: bool = False):
        if self.mode == "vanilla":
            return eng.cold_start_vanilla()
        if self.mode == "eager":
            return eng.cold_start_eager()
        return eng.cold_start_foundry(self.archive,
                                      background_exact=self.background_exact,
                                      allow_stamping=self.allow_stamping,
                                      warm=warm)

    def _respawn_cold_start(self, eng: ServingEngine):
        """Warm foundry LOAD with a verify-degrade rung: if the strict
        pre-flight verify rejects the archive on respawn (a blob rotted
        since the original LOAD), degrade THIS load to non-strict fallback
        compilation instead of failing the replacement — one slow replica
        beats a supervisor stuck in a FAILED loop (docs §12 ladder)."""
        from repro.analysis.checker import ArchiveVerificationError
        try:
            return self._cold_start(eng, warm=True)
        except ArchiveVerificationError as e:
            self.verify_degraded_loads += 1
            log.warning("respawn LOAD failed strict verify (%s); degrading "
                        "to fallback compile", e)
            return eng.cold_start_foundry(
                self.archive, background_exact=self.background_exact,
                allow_stamping=self.allow_stamping, warm=True, strict=False)

    def _salvage_targets(self, crashed: Replica) -> List[Replica]:
        """Crash-salvage adopter candidates, CROSS-POOL: every pool's READY
        replicas except pending reshard generations — a crashed prefill
        replica's mid-fill rows can land on decode replicas (the adopter
        resumes the fill; the request simply never needs a handoff)."""
        out: List[Replica] = []
        for p in self.pools.values():
            pend = ({id(t) for t in p._reshard.new}
                    if p._reshard is not None
                    and p._reshard.strategy == "live" else set())
            out += [t for t in p._ready()
                    if t is not crashed and t.engine is not None
                    and id(t) not in pend]
        return out

    # -- pool composition / legacy Fleet surface -------------------------
    def _pool(self, phase: Optional[str] = None) -> ReplicaPool:
        if phase is None:
            if len(self.pools) == 1:
                return self._entry
            raise ValueError(
                f"this fleet has pools {sorted(self.pools)}; pass pool=")
        if phase not in self.pools:
            raise ValueError(f"no pool {phase!r} (have {sorted(self.pools)})")
        return self.pools[phase]

    def _alive(self) -> List[Replica]:
        return [r for p in self.pools.values() for r in p._alive()]

    def _ready(self) -> List[Replica]:
        return [r for p in self.pools.values() for r in p._ready()]

    @property
    def replicas(self) -> List[Replica]:
        return [r for p in self.pools.values() for r in p.replicas]

    @property
    def backlog(self) -> Deque[Request]:
        return self._entry.backlog

    @property
    def mesh(self):
        return self._entry.mesh

    @mesh.setter
    def mesh(self, m):
        self._entry.mesh = resolve_mesh(m)

    @property
    def policy(self) -> AutoscalePolicy:
        return self._entry.policy

    @property
    def suppress_scale_out(self) -> bool:
        return self._entry.suppress_scale_out

    @suppress_scale_out.setter
    def suppress_scale_out(self, v: bool):
        for p in self.pools.values():
            p.suppress_scale_out = v

    @property
    def spawn_failures(self) -> int:
        return sum(p.spawn_failures for p in self.pools.values())

    @property
    def crashes(self) -> int:
        return sum(p.crashes for p in self.pools.values())

    @property
    def respawns(self) -> int:
        return sum(p.respawns for p in self.pools.values())

    @property
    def salvaged_requests(self) -> int:
        return sum(p.salvaged_requests for p in self.pools.values())

    @property
    def crash_requeued_requests(self) -> int:
        return sum(p.crash_requeued_requests for p in self.pools.values())

    @property
    def degraded_ticks(self) -> int:
        return sum(p.degraded_ticks for p in self.pools.values())

    @property
    def crash_budget_exhausted(self) -> bool:
        return any(p.crash_budget_exhausted for p in self.pools.values())

    @property
    def degraded(self) -> bool:
        return any(p.degraded for p in self.pools.values())

    @property
    def reshard_reports(self) -> List[ReshardReport]:
        out = [s for p in self.pools.values() for s in p.reshard_reports]
        return sorted(out, key=lambda s: s.started_t)

    @property
    def _reshard(self) -> Optional[_ReshardOp]:
        for p in self.pools.values():
            if p._reshard is not None:
                return p._reshard
        return None

    def _can_spawn(self) -> bool:
        return self._entry._can_spawn()

    def scale_up(self, n: int = 1) -> List[Replica]:
        return self._entry.scale_up(n)

    def inflight(self) -> int:
        return sum(p.inflight() for p in self.pools.values())

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "Fleet":
        """Spawn every pool's policy floor (idempotent)."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        for p in self.pools.values():
            p.spawn_floor()
        return self

    # -- traffic ---------------------------------------------------------
    def _shedding_pool(self) -> Optional[ReplicaPool]:
        for p in self.pools.values():
            if p.sheds_load():
                return p
        return None

    def submit(self, prompt: Sequence[int], max_new_tokens: int) -> Request:
        """Enqueue on the entry pool's queue (prefill when disaggregated);
        arrival time is fleet arrival, so TTFT includes queueing AND any
        cold start it had to wait for. A fleet with a pool in terminal
        degradation (``ReplicaPool.sheds_load``) rejects at admission —
        ``Scheduler.reject`` bookkeeping, no KV, no dispatch."""
        r = Request(next(self._ids), list(prompt), max_new_tokens)
        r.phase = self._entry.phase
        r.phase_enqueued_t[r.phase] = r.arrival_t
        self.requests.append(r)
        shedding = self._shedding_pool()
        if shedding is not None:
            self._shed.reject(
                r, f"fleet degraded: pool {shedding.phase!r} has "
                   f"{len(shedding._ready())} READY < min_replicas="
                   f"{shedding.policy.min_replicas} and the respawn budget "
                   f"is exhausted; shed at admission")
            _M_SHED.inc()
            return r
        self._entry.backlog.append(r)
        return r

    # -- prefill->decode handoff (docs/architecture.md §14) --------------
    def _handoff_pass(self):
        """Move every finished fill off the prefill pool: a request whose
        decode-fill completed (first token sampled, no fill target left)
        exports its KV rows — freeing the prefill slot for the next fill —
        and a decode replica adopts them mid-stream."""
        src, dst = self.pools["prefill"], self.pools["decode"]
        for rep in src._ready():
            eng = rep.engine
            if eng is None:
                continue
            done_fills = [r for r in list(eng.scheduler.running.values())
                          if r.slot is not None and r.generated
                          and r.req_id not in eng._fill_target]
            for req in done_fills:
                self._handoff_one(rep, eng, req, dst)

    def _handoff_one(self, rep: Replica, eng: ServingEngine,
                     req: Request, dst: ReplicaPool):
        t0 = time.perf_counter()
        with rep._ctx():
            bundle = eng.export_requests([req], release=True)
        req.phase = "decode"
        req.handoff_export_t = t0
        req.phase_enqueued_t["decode"] = t0
        try:
            # chaos hook between export and adopt — the window where the
            # request exists only as a detached RowBundle
            fault_point("kv.handoff", tag=eng.fault_tag)
            tgt = dst.adoption_target()
            if tgt is None:
                raise RuntimeError("no decode replica with free capacity")
            with tgt._ctx():
                k = tgt.engine.adopt_inflight([req], bundle)
            if k != 1:
                raise RuntimeError("decode replica refused the row")
        except Exception as e:
            # failed handoff: requeue onto the DECODE pool with prompt +
            # generated prefix kept (no retry charged — this is a resource/
            # transport shortfall, not a worker failure); the admitting
            # decode replica re-fills the row deterministically, so the
            # token stream still does not diverge
            self.handoff_requeued += 1
            _M_HANDOFFS.inc(outcome="requeued")
            log.warning("handoff of request %d failed (%s: %s); requeued "
                        "onto decode pool with prefix kept",
                        req.req_id, type(e).__name__, e)
            dst.backlog.append(req)
            return
        now = time.perf_counter()
        req.handoff_done_t = now
        req.phase_admitted_t.setdefault("decode", now)
        self.handoffs += 1
        _M_HANDOFFS.inc(outcome="adopted")
        if obs_metrics.enabled():
            _M_HANDOFF_WAIT.observe(now - t0)
        obs_trace.complete("kv.handoff", "fleet", t0, now, req=req.req_id,
                           src=rep.stats.replica_id,
                           dst=tgt.stats.replica_id)

    # -- per-pool live reshard -------------------------------------------
    def reshard(self, new_mesh, *, pool: Optional[str] = None,
                factory: Optional[Callable[[], ServingEngine]] = None,
                n_replicas: Optional[int] = None, strategy: str = "live",
                warm: bool = True, wait: bool = False,
                wait_timeout_s: float = 600.0) -> ReshardReport:
        """Move one pool onto ``new_mesh`` (``ReplicaPool.reshard`` has the
        full state-machine contract). ``pool`` names the target phase;
        optional for single-pool fleets. The other pools keep serving
        throughout — ``wait=True`` blocks on the whole fleet's ``tick``."""
        if self._t0 is None:
            self.start()
        return self._pool(pool).reshard(
            new_mesh, factory=factory, n_replicas=n_replicas,
            strategy=strategy, warm=warm, wait=wait,
            wait_timeout_s=wait_timeout_s)

    def abort_reshard(self, reason: str = "aborted by caller",
                      pool: Optional[str] = None) -> Optional[ReshardReport]:
        """Cancel an in-flight reshard. Without ``pool``, aborts whichever
        pool has one in flight (None when nobody does)."""
        if pool is not None:
            return self.pools[pool].abort_reshard(reason)
        for p in self.pools.values():
            if p._reshard is not None:
                return p.abort_reshard(reason)
        return None

    # -- serving loop ----------------------------------------------------
    def tick(self) -> int:
        """One fleet iteration: per pool — poll provisioning, advance any
        in-flight reshard, dispatch, autoscale, one supervised decode step
        per READY replica — then the prefill->decode handoff pass and
        end-of-tick accounting. Returns requests actively served."""
        if self._t0 is None:
            self.start()
        self._tick += 1
        pools = list(self.pools.values())
        for p in pools:
            p.poll_all()
        for p in pools:
            if p._reshard is not None:
                p.advance_reshard()
        for p in pools:
            p.dispatch()
        for p in pools:
            # replica-count autoscaling pauses while the pool's own topology
            # switch is in flight (it would spawn on a mesh about to change)
            if p._reshard is None:
                p.autoscale()
        served = 0
        for p in pools:
            served += p.step_all()
        if self.disaggregated:
            self._handoff_pass()
        for p in pools:
            if p.sheds_load() and not p._ready() and p.backlog:
                # terminal incapacity with zero serving capacity: what
                # already queued will never run either — shed it with the
                # same terminal bookkeeping admission uses, so callers see
                # FAILED, not a hang
                while p.backlog:
                    self._shed.reject(
                        p.backlog.popleft(),
                        f"pool {p.phase!r} degraded with no READY replicas "
                        f"and the respawn budget exhausted; backlog shed")
                    _M_SHED.inc()
            p.note_floor()
        self.peak_alive = max(self.peak_alive, len(self._alive()))
        if obs_metrics.enabled():
            for p in pools:
                p.publish_gauges()
        return served

    def _unresolved(self) -> int:
        return sum(r.state not in (ReqState.DONE, ReqState.FAILED)
                   for r in self.requests)

    def run_trace(self, trace: Sequence[int], *,
                  prompt_fn: Optional[Callable[[random.Random], tuple]] = None,
                  seed: int = 0, drain: bool = True,
                  max_ticks: int = 20000) -> FleetReport:
        """Replay an arrivals-per-tick trace (see ``spike_trace``), then
        optionally tick until every request resolves. ``prompt_fn(rng)``
        returns (prompt, max_new_tokens); the default generates short random
        prompts."""
        rng = random.Random(seed)
        if prompt_fn is None:
            def prompt_fn(rg):
                return ([rg.randrange(1, 50)
                         for _ in range(rg.randrange(2, 10))],
                        rg.randrange(4, 12))
        self.start()
        for arrivals in trace:
            for _ in range(arrivals):
                self.submit(*prompt_fn(rng))
            self.tick()
        while drain and self._unresolved() and self._tick < max_ticks:
            if not self._ready() and not self._alive():
                break  # every replica failed; report what we have
            if self.tick() == 0:
                # idle tick: yield the GIL so provisioning threads make
                # progress — in a disaggregated fleet one pool can be READY
                # (keeping _ready() non-empty) while the other pool's
                # replica is still cold-starting, and busy-spinning here
                # starves that thread until max_ticks burns out
                time.sleep(0.001)
        return self.report()

    # -- accounting ------------------------------------------------------
    def drain_background(self, timeout: float = 300.0):
        """Join every replica LOAD's background workers (deterministic tests
        / benchmarks; serving itself never needs this)."""
        for p in self.pools.values():
            p.drain_background(timeout)

    def _pool_summary(self, p: ReplicaPool) -> Dict[str, object]:
        pct = FleetReport._pct
        return {
            "phase": p.phase,
            "mesh": describe_mesh(p.mesh),
            "replicas_spawned": len(p.replicas),
            "ready": len(p._ready()),
            "backlog": len(p.backlog),
            "steps": len(p.step_walls),
            "step_wall_p50_s": pct(p.step_walls, 0.50),
            "step_wall_p99_s": pct(p.step_walls, 0.99),
            "crashes": p.crashes,
            "respawns": p.respawns,
            "degraded_ticks": p.degraded_ticks,
        }

    def report(self) -> FleetReport:
        rep = FleetReport(
            mode=self.mode, ticks=self._tick,
            wall_s=(time.perf_counter() - self._t0) if self._t0 else 0.0,
            peak_alive=self.peak_alive,
            reshards=[r.summary() for r in self.reshard_reports],
            crashes=self.crashes, respawns=self.respawns,
            salvaged_requests=self.salvaged_requests,
            crash_requeued_requests=self.crash_requeued_requests,
            shed_requests=len(self._shed.failed),
            verify_degraded_loads=self.verify_degraded_loads,
            degraded=self.degraded, degraded_ticks=self.degraded_ticks,
            handoffs=self.handoffs, handoff_requeued=self.handoff_requeued,
            pools=[self._pool_summary(p) for p in self.pools.values()])
        for r in self.replicas:
            lr = (None if r.discard_engine
                  else getattr(r.engine, "_load_report", None))
            if lr is not None:
                r.stats.background_errors = lr.background_errors
            rep.replicas.append(r.stats)
        for q in self.requests:
            if q.state is ReqState.DONE:
                rep.n_done += 1
                if q.ttft is not None:
                    rep.ttfts.append(q.ttft)
                if q.queue_wait_s is not None:
                    rep.queue_waits.append(q.queue_wait_s)
                if q.handoff_wait_s is not None:
                    rep.handoff_waits.append(q.handoff_wait_s)
                for ph, w in q.queue_wait_by_phase.items():
                    rep.phase_queue_waits.setdefault(ph, []).append(w)
                if (q.done_t is not None and q.first_token_t is not None
                        and len(q.generated) > 1):
                    rep.tpots.append((q.done_t - q.first_token_t)
                                     / (len(q.generated) - 1))
            elif q.state is ReqState.FAILED:
                rep.n_failed += 1
        if self.trace_path is not None:
            obs_trace.save(self.trace_path)
            if self._trace_started_here:
                obs_trace.stop()
                self._trace_started_here = False
        return rep

"""Autoscaling replica fleet: N serving engines cold-starting against ONE
shared Foundry archive while traffic is in flight (paper §1-2).

This is the paper's motivating scenario made executable: a load spike
arrives, the autoscaler spins up replicas, and every second a replica spends
in cold start is a second of queue growth ("Breaking the Ice"; HydraServe's
serverless scale-out framing). The fleet makes the cold-start path the
measured quantity:

  * one ``Archive`` object is shared by every replica LOAD — the lazy v2
    blob store (core/archive.py) parses the manifest once and decompresses
    each blob at most once fleet-wide, so concurrent LOADs de-duplicate
    instead of multiplying work;
  * each replica provisions on a background thread (build engine + cold
    start) while the fleet keeps dispatching to already-READY replicas;
  * serving steps run cooperatively on the fleet's own thread (one
    ``tick()`` = one decode step per READY replica), so scale-up/scale-down
    behavior is deterministic enough to unit-test;
  * per-replica cold-start-to-first-token and fleet-wide TTFT/TPOT are
    recorded (``FleetReport``), which is exactly the comparison
    benchmarks/fig13_autoscale.py plots across vanilla / foundry /
    foundry-stamped cold starts.

Autoscaling policy (``AutoscalePolicy``): scale up toward
``ceil(inflight / target_inflight_per_replica)`` (counting replicas already
provisioning, so a burst does not spawn a storm), scale down — at most one
replica per tick — when a replica has been idle for
``scale_down_idle_ticks`` consecutive ticks and the fleet is above
``min_replicas``.
"""
from __future__ import annotations

import itertools
import math
import random
import threading
import time
from collections import deque
from contextlib import nullcontext
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Deque, Dict, List, Optional, Sequence

from repro.core import Archive, wait_for_background
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import Request, ReqState


class ReplicaState(Enum):
    PROVISIONING = "provisioning"   # cold-start thread running
    READY = "ready"                 # serving
    STOPPED = "stopped"             # scaled down
    FAILED = "failed"               # cold start raised


@dataclass
class ReplicaStats:
    """Lifecycle timeline of one replica (all times perf_counter seconds)."""
    replica_id: int
    spawned_t: float
    ready_t: Optional[float] = None
    first_token_t: Optional[float] = None
    stopped_t: Optional[float] = None
    mode: Optional[str] = None            # cold-start path actually taken
    cold_start_s: Optional[float] = None  # engine cold-start phase total
    fallback_compiles: int = 0
    background_errors: int = 0
    steps: int = 0
    served_requests: int = 0
    error: Optional[str] = None

    @property
    def provision_s(self) -> Optional[float]:
        """Spawn -> servable (engine build + weights + cold start)."""
        return None if self.ready_t is None else self.ready_t - self.spawned_t

    @property
    def cold_start_to_first_token_s(self) -> Optional[float]:
        """Spawn -> first token out of this replica: the scale-out latency a
        user stuck in the queue actually experiences."""
        return (None if self.first_token_t is None
                else self.first_token_t - self.spawned_t)


class Replica:
    """One ServingEngine behind the fleet queue.

    Provisioning (engine build + cold start) runs on a daemon thread so
    replicas come up while traffic is in flight; decode steps run on the
    fleet's thread via ``step()``.
    """

    def __init__(self, rid: int, engine_factory: Callable[[], ServingEngine],
                 cold_start: Callable[[ServingEngine], object], mesh=None):
        self.stats = ReplicaStats(rid, spawned_t=time.perf_counter())
        self.state = ReplicaState.PROVISIONING
        self.engine: Optional[ServingEngine] = None
        self.cold_report = None
        self.idle_ticks = 0
        self._engine_factory = engine_factory
        self._cold_start = cold_start
        self._mesh = mesh
        self._error: Optional[str] = None
        self._thread = threading.Thread(target=self._provision, daemon=True)
        self._thread.start()

    def _ctx(self):
        return self._mesh if self._mesh is not None else nullcontext()

    def _provision(self):
        try:
            with self._ctx():
                eng = self._engine_factory()
                t0 = time.perf_counter()
                rep = self._cold_start(eng)
            self.cold_report = rep
            self.stats.mode = getattr(rep, "mode", None)
            self.stats.cold_start_s = getattr(
                rep, "total_s", time.perf_counter() - t0)
            self.stats.fallback_compiles = getattr(rep, "fallback_compiles", 0)
            self.engine = eng
        except Exception as e:  # surfaced via ReplicaState.FAILED
            self._error = f"{type(e).__name__}: {e}"

    def poll(self) -> ReplicaState:
        """Advance PROVISIONING -> READY/FAILED when the thread finishes."""
        if self.state is ReplicaState.PROVISIONING and not self._thread.is_alive():
            if self._error is not None or self.engine is None:
                self.state = ReplicaState.FAILED
                self.stats.error = self._error or "cold start produced no engine"
            else:
                self.state = ReplicaState.READY
                self.stats.ready_t = time.perf_counter()
        return self.state

    @property
    def load(self) -> int:
        """Requests this replica still owns (queued + running)."""
        return 0 if self.engine is None else self.engine.scheduler.pending

    def assign(self, req: Request):
        self.engine.scheduler.queue.append(req)

    def step(self) -> int:
        with self._ctx():
            n = self.engine.step()
        self.stats.steps += 1
        self.stats.served_requests = len(self.engine.scheduler.done)
        if self.stats.first_token_t is None:
            firsts = [r.first_token_t
                      for r in self.engine.scheduler.running.values()
                      if r.first_token_t is not None]
            firsts += [r.first_token_t for r in self.engine.scheduler.done
                       if r.first_token_t is not None]
            if firsts:
                self.stats.first_token_t = min(firsts)
        self.idle_ticks = self.idle_ticks + 1 if self.load == 0 else 0
        return n

    def stop(self):
        self.state = ReplicaState.STOPPED
        self.stats.stopped_t = time.perf_counter()

    def join_provision(self, timeout: float = 120.0) -> ReplicaState:
        """Wait for an in-flight provision to finish and resolve the state.
        Stopping a PROVISIONING replica without this races the daemon
        thread, which would re-attach the freshly built engine (and its KV
        pool) to the stopped replica after the caller released it."""
        self._thread.join(timeout)
        return self.poll()

    def drain_background(self, timeout: float = 300.0):
        """Join the engine LOAD's background exact-bucket workers and copy
        their error count into the stats (tests assert it is 0)."""
        rep = getattr(self.engine, "_load_report", None)
        if rep is not None:
            wait_for_background(rep, timeout)
            self.stats.background_errors = rep.background_errors


@dataclass
class AutoscalePolicy:
    min_replicas: int = 1
    max_replicas: int = 4
    # inflight requests one replica is expected to absorb before the fleet
    # scales; engines can batch max_batch of them per step
    target_inflight_per_replica: int = 8
    scale_down_idle_ticks: int = 25
    # provisioning failures after which the fleet stops respawning (a
    # systematically failing cold start — bad archive, broken factory —
    # must fail fast, not spawn replicas forever)
    max_spawn_failures: int = 3


@dataclass
class FleetReport:
    """Fleet-wide outcome of a trace replay (see Fleet.report)."""
    mode: str
    ticks: int
    wall_s: float
    peak_alive: int
    replicas: List[ReplicaStats] = field(default_factory=list)
    ttfts: List[float] = field(default_factory=list)
    tpots: List[float] = field(default_factory=list)
    n_done: int = 0
    n_failed: int = 0

    @staticmethod
    def _pct(xs: List[float], q: float) -> Optional[float]:
        if not xs:
            return None
        xs = sorted(xs)
        return xs[min(len(xs) - 1, int(q * (len(xs) - 1) + 0.5))]

    def summary(self) -> Dict[str, object]:
        cold = [r.cold_start_to_first_token_s for r in self.replicas
                if r.cold_start_to_first_token_s is not None]
        return {
            "mode": self.mode,
            "ticks": self.ticks,
            "wall_s": self.wall_s,
            "n_done": self.n_done,
            "n_failed": self.n_failed,
            "peak_alive": self.peak_alive,
            "replicas_spawned": len(self.replicas),
            "ttft_p50_s": self._pct(self.ttfts, 0.50),
            "ttft_p95_s": self._pct(self.ttfts, 0.95),
            "tpot_mean_s": (sum(self.tpots) / len(self.tpots)
                            if self.tpots else None),
            "cold_start_to_first_token_s": cold,
            "cold_start_to_first_token_max_s": max(cold) if cold else None,
            "fallback_compiles": sum(r.fallback_compiles
                                     for r in self.replicas),
            "background_errors": sum(r.background_errors
                                     for r in self.replicas),
        }


def spike_trace(warm_ticks: int = 10, spike_ticks: int = 25,
                cool_ticks: int = 30, base_rate: int = 1,
                spike_rate: int = 6) -> List[int]:
    """Synthetic arrivals-per-tick trace: steady base load, a hard spike
    (the autoscaling trigger), then a cool-down tail for scale-down."""
    return ([base_rate] * warm_ticks + [spike_rate] * spike_ticks
            + [base_rate if t % 2 == 0 else 0 for t in range(cool_ticks)])


class Fleet:
    """N ServingEngine replicas behind one shared request queue.

    ``mode`` picks the replica cold-start path: "vanilla" | "eager" |
    "foundry" (LOAD ``archive``; reported as "foundry-stamped" automatically
    when the archive was captured on a different, shape-compatible mesh).
    ``mesh`` (optional) is entered around every engine build/step — pass the
    deployment mesh for stamped fleets.
    """

    def __init__(self, engine_factory: Callable[[], ServingEngine], *,
                 mode: str = "foundry", archive: Optional[Archive] = None,
                 policy: Optional[AutoscalePolicy] = None,
                 allow_stamping: bool = True, background_exact: bool = True,
                 mesh=None, verbose: bool = False):
        if mode == "foundry" and archive is None:
            raise ValueError("foundry fleet needs the shared archive")
        if mode not in ("foundry", "vanilla", "eager"):
            raise ValueError(f"unknown fleet mode {mode!r}")
        self.engine_factory = engine_factory
        self.mode = mode
        self.archive = archive
        self.policy = policy or AutoscalePolicy()
        self.allow_stamping = allow_stamping
        self.background_exact = background_exact
        self.mesh = mesh
        self.verbose = verbose
        self.replicas: List[Replica] = []
        self.backlog: Deque[Request] = deque()
        self.requests: List[Request] = []
        self.peak_alive = 0
        self.spawn_failures = 0
        self._ids = itertools.count()
        self._rids = itertools.count()
        self._tick = 0
        self._t0: Optional[float] = None

    # -- lifecycle -------------------------------------------------------
    def _cold_start(self, eng: ServingEngine):
        if self.mode == "vanilla":
            return eng.cold_start_vanilla()
        if self.mode == "eager":
            return eng.cold_start_eager()
        return eng.cold_start_foundry(self.archive,
                                      background_exact=self.background_exact,
                                      allow_stamping=self.allow_stamping)

    def _alive(self) -> List[Replica]:
        return [r for r in self.replicas
                if r.state in (ReplicaState.PROVISIONING, ReplicaState.READY)]

    def _ready(self) -> List[Replica]:
        return [r for r in self.replicas if r.state is ReplicaState.READY]

    def scale_up(self, n: int = 1) -> List[Replica]:
        out = []
        for _ in range(n):
            r = Replica(next(self._rids), self.engine_factory,
                        self._cold_start, mesh=self.mesh)
            self.replicas.append(r)
            out.append(r)
            if self.verbose:
                print(f"[fleet] +replica {r.stats.replica_id} "
                      f"({self.mode}, tick {self._tick})")
        return out

    def _can_spawn(self) -> bool:
        return self.spawn_failures < self.policy.max_spawn_failures

    def start(self) -> "Fleet":
        """Spawn the floor of the policy (idempotent)."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        missing = self.policy.min_replicas - len(self._alive())
        if missing > 0 and self._can_spawn():
            self.scale_up(missing)
        return self

    # -- traffic ---------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int) -> Request:
        """Enqueue on the fleet-wide queue; arrival time is fleet arrival,
        so TTFT includes queueing AND any cold start it had to wait for."""
        r = Request(next(self._ids), list(prompt), max_new_tokens)
        self.backlog.append(r)
        self.requests.append(r)
        return r

    def _dispatch(self):
        """Drain the shared backlog onto READY replicas, least-loaded first,
        never queueing more than one batch-worth ahead per replica."""
        ready = self._ready()
        while self.backlog and ready:
            ready.sort(key=lambda r: r.load)
            tgt = ready[0]
            if tgt.load >= tgt.engine.max_batch:
                break  # everyone is saturated; leave work visible on backlog
            tgt.assign(self.backlog.popleft())

    def _autoscale(self):
        pol = self.policy
        alive = self._alive()
        inflight = len(self.backlog) + sum(r.load for r in self._ready())
        desired = max(pol.min_replicas,
                      math.ceil(inflight / max(1, pol.target_inflight_per_replica)))
        desired = min(pol.max_replicas, desired)
        if desired > len(alive) and self._can_spawn():
            self.scale_up(desired - len(alive))
        elif not self.backlog and len(alive) > pol.min_replicas:
            # scale down at most one per tick: oldest idle replica first
            for r in self._ready():
                if r.load == 0 and r.idle_ticks >= pol.scale_down_idle_ticks:
                    r.stop()
                    if self.verbose:
                        print(f"[fleet] -replica {r.stats.replica_id} "
                              f"(idle {r.idle_ticks} ticks)")
                    break

    # -- serving loop ----------------------------------------------------
    def tick(self) -> int:
        """One fleet iteration: poll provisioning, dispatch, autoscale, one
        decode step per READY replica. Returns requests actively served."""
        if self._t0 is None:
            self.start()
        self._tick += 1
        for r in self.replicas:
            was = r.state
            if (r.poll() is ReplicaState.FAILED
                    and was is ReplicaState.PROVISIONING):
                self.spawn_failures += 1
                print(f"[fleet] replica {r.stats.replica_id} FAILED to "
                      f"provision ({self.spawn_failures}/"
                      f"{self.policy.max_spawn_failures} before giving up): "
                      f"{r.stats.error}")
        self._dispatch()
        self._autoscale()
        served = 0
        for r in self._ready():
            served += r.step()
        self.peak_alive = max(self.peak_alive, len(self._alive()))
        return served

    def _unresolved(self) -> int:
        return sum(r.state not in (ReqState.DONE, ReqState.FAILED)
                   for r in self.requests)

    def run_trace(self, trace: Sequence[int], *,
                  prompt_fn: Optional[Callable[[random.Random], tuple]] = None,
                  seed: int = 0, drain: bool = True,
                  max_ticks: int = 20000) -> FleetReport:
        """Replay an arrivals-per-tick trace (see ``spike_trace``), then
        optionally tick until every request resolves. ``prompt_fn(rng)``
        returns (prompt, max_new_tokens); the default generates short random
        prompts."""
        rng = random.Random(seed)
        if prompt_fn is None:
            def prompt_fn(rg):
                return ([rg.randrange(1, 50)
                         for _ in range(rg.randrange(2, 10))],
                        rg.randrange(4, 12))
        self.start()
        for arrivals in trace:
            for _ in range(arrivals):
                self.submit(*prompt_fn(rng))
            self.tick()
        while drain and self._unresolved() and self._tick < max_ticks:
            if not self._ready() and not self._alive():
                break  # every replica failed; report what we have
            if self.tick() == 0 and not self._ready():
                time.sleep(0.001)  # all replicas still provisioning
        return self.report()

    # -- accounting ------------------------------------------------------
    def drain_background(self, timeout: float = 300.0):
        """Join every replica LOAD's background workers (deterministic tests
        / benchmarks; serving itself never needs this)."""
        for r in self.replicas:
            if r.engine is not None:
                r.drain_background(timeout)

    def report(self) -> FleetReport:
        rep = FleetReport(
            mode=self.mode, ticks=self._tick,
            wall_s=(time.perf_counter() - self._t0) if self._t0 else 0.0,
            peak_alive=self.peak_alive)
        for r in self.replicas:
            lr = getattr(r.engine, "_load_report", None)
            if lr is not None:
                r.stats.background_errors = lr.background_errors
            rep.replicas.append(r.stats)
        for q in self.requests:
            if q.state is ReqState.DONE:
                rep.n_done += 1
                if q.ttft is not None:
                    rep.ttfts.append(q.ttft)
                if q.done_t and q.first_token_t and len(q.generated) > 1:
                    rep.tpots.append((q.done_t - q.first_token_t)
                                     / (len(q.generated) - 1))
            elif q.state is ReqState.FAILED:
                rep.n_failed += 1
        return rep

"""Slot-based KV/state cache pool for the serving engine.

The pool owns the decode-state pytree (attention KV, SSM states, lengths) at
the current *bucket* batch size and maps request slots onto batch rows.
Growing/shrinking across buckets pads/slices the batch dim (a one-time copy,
amortized over the bucket's lifetime — the continuous-batching analogue of
vLLM's batch expansion). Slot compaction keeps active rows contiguous at the
front so any bucket >= n_active is a valid padded execution.

Memory determinism: pool construction registers its buffers with the
MemoryPlan (name, bytes) so SAVE and LOAD runs allocate identically (the
engine pins the pool size before LOAD, paper §5.4).
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.memory_plan import MemoryPlan
from repro.serving.rowbundle import (RowBundle, check_export_slots,
                                     check_import, reshard_rows)

__all__ = ["KVCachePool", "RowBundle", "reshard_rows"]  # historical home of
# RowBundle/reshard_rows — engine.py and older callers import them from here


def _leaf_bytes(sd) -> int:
    return int(np.prod(sd.shape)) * jnp.dtype(sd.dtype).itemsize


class KVCachePool:
    def __init__(self, model, max_batch: int, max_seq: int,
                 bucket_of, memory_plan: Optional[MemoryPlan] = None):
        """bucket_of(n) -> smallest capture bucket >= n."""
        self.model = model
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.bucket_of = bucket_of
        self.cur_bucket = bucket_of(1)
        self.cache = model.init_cache(self.cur_bucket, max_seq)
        self.slots: List[Optional[int]] = [None] * self.cur_bucket  # req ids
        # batch dim per leaf, derived structurally (comparing specs at two
        # probe batch sizes — a size-match heuristic breaks when e.g.
        # num_layers == bucket)
        sa = jax.tree.leaves(model.cache_specs(3, max_seq))
        sb = jax.tree.leaves(model.cache_specs(5, max_seq))
        self._bdims = []
        for a, b in zip(sa, sb):
            dims = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
            self._bdims.append(dims[0] if dims else None)
        if memory_plan is not None:
            # KV state is sharded across the model axis at deployment: record
            # it rank-relative so a stamped LOAD can re-derive each rank's
            # buffer extents from a single-rank capture (paper §4.3).
            for path, sd in jax.tree_util.tree_flatten_with_path(
                    model.cache_specs(max_batch, max_seq))[0]:
                memory_plan.alloc("kv_pool" + jax.tree_util.keystr(path),
                                  _leaf_bytes(sd), scope="per_rank")

    # ------------------------------------------------------------------
    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def _map_leaves(self, fn):
        """Apply fn(leaf, batch_dim) to every cache leaf."""
        leaves, treedef = jax.tree.flatten(self.cache)
        out = [fn(x, bd) for x, bd in zip(leaves, self._bdims)]
        self.cache = jax.tree.unflatten(treedef, out)

    def _apply_shardings(self):
        """Re-pin every leaf to its spec sharding (pad/slice/np round-trips
        drop shardings; captured executables require exact input shardings)."""
        if self.model.ctx.mesh is None:
            return
        specs = jax.tree.leaves(
            self.model.cache_specs(self.cur_bucket, self.max_seq))
        leaves, treedef = jax.tree.flatten(self.cache)
        out = [jax.device_put(x, sd.sharding) if sd.sharding is not None else x
               for x, sd in zip(leaves, specs)]
        self.cache = jax.tree.unflatten(treedef, out)

    def _resize(self, new_bucket: int):
        """Pad or slice every batch-dim leaf to the new bucket size."""
        def fix(x, bdim):
            if bdim is None or x.shape[bdim] == new_bucket:
                return x
            if new_bucket > x.shape[bdim]:
                pad = [(0, 0)] * x.ndim
                pad[bdim] = (0, new_bucket - x.shape[bdim])
                return jnp.pad(x, pad)
            idx = [slice(None)] * x.ndim
            idx[bdim] = slice(0, new_bucket)
            return x[tuple(idx)]

        self._map_leaves(fix)
        self.slots = (self.slots + [None] * new_bucket)[:new_bucket]
        self.cur_bucket = new_bucket
        self._apply_shardings()

    # ------------------------------------------------------------------
    def acquire(self, req_id: int) -> int:
        """Assign a slot (growing the bucket if needed). Returns slot index."""
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = req_id
                return i
        n = self.n_active + 1
        if n > self.max_batch:
            raise RuntimeError("pool exhausted")
        self._resize(self.bucket_of(n))
        return self.acquire(req_id)

    def release(self, slot: int):
        """Free a slot and compact: move the last active row into the hole.

        Guarded against the two failure-path corruptions: releasing on an
        empty pool used to raise a bare ``ValueError`` out of ``max()``, and
        double-releasing an already-free slot silently compacted a *live*
        row into it (evicting an unrelated request's KV state)."""
        if not (0 <= slot < len(self.slots)):
            raise ValueError(
                f"release of slot {slot}: out of range for bucket "
                f"{self.cur_bucket} (valid slots 0..{len(self.slots) - 1})")
        if self.slots[slot] is None:
            raise ValueError(
                f"release of slot {slot}: not an active slot "
                f"({'pool is empty' if self.n_active == 0 else 'double release'}"
                f") — compacting would corrupt a live row")
        last = max(i for i, s in enumerate(self.slots) if s is not None)
        if last != slot:
            self._move_row(last, slot)
            self.slots[slot] = self.slots[last]
        self.slots[last] = None
        # shrink with hysteresis (stay one bucket above need)
        want = self.bucket_of(max(1, self.n_active))
        if want < self.cur_bucket and self.bucket_of(self.n_active + 1) < self.cur_bucket:
            self._resize(want)

    def moved_request(self, slot: int) -> Optional[int]:
        return self.slots[slot]

    # ------------------------------------------------------------------
    # cross-pool row migration (live reshard, serving/fleet.py)
    # ------------------------------------------------------------------
    def export_rows(self, slots: List[int]) -> RowBundle:
        """Gather the given slots' rows (KV, SSM state, lengths — every
        batch-dim leaf) into a standalone ``RowBundle``. The pool itself is
        left untouched; callers release the slots separately."""
        check_export_slots(slots, self.slots)
        idx = jnp.asarray(list(slots), jnp.int32)
        leaves = jax.tree.leaves(self.cache)
        rows = [jnp.take(x, idx, axis=bd) if bd is not None else None
                for x, bd in zip(leaves, self._bdims)]
        return RowBundle(rows, list(self._bdims), len(slots))

    def import_rows(self, bundle: RowBundle, req_ids: List[int]) -> List[int]:
        """Adopt a foreign pool's exported rows: acquire one slot per
        request, reshard each row onto THIS pool's cache specs with
        ``device_put`` (the source may live on a different mesh), and write
        it in place. Returns the assigned slots, in ``req_ids`` order."""
        check_import(bundle, req_ids, self.n_active, self.max_batch)
        slots = [self.acquire(rid) for rid in req_ids]
        specs = jax.tree.leaves(
            self.model.cache_specs(self.cur_bucket, self.max_seq))
        leaves, treedef = jax.tree.flatten(self.cache)
        out = []
        for pool, rows, bd, sd in zip(leaves, bundle.rows, self._bdims, specs):
            if bd is None or rows is None:
                out.append(pool)
                continue
            rows = self._reshard_rows(rows, sd)
            for i, slot in enumerate(slots):
                one = jax.lax.slice_in_dim(rows, i, i + 1, axis=bd)
                pool = jax.lax.dynamic_update_slice_in_dim(
                    pool, one.astype(pool.dtype), slot, axis=bd)
            out.append(pool)
        self.cache = jax.tree.unflatten(treedef, out)
        self._apply_shardings()
        return slots

    def _reshard_rows(self, rows, sd):
        return reshard_rows(rows, sd, self.model.ctx.mesh)

    # ------------------------------------------------------------------
    # uniform row accessors (layout-neutral seams for tests/tools)
    # ------------------------------------------------------------------
    def row_length(self, slot: int) -> int:
        return int(self.cache["lengths"][slot])

    def seed_length(self, slot: int, n: int):
        """Force a slot's length to ``n`` (test/tool seam; the slot layout
        keeps per-row lengths directly in the device cache)."""
        self.cache["lengths"] = self.cache["lengths"].at[slot].set(n)

    def _move_row(self, src: int, dst: int):
        # device-side row move: slice + in-place-style update on the
        # persistent pool buffers. The old implementation round-tripped every
        # leaf through numpy (an O(cache) device->host->device copy per
        # compaction); decode state must stay device-resident (a TPU
        # deployment would use block tables + the paged decode kernel).
        def mv(x, bdim):
            if bdim is None:
                return x
            row = jax.lax.slice_in_dim(x, src, src + 1, axis=bdim)
            return jax.lax.dynamic_update_slice_in_dim(x, row, dst, axis=bdim)
        self._map_leaves(mv)
        self._apply_shardings()

    def reset_slot(self, slot: int):
        """Zero a slot's lengths so prefill can refill it."""
        self.cache["lengths"] = self.cache["lengths"].at[slot].set(0)

    def write_prefill(self, slot: int, prefill_cache):
        """Copy a 1-row prefilled cache into the pool at ``slot``."""
        ones = iter(jax.tree.leaves(prefill_cache))

        def wr(pool, bdim):
            one = next(ones)
            if bdim is None:
                return pool
            return jax.lax.dynamic_update_slice_in_dim(
                pool, one.astype(pool.dtype), slot, axis=bdim)
        self._map_leaves(wr)

"""Dense KV-row interchange shared by both pool layouts.

``RowBundle`` is the one format in which decode state (attention KV, SSM
state, lengths) travels between serving engines — live reshard cutover
(docs/architecture.md §8), crash salvage (§12), and the prefill->decode
handoff of phase-disaggregated pools (§14) all speak it. Both pool layouts
(``serving/kvcache.KVCachePool``, slot rows; ``serving/blockpool.
PagedKVCachePool``, block tables densified on export) implement
``export_rows``/``import_rows`` against this module so the migration path
cannot fork per layout:

  * rows stay committed to the *source* pool's mesh on export; the
    importing pool calls ``reshard_rows`` to ``device_put`` them onto its
    own cache specs (possibly a different mesh — that is the §4.3 story:
    one capture, many topologies, KV free to move between them);
  * the export/import guard errors (inactive slot, row/request count
    mismatch, capacity) are defined HERE once, so every caller sees the
    same failure surface regardless of which layout raised it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional

import jax
import jax.numpy as jnp


def reshard_rows(rows, sd, mesh):
    """Commit migrated rows to a destination pool's devices: the leaf's spec
    sharding when it accepts the row-count (batch may not divide the data
    axes), replicated on the mesh otherwise, first local device when
    un-meshed (eager update ops reject operands committed to a different
    mesh's device set). Shared by both pool layouts (slot and paged)."""
    if sd.sharding is not None:
        try:
            return jax.device_put(rows, sd.sharding)
        except Exception:
            pass
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        return jax.device_put(rows, NamedSharding(mesh, PartitionSpec()))
    return jax.device_put(rows, jax.devices()[0])


@dataclass
class RowBundle:
    """Device-resident export of pool rows for cross-pool migration.

    One entry per cache leaf, in tree-leaf order; ``rows[i]`` holds the
    exported requests' rows stacked along that leaf's batch dim (``None``
    for batch-invariant leaves — the importing pool keeps its own). The
    arrays stay committed to the *source* pool's mesh; ``import_rows``
    reshards them onto the destination's cache specs with ``device_put``
    (live-reshard KV migration, docs/architecture.md §8).
    """
    rows: List[Optional[Any]]
    bdims: List[Optional[int]]
    n: int

    def select(self, idx) -> "RowBundle":
        """Sub-bundle of the given row indices (e.g. the remainder after a
        partial adopt)."""
        idx = list(idx)
        if idx == list(range(self.n)):
            return self
        j = jnp.asarray(idx, jnp.int32)
        rows = [None if (r is None or bd is None) else jnp.take(r, j, axis=bd)
                for r, bd in zip(self.rows, self.bdims)]
        return RowBundle(rows, list(self.bdims), len(idx))


def check_export_slots(slots, pool_slots) -> None:
    """Shared export precondition: every requested slot must be active.
    Raises the layout-independent guard error both pools used to duplicate."""
    for s in slots:
        if not (0 <= s < len(pool_slots)) or pool_slots[s] is None:
            raise ValueError(f"export of slot {s}: not an active slot")


def check_import(bundle: RowBundle, req_ids, n_active: int,
                 max_batch: int) -> None:
    """Shared import preconditions: one bundle row per request, and the
    destination pool must have capacity for all of them (partial adoption is
    the *caller's* job, via ``bundle.select``)."""
    if len(req_ids) != bundle.n:
        raise ValueError(f"import of {bundle.n} rows for {len(req_ids)} "
                         f"requests")
    if n_active + bundle.n > max_batch:
        raise RuntimeError(
            f"pool cannot host {bundle.n} imported rows "
            f"({n_active} active, max_batch {max_batch})")

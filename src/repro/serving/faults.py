"""Deterministic fault injection for the serving plane.

The chaos literature the fleet's supervision answers (HydraServe, ParaServe,
"Breaking the Ice" — PAPERS.md) treats worker death as routine; to test that
without flaky monkeypatching, production code calls ``fault_point("site")``
at a handful of REGISTERED sites and tests/benchmarks activate a
``FaultPlan`` describing what should go wrong there. With no plan active the
hook is one global read and a return — zero-cost on the serving fast path.

Sites (the registry ``FAULT_SITES`` is the source of truth; a lint-guard
test asserts the code's ``fault_point`` calls and this table stay in sync):

    depot.fetch         blob fetch in core/archive.py BlobStore (covers file,
                        bytes and depot-backed sources); payload = comp bytes
    archive.deserialize template executable deserialization (core/restore.py)
    restore.install     per-group install step of foundry_load
    engine.decode_step  top of ServingEngine.step (tag = replica fault_tag)
    kv.import_rows      ServingEngine.adopt_inflight before the pool import
    kv.handoff          prefill->decode handoff in Fleet, after the export
                        but before a decode replica adopts (tag = source
                        replica fault_tag); a hit requeues the request onto
                        the decode pool with its prefix kept
    reshard.cutover     top of Fleet._cutover, before any mutation

Fault kinds:

    raise    raise ``spec.exc(message)`` (default ``InjectedFault``; use
             ``InjectedIOError`` to exercise the OSError retry paths)
    corrupt  flip bytes of the site's payload (sites without a payload fall
             back to raising — there is nothing to corrupt)
    hang     sleep ``hang_s`` then continue; the call-site's deadline
             (``AutoscalePolicy.provision_deadline_s``, reshard
             ``wait_timeout_s``) is what turns a hang into a FAILED replica

Triggers (evaluated per matching call, under the plan lock, so counts are
deterministic even with concurrent provisioning threads):

    nth      fire on the nth matching call (1-based)
    tag      only calls carrying this tag (e.g. ``replica3``) match
    p/seed   seeded per-call probability (``random.Random(seed)``)
    times    stop firing after this many hits (None = unlimited)

Plans are process-global but explicitly scoped: ``with fault_plan(plan):``
or ``plan.activate()`` / ``deactivate_all()``. Nothing in this module
imports the rest of the package, so core/ and serving/ can both call
``fault_point`` without import cycles.
"""
from __future__ import annotations

import random
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, List, Optional

FAULT_SITES: Dict[str, str] = {
    "depot.fetch": "blob fetch from the archive/depot backing store",
    "archive.deserialize": "template executable deserialization",
    "restore.install": "per-group template install during foundry_load",
    "engine.decode_step": "one serving decode step",
    "kv.import_rows": "KV row import during adopt_inflight",
    "kv.handoff": "prefill->decode KV handoff (export -> adopt)",
    "reshard.cutover": "fleet reshard cutover",
}


class InjectedFault(RuntimeError):
    """Raised by a ``kind='raise'`` fault (and by ``corrupt`` at a site
    with no payload)."""


class InjectedIOError(InjectedFault, OSError):
    """An injected fault that IS an OSError: exercises the bounded
    exponential-backoff retry paths (core/archive.py ``io_retries``)."""


@dataclass
class FaultSpec:
    """One 'what goes wrong where' entry of a FaultPlan (module docstring)."""
    site: str
    kind: str = "raise"            # "raise" | "corrupt" | "hang"
    nth: Optional[int] = None      # fire on the nth matching call (1-based)
    tag: Optional[str] = None      # only calls with this tag match (None=any)
    p: float = 0.0                 # seeded per-call probability (nth=None)
    seed: int = 0
    times: Optional[int] = 1       # max firings; None = unlimited
    hang_s: float = 0.05
    message: str = "injected fault"
    exc: type = InjectedFault
    # runtime counters (owned by the plan lock)
    calls: int = 0
    fired: int = 0
    _rng: Optional[random.Random] = field(default=None, repr=False)

    def __post_init__(self):
        if self.site not in FAULT_SITES:
            raise ValueError(f"unknown fault site {self.site!r} "
                             f"(registered: {sorted(FAULT_SITES)})")
        if self.kind not in ("raise", "corrupt", "hang"):
            raise ValueError(f"unknown fault kind {self.kind!r}")

    def _should_fire(self) -> bool:
        """Trigger decision for one matching call (plan lock held)."""
        self.calls += 1
        if self.times is not None and self.fired >= self.times:
            return False
        if self.nth is not None:
            return self.calls == self.nth
        if self.p > 0.0:
            if self._rng is None:
                self._rng = random.Random(self.seed)
            return self._rng.random() < self.p
        return True  # no trigger spec: every matching call (bounded by times)


def _corrupt_bytes(payload: bytes) -> bytes:
    """Flip the leading bytes: breaks codec sniffing / content hashes while
    keeping the length (a torn or bit-rotted read, not a truncation)."""
    head = bytes(b ^ 0xFF for b in payload[:64])
    return head + payload[64:]


class FaultPlan:
    """A set of FaultSpecs plus firing accounting. Thread-safe: trigger
    evaluation runs under one lock so nth-call counting is deterministic
    across provisioning threads."""

    def __init__(self, *specs: FaultSpec):
        self.specs: List[FaultSpec] = list(specs)
        self._lock = threading.Lock()

    def add(self, spec: FaultSpec) -> FaultSpec:
        """Arm another spec (chaos schedules add faults mid-run)."""
        with self._lock:
            self.specs.append(spec)
        return spec

    def fired(self, site: Optional[str] = None) -> int:
        with self._lock:
            return sum(s.fired for s in self.specs
                       if site is None or s.site == site)

    def calls(self, site: Optional[str] = None) -> int:
        with self._lock:
            return sum(s.calls for s in self.specs
                       if site is None or s.site == site)

    # -- hook plumbing ---------------------------------------------------
    def _hit(self, site: str, payload, tag):
        fired = None
        with self._lock:
            for spec in self.specs:
                if spec.site != site:
                    continue
                if spec.tag is not None and spec.tag != tag:
                    continue
                if spec._should_fire():
                    spec.fired += 1
                    fired = spec
                    break
        if fired is None:
            return payload
        if fired.kind == "hang":
            time.sleep(fired.hang_s)
            return payload
        if fired.kind == "corrupt" and isinstance(payload, (bytes, bytearray)):
            return _corrupt_bytes(bytes(payload))
        raise fired.exc(f"[fault:{site}] {fired.message}")

    def activate(self) -> "FaultPlan":
        global _ACTIVE
        _ACTIVE = self
        return self

    def deactivate(self) -> None:
        global _ACTIVE
        if _ACTIVE is self:
            _ACTIVE = None


_ACTIVE: Optional[FaultPlan] = None


def deactivate_all() -> None:
    global _ACTIVE
    _ACTIVE = None


def active_plan() -> Optional[FaultPlan]:
    return _ACTIVE


@contextmanager
def fault_plan(plan: FaultPlan):
    """Scope a plan to a with-block (tests): always deactivated on exit."""
    plan.activate()
    try:
        yield plan
    finally:
        plan.deactivate()


def fault_point(site: str, payload=None, tag: Optional[str] = None):
    """Production-side injection hook. Returns ``payload`` (possibly
    corrupted), raises, or hangs per the active plan; with no plan active it
    is a single global read + return."""
    plan = _ACTIVE
    if plan is None:
        return payload
    if site not in FAULT_SITES:  # only checked when a plan is live
        raise ValueError(f"fault_point at unregistered site {site!r}")
    return plan._hit(site, payload, tag)

"""Multi-model gateway: one front door, N models, scale-to-zero serving.

The paper's economics (§1-2, §4.4) say cold start is cheap enough that
capacity can follow traffic; HydraServe and "Breaking the Ice" (PAPERS.md)
frame the serverless version — a zoo of models with shifting popularity
where every activation of a cold model eats its cold start in user TTFT.
The ``ModelRouter`` makes that scenario executable on the existing
``Fleet``/``Replica`` machinery:

  * requests are routed by model name to a per-model replica group
    (one ``serving/fleet.py`` Fleet per ACTIVE model);
  * each model has a ``ModelPolicy``: the fleet's ``AutoscalePolicy`` plus
    scale-to-ZERO — a model idle for ``idle_ticks_to_zero`` consecutive
    router ticks drains and releases its ENTIRE fleet (replicas, engines,
    KV pools), leaving only its archive manifest in memory;
  * a request for a COLD model triggers reactivation: a fresh fleet whose
    replicas ``cold_start_foundry`` from the shared ``TemplateDepot``
    archive (``core/depot.py``). Because the depot store caches fetched
    blobs process-wide, the second activation of a model skips even the
    blob read — reactivation cost is essentially deserialize + install;
  * per-model activation latency (trigger -> first replica READY and
    trigger -> first token) and TTFT are recorded (``RouterReport``), which
    is exactly what ``benchmarks/fig14_modelzoo.py`` compares against the
    keep-everything-resident baseline.

Model lifecycle state machine (docs/architecture.md §7):

    COLD ──submit()──▶ ACTIVATING ──first replica READY──▶ ACTIVE
      ▲                                                      │
      └────────── idle_ticks_to_zero reached ◀───(drain)─────┘
                 (scale_to_zero only; fleet/KV released)
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core import Archive
from repro.launch.mesh import resolve_mesh
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving.engine import ServingEngine
from repro.serving.fleet import (AutoscalePolicy, Fleet, FleetReport,
                                 PoolSpec, ReplicaState)
from repro.serving.scheduler import ReqState, Request

log = logging.getLogger("repro.serving.router")

# docs/architecture.md §13 has the full metric catalog
_M_ACTIVATIONS = obs_metrics.counter(
    "router_activations_total",
    "Cold -> activating transitions (fresh fleet spawned).", ("model",))
_M_DEACTIVATIONS = obs_metrics.counter(
    "router_deactivations_total",
    "Scale-to-zero teardowns (fleet + KV released).", ("model",))
_M_MESH_LEVEL = obs_metrics.gauge(
    "router_mesh_level",
    "Parallelism level the model currently serves at (0=low, 1=high).",
    ("model",))


class ModelState(Enum):
    COLD = "cold"               # no fleet; archive manifest only
    ACTIVATING = "activating"   # fleet spawned, no replica READY yet
    ACTIVE = "active"           # serving


@dataclass
class ReshardPolicy:
    """Load-adaptive parallelism switching (paper §4.3; ParaServe/HydraServe
    adapt parallelism to load in exactly this shape): sustained inflight at
    or above ``up_inflight`` for ``sustain_ticks`` consecutive router ticks
    flips the model's fleet onto ``high_mesh`` via ``Fleet.reshard``
    (live, KV-migrating, zero-drop); sustained load at or below
    ``down_inflight`` flips it back onto ``low_mesh``. Meshes are
    ``launch.mesh.MeshSpec``s (or concrete meshes / None) so the policy can
    be declared before any devices are claimed.

    ``prefer_reshard_over_scale_out=True`` (default) pins the fleet's
    replica count while the policy is active: the answer to sustained load
    is a bigger mesh for the SAME replicas, not more replicas — the
    ParaServe trade (intra-request parallelism over instance count).
    """
    high_mesh: object = None     # MeshSpec | Mesh | None
    low_mesh: object = None
    up_inflight: int = 8
    down_inflight: int = 0
    sustain_ticks: int = 5
    # minimum ticks between switches (a reshard takes wall-clock seconds;
    # without a cooldown an oscillating queue would thrash topologies)
    cooldown_ticks: int = 50
    prefer_reshard_over_scale_out: bool = True
    # which pool of a phase-disaggregated fleet the policy reshards (e.g.
    # "prefill"); None targets the sole pool of a colocated fleet
    pool: Optional[str] = None


@dataclass
class ModelPolicy:
    """Per-model serving policy: the fleet autoscaler plus scale-to-zero,
    plus optional load-adaptive parallelism switching (``reshard``)."""
    autoscale: AutoscalePolicy = field(default_factory=AutoscalePolicy)
    scale_to_zero: bool = True
    # consecutive router ticks with nothing inflight (and no replica still
    # provisioning) before the model's fleet is drained and released
    idle_ticks_to_zero: int = 30
    reshard: Optional[ReshardPolicy] = None
    # phase-disaggregated serving (docs §14): pool specs handed to the
    # model's Fleet on every (re)activation; None keeps the colocated
    # single-pool fleet built from ``autoscale``
    pools: Optional[Sequence[PoolSpec]] = None


@dataclass
class ModelStats:
    """Lifetime accounting for one model across activation cycles."""
    name: str
    activations: int = 0
    deactivations: int = 0
    # per activation: trigger -> first replica READY (the queue-unblocking
    # latency) and trigger -> first token out of the new fleet
    activation_ready_s: List[float] = field(default_factory=list)
    activation_first_token_s: List[float] = field(default_factory=list)
    # accumulated over released fleets + the live one at report time
    fallback_compiles: int = 0
    background_errors: int = 0
    replicas_spawned: int = 0
    # parallelism switches the reshard policy triggered (ReshardReport
    # summaries, in order), and the mesh level the model currently serves at
    reshards: List[Dict[str, Any]] = field(default_factory=list)
    mesh_level: str = "low"
    # supervision accounting (fleet crash recovery; docs §12): mid-serving
    # crashes absorbed, replacements respawned, requests shed at admission
    # while degraded, and whether the live fleet is degraded right now
    crashes: int = 0
    respawns: int = 0
    shed_requests: int = 0
    degraded: bool = False

    def summary(self, requests: Sequence[Request]) -> Dict[str, Any]:
        ttfts = [r.ttft for r in requests
                 if r.state is ReqState.DONE and r.ttft is not None]

        waits = [r.queue_wait_s for r in requests
                 if r.state is ReqState.DONE and r.queue_wait_s is not None]
        howaits = [r.handoff_wait_s for r in requests
                   if r.state is ReqState.DONE
                   and r.handoff_wait_s is not None]

        def pct(q):
            return FleetReport._pct(ttfts, q)
        return {
            "activations": self.activations,
            "deactivations": self.deactivations,
            "activation_ready_s": list(self.activation_ready_s),
            "activation_ready_max_s": (max(self.activation_ready_s)
                                       if self.activation_ready_s else None),
            "activation_first_token_s": list(self.activation_first_token_s),
            "n_done": sum(r.state is ReqState.DONE for r in requests),
            "n_failed": sum(r.state is ReqState.FAILED for r in requests),
            "ttft_p50_s": pct(0.50),
            "ttft_p95_s": pct(0.95),
            "queue_wait_p50_s": FleetReport._pct(waits, 0.50),
            "queue_wait_p95_s": FleetReport._pct(waits, 0.95),
            "handoff_wait_p50_s": FleetReport._pct(howaits, 0.50),
            "handoff_wait_p95_s": FleetReport._pct(howaits, 0.95),
            "fallback_compiles": self.fallback_compiles,
            "background_errors": self.background_errors,
            "replicas_spawned": self.replicas_spawned,
            "reshards": list(self.reshards),
            "mesh_level": self.mesh_level,
            "crashes": self.crashes,
            "respawns": self.respawns,
            "shed_requests": self.shed_requests,
            "degraded": self.degraded,
        }


class _ModelEntry:
    """Router-internal per-model record (archive handle outlives fleets)."""

    def __init__(self, name: str, factory: Optional[Callable[[], ServingEngine]],
                 archive: Optional[Archive], policy: ModelPolicy, mode: str,
                 factory_for_mesh: Optional[Callable] = None):
        self.name = name
        self.factory = factory
        self.factory_for_mesh = factory_for_mesh
        self.archive = archive
        self.policy = policy
        self.mode = mode
        self.state = ModelState.COLD
        self.fleet: Optional[Fleet] = None
        self.idle_ticks = 0
        self.trigger_t: Optional[float] = None
        self.await_first_token = False
        self.requests: List[Request] = []
        self.stats = ModelStats(name)
        self.fleet_reports: List[FleetReport] = []
        # reshard-policy bookkeeping: sustained-load tick counters + the
        # tick of the last switch (cooldown); mesh_level lives on stats so
        # a scale-to-zero/reactivate cycle resumes at the same parallelism
        self.sustain_ticks = 0
        self.last_reshard_tick: Optional[int] = None
        # (ReshardReport, target_level) of the in-flight switch; mesh_level
        # flips only when the report confirms the switch completed — an
        # aborted reshard leaves the fleet on the OLD topology and the
        # policy must keep saying so or it wedges (never re-triggers)
        self.pending_reshard: Optional[tuple] = None

    def current_mesh_spec(self):
        rp = self.policy.reshard
        if rp is None:
            return None
        return rp.high_mesh if self.stats.mesh_level == "high" else rp.low_mesh


@dataclass
class RouterReport:
    ticks: int
    wall_s: float
    peak_resident_replicas: int
    models: Dict[str, Dict[str, Any]] = field(default_factory=dict)

    def summary(self) -> Dict[str, Any]:
        return {
            "ticks": self.ticks,
            "wall_s": self.wall_s,
            "peak_resident_replicas": self.peak_resident_replicas,
            "models": self.models,
            "fallback_compiles": sum(m["fallback_compiles"]
                                     for m in self.models.values()),
            "background_errors": sum(m["background_errors"]
                                     for m in self.models.values()),
            "n_done": sum(m["n_done"] for m in self.models.values()),
            "n_failed": sum(m["n_failed"] for m in self.models.values()),
            "crashes": sum(m["crashes"] for m in self.models.values()),
            "shed_requests": sum(m["shed_requests"]
                                 for m in self.models.values()),
        }


def default_prompt_fn(rng) -> tuple:
    """(prompt, max_new_tokens) generator shared by run_trace/run_phases."""
    return ([rng.randrange(1, 50) for _ in range(rng.randrange(2, 8))],
            rng.randrange(4, 10))


def popularity_trace(models: Sequence[str], *, phase_ticks: int = 12,
                     hot_rate: int = 3, cold_rate: int = 0,
                     rounds: int = 2,
                     gap_ticks: int = 0) -> List[Dict[str, int]]:
    """Popularity-shifting arrivals: each model takes a turn as the hot one
    (``hot_rate`` arrivals/tick for ``phase_ticks``; everyone else gets
    ``cold_rate``), cycling ``rounds`` times — so a model that was hot goes
    fully idle for (len(models)-1) phases and must reactivate when its turn
    comes back. ``gap_ticks`` of global silence between phases lets
    scale-to-zero engage even with chatty ``cold_rate``."""
    trace: List[Dict[str, int]] = []
    for _ in range(rounds):
        for hot in models:
            for _ in range(phase_ticks):
                trace.append({m: (hot_rate if m == hot else cold_rate)
                              for m in models})
            trace.extend({} for _ in range(gap_ticks))
    return trace


class ModelRouter:
    """Gateway owning per-model replica groups with scale-to-zero.

    ``add_model`` registers a model: an engine factory, an archive (usually
    ``depot.open(name)``), and a ``ModelPolicy``. ``submit`` routes by model
    name, activating a COLD model's fleet on demand; ``tick`` advances every
    live fleet one step and applies the lifecycle state machine (module
    docstring). ``mode`` per model picks the replica cold-start path —
    "foundry" (LOAD from the depot archive) or the "vanilla"/"eager"
    baselines.
    """

    def __init__(self, *, verbose: bool = False):
        self.entries: Dict[str, _ModelEntry] = {}
        self.verbose = verbose
        self.peak_resident_replicas = 0
        self._tick = 0
        self._t0: Optional[float] = None
        if verbose:
            from repro.obs import configure_logging
            configure_logging()

    # -- registry --------------------------------------------------------
    def add_model(self, name: str,
                  factory: Optional[Callable[[], ServingEngine]] = None, *,
                  archive: Optional[Archive] = None,
                  policy: Optional[ModelPolicy] = None,
                  factory_for_mesh: Optional[Callable] = None,
                  mode: str = "foundry") -> None:
        """Register a model. ``factory`` is the zero-arg engine factory;
        a model with a ``ReshardPolicy`` needs ``factory_for_mesh(mesh)``
        instead, so its fleet can rebuild engines for whichever topology
        the policy currently selects."""
        if mode == "foundry" and archive is None:
            raise ValueError(f"model {name!r}: foundry mode needs an archive "
                             f"(e.g. depot.open({name!r}))")
        policy = policy or ModelPolicy()
        if factory is None and factory_for_mesh is None:
            raise ValueError(f"model {name!r}: needs factory or "
                             f"factory_for_mesh")
        if policy.reshard is not None and factory_for_mesh is None:
            raise ValueError(f"model {name!r}: a ReshardPolicy needs "
                             f"factory_for_mesh (engines must be buildable "
                             f"for both topologies)")
        self.entries[name] = _ModelEntry(name, factory, archive, policy,
                                         mode, factory_for_mesh)

    def models(self) -> List[str]:
        return sorted(self.entries)

    def state_of(self, name: str) -> ModelState:
        return self.entries[name].state

    # -- lifecycle -------------------------------------------------------
    def _activate(self, e: _ModelEntry) -> None:
        e.fleet = Fleet(e.factory, mode=e.mode, archive=e.archive,
                        policy=e.policy.autoscale,
                        mesh=resolve_mesh(e.current_mesh_spec()),
                        factory_for_mesh=e.factory_for_mesh,
                        pools=e.policy.pools,
                        verbose=self.verbose, name=e.name)
        rp = e.policy.reshard
        if rp is not None and rp.prefer_reshard_over_scale_out:
            e.fleet.suppress_scale_out = True
        e.sustain_ticks = 0
        e.last_reshard_tick = None
        e.pending_reshard = None
        e.fleet.start()
        e.state = ModelState.ACTIVATING
        e.trigger_t = time.perf_counter()
        e.await_first_token = True
        e.idle_ticks = 0
        e.stats.activations += 1
        _M_ACTIVATIONS.inc(model=e.name)
        if self.verbose:
            log.info("+model %s (activation %d, tick %d)",
                     e.name, e.stats.activations, self._tick)

    def activate(self, name: str) -> None:
        """Pre-warm a model (the keep-resident baseline activates everything
        up front; normal operation lets ``submit`` trigger this lazily)."""
        e = self.entries[name]
        if e.fleet is None:
            self._activate(e)

    def _deactivate(self, e: _ModelEntry) -> None:
        fleet = e.fleet
        if e.pending_reshard is not None:
            # reconcile a switch that completed since the last policy tick
            rep, want = e.pending_reshard
            e.pending_reshard = None
            if rep.done and rep.aborted is None:
                e.stats.mesh_level = want
                _M_MESH_LEVEL.set(1.0 if want == "high" else 0.0,
                                  model=e.name)
        for r in fleet.replicas:
            # deactivate_all may catch an autoscale-spawned replica mid
            # cold start; let it finish so releasing the engine below is
            # not undone by the provisioning thread (and so its LOAD's
            # background errors are drained + counted like everyone else's)
            if r.state is ReplicaState.PROVISIONING:
                r.join_provision(120.0)
        fleet.drain_background(timeout=120.0)  # join LOAD workers, count errs
        rep = fleet.report()
        e.fleet_reports.append(rep)
        e.stats.fallback_compiles += sum(r.fallback_compiles
                                         for r in rep.replicas)
        e.stats.background_errors += sum(r.background_errors
                                         for r in rep.replicas)
        e.stats.replicas_spawned += len(rep.replicas)
        e.stats.reshards = e.stats.reshards + list(rep.reshards)
        e.stats.crashes += rep.crashes
        e.stats.respawns += rep.respawns
        e.stats.shed_requests += rep.shed_requests
        for r in fleet.replicas:
            if r.state in (ReplicaState.PROVISIONING, ReplicaState.READY):
                r.stop()
            r.engine = None  # release engine + KV pool now, not at GC whim
        e.fleet = None
        e.state = ModelState.COLD
        e.idle_ticks = 0
        e.stats.deactivations += 1
        _M_DEACTIVATIONS.inc(model=e.name)
        obs_trace.instant("model.deactivate", cat="router", model=e.name)
        if self.verbose:
            log.info("-model %s (scale-to-zero after %d idle ticks, "
                     "tick %d)", e.name, e.policy.idle_ticks_to_zero,
                     self._tick)

    def deactivate_all(self) -> None:
        """Drain and release every live fleet (end-of-run accounting)."""
        for e in self.entries.values():
            if e.fleet is not None:
                self._deactivate(e)

    # -- traffic ---------------------------------------------------------
    def submit(self, model: str, prompt: Sequence[int],
               max_new_tokens: int) -> Request:
        """Route one request. A COLD model starts activating immediately;
        the request waits on the new fleet's backlog, so its TTFT includes
        the activation it triggered — the quantity fig14 measures."""
        try:
            e = self.entries[model]
        except KeyError:
            raise KeyError(f"unknown model {model!r} "
                           f"(have: {self.models()})") from None
        if e.fleet is None:
            self._activate(e)
        req = e.fleet.submit(prompt, max_new_tokens)
        e.requests.append(req)
        return req

    # -- serving loop ----------------------------------------------------
    def _fleet_idle(self, e: _ModelEntry) -> bool:
        fleet = e.fleet
        if fleet.backlog:
            return False
        if any(r.state is ReplicaState.PROVISIONING for r in fleet.replicas):
            return False  # never drop a fleet under a replica mid-cold-start
        return all(q.state in (ReqState.DONE, ReqState.FAILED)
                   for q in fleet.requests)

    def tick(self) -> int:
        """One gateway iteration: advance every live fleet one tick, apply
        activation/deactivation transitions, track resident replicas.
        Returns requests actively served across all models."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        self._tick += 1
        served = resident = 0
        for e in self.entries.values():
            if e.fleet is None:
                continue
            served += e.fleet.tick()
            resident += len(e.fleet._alive())
            now = time.perf_counter()
            if e.state is ModelState.ACTIVATING and e.fleet._ready():
                e.stats.activation_ready_s.append(now - e.trigger_t)
                e.state = ModelState.ACTIVE
                # the activation window on the router timeline: trigger ->
                # first replica READY (what a queued user actually waits)
                obs_trace.complete("model.activate", "router",
                                   e.trigger_t, now, model=e.name)
            if e.await_first_token:
                firsts = [q.first_token_t for q in e.fleet.requests
                          if q.first_token_t is not None
                          and q.first_token_t >= e.trigger_t]
                if firsts:
                    e.stats.activation_first_token_s.append(
                        min(firsts) - e.trigger_t)
                    e.await_first_token = False
            if e.state is ModelState.ACTIVE:
                if e.policy.reshard is not None and e.fleet is not None:
                    self._apply_reshard_policy(e)
                if e.fleet is not None and self._fleet_idle(e):
                    e.idle_ticks += 1
                    if (e.policy.scale_to_zero
                            and e.idle_ticks >= e.policy.idle_ticks_to_zero):
                        self._deactivate(e)
                else:
                    e.idle_ticks = 0
        self.peak_resident_replicas = max(self.peak_resident_replicas,
                                          resident)
        return served

    def _apply_reshard_policy(self, e: _ModelEntry) -> None:
        """One tick of the load-adaptive parallelism trigger (``ReshardPolicy``):
        count consecutive ticks of sustained load outside the current mesh
        level's band; past ``sustain_ticks`` (and outside the cooldown),
        flip the fleet onto the other topology with a live, KV-migrating
        ``Fleet.reshard`` — the paper's "dynamic parallelism switching"
        answered with a bigger/smaller mesh instead of more/fewer replicas."""
        rp = e.policy.reshard
        if e.pending_reshard is not None and e.fleet._reshard is None:
            # the async switch resolved: adopt the new level only if it
            # actually happened (an abort leaves the old topology serving)
            rep, want = e.pending_reshard
            e.pending_reshard = None
            if rep.aborted is None:
                e.stats.mesh_level = want
                _M_MESH_LEVEL.set(1.0 if want == "high" else 0.0,
                                  model=e.name)
            else:
                log.warning("~model %s: reshard to %s mesh ABORTED (%s); "
                            "staying at %s", e.name, want, rep.aborted,
                            e.stats.mesh_level)
        if e.fleet._reshard is not None:
            return  # a switch is already in flight
        inflight = e.fleet.inflight()
        level = e.stats.mesh_level
        want = None
        if level == "low" and inflight >= rp.up_inflight:
            want = "high"
        elif level == "high" and inflight <= rp.down_inflight:
            want = "low"
        if want is None:
            e.sustain_ticks = 0
            return
        e.sustain_ticks += 1
        if e.sustain_ticks < rp.sustain_ticks:
            return
        if (e.last_reshard_tick is not None
                and self._tick - e.last_reshard_tick < rp.cooldown_ticks):
            return
        mesh = rp.high_mesh if want == "high" else rp.low_mesh
        e.pending_reshard = (e.fleet.reshard(mesh, pool=rp.pool), want)
        e.last_reshard_tick = self._tick
        e.sustain_ticks = 0
        if self.verbose:
            log.info("~model %s: reshard -> %s mesh (inflight %d for %d "
                     "ticks, tick %d)", e.name, want, inflight,
                     rp.sustain_ticks, self._tick)

    def _unresolved(self) -> int:
        return sum(q.state not in (ReqState.DONE, ReqState.FAILED)
                   for e in self.entries.values() for q in e.requests)

    def run_trace(self, trace: Sequence[Dict[str, int]], *,
                  prompt_fn: Optional[Callable] = None, seed: int = 0,
                  drain: bool = True, max_ticks: int = 20000) -> "RouterReport":
        """Replay a per-model arrivals trace (see ``popularity_trace``):
        ``trace[t]`` maps model name -> arrivals that tick. ``prompt_fn(rng)``
        returns (prompt, max_new_tokens)."""
        import random
        rng = random.Random(seed)
        prompt_fn = prompt_fn or default_prompt_fn
        for arrivals in trace:
            for model, n in arrivals.items():
                for _ in range(n):
                    self.submit(model, *prompt_fn(rng))
            if self.tick() == 0 and self._unresolved():
                time.sleep(0.001)  # yield to provisioning threads
        while drain and self._unresolved() and self._tick < max_ticks:
            if self.tick() == 0:
                time.sleep(0.001)  # everything still provisioning
        return self.report()

    def run_phases(self, phases: Sequence[tuple], *,
                   prompt_fn: Optional[Callable] = None, seed: int = 0,
                   gap_ticks: int = 0,
                   max_ticks_per_phase: int = 200000) -> "RouterReport":
        """Replay a popularity-shifting workload as completion-paced phases:
        each ``(model, n_requests)`` phase submits n requests to the hot
        model and ticks the WHOLE gateway until they resolve — so models
        left idle by the shift accrue idle ticks during the next phase and
        scale to zero while other models serve. A model hot again in a
        later phase therefore exercises the reactivation path. (The
        tick-per-arrival ``run_trace`` is kept for externally-timed traces;
        completion pacing is what makes phase boundaries meaningful when one
        tick is microseconds but an activation is wall-clock seconds.)

        ``gap_ticks`` inserts a quiet period after each phase. Idle-ness is
        counted in ticks but phases end on wall-clock completion, so whether
        the previous hot model reaches ``idle_ticks_to_zero`` *during* the
        next phase depends on scheduler timing; a gap >= the idle threshold
        makes every popularity shift deterministically reach COLD — what the
        examples/benchmarks assert on."""
        import random
        rng = random.Random(seed)
        prompt_fn = prompt_fn or default_prompt_fn
        for model, n in phases:
            reqs = [self.submit(model, *prompt_fn(rng)) for _ in range(n)]
            start = self._tick
            while (any(q.state not in (ReqState.DONE, ReqState.FAILED)
                       for q in reqs)
                   and self._tick - start < max_ticks_per_phase):
                if self.tick() == 0:
                    time.sleep(0.001)  # yield to provisioning threads
            for _ in range(gap_ticks):
                if self.tick() == 0:
                    time.sleep(0.0001)
        return self.report()

    # -- accounting ------------------------------------------------------
    def report(self) -> RouterReport:
        rep = RouterReport(
            ticks=self._tick,
            wall_s=(time.perf_counter() - self._t0) if self._t0 else 0.0,
            peak_resident_replicas=self.peak_resident_replicas)
        for name, e in self.entries.items():
            stats = ModelStats(**vars(e.stats))
            if e.fleet is not None:  # fold the live fleet in, non-destructively
                e.fleet.drain_background(timeout=120.0)
                frep = e.fleet.report()
                stats.fallback_compiles += sum(r.fallback_compiles
                                               for r in frep.replicas)
                stats.background_errors += sum(r.background_errors
                                               for r in frep.replicas)
                stats.replicas_spawned += len(frep.replicas)
                # rebind, don't append: the list object is shared with
                # e.stats and this fold must stay non-destructive
                stats.reshards = stats.reshards + list(frep.reshards)
                stats.crashes += frep.crashes
                stats.respawns += frep.respawns
                stats.shed_requests += frep.shed_requests
                stats.degraded = stats.degraded or frep.degraded
            entry = stats.summary(e.requests)
            entry["state"] = e.state.value
            rep.models[name] = entry
        return rep

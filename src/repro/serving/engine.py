"""Serving engine: bucketed decode + continuous batching + Foundry cold start.

Four cold-start paths (the paper's Figure 7/8 comparison, plus §4.3):
  * "vanilla"          — trace+lower+compile every capture bucket up front
                         (vLLM with CUDA graphs: full warmup + stream
                         capture);
  * "foundry"          — LOAD an archive captured on THIS topology: templates
                         restored with zero compile, all buckets pad-served
                         immediately, exact buckets hot-swap in the
                         background;
  * "foundry-stamped"  — LOAD an archive captured on a DIFFERENT but
                         shape-compatible topology (1-rank offline capture,
                         or a TP<->EP re-arrangement): the shared templates
                         are reused byte-identically and only rank-dependent
                         communication state is stamped per deployment rank
                         (core/rank_stamp.py). Still zero compile; reported
                         automatically when the LOAD takes the stamped path;
  * "eager"            — no capture; each bucket compiles lazily on first use
                         (vLLM without CUDA graphs: fast start, degraded
                         serving).

The decode hot loop is identical in all of them — only program provenance
differs — so TPOT preservation (Figure 9) is measured on the same code path.

Decode hot loop (docs/architecture.md "decode hot path"): the captured step
is the fused ``decode_step(params, cache, tokens) -> (cache', token_ids)``
with the KV cache donated (in-place update, the cache never leaves the
device) and greedy sampling folded into the graph, so steady-state decode
moves only O(B) int32 token ids across the host boundary per token — never
the O(B x padded_vocab) logits matrix. Sampled ids feed straight back as the
next step's input from the device side; the host rebuilds the token vector
(O(B) ints, one transfer) only when scheduling events invalidate it
(prefill, completion/compaction, pool resize). ``decode_loop="host"``
preserves the pre-fusion loop — captured programs return full logits and the
host argmaxes in numpy — as the measurable baseline for benchmarks/fig9 and
the token-identity regression tests.
"""
from __future__ import annotations

import bisect
import logging
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.trace import span

from repro.core import (Archive, CaptureSpec, MemoryPlan, ProgramSet,
                        default_bucket_ladder, foundry_load, foundry_save,
                        group_buckets, pad_batch_arg, topology_key)
from repro.core.templates import TopologyGroup
from repro.launch.mesh import ShardCtx
from repro.models.model import Model
from repro.serving.blockpool import PagedKVCachePool
from repro.serving.faults import fault_point
from repro.serving.kvcache import KVCachePool, RowBundle
from repro.serving.scheduler import ReqState, Request, Scheduler

log = logging.getLogger("repro.serving.engine")

# docs/architecture.md §13 has the full metric catalog
_M_TPOT = obs_metrics.histogram(
    "serving_tpot_seconds",
    "Per-decode-step wall time (the steady-state TPOT proxy).",
    buckets=(1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
             0.1, 0.25, 0.5, 1.0))
_M_DECODE_STEPS = obs_metrics.counter(
    "engine_decode_steps_total", "Decode steps that served >= 1 request.")
_M_COLD_STARTS = obs_metrics.counter(
    "engine_cold_starts_total", "Engine cold starts by mode.", ("mode",))


#: The supported-convention matrix: every ``CaptureSpec.tags`` key this
#: engine can serve, with its legal value domain (a tuple enumerates the
#: values; ``"int+"`` means a positive int). Tags version the captured
#: calling convention — the archived programs bake in the decode loop and
#: KV layout, so a key or value outside this matrix means the archive
#: speaks a convention this engine does not, and serving it anyway risks
#: silent token corruption rather than a graceful fallback.
#: ``repro.analysis.checker`` validates archives against this matrix
#: statically (the ``tags-schema`` pass).
TAG_CONVENTIONS: Dict[str, Any] = {
    "decode_loop": ("host", "device"),
    "fused_sampling": (False, True),
    "kv_layout": ("slot", "paged"),
    "kv_block_size": "int+",
    "kv_blocks": "int+",
}


def validate_tags(tags: Dict[str, Any]) -> List[str]:
    """Problems (empty = clean) with a tag dict vs ``TAG_CONVENTIONS``."""
    problems = []
    for k, v in tags.items():
        domain = TAG_CONVENTIONS.get(k)
        if domain is None:
            problems.append(f"unknown tag key {k!r} (engine speaks: "
                            f"{sorted(TAG_CONVENTIONS)})")
        elif domain == "int+":
            if not isinstance(v, int) or isinstance(v, bool) or v < 1:
                problems.append(f"tag {k}={v!r} must be a positive int")
        elif v not in domain or isinstance(v, bool) != any(
                isinstance(d, bool) for d in domain):
            problems.append(f"tag {k}={v!r} not in supported domain {domain}")
    return problems


@dataclass
class ColdStartReport:
    """How this engine became servable and what it cost.

    Fields:
        mode              cold-start path actually taken: "vanilla" |
                          "foundry" | "foundry-stamped" | "eager" (module
                          docstring). "foundry-stamped" means the archive was
                          captured on a different, shape-compatible topology
                          and was rank-stamped rather than recompiled.
        phases            phase name -> seconds; for foundry modes these are
                          the LoadReport phases (core/restore.py).
        n_buckets         capture buckets this engine dispatches over.
        n_templates       topology-group templates backing those buckets.
        rank_stamped      (template x rank) stampings performed by the LOAD;
                          0 for non-stamped modes.
        fallback_compiles critical-path compiles the LOAD could not avoid;
                          0 on exact and shape-compatible stamped loads.
    """
    mode: str
    phases: Dict[str, float] = field(default_factory=dict)
    n_buckets: int = 0
    n_templates: int = 0
    rank_stamped: int = 0
    fallback_compiles: int = 0

    @property
    def total_s(self) -> float:
        return sum(self.phases.values())


class ServingEngine:
    def __init__(self, model: Model, *, max_batch: int = 16,
                 max_seq: int = 128, bucket_mode: str = "all",
                 eos_token: Optional[int] = None,
                 memory_plan: Optional[MemoryPlan] = None,
                 decode_loop: str = "device",
                 kv_layout: str = "auto", kv_block_size: int = 16,
                 kv_blocks: Optional[int] = None):
        if decode_loop not in ("device", "host"):
            raise ValueError(f"decode_loop must be 'device' or 'host', "
                             f"got {decode_loop!r}")
        if kv_layout not in ("auto", "paged", "slot"):
            raise ValueError(f"kv_layout must be 'auto', 'paged' or 'slot', "
                             f"got {kv_layout!r}")
        self.model = model
        self.cfg = model.cfg
        self.ctx = model.ctx
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.buckets = default_bucket_ladder(max_batch, bucket_mode)
        self.eos_token = eos_token
        self.memory_plan = memory_plan or MemoryPlan()
        self.params = None
        self.programs: Optional[ProgramSet] = None
        self.scheduler = Scheduler()
        self.pool = None  # KVCachePool or PagedKVCachePool per kv_layout
        self._prefill_cache: Dict[int, Any] = {}
        self._eager_mode = False
        self.decode_steps = 0
        self.decode_loop = decode_loop
        # KV layout: block-table paged pool with radix prefix cache for the
        # attention families; slot compaction for SSM/hybrid/seqpar layouts
        # (their decode state has no block structure to page).
        self.kv_layout = (self._auto_kv_layout() if kv_layout == "auto"
                          else kv_layout)
        if self.kv_layout == "paged" and self._auto_kv_layout() == "slot":
            raise ValueError(
                f"kv_layout='paged' unsupported for family "
                f"'{self.cfg.family}' / seqpar sharding; use 'slot'")
        self.kv_block_size = kv_block_size
        self.kv_blocks = (kv_blocks or
                          max_batch * (-(-max_seq // kv_block_size)) + 1)
        # paged decode-fill bookkeeping: req_id -> prompt+prefix length the
        # fill must reach before sampled ids become recordable
        self._fill_target: Dict[int, int] = {}
        self.prefill_stats = {"prefilled_tokens": 0, "cached_tokens": 0,
                              "prefix_hits": 0, "prefix_misses": 0}
        # device-resident token state (decode_loop="device"): the sampled ids
        # of step k ARE step k+1's input, device-to-device; dirty marks the
        # scheduling events that force an O(B) host rebuild.
        self._tokens_dev: Optional[Any] = None
        self._tokens_bucket: int = 0
        self._tokens_dirty: bool = True
        # host<->device traffic of the decode loop, in bytes (the fig9
        # transfer accounting; tests cross-check it with patched transports)
        self.transfer_stats = {"h2d_bytes": 0, "d2h_bytes": 0,
                               "token_rebuilds": 0}
        # fault-injection identity (serving/faults.py): the owning fleet
        # stamps this with the replica id so chaos plans can target one
        # replica's decode steps / KV imports; None outside a fleet
        self.fault_tag: Optional[str] = None

    def _auto_kv_layout(self) -> str:
        if (self.cfg.family in ("dense", "vlm", "moe")
                and not self.model._seqpar_axes()):
            return "paged"
        return "slot"

    # ------------------------------------------------------------------
    def _decode_fn(self, loop: Optional[str] = None):
        """The captured step for this engine's decode loop.

        device: fused ``(params, cache, tokens) -> (cache', token_ids)`` —
                greedy sampling over the real (unpadded) vocab happens inside
                the graph; only B int32 ids ever cross to the host.
        host:   pre-fusion ``(params, cache, tokens) -> (cache', logits)``.
        """
        m = self.model
        vocab = self.cfg.vocab_size
        step_fn = (m.decode_step_paged if self.kv_layout == "paged"
                   else m.decode_step)
        if (loop or self.decode_loop) == "device":
            def decode_step(params, cache, tokens):
                new_cache, logits = step_fn(params, cache, tokens)
                live = logits[:, :vocab]
                # first-max argmax as two vectorizable reduces (max, then min
                # over the tied-index iota). XLA:CPU lowers jnp.argmax to a
                # scalar-looped variadic reduce ~3.5x slower than the logits
                # readback it is meant to replace; tie-breaking (lowest
                # index) matches np.argmax, which the host loop uses — the
                # token-identity tests pin that equivalence.
                mx = jnp.max(live, axis=-1, keepdims=True)
                iota = jax.lax.broadcasted_iota(jnp.int32, live.shape, 1)
                ids = jnp.min(jnp.where(live == mx, iota, jnp.int32(vocab)),
                              axis=-1)
                return new_cache, ids
        else:
            def decode_step(params, cache, tokens):
                return step_fn(params, cache, tokens)
        return decode_step

    def _decode_args(self, bucket: int):
        m, ctx = self.model, self.ctx
        tok_sh = (ctx.sharding(("batch",), (bucket,))
                  if ctx.mesh is not None else None)
        if self.kv_layout == "paged":
            cache = m.paged_cache_specs(bucket, self.max_seq,
                                        self.kv_blocks, self.kv_block_size)
        else:
            cache = m.cache_specs(bucket, self.max_seq)
        return (m.param_specs(), cache,
                jax.ShapeDtypeStruct((bucket,), jnp.int32, sharding=tok_sh))

    def capture_spec(self) -> CaptureSpec:
        # kv_* tags version the captured calling convention: a paged archive
        # must be served through the paged pool (and vice versa); archives
        # without the tag predate paging and load via the slot path.
        return CaptureSpec("decode", self._decode_fn(), self._decode_args,
                           self.buckets, donate_argnums=(1,),
                           tags={"decode_loop": self.decode_loop,
                                 "fused_sampling":
                                     self.decode_loop == "device",
                                 "kv_layout": self.kv_layout,
                                 "kv_block_size": self.kv_block_size,
                                 "kv_blocks": self.kv_blocks})

    # ---- weights -------------------------------------------------------
    def load_weights(self, params=None, rng=None):
        """Weight loading is assumed solved (RDMA, 1-2 s; paper §2); here we
        either take provided params or init. Registers with the memory plan."""
        t0 = time.perf_counter()
        self.params = params if params is not None else self.model.init(
            rng if rng is not None else jax.random.PRNGKey(0))
        for path, leaf in jax.tree_util.tree_flatten_with_path(self.params)[0]:
            self.memory_plan.alloc(
                "params" + jax.tree_util.keystr(path),
                leaf.size * leaf.dtype.itemsize)
        return time.perf_counter() - t0

    def _init_pool(self):
        if self.kv_layout == "paged":
            self.pool = PagedKVCachePool(
                self.model, self.max_batch, self.max_seq,
                bucket_of=self._bucket_of, memory_plan=self.memory_plan,
                block_size=self.kv_block_size, n_blocks=self.kv_blocks)
        else:
            self.pool = KVCachePool(
                self.model, self.max_batch, self.max_seq,
                bucket_of=self._bucket_of, memory_plan=self.memory_plan)
        self._fill_target.clear()
        self._tokens_dev = None
        self._tokens_dirty = True

    def _bucket_of(self, n: int) -> int:
        i = bisect.bisect_left(self.buckets, n)
        return self.buckets[min(i, len(self.buckets) - 1)]

    # ---- cold start paths ------------------------------------------------
    def cold_start_vanilla(self, verbose: bool = False) -> ColdStartReport:
        """Full capture: per-bucket trace+lower+compile (stream capture)."""
        rep = ColdStartReport("vanilla", n_buckets=len(self.buckets))
        step = self._decode_fn()
        keys = {}
        t0 = time.perf_counter()
        extra = {"mesh": str(None if self.ctx.mesh is None
                             else self.ctx.mesh.shape)}
        for b in self.buckets:
            keys[b] = topology_key(step, *self._decode_args(b), extra=extra)
        rep.phases["trace_key_s"] = time.perf_counter() - t0
        groups = group_buckets(keys)
        rep.n_templates = len(groups)
        ps = ProgramSet(groups)
        t0 = time.perf_counter()
        jitted = jax.jit(step, donate_argnums=(1,))
        for b in self.buckets:
            exe = jitted.lower(*self._decode_args(b)).compile()
            ps.set_exact(b, exe)
            g = next(g for g in groups if b in g.buckets)
            if b == g.template_bucket:
                ps.set_template(g.key, exe)
        rep.phases["capture_compile_s"] = time.perf_counter() - t0
        self.programs = ps
        self._init_pool()
        _M_COLD_STARTS.inc(mode="vanilla")
        if verbose:
            log.info("[cold-start vanilla] %.2fs (%d buckets)",
                     rep.total_s, len(self.buckets))
        return rep

    def cold_start_foundry(self, archive: Archive,
                           background_exact: bool = True,
                           allow_stamping: bool = True,
                           warm: bool = False,
                           strict: bool = True,
                           verbose: bool = False) -> ColdStartReport:
        """LOAD ``archive`` and become servable. The report's mode is
        "foundry" when the archive was captured on this engine's topology
        and "foundry-stamped" when LOAD rank-stamped a shape-compatible
        capture onto it (``allow_stamping=False`` forces mesh mismatches
        down the compile-from-StableHLO fallback instead).

        The engine adopts the archive's decode loop: the archived programs
        either fuse sampling (device loop) or return logits (host loop), and
        the serving loop must match what SAVE captured. Archives without the
        tag (pre-fusion) are served with the host loop.

        ``warm=True`` marks this a LOAD into an already-warm serving process
        (live reshard: the old topology's replicas are still serving when
        the new ones come up): the memory-plan preallocation is skipped —
        the extent is already mapped in this process — and templates
        deserialized by an earlier LOAD of the same archive are reused."""
        spec_m = archive.manifest.get("specs", {}).get("decode", {})
        tags = spec_m.get("tags") or {}
        if strict:
            # validate BEFORE adopting: a tag outside the convention matrix
            # would otherwise mutate engine state (loop/pool selection) into
            # a convention SAVE never captured — token corruption, not a
            # fallback. foundry_load(strict=True) re-checks the full
            # manifest; this guards the two fields adopted pre-LOAD.
            problems = validate_tags(tags)
            if problems:
                raise ValueError(
                    f"archive capture tags fail the engine convention "
                    f"matrix: {'; '.join(problems)} (run `python -m "
                    f"repro.analysis.check` on the archive)")
        archived_loop = tags.get("decode_loop", "host")
        if archived_loop != self.decode_loop and verbose:
            log.info("[LOAD] archive captured for decode_loop='%s'; "
                     "adopting it", archived_loop)
        self.decode_loop = archived_loop
        # adopt the archived KV calling convention: the restored programs
        # bake in the cache layout, so the pool must match it. Untagged
        # (pre-paged) archives default to the slot path.
        self.kv_layout = tags.get("kv_layout", "slot")
        self.kv_block_size = tags.get("kv_block_size", self.kv_block_size)
        self.kv_blocks = tags.get("kv_blocks", self.kv_blocks)
        with span("engine.cold_start", cat="engine", mode="foundry"):
            progs, load_rep, plan = foundry_load(
                archive, self.ctx.mesh,
                background_exact=background_exact,
                allow_stamping=allow_stamping, warm=warm, strict=strict,
                verbose=verbose)
        mode = ("foundry-stamped" if load_rep.restore_path == "stamped"
                else "foundry")
        _M_COLD_STARTS.inc(mode=mode)
        rep = ColdStartReport(mode, n_buckets=len(self.buckets),
                              rank_stamped=load_rep.rank_stamped,
                              fallback_compiles=load_rep.fallback_compiles)
        self.programs = progs["decode"]
        rep.phases.update(load_rep.phases)
        rep.n_templates = load_rep.n_templates
        self._load_report = load_rep
        self._init_pool()
        return rep

    def cold_start_eager(self, verbose: bool = False) -> ColdStartReport:
        """No capture: programs compile lazily on first use."""
        rep = ColdStartReport("eager", n_buckets=len(self.buckets))
        step = self._decode_fn()
        keys = {b: f"eager-{b}" for b in self.buckets}  # no grouping
        ps = ProgramSet(group_buckets(keys))
        self.programs = ps
        self._eager_mode = True
        self._eager_jit = jax.jit(step, donate_argnums=(1,))
        rep.phases["noop_s"] = 0.0
        self._init_pool()
        return rep

    def save_archive(self, path: Optional[str] = None, **kw):
        """Offline SAVE for this engine's capture set."""
        if self.pool is None:
            # register the KV pool's (rank-relative) extents in the memory
            # plan so the archive's RankDelta section records them (§4.3)
            self._init_pool()
        ar, rep = foundry_save([self.capture_spec()], self.ctx.mesh,
                               memory_plan=self.memory_plan,
                               meta={"arch": self.cfg.name,
                                     "max_seq": self.max_seq,
                                     "decode_loop": self.decode_loop}, **kw)
        if path:
            ar.save(path)
        return ar, rep

    # ---- serving ---------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int) -> Request:
        return self.scheduler.submit(list(prompt), max_new_tokens)

    def _prefill(self, req: Request):
        """Prefill one request into its slot (pads prompt to pow2 bucket)."""
        m = self.model
        plen = len(req.prompt) + len(req.generated)
        toks = list(req.prompt) + list(req.generated)
        pb = 1 << (plen - 1).bit_length()
        pb = min(max(pb, 8), self.max_seq)
        padded = np.zeros((1, pb), np.int32)
        padded[0, :plen] = toks
        key = pb
        if key not in self._prefill_cache:
            self._prefill_cache[key] = jax.jit(
                lambda p, b: m.prefill(p, b, cache_len=self.max_seq))
        logits, cache1 = self._prefill_cache[key](
            self.params, {"tokens": jnp.asarray(padded)})
        # fix lengths: prefill padded to pb, true length is plen
        cache1 = {**cache1, "lengths": jnp.asarray([plen], jnp.int32)}
        slot = self.pool.acquire(req.req_id)
        req.slot = slot
        self.pool.write_prefill(slot, cache1)
        # the prefill handoff writes device-to-device into the persistent
        # pool rows; only the token vector needs a host rebuild next step
        self._tokens_dirty = True
        # note: prefill over right-padded prompts is exact for causal attn
        # (pad positions sit after plen and are never attended by pos<plen),
        # and for SSM archs we re-run prefill at exact length buckets.
        return slot

    def _begin_fill(self, req: Request) -> int:
        """Paged admission: attach the radix-cached prefix of the request's
        tokens to a fresh slot and schedule the rest for decode-fill — the
        uncached positions run token-by-token through the *captured* decode
        graph (no separate prefill program, no extra compile). Sampled ids
        become recordable once the fill reaches the last prompt token; a
        prefix hit skips straight there, which is the TTFT win."""
        toks = list(req.prompt) + list(req.generated)
        slot = self.pool.acquire(req.req_id)
        req.slot = slot
        cached = self.pool.begin_sequence(slot, toks)
        self._fill_target[req.req_id] = len(toks)
        self.prefill_stats["prefilled_tokens"] += len(toks) - cached
        self.prefill_stats["cached_tokens"] += cached
        self.prefill_stats["prefix_hits" if cached else
                           "prefix_misses"] += 1
        self._tokens_dirty = True
        return slot

    def _put_tokens(self, t):
        t = jnp.asarray(t)
        if self.ctx.mesh is not None:
            sh = self.ctx.sharding(("batch",), t.shape)
            if sh is not None:
                t = jax.device_put(t, sh)
        return t

    def _rebuild_tokens(self, exec_bucket: int, by_slot):
        """O(B) host rebuild of the token vector (the only host->device
        transfer the decode loop ever makes, and only on dirty steps)."""
        arr = np.zeros((exec_bucket,), np.int32)
        if self.kv_layout == "paged":
            # unified decode-fill rule: every step feeds the token at the
            # row's next write position. Steady state this is the last
            # sampled token (host_len == len(toks) - 1); during a fill it
            # walks the uncached prompt suffix.
            for slot, req in by_slot.items():
                toks = req.prompt + req.generated
                arr[slot] = toks[min(self.pool.host_len[slot],
                                     len(toks) - 1)]
        else:
            for slot, req in by_slot.items():
                arr[slot] = (req.generated or req.prompt)[-1]
        self.transfer_stats["h2d_bytes"] += arr.nbytes
        self.transfer_stats["token_rebuilds"] += 1
        return self._put_tokens(arr)

    def _device_tokens(self, exec_bucket: int, by_slot):
        """Token input for the fused step: previous step's on-device sampled
        ids when clean; bucket growth pads the device view in place (no host
        round-trip); anything dirty rebuilds from host state."""
        t = self._tokens_dev
        if not self._tokens_dirty and t is not None:
            if self._tokens_bucket == exec_bucket:
                return t
            if self._tokens_bucket < exec_bucket:
                # pre-padded device view for the bucket transition
                t = pad_batch_arg(t, self._tokens_bucket, exec_bucket)
            else:
                t = t[:exec_bucket]
            return self._put_tokens(t)
        return self._rebuild_tokens(exec_bucket, by_slot)

    def _step_device(self, bucket: int, by_slot) -> np.ndarray:
        """Fused dispatch: donated cache, on-device sampling, O(B) readback."""
        if self._eager_mode:
            exec_bucket, exe = bucket, self._eager_jit
        else:
            exec_bucket, exe, _path = self.programs.lookup(bucket)
            if exec_bucket != bucket:
                self.pool._resize(exec_bucket)
        toks = self._device_tokens(exec_bucket, by_slot)
        cache, sampled = exe(self.params, self.pool.cache, toks)
        self.pool.cache = cache
        self._tokens_dev = sampled
        self._tokens_bucket = exec_bucket
        self._tokens_dirty = False
        ids = np.asarray(sampled)  # the loop's only device->host readback
        self.transfer_stats["d2h_bytes"] += ids.nbytes
        return ids

    def _step_host(self, bucket: int, by_slot) -> np.ndarray:
        """Pre-fusion loop (decode_loop="host"): host re-packs tokens every
        step and pulls the full padded-vocab logits back to argmax in numpy.
        Kept as the measurable baseline for fig9 and the identity tests."""
        if self._eager_mode:
            exec_bucket, exe = bucket, self._eager_jit
        else:
            exec_bucket, exe, _path = self.programs.lookup(bucket)
            if exec_bucket != bucket:
                self.pool._resize(exec_bucket)
        cache, logits = exe(self.params, self.pool.cache,
                            self._rebuild_tokens(exec_bucket, by_slot))
        self.pool.cache = cache
        logits_np = np.asarray(logits[:, :self.cfg.vocab_size])
        self.transfer_stats["d2h_bytes"] += logits_np.nbytes
        return logits_np.argmax(axis=-1)

    def _admit(self, free: int):
        """Pull admissions from the scheduler and give each a slot.

        Paged admission accounting charges a request only for its *uncached*
        KV blocks: the radix-matched prefix is served from shared cached
        blocks, so a request whose full prompt would blow the block budget
        is still admitted when the cached suffix fits (ISSUE 6 satellite).
        A genuine shortfall defers (queue front, no retry penalty); only a
        request that could never fit — uncached need beyond every usable
        block — fails terminally."""
        sched, pool = self.scheduler, self.pool
        admitted = sched.admissions(free)
        to_defer: List[Request] = []
        for req in admitted:
            plen = len(req.prompt) + len(req.generated)
            if plen >= self.max_seq:
                # position capacity, not block budget: even a fully cached
                # prompt occupies plen positions + one generated token
                sched.reject(
                    req, f"prompt+prefix length {plen} exceeds engine "
                         f"capacity (max_seq={self.max_seq} incl. one "
                         f"generated token)")
                continue
            if to_defer:
                to_defer.append(req)  # keep FIFO order behind the blocker
                continue
            if self.kv_layout != "paged":
                self._prefill(req)
                continue
            # end-of-life table size; generated-prefix retries fold into
            # max_new (finished counts generated against the same budget)
            total = pool.blocks_needed(len(req.prompt), req.max_new_tokens)
            if total > pool.allocator.n_usable:
                sched.reject(
                    req, f"request needs {total} KV blocks end-to-end, "
                         f"beyond pool capacity ({pool.allocator.n_usable} "
                         f"usable blocks of {pool.block_size} tokens)")
                continue
            toks = list(req.prompt) + list(req.generated)
            matched = pool.prefix.match(toks[:max(0, len(toks) - 1)])
            need = total - len(matched)
            headroom = (pool.allocator.n_free
                        + pool.prefix.reclaimable_count(
                            frozenset(n.block for n in matched))
                        - self._outstanding_blocks())
            if need > headroom:
                to_defer.append(req)
                continue
            self._begin_fill(req)
        for req in reversed(to_defer):
            sched.defer(req)

    def _outstanding_blocks(self) -> int:
        """Blocks already-admitted running requests will still allocate on
        their way to their generation budget — reserved, not yet drawn from
        the free list. Admission headroom subtracts this so two admissions
        cannot jointly over-commit the pool and thrash via preemption."""
        pool, out = self.pool, 0
        for r in self.scheduler.running.values():
            if r.slot is None:
                continue
            total = pool.blocks_needed(len(r.prompt), r.max_new_tokens)
            out += max(0, total - len(pool.tables[r.slot]))
        return out

    def _preempt_until_feasible(self):
        """Paged mid-decode block exhaustion: running requests' tables grow
        every block_size steps, and the admission budget can be overtaken by
        later admissions' growth. Preempt (defer + release) the slot that
        failed to get its write block until the rest of the batch fits."""
        sched, pool = self.scheduler, self.pool
        while True:
            stuck = pool.ensure_step_capacity()
            if stuck is None:
                return
            victim = sched.running[pool.slots[stuck]]
            self._fill_target.pop(victim.req_id, None)
            sched.defer(victim)
            pool.release(stuck)
            moved_id = (pool.slots[stuck]
                        if stuck < len(pool.slots) else None)
            if moved_id is not None and moved_id in sched.running:
                sched.running[moved_id].slot = stuck
            self._tokens_dirty = True

    def step(self) -> int:
        """One engine iteration: admit + decode one token for all running.
        Returns number of active requests served.

        When telemetry is on (obs.metrics enabled and/or tracing active)
        the step is timed once and the measurement feeds both the
        ``serving_tpot_seconds`` histogram and an ``engine.decode_step``
        trace span; when off, the cost is two module-global reads."""
        if not (obs_metrics.enabled() or obs_trace.active()):
            return self._step_impl()
        t0 = time.perf_counter()
        n = self._step_impl()
        dt = time.perf_counter() - t0
        if n:  # idle ticks are not decode steps — they would skew TPOT
            if obs_metrics.enabled():
                _M_TPOT.observe(dt)
                _M_DECODE_STEPS.inc()
            if obs_trace.active():
                obs_trace.collector().add_complete(
                    "engine.decode_step", "engine", t0, dt, {"batch": n})
        return n

    def _step_impl(self) -> int:
        # injected BEFORE any scheduler/pool mutation: a crash here leaves
        # the engine coherent, so the fleet's salvage path (export_inflight)
        # can migrate the in-flight KV rows instead of re-prefilling
        fault_point("engine.decode_step", tag=self.fault_tag)
        sched, pool = self.scheduler, self.pool
        self._admit(self.max_batch - pool.n_active)
        if self.kv_layout == "paged":
            self._preempt_until_feasible()
        n = pool.n_active
        if n == 0:
            return 0
        if self.kv_layout == "paged":
            # rebuild the (small) device block tables if scheduling dirtied
            # them; steady-state decode takes the free fast path
            self.transfer_stats["h2d_bytes"] += pool.sync()
            if self._fill_target:
                # fill steps feed prompt tokens, not the sampled ids
                self._tokens_dirty = True
        bucket = pool.cur_bucket
        by_slot = {r.slot: r for r in sched.running.values()}
        if self.kv_layout == "paged":
            # recordability is decided on PRE-step lengths: the step feeding
            # the last prompt token produces the first real sample
            eligible = {
                slot: (self._fill_target.get(req.req_id) is None
                       or pool.host_len[slot]
                       >= self._fill_target[req.req_id] - 1)
                for slot, req in by_slot.items()}
        if self.decode_loop == "device":
            next_tokens = self._step_device(bucket, by_slot)
        else:
            next_tokens = self._step_host(bucket, by_slot)
        self.decode_steps += 1
        if self.kv_layout == "paged":
            pool.note_step()  # host mirror of the in-graph lengths + 1
            for slot, req in by_slot.items():
                tgt = self._fill_target.get(req.req_id)
                if tgt is not None and pool.host_len[slot] >= tgt:
                    # fill finished: publish the prompt's full blocks to the
                    # radix tree for later requests to hit
                    pool.commit_prefix(slot, req.prompt)
                    del self._fill_target[req.req_id]
            pairs = [(req, int(next_tokens[slot]))
                     for slot, req in by_slot.items() if eligible[slot]]
        else:
            pairs = [(req, int(next_tokens[slot]))
                     for slot, req in by_slot.items()]
        self._finish_step(pairs)
        return n

    def _finish_step(self, pairs):
        """Batched host readback bookkeeping: record the sampled ids,
        complete/compact finished requests, invalidate device token state
        when slots moved."""
        sched = self.scheduler
        finished = sched.record_step(
            pairs, eos_token=self.eos_token, max_total_len=self.max_seq - 1)
        for req in finished:
            sched.complete(req)
            self.pool.release(req.slot)
            # compaction may have moved another request into this slot
            moved_id = self.pool.slots[req.slot] if req.slot < len(self.pool.slots) else None
            if moved_id is not None and moved_id in sched.running:
                sched.running[moved_id].slot = req.slot
            req.slot = None
        if finished:
            # release/compaction/shrink reshuffled rows under the sampled ids
            self._tokens_dirty = True

    def run_until_drained(self, max_steps: int = 10000) -> int:
        steps = 0
        while self.scheduler.pending and steps < max_steps:
            self.step()
            steps += 1
        return steps

    # ---- live migration (Fleet.reshard cutover, prefill->decode handoff) ---
    def export_requests(self, reqs: List[Request], *,
                        release: bool = False) -> RowBundle:
        """Detach specific RUNNING requests with their KV rows for migration
        to another engine. The requests leave WAITING with no slot — in
        flight between engines; fill progress travels as the exported row's
        length (the adopter re-derives its own fill target from it).

        ``release=False`` leaves the pool slots occupied — for callers that
        strip a replica about to be retired (reshard cutover, salvage), where
        releasing would only churn the doomed pool. ``release=True`` is the
        per-request handoff path (docs/architecture.md §14): this engine
        keeps serving, so the slots must go back to the pool. Slots are
        released highest-first — ``release`` compacts the max active row
        into the hole, and under that order the moved row always belongs to
        a still-running request, so its slot fixup can land."""
        sched = self.scheduler
        for r in reqs:
            if r.slot is None or sched.running.get(r.req_id) is not r:
                raise ValueError(f"export of request {r.req_id}: not running "
                                 f"with a slot on this engine")
        bundle = self.pool.export_rows([r.slot for r in reqs])
        slots = []
        for r in reqs:
            sched.running.pop(r.req_id, None)
            self._fill_target.pop(r.req_id, None)
            slots.append(r.slot)
            r.slot = None
            r.state = ReqState.WAITING
        if release:
            for s in sorted(slots, reverse=True):
                self.pool.release(s)
                moved_id = (self.pool.slots[s]
                            if s < len(self.pool.slots) else None)
                if moved_id is not None and moved_id in sched.running:
                    sched.running[moved_id].slot = s
        self._tokens_dirty = True
        return bundle

    def export_inflight(self):
        """Detach this engine's whole in-flight population for migration to
        another engine (possibly on a different mesh): every RUNNING request
        with its KV rows, plus the queued-but-not-admitted requests. Returns
        ``(running, bundle, queued)`` where ``bundle`` is a ``RowBundle``
        aligned with ``running`` (None when nothing was running). Slots stay
        occupied — every caller retires this engine afterwards."""
        running = [r for r in self.scheduler.running.values()
                   if r.slot is not None]
        bundle = self.export_requests(running) if running else None
        # anything admitted but slotless (mid-failure) rides with the queue
        stragglers = list(self.scheduler.running.values())
        for r in stragglers:
            self.scheduler.running.pop(r.req_id, None)
            r.state = ReqState.WAITING
        queued = stragglers + list(self.scheduler.queue)
        self.scheduler.queue.clear()
        self._tokens_dirty = True
        return running, bundle, queued

    def adopt_inflight(self, reqs: List[Request],
                       bundle: Optional[RowBundle]) -> int:
        """Adopt migrated requests together with their exported KV rows from
        a foreign pool: rows are resharded onto this pool's cache specs
        (``KVCachePool.import_rows``) and decode continues from the migrated
        state — token streams stay byte-identical across the move. Adopts as
        many requests as this engine has free capacity for and returns the
        count; the caller re-routes the remainder (with
        ``bundle.select(range(n, bundle.n))``)."""
        if not reqs:
            return 0
        if bundle is None or bundle.n != len(reqs):
            raise ValueError("adopt_inflight needs one bundle row per request")
        n_fit = min(len(reqs), self.max_batch - self.pool.n_active)
        if n_fit <= 0:
            return 0
        # before the pool import touches anything: a poisoned import raises
        # with the target pool unmutated, so the caller (cutover/salvage)
        # can exclude this engine and route the requests elsewhere
        fault_point("kv.import_rows", tag=self.fault_tag)
        take = reqs[:n_fit]
        slots = self.pool.import_rows(bundle.select(range(n_fit)),
                                      [r.req_id for r in take])
        for r, s in zip(take, slots):
            r.slot = s
            r.state = ReqState.RUNNING
            self.scheduler.running[r.req_id] = r
            if self.kv_layout == "paged":
                # re-derive fill state from the migrated row length: a row
                # short of prompt+prefix resumes its decode-fill here (a
                # steady row degenerates to a one-step-left fill, which is
                # exactly the steady-state feeding rule)
                tot = len(r.prompt) + len(r.generated)
                if self.pool.host_len[s] < tot:
                    self._fill_target[r.req_id] = tot
        self._tokens_dirty = True
        return n_fit

    # ---- fault tolerance ---------------------------------------------------
    def simulate_worker_failure(self):
        """Drop all running requests (worker died): re-queue with prefix kept,
        reset the pool (fresh replacement worker)."""
        for req in list(self.scheduler.running.values()):
            self.scheduler.requeue_on_failure(req)
        self._init_pool()

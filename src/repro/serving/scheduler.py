"""Request scheduler: continuous batching with failure re-queue.

Deliberately engine-agnostic: the engine asks for admissions each step and
reports completions/failures. Fault tolerance: a request whose step failed
(worker died, slot evicted) returns to the front of the queue with its
already-generated prefix intact (decode restarts from the kept tokens).
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from enum import Enum
from typing import Deque, Dict, List, Optional

from repro.obs import metrics as obs_metrics

# docs/architecture.md §13 has the full metric catalog
_M_ADMITTED = obs_metrics.counter(
    "sched_admissions_total", "Requests admitted to a decode slot.")
_M_REJECTS = obs_metrics.counter(
    "sched_rejects_total", "Requests terminally rejected.")
_M_DEFERS = obs_metrics.counter(
    "sched_defers_total",
    "Admission-time resource deferrals (request returns to queue front).")
_M_REQUEUES = obs_metrics.counter(
    "sched_requeues_total",
    "Worker-failure requeues with generated prefix kept.")
_M_QUEUE_WAIT = obs_metrics.histogram(
    "serving_queue_wait_seconds",
    "Arrival -> first admission wait (the queueing share of TTFT).")
_M_TTFT = obs_metrics.histogram(
    "serving_ttft_seconds", "Arrival -> first generated token.")


class ReqState(Enum):
    WAITING = "waiting"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"


@dataclass
class Request:
    req_id: int
    prompt: List[int]
    max_new_tokens: int
    arrival_t: float = field(default_factory=time.perf_counter)
    generated: List[int] = field(default_factory=list)
    state: ReqState = ReqState.WAITING
    slot: Optional[int] = None
    first_token_t: Optional[float] = None
    admitted_t: Optional[float] = None
    done_t: Optional[float] = None
    retries: int = 0
    fail_reason: Optional[str] = None
    # phase-disaggregated serving (docs/architecture.md §14): which pool the
    # request currently belongs to ("serve" in colocated fleets, else
    # "prefill" -> "decode"), plus per-phase queue timestamps and the
    # prefill->decode handoff interval
    phase: str = "serve"
    phase_enqueued_t: Dict[str, float] = field(default_factory=dict)
    phase_admitted_t: Dict[str, float] = field(default_factory=dict)
    handoff_export_t: Optional[float] = None
    handoff_done_t: Optional[float] = None

    @property
    def finished(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    @property
    def ttft(self) -> Optional[float]:
        # `is not None`, not truthiness: perf_counter() can legitimately be
        # 0.0 (monotonic epoch is unspecified), and summaries must not drop
        # a request whose first token landed exactly there
        return (self.first_token_t - self.arrival_t
                if self.first_token_t is not None else None)

    @property
    def queue_wait_s(self) -> Optional[float]:
        """Arrival -> FIRST admission. TTFT bundles queueing + cold start +
        prefill; this isolates the queueing share (a deferred/requeued
        request keeps its first admission time — later waits are failure
        recovery, not arrival queueing)."""
        return (self.admitted_t - self.arrival_t
                if self.admitted_t is not None else None)

    @property
    def handoff_wait_s(self) -> Optional[float]:
        """Prefill-exit -> decode-adopt interval: how long the finished fill
        sat in flight (or requeued) before a decode replica owned it. None
        for colocated requests and for handoffs still in flight."""
        return (self.handoff_done_t - self.handoff_export_t
                if self.handoff_export_t is not None
                and self.handoff_done_t is not None else None)

    @property
    def queue_wait_by_phase(self) -> Dict[str, float]:
        """Per-phase enqueue -> admission waits (phases still queued are
        omitted). ``queue_wait_s`` keeps its arrival -> FIRST admission
        meaning; this breaks the later phases out separately."""
        return {ph: self.phase_admitted_t[ph] - t0
                for ph, t0 in self.phase_enqueued_t.items()
                if ph in self.phase_admitted_t}


class Scheduler:
    def __init__(self, max_retries: int = 2):
        self._ids = itertools.count()
        self.queue: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}
        self.done: List[Request] = []
        self.failed: List[Request] = []
        self.max_retries = max_retries

    def submit(self, prompt: List[int], max_new_tokens: int) -> Request:
        r = Request(next(self._ids), list(prompt), max_new_tokens)
        self.queue.append(r)
        return r

    def admissions(self, free_capacity: int) -> List[Request]:
        out = []
        while self.queue and len(out) < free_capacity:
            r = self.queue.popleft()
            r.state = ReqState.RUNNING
            now = time.perf_counter()
            if r.admitted_t is None:  # first admission only (queue_wait_s)
                r.admitted_t = now
                if obs_metrics.enabled():
                    _M_ADMITTED.inc()
                    _M_QUEUE_WAIT.observe(r.queue_wait_s)
            # phase-aware bookkeeping: first admission per phase, and a
            # requeued handoff completes when the decode pool re-admits it
            r.phase_admitted_t.setdefault(r.phase, now)
            if r.handoff_export_t is not None and r.handoff_done_t is None:
                r.handoff_done_t = now
            self.running[r.req_id] = r
            out.append(r)
        return out

    def record_token(self, req: Request, token: int):
        if req.first_token_t is None:
            req.first_token_t = time.perf_counter()
            if obs_metrics.enabled():
                _M_TTFT.observe(req.first_token_t - req.arrival_t)
        req.generated.append(token)

    def record_step(self, req_tokens, *, eos_token: Optional[int] = None,
                    max_total_len: Optional[int] = None) -> List[Request]:
        """Batched per-step readback: record one sampled token for every
        running request of a decode step and return the ones that finished
        (max_new_tokens reached, EOS hit, or prompt+generated at
        ``max_total_len``). The engine calls this once per step with the
        O(B) id vector it read back from the device."""
        finished = []
        for req, token in req_tokens:
            self.record_token(req, token)
            hit_eos = eos_token is not None and token == eos_token
            full = (max_total_len is not None
                    and len(req.prompt) + len(req.generated) >= max_total_len)
            if req.finished or hit_eos or full:
                finished.append(req)
        return finished

    def complete(self, req: Request):
        req.state = ReqState.DONE
        req.done_t = time.perf_counter()
        self.running.pop(req.req_id, None)
        self.done.append(req)

    def reject(self, req: Request, reason: str):
        """Fail a request the engine cannot serve (e.g. prompt longer than
        the engine's max_seq). Terminal: no retry, no slot, caller sees
        state FAILED + fail_reason instead of a request wedged in running."""
        self.running.pop(req.req_id, None)
        req.state = ReqState.FAILED
        req.fail_reason = reason
        req.done_t = time.perf_counter()
        req.slot = None
        self.failed.append(req)
        _M_REJECTS.inc()

    def defer(self, req: Request):
        """Return a request to the queue front with prefix intact: an
        admission-time (or preemption) *resource* shortfall — e.g. the KV
        block budget — not a worker failure, so no retry penalty accrues.
        The engine re-attempts it next step once capacity frees up."""
        self.running.pop(req.req_id, None)
        req.state = ReqState.WAITING
        req.slot = None
        self.queue.appendleft(req)
        _M_DEFERS.inc()

    def requeue_on_failure(self, req: Request):
        """Worker failure path: keep generated prefix, retry at queue front.
        The terminal branch is a real completion: it must set ``fail_reason``
        and ``done_t`` exactly like ``reject`` does, or fleet/router latency
        summaries see a FAILED request with ``done_t=None``."""
        self.running.pop(req.req_id, None)
        req.retries += 1
        req.slot = None
        if req.retries > self.max_retries:
            req.state = ReqState.FAILED
            req.fail_reason = (f"retries exhausted after {req.retries} "
                               f"worker failures (max_retries="
                               f"{self.max_retries})")
            req.done_t = time.perf_counter()
            self.failed.append(req)
            _M_REJECTS.inc()
            return
        req.state = ReqState.WAITING
        self.queue.appendleft(req)
        _M_REQUEUES.inc()

    @property
    def pending(self) -> int:
        return len(self.queue) + len(self.running)

"""Phase-aware replica pool: the unit a Fleet composes (docs §14).

Extracted from the fleet monolith so "a fleet" can be "a set of pools":
everything that manages ONE homogeneous group of replicas lives here —
provisioning (``Replica`` daemon threads + deadline supervision), dispatch
(least-loaded over the pool's own backlog), autoscaling
(``AutoscalePolicy``), crash containment with KV salvage, and the live
reshard state machine (SERVING -> DUAL -> CUTOVER -> DRAINED). A colocated
fleet is one pool of phase "serve"; a phase-disaggregated fleet is a
"prefill" pool on a wide mesh plus a "decode" pool on a narrow one, sharing
one archive and handing requests off per-request (the fleet owns the
handoff — it is the only cross-pool motion besides crash salvage).

What a pool deliberately does NOT own: the shared archive and cold-start
mode (the fleet's ``cold_start`` callable closes over them), request
identity/admission-shed bookkeeping, and cross-pool salvage targeting (the
``salvage_targets`` callable lets a fleet offer OTHER pools' replicas as
adopters, so a crashed prefill replica's mid-fill rows can land on the
decode pool).

Each pool records its own decode-step wall times (``step_walls``): in the
cooperative single-threaded tick loop this is the honest per-pool TPOT
proxy — the decode pool's step cost is what dedicated decode hardware would
see, independent of how long the prefill pool's fills run on the same
thread (benchmarks/fig19_disagg.py).
"""
from __future__ import annotations

import itertools
import logging
import math
import threading
import time
from collections import deque
from contextlib import nullcontext
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.core import wait_for_background
from repro.launch.mesh import describe_mesh, resolve_mesh
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving.engine import ServingEngine
from repro.serving.faults import fault_point
from repro.serving.scheduler import Request

log = logging.getLogger("repro.serving.pool")

# docs/architecture.md §13 has the full metric catalog
_M_REPLICA_EVENTS = obs_metrics.counter(
    "fleet_replica_events_total",
    "Replica lifecycle transitions (spawn/ready/failed/crashed/respawn/"
    "stopped).", ("event",))
_M_CRASHES = obs_metrics.counter(
    "fleet_crashes_total", "Mid-serving replica crashes contained by "
    "supervision.")
_M_RESPAWNS = obs_metrics.counter(
    "fleet_respawns_total", "Replacement replicas spawned after crashes.")
_M_SALVAGED = obs_metrics.counter(
    "fleet_salvaged_requests_total",
    "In-flight requests whose KV rows migrated off a crashed replica.")
_M_CRASH_REQUEUED = obs_metrics.counter(
    "fleet_crash_requeued_requests_total",
    "Requests retried from kept prefixes after a crash (no KV carried).")
_M_RESHARDS = obs_metrics.counter(
    "fleet_reshard_total", "Parallelism switches by outcome.", ("outcome",))
_M_BACKLOG = obs_metrics.gauge(
    "fleet_backlog_depth", "Per-pool queued requests (not yet dispatched "
    "to a replica).", ("fleet", "pool"))
_M_READY = obs_metrics.gauge(
    "fleet_replicas_ready", "READY replicas per pool.", ("fleet", "pool"))
_M_INFLIGHT = obs_metrics.gauge(
    "fleet_inflight", "Per-pool backlog + replica queued/running load (the "
    "autoscale signal).", ("fleet", "pool"))
_M_DEGRADED = obs_metrics.gauge(
    "fleet_degraded", "1 while a pool's READY replicas < policy.min_replicas "
    "after having reached the floor once.", ("fleet", "pool"))


class ReplicaState(Enum):
    PROVISIONING = "provisioning"   # cold-start thread running
    READY = "ready"                 # serving
    STOPPED = "stopped"             # scaled down
    FAILED = "failed"               # cold start raised / provision timed out
    CRASHED = "crashed"             # died MID-SERVING; salvaged + replaced


@dataclass
class ReplicaStats:
    """Lifecycle timeline of one replica (all times perf_counter seconds)."""
    replica_id: int
    spawned_t: float
    ready_t: Optional[float] = None
    first_token_t: Optional[float] = None
    stopped_t: Optional[float] = None
    mode: Optional[str] = None            # cold-start path actually taken
    cold_start_s: Optional[float] = None  # engine cold-start phase total
    fallback_compiles: int = 0
    background_errors: int = 0
    steps: int = 0
    served_requests: int = 0
    error: Optional[str] = None

    @property
    def provision_s(self) -> Optional[float]:
        """Spawn -> servable (engine build + weights + cold start)."""
        return None if self.ready_t is None else self.ready_t - self.spawned_t

    @property
    def cold_start_to_first_token_s(self) -> Optional[float]:
        """Spawn -> first token out of this replica: the scale-out latency a
        user stuck in the queue actually experiences."""
        return (None if self.first_token_t is None
                else self.first_token_t - self.spawned_t)


class Replica:
    """One ServingEngine behind a pool's queue.

    Provisioning (engine build + cold start) runs on a daemon thread so
    replicas come up while traffic is in flight; decode steps run on the
    fleet's thread via ``step()``.
    """

    def __init__(self, rid: int, engine_factory: Callable[[], ServingEngine],
                 cold_start: Callable[[ServingEngine], object], mesh=None,
                 deadline_s: Optional[float] = None):
        self.stats = ReplicaStats(rid, spawned_t=time.perf_counter())
        self.state = ReplicaState.PROVISIONING
        self.engine: Optional[ServingEngine] = None
        self.cold_report = None
        self.idle_ticks = 0
        # set by ReplicaPool.abort_reshard on a replica it could not join: an
        # engine the provisioning thread attaches later must be dropped,
        # not served or accounted (poll() reaps it on the next tick)
        self.discard_engine = False
        self._engine_factory = engine_factory
        self._cold_start = cold_start
        self._mesh = mesh
        self._deadline_s = deadline_s
        self._error: Optional[str] = None
        _M_REPLICA_EVENTS.inc(event="spawn")
        obs_trace.instant("replica.spawn", cat="fleet", replica=rid)
        self._thread = threading.Thread(target=self._provision, daemon=True)
        self._thread.start()

    def _ctx(self):
        return self._mesh if self._mesh is not None else nullcontext()

    def _provision(self):
        try:
            with self._ctx():
                eng = self._engine_factory()
                t0 = time.perf_counter()
                rep = self._cold_start(eng)
            self.cold_report = rep
            self.stats.mode = getattr(rep, "mode", None)
            self.stats.cold_start_s = getattr(
                rep, "total_s", time.perf_counter() - t0)
            self.stats.fallback_compiles = getattr(rep, "fallback_compiles", 0)
            self.engine = eng
        except Exception as e:  # surfaced via ReplicaState.FAILED
            self._error = f"{type(e).__name__}: {e}"

    def poll(self) -> ReplicaState:
        """Advance PROVISIONING -> READY/FAILED when the thread finishes.
        A provision past its deadline (hung IO, wedged compile) is FAILED
        in place — the caller can respawn — and its engine, should the
        thread eventually attach one, is reaped like an aborted reshard's."""
        if self.discard_engine and self.engine is not None:
            self.engine = None  # late attach after abort/timeout/crash
        if self.state is ReplicaState.PROVISIONING and self._thread.is_alive():
            if (self._deadline_s is not None
                    and time.perf_counter() - self.stats.spawned_t
                    > self._deadline_s):
                self.state = ReplicaState.FAILED
                self.stats.error = (f"provision deadline exceeded "
                                    f"({self._deadline_s:.1f}s; thread "
                                    f"still running)")
                self.discard_engine = True
                _M_REPLICA_EVENTS.inc(event="failed")
        if self.state is ReplicaState.PROVISIONING and not self._thread.is_alive():
            if self._error is not None or self.engine is None:
                self.state = ReplicaState.FAILED
                self.stats.error = self._error or "cold start produced no engine"
                _M_REPLICA_EVENTS.inc(event="failed")
            else:
                self.state = ReplicaState.READY
                self.stats.ready_t = time.perf_counter()
                # stamp the fault-injection identity so chaos plans can
                # target this replica (serving/faults.py)
                self.engine.fault_tag = f"replica{self.stats.replica_id}"
                _M_REPLICA_EVENTS.inc(event="ready")
                # provision_s as a span on the fleet timeline: spawn->READY
                obs_trace.complete(
                    "replica.provision", "fleet", self.stats.spawned_t,
                    self.stats.ready_t, replica=self.stats.replica_id,
                    mode=self.stats.mode or "?")
        return self.state

    @property
    def load(self) -> int:
        """Requests this replica still owns (queued + running)."""
        return 0 if self.engine is None else self.engine.scheduler.pending

    def assign(self, req: Request):
        self.engine.scheduler.queue.append(req)

    def step(self) -> int:
        with self._ctx():
            n = self.engine.step()
        self.stats.steps += 1
        self.stats.served_requests = len(self.engine.scheduler.done)
        if self.stats.first_token_t is None:
            # only tokens emitted by THIS replica count: a request migrated
            # in by a reshard cutover carries a first_token_t from the old
            # generation, which predates this replica's spawn
            firsts = [r.first_token_t
                      for r in self.engine.scheduler.running.values()
                      if r.first_token_t is not None
                      and r.first_token_t >= self.stats.spawned_t]
            firsts += [r.first_token_t for r in self.engine.scheduler.done
                       if r.first_token_t is not None
                       and r.first_token_t >= self.stats.spawned_t]
            if firsts:
                self.stats.first_token_t = min(firsts)
        self.idle_ticks = self.idle_ticks + 1 if self.load == 0 else 0
        return n

    def stop(self):
        self.state = ReplicaState.STOPPED
        self.stats.stopped_t = time.perf_counter()
        _M_REPLICA_EVENTS.inc(event="stopped")

    def crash(self, reason: str):
        """Mark this replica dead MID-SERVING (pool supervision): distinct
        from FAILED (never came up) so reports can tell a cold-start problem
        from a serving-time one. The pool salvages the engine's in-flight
        population before releasing it."""
        self.state = ReplicaState.CRASHED
        self.stats.error = reason
        self.stats.stopped_t = time.perf_counter()
        _M_REPLICA_EVENTS.inc(event="crashed")
        obs_trace.instant("replica.crash", cat="fleet",
                          replica=self.stats.replica_id, reason=reason)

    def join_provision(self, timeout: float = 120.0) -> ReplicaState:
        """Wait for an in-flight provision to finish and resolve the state.
        Stopping a PROVISIONING replica without this races the daemon
        thread, which would re-attach the freshly built engine (and its KV
        pool) to the stopped replica after the caller released it.

        A thread STILL alive after ``timeout`` resolves to FAILED with a
        distinct timeout error (callers respawn on it) instead of leaving
        the replica looking PROVISIONING forever; the wedged thread's
        eventual engine attach is reaped by ``poll()``."""
        self._thread.join(timeout)
        if self._thread.is_alive() and self.state is ReplicaState.PROVISIONING:
            self.state = ReplicaState.FAILED
            self.stats.error = (f"provision join timed out after "
                                f"{timeout:.1f}s (thread still running)")
            self.discard_engine = True
            return self.state
        return self.poll()

    def drain_background(self, timeout: float = 300.0):
        """Join the engine LOAD's background exact-bucket workers and copy
        their error count into the stats (tests assert it is 0)."""
        rep = getattr(self.engine, "_load_report", None)
        if rep is not None:
            wait_for_background(rep, timeout)
            self.stats.background_errors = rep.background_errors


@dataclass
class AutoscalePolicy:
    min_replicas: int = 1
    max_replicas: int = 4
    # inflight requests one replica is expected to absorb before the pool
    # scales; engines can batch max_batch of them per step
    target_inflight_per_replica: int = 8
    scale_down_idle_ticks: int = 25
    # provisioning failures after which the pool stops respawning (a
    # systematically failing cold start — bad archive, broken factory —
    # must fail fast, not spawn replicas forever)
    max_spawn_failures: int = 3
    # mid-serving crash budget, the serving-time analogue of
    # max_spawn_failures: more than this many CRASHED replicas inside a
    # sliding crash_window_s means the pool is crash-looping (poisoned
    # archive, broken kernel) and must stop respawning and degrade
    max_crashes_in_window: int = 5
    crash_window_s: float = 60.0
    # wall-clock deadline for one replica provision (None: wait forever —
    # the pre-supervision behavior); a hung cold start past it is FAILED by
    # poll() so the autoscaler/supervisor can respawn instead of blocking
    provision_deadline_s: Optional[float] = None


@dataclass
class PoolSpec:
    """Declarative description of one pool in a fleet: phase name
    ("prefill" | "decode" | "serve"), its autoscale policy, and the mesh its
    replicas provision on (a Mesh, ``launch.mesh.MeshSpec``, or None for
    un-meshed single-process)."""
    phase: str
    policy: AutoscalePolicy = field(default_factory=AutoscalePolicy)
    mesh: object = None


@dataclass
class ReshardReport:
    """Timeline + accounting of one parallelism switch
    (``ReplicaPool.reshard`` / ``Fleet.reshard``).

    All times are perf_counter seconds. ``cutover_t``/``drained_t`` stay
    None until the corresponding transition happens; ``aborted`` carries the
    reason when the switch could not complete (the old generation keeps
    serving on a "live" abort).
    """
    strategy: str               # "live" | "restart"
    from_mesh: str
    to_mesh: str
    started_t: float
    new_replicas: int = 0
    cutover_t: Optional[float] = None
    drained_t: Optional[float] = None
    dual_ticks: int = 0          # ticks the two generations coexisted
                                 # (live only; stays 0 for "restart")
    migrated_requests: int = 0   # in-flight KV rows moved across meshes
    requeued_requests: int = 0   # retried from kept prefix (no KV carried)
    released_replicas: int = 0
    aborted: Optional[str] = None
    pool: str = "serve"          # which pool switched (phase name)

    @property
    def done(self) -> bool:
        return self.drained_t is not None or self.aborted is not None

    @property
    def time_to_new_topology_s(self) -> Optional[float]:
        """reshard() call -> old generation fully drained and released."""
        return (None if self.drained_t is None
                else self.drained_t - self.started_t)

    def summary(self) -> Dict[str, object]:
        return {
            "strategy": self.strategy,
            "pool": self.pool,
            "from_mesh": self.from_mesh, "to_mesh": self.to_mesh,
            "time_to_new_topology_s": self.time_to_new_topology_s,
            "dual_ticks": self.dual_ticks,
            "migrated_requests": self.migrated_requests,
            "requeued_requests": self.requeued_requests,
            "new_replicas": self.new_replicas,
            "released_replicas": self.released_replicas,
            "aborted": self.aborted,
        }


@dataclass
class _ReshardOp:
    """In-flight reshard state (one at a time per pool)."""
    new_mesh: object
    factory: Callable[[], ServingEngine]
    strategy: str
    report: ReshardReport
    old: List[Replica] = field(default_factory=list)
    new: List[Replica] = field(default_factory=list)
    deferrals: int = 0  # cutover holds (see ReplicaPool.advance_reshard)


class ReplicaPool:
    """One phase's replicas behind one backlog (module docstring).

    The composing fleet supplies the shared pieces as callables:
    ``cold_start(engine, warm=False)`` (closes over mode + the shared
    archive), ``respawn_cold_start(engine)`` (the verify-degrade rung; None
    falls back to a plain cold start), ``salvage_targets(crashed)`` (adopter
    candidates, possibly from OTHER pools; None restricts salvage to this
    pool), ``tick_fn()`` (ticks the whole fleet so a blocking
    ``reshard(wait=True)`` keeps every pool serving; None runs a pool-local
    tick), and ``rid_source`` (a shared ``itertools.count`` so replica ids
    stay unique fleet-wide).
    """

    def __init__(self, phase: str, *,
                 policy: Optional[AutoscalePolicy] = None, mesh=None,
                 engine_factory: Optional[Callable[[], ServingEngine]] = None,
                 factory_for_mesh: Optional[Callable] = None,
                 cold_start: Callable = None,
                 respawn_cold_start: Optional[Callable] = None,
                 salvage_targets: Optional[Callable] = None,
                 tick_fn: Optional[Callable[[], int]] = None,
                 rid_source=None, fleet_name: str = "fleet"):
        if engine_factory is None and factory_for_mesh is None:
            raise ValueError(
                "ReplicaPool needs engine_factory or factory_for_mesh")
        if cold_start is None:
            raise ValueError("ReplicaPool needs a cold_start callable")
        self.phase = phase
        self.policy = policy or AutoscalePolicy()
        self.mesh = resolve_mesh(mesh)
        self.engine_factory = engine_factory
        self.factory_for_mesh = factory_for_mesh
        self._cold_start = cold_start
        self._respawn_cold_start = respawn_cold_start
        self._salvage_targets_fn = salvage_targets
        self._tick_fn = tick_fn
        self._rids = rid_source if rid_source is not None else itertools.count()
        self.fleet_name = fleet_name
        self.label = f"{fleet_name}/{phase}"
        self.replicas: List[Replica] = []
        self.backlog: Deque[Request] = deque()
        self.spawn_failures = 0
        # set True (router ReshardPolicy.prefer_reshard_over_scale_out) when
        # the answer to sustained load is a bigger mesh, not more replicas
        self.suppress_scale_out = False
        self.reshard_reports: List[ReshardReport] = []
        self._reshard: Optional[_ReshardOp] = None
        # supervision state (docs/architecture.md §12): crash accounting,
        # the sliding-window crash budget, floor tracking
        self.crashes = 0
        self.respawns = 0
        self.salvaged_requests = 0
        self.crash_requeued_requests = 0
        self.degraded_ticks = 0
        self.crash_budget_exhausted = False
        self._crash_times: Deque[float] = deque()
        self._was_at_floor = False  # degradation = DROPPING below the floor
        self._tick = 0
        # per-pool decode-step wall times (the fig19 TPOT proxy); capped so
        # a long soak cannot grow without bound
        self.step_walls: List[float] = []
        self._step_walls_cap = 65536

    # -- membership ------------------------------------------------------
    def _alive(self) -> List[Replica]:
        return [r for r in self.replicas
                if r.state in (ReplicaState.PROVISIONING, ReplicaState.READY)]

    def _ready(self) -> List[Replica]:
        return [r for r in self.replicas if r.state is ReplicaState.READY]

    def _factory_for(self, mesh) -> Callable[[], ServingEngine]:
        """Zero-arg factory for one replica, with the mesh snapshotted at
        spawn time (a reshard may flip ``self.mesh`` while a provisioning
        thread is still running)."""
        if self.factory_for_mesh is not None:
            return lambda fm=self.factory_for_mesh, m=mesh: fm(m)
        return self.engine_factory

    def scale_up(self, n: int = 1) -> List[Replica]:
        out = []
        for _ in range(n):
            mesh = self.mesh
            r = Replica(next(self._rids), self._factory_for(mesh),
                        self._cold_start, mesh=mesh,
                        deadline_s=self.policy.provision_deadline_s)
            self.replicas.append(r)
            out.append(r)
            log.info("+replica %d (%s, tick %d)",
                     r.stats.replica_id, self.label, self._tick)
        return out

    def _can_spawn(self) -> bool:
        return (self.spawn_failures < self.policy.max_spawn_failures
                and not self.crash_budget_exhausted)

    def _respawn(self, n: int = 1) -> List[Replica]:
        """Replace crashed capacity: same path as ``scale_up`` but through
        the fleet-supplied respawn cold start — warm for foundry fleets (the
        shared archive's blobs are already fetched and ``_template_cache``
        is hot, so the replacement comes up at warm-LOAD speed: the paper's
        pitch applied to crash recovery, not just scale-out)."""
        out = []
        for _ in range(n):
            mesh = self.mesh
            cold = self._respawn_cold_start or self._cold_start
            r = Replica(next(self._rids), self._factory_for(mesh),
                        cold, mesh=mesh,
                        deadline_s=self.policy.provision_deadline_s)
            self.replicas.append(r)
            out.append(r)
            self.respawns += 1
            _M_RESPAWNS.inc()
            _M_REPLICA_EVENTS.inc(event="respawn")
            log.info("+replica %d (%s respawn after crash, tick %d)",
                     r.stats.replica_id, self.label, self._tick)
        return out

    def spawn_floor(self):
        """Bring the pool up to the policy floor (idempotent)."""
        missing = self.policy.min_replicas - len(self._alive())
        if missing > 0 and self._can_spawn():
            self.scale_up(missing)

    # -- degradation ladder (docs/architecture.md §12) -------------------
    @property
    def degraded(self) -> bool:
        """Below the autoscale floor after having reached it once: fewer
        READY replicas than ``policy.min_replicas``. (The initial
        provisioning ramp is not degradation — nothing was lost.)"""
        return (self._was_at_floor
                and len(self._ready()) < self.policy.min_replicas)

    def sheds_load(self) -> bool:
        """Terminal incapacity: degraded, nothing provisioning, and the
        spawn/crash budgets forbid respawning — capacity is NOT coming back,
        so new load is shed cheaply at admission instead of queueing
        forever. A degraded pool with a respawn in flight keeps queueing
        (recovery is ~a warm LOAD away — the whole point of foundry)."""
        return (self.degraded and not self._can_spawn()
                and not any(r.state is ReplicaState.PROVISIONING
                            for r in self.replicas))

    def note_floor(self):
        """End-of-tick floor accounting: remember having reached the floor
        once, count ticks spent below it afterwards."""
        if len(self._ready()) >= self.policy.min_replicas:
            self._was_at_floor = True
        elif self._was_at_floor:
            self.degraded_ticks += 1

    # -- traffic ---------------------------------------------------------
    def dispatch(self):
        """Drain the pool backlog onto READY replicas, least-loaded first,
        never queueing more than one batch-worth ahead per replica. During a
        live reshard's DUAL phase the replacement generation is NOT a
        dispatch target: the queue flips to it atomically at cutover, and
        routing work there early would leave the cutover nothing to
        migrate."""
        ready = self._ready()
        if self._reshard is not None and self._reshard.strategy == "live":
            pending_new = {id(r) for r in self._reshard.new}
            ready = [r for r in ready if id(r) not in pending_new]
        while self.backlog and ready:
            ready.sort(key=lambda r: r.load)
            tgt = ready[0]
            if tgt.load >= tgt.engine.max_batch:
                break  # everyone is saturated; leave work visible on backlog
            tgt.assign(self.backlog.popleft())

    def inflight(self) -> int:
        """Requests the pool currently owes: backlog + every READY
        replica's queued/running load (the autoscale and router reshard
        trigger signal)."""
        return len(self.backlog) + sum(r.load for r in self._ready())

    def adoption_target(self, exclude=()) -> Optional[Replica]:
        """Least-loaded READY replica with free pool capacity — the
        destination of a prefill->decode handoff. A live reshard's pending
        new generation is excluded (same reason ``dispatch`` skips it)."""
        skip = {id(t) for t in exclude}
        if self._reshard is not None and self._reshard.strategy == "live":
            skip |= {id(t) for t in self._reshard.new}
        cands = [t for t in self._ready()
                 if t.engine is not None and id(t) not in skip
                 and t.engine.max_batch - t.engine.pool.n_active > 0]
        return min(cands, key=lambda t: t.load) if cands else None

    def autoscale(self):
        pol = self.policy
        alive = self._alive()
        inflight = self.inflight()
        desired = max(pol.min_replicas,
                      math.ceil(inflight / max(1, pol.target_inflight_per_replica)))
        desired = min(pol.max_replicas, desired)
        if self.suppress_scale_out:
            desired = min(desired, max(pol.min_replicas, len(alive)))
        if desired > len(alive) and self._can_spawn():
            self.scale_up(desired - len(alive))
        elif not self.backlog and len(alive) > pol.min_replicas:
            # scale down at most one per tick: oldest idle replica first
            for r in self._ready():
                if r.load == 0 and r.idle_ticks >= pol.scale_down_idle_ticks:
                    r.stop()
                    log.info("-replica %d (%s idle %d ticks)",
                             r.stats.replica_id, self.label, r.idle_ticks)
                    break

    # -- serving ---------------------------------------------------------
    def poll_all(self):
        """Advance every provisioning thread and count provision failures
        toward the pool's spawn budget."""
        self._tick += 1
        for r in self.replicas:
            was = r.state
            if (r.poll() is ReplicaState.FAILED
                    and was is ReplicaState.PROVISIONING):
                self.spawn_failures += 1
                log.warning("replica %d FAILED to provision (%s: %d/%d "
                            "before giving up): %s", r.stats.replica_id,
                            self.label, self.spawn_failures,
                            self.policy.max_spawn_failures, r.stats.error)

    def step_all(self) -> int:
        """One supervised decode step per READY replica. A replica whose
        ``step()`` raises transitions to CRASHED and is salvaged + replaced
        (``_on_replica_crash``) WITHOUT unwinding the loop — one bad
        replica must not take the pool down with it. Non-idle step wall
        times feed ``step_walls`` (the per-pool TPOT proxy)."""
        served = 0
        for r in self._ready():
            t0 = time.perf_counter()
            try:
                n = r.step()
            except Exception as e:
                self._on_replica_crash(r, e)
                continue
            if n and len(self.step_walls) < self._step_walls_cap:
                self.step_walls.append(time.perf_counter() - t0)
            served += n
        return served

    def _self_tick(self) -> int:
        """Pool-local serving iteration for a standalone pool (a composing
        fleet passes ``tick_fn`` instead so EVERY pool keeps serving while
        this one blocks in ``reshard(wait=True)``)."""
        self.poll_all()
        if self._reshard is not None:
            self.advance_reshard()
        self.dispatch()
        if self._reshard is None:
            self.autoscale()
        return self.step_all()

    # -- supervision (docs/architecture.md §12) --------------------------
    def _on_replica_crash(self, r: Replica, exc: Exception):
        """A decode step raised: contain it. The replica transitions to
        CRASHED (the loop keeps serving everyone else), its in-flight
        requests are salvaged — KV rows migrated to surviving replicas when
        the engine is still coherent, requeued from kept prefixes otherwise
        — and a replacement is respawned from the shared archive unless the
        sliding-window crash budget says the pool is crash-looping."""
        self.crashes += 1
        _M_CRASHES.inc()
        now = time.perf_counter()
        self._crash_times.append(now)
        while (self._crash_times
               and now - self._crash_times[0] > self.policy.crash_window_s):
            self._crash_times.popleft()
        r.crash(f"{type(exc).__name__}: {exc}")
        migrated, requeued, failed = self._salvage(r)
        self.salvaged_requests += migrated
        self.crash_requeued_requests += requeued
        _M_SALVAGED.inc(migrated)
        _M_CRASH_REQUEUED.inc(requeued)
        log.warning("replica %d CRASHED (%s: %s): salvaged %d, requeued %d, "
                    "failed %d", r.stats.replica_id, self.label,
                    r.stats.error, migrated, requeued, failed)
        r.engine = None  # release weights + KV pool
        if len(self._crash_times) > self.policy.max_crashes_in_window:
            self.crash_budget_exhausted = True
            log.error("crash budget exhausted (%s: %d crashes inside %.0fs "
                      "> %d): pool stops respawning and degrades",
                      self.label, len(self._crash_times),
                      self.policy.crash_window_s,
                      self.policy.max_crashes_in_window)
            return
        if (self._reshard is None and self._can_spawn()
                and len(self._alive()) < self.policy.max_replicas):
            self._respawn(1)

    def _salvage_targets(self, crashed: Replica) -> List[Replica]:
        """Adopter candidates for a crashed replica's KV rows: the
        fleet-supplied cross-pool callable when present (a prefill crash can
        salvage onto the decode pool), else this pool's other READY
        replicas. A live reshard's pending new generation is excluded for
        the same reason ``dispatch`` skips it: it must stand empty until
        cutover."""
        if self._salvage_targets_fn is not None:
            return [t for t in self._salvage_targets_fn(crashed)
                    if t is not crashed and t.engine is not None]
        out = [t for t in self._ready()
               if t is not crashed and t.engine is not None]
        if self._reshard is not None and self._reshard.strategy == "live":
            pending_new = {id(t) for t in self._reshard.new}
            out = [t for t in out if id(t) not in pending_new]
        return out

    def _salvage(self, r: Replica) -> Tuple[int, int, int]:
        """Recover a crashed replica's in-flight population. Returns
        ``(migrated, requeued, failed)``.

        Fast path — the crash left the engine coherent (decode-step faults
        fire before any mutation): ``export_inflight`` pulls every running
        request's KV rows and they migrate into surviving replicas' pools
        exactly like a reshard cutover; overflow requeues with its prefix
        kept. Slow path — export itself raises (pool corrupt): every
        running request retries from its kept prefix through
        ``Scheduler.requeue_on_failure``, which charges one retry and
        terminally FAILs requests past ``max_retries``."""
        if r.engine is None:
            return 0, 0, 0
        eng = r.engine
        try:
            with r._ctx():
                reqs, bundle, queued = eng.export_inflight()
        except Exception as e:
            log.warning("export_inflight failed on crashed replica %d "
                        "(%s: %s); requeueing from kept prefixes",
                        r.stats.replica_id, type(e).__name__, e)
            return self._requeue_crashed(eng)
        for q in reversed(queued):
            self.backlog.appendleft(q)
        migrated = requeued = 0
        targets = self._salvage_targets(r)
        while reqs:
            cands = [t for t in targets
                     if t.engine.max_batch - t.engine.pool.n_active > 0]
            if not cands:
                for q in reversed(reqs):
                    self.backlog.appendleft(q)
                requeued += len(reqs)
                break
            tgt = min(cands, key=lambda t: t.load)
            try:
                with tgt._ctx():
                    k = tgt.engine.adopt_inflight(reqs, bundle)
            except Exception as e:
                log.warning("adopt_inflight into replica %d failed during "
                            "salvage (%s: %s); excluding it",
                            tgt.stats.replica_id, type(e).__name__, e)
                targets = [t for t in targets if t is not tgt]
                continue
            migrated += k
            reqs = reqs[k:]
            bundle = bundle.select(range(k, bundle.n)) if reqs else None
        return migrated, requeued, 0

    def _requeue_crashed(self, eng: ServingEngine) -> Tuple[int, int, int]:
        """Incoherent-engine salvage: no KV leaves the wreck. Running
        requests go through ``Scheduler.requeue_on_failure`` (kept prefix,
        one retry charged, terminal FAILED past the budget); the engine's
        local queue drains back onto the pool backlog untouched."""
        sched = eng.scheduler
        n_failed0 = len(sched.failed)
        requeued = 0
        for q in list(sched.running.values()):
            sched.requeue_on_failure(q)
        # requeue_on_failure pushes survivors onto the ENGINE queue; move
        # the whole local queue (survivors + never-started) to the pool
        for q in reversed(list(sched.queue)):
            self.backlog.appendleft(q)
            requeued += 1
        sched.queue.clear()
        failed = len(sched.failed) - n_failed0
        return 0, requeued, failed

    # -- live reshard (docs/architecture.md §8) --------------------------
    def reshard(self, new_mesh, *,
                factory: Optional[Callable[[], ServingEngine]] = None,
                n_replicas: Optional[int] = None, strategy: str = "live",
                warm: bool = True, wait: bool = False,
                wait_timeout_s: float = 600.0) -> ReshardReport:
        """Move this pool onto ``new_mesh`` (a Mesh, a
        ``launch.mesh.MeshSpec``, or None for un-meshed single-process).

        strategy="live": replacement replicas provision on the new topology
        — stamped-template LOAD of the same shared archive, ``warm`` by
        default — while the old generation keeps serving (DUAL); once every
        replacement resolves, the cutover migrates each in-flight request's
        KV rows from the old pools into the new mesh's pools
        (``ServingEngine.export_inflight`` / ``adopt_inflight``), flips the
        backlog, and drains + releases the old replicas. No request is
        dropped and no token diverges. In a multi-pool fleet the OTHER pools
        keep serving throughout — the switch is scoped to this pool.

        strategy="restart" is the drain-and-restart baseline fig15 measures
        against: the old topology is torn down FIRST (in-flight requests
        requeue with their generated prefixes, losing their KV rows) and
        the backlog stalls until the new topology provisions.

        The switch is asynchronous — ``advance_reshard`` (driven by the
        fleet tick) advances it — unless ``wait=True``, which ticks the
        fleet (still serving) until the switch completes. Returns the live
        ``ReshardReport``; a "live" switch whose every replacement replica
        fails to provision is aborted in place and the old generation keeps
        serving.
        """
        if self._reshard is not None:
            raise RuntimeError("a reshard is already in progress")
        if strategy not in ("live", "restart"):
            raise ValueError(f"unknown reshard strategy {strategy!r}")
        new_mesh = resolve_mesh(new_mesh)
        if factory is None:
            if self.factory_for_mesh is None:
                raise ValueError(
                    "reshard needs `factory` (zero-arg engine factory for "
                    "the new topology) or a pool-level factory_for_mesh")
            factory = (lambda fm=self.factory_for_mesh, m=new_mesh: fm(m))
        if not self.replicas:
            self.spawn_floor()
        n = n_replicas if n_replicas is not None else max(len(self._ready()), 1)
        n = max(1, min(n, self.policy.max_replicas))
        report = ReshardReport(
            strategy=strategy, from_mesh=describe_mesh(self.mesh),
            to_mesh=describe_mesh(new_mesh),
            started_t=time.perf_counter(), new_replicas=n, pool=self.phase)
        op = _ReshardOp(new_mesh=new_mesh, factory=factory,
                        strategy=strategy, report=report,
                        old=list(self._alive()))
        log.info("reshard[%s] %s: %s -> %s (%d replicas, tick %d)",
                 strategy, self.label, report.from_mesh, report.to_mesh, n,
                 self._tick)
        if strategy == "restart":
            # baseline: tear the old topology down before the new one exists
            for old in op.old:
                self._requeue_replica(old, report)
            self.mesh = op.new_mesh
            self.engine_factory = op.factory
            report.cutover_t = time.perf_counter()
        op.new = self._spawn_generation(op, n, warm)
        self._reshard = op
        if wait:
            tick = self._tick_fn or self._self_tick
            t_end = time.perf_counter() + wait_timeout_s
            while self._reshard is not None:
                if time.perf_counter() > t_end:
                    # abort before raising: leaving the op installed would
                    # block every later reshard AND keep autoscaling paused
                    self.abort_reshard(f"wait timeout after {wait_timeout_s}s")
                    raise RuntimeError(
                        f"reshard to {report.to_mesh} did not complete in "
                        f"{wait_timeout_s}s (replacement replicas stuck "
                        f"provisioning); aborted — the old topology keeps "
                        f"serving")
                if tick() == 0:
                    time.sleep(0.001)  # serving idle; yield to provisioning
        return report

    def abort_reshard(self, reason: str = "aborted by caller"
                      ) -> Optional[ReshardReport]:
        """Cancel an in-flight reshard (e.g. replacement provisioning is
        wedged): the pending new generation is stopped and dropped, and the
        pool resumes normal dispatch/autoscaling on the next tick. A
        "live" abort leaves the old generation serving exactly as before;
        a "restart" abort (the old generation is already gone) resumes
        autoscaling on the new topology, which respawns replicas. A stuck
        provisioning thread cannot be killed — its replica is STOPPED, so
        an engine it attaches later is never dispatched to. Returns the
        aborted report, or None when no reshard was in flight."""
        op = self._reshard
        if op is None:
            return None
        op.report.aborted = reason
        for r in op.new:
            if r.state is ReplicaState.PROVISIONING:
                # a briefly-slow (not dead) provision may attach its engine
                # after we give up; flag it for the poll() reaper so the
                # engine (KV pool + weights) is released, never served, and
                # never folded into fleet accounting
                r.discard_engine = True
            if r.state in (ReplicaState.PROVISIONING, ReplicaState.READY):
                r.stop()
            r.engine = None
        self._finish_reshard(op)
        return op.report

    def _spawn_generation(self, op: _ReshardOp, n: int,
                          warm: bool) -> List[Replica]:
        cold = ((lambda eng: self._cold_start(eng, warm=True)) if warm
                else self._cold_start)
        out = []
        for _ in range(n):
            r = Replica(next(self._rids), op.factory, cold, mesh=op.new_mesh,
                        deadline_s=self.policy.provision_deadline_s)
            self.replicas.append(r)
            out.append(r)
            log.info("+replica %d (%s reshard -> %s, tick %d)",
                     r.stats.replica_id, self.label, op.report.to_mesh,
                     self._tick)
        return out

    def _retire_replica(self, r: Replica):
        """Stop a replica and release its engine + KV pool immediately,
        preserving its stats (background errors drained and counted)."""
        if r.state is ReplicaState.PROVISIONING:
            r.join_provision()
        if r.engine is not None:
            r.drain_background(timeout=120.0)
        if r.state in (ReplicaState.PROVISIONING, ReplicaState.READY):
            r.stop()
        r.engine = None

    def _requeue_replica(self, old: Replica, report: ReshardReport):
        """restart-baseline teardown: push the replica's whole in-flight
        population back onto the pool backlog (KV rows dropped; requests
        re-prefill from their kept prefixes) and release it."""
        if old.state is ReplicaState.PROVISIONING:
            old.join_provision()
        if old.state is ReplicaState.READY and old.engine is not None:
            with old._ctx():
                reqs, _bundle, queued = old.engine.export_inflight()
            for r in reversed(reqs + queued):
                self.backlog.appendleft(r)
            report.requeued_requests += len(reqs) + len(queued)
        self._retire_replica(old)
        report.released_replicas += 1

    def advance_reshard(self):
        """One tick of the reshard state machine (called from the fleet
        tick while an op is installed)."""
        op = self._reshard
        if op.strategy == "live":
            # only the live strategy has two generations coexisting; the
            # restart baseline's provisioning ticks are a backlog stall,
            # not a dual-serving window
            op.report.dual_ticks += 1
        if any(r.state is ReplicaState.PROVISIONING for r in op.new):
            return  # DUAL: old generation is serving; new one still warming
        ready_new = [r for r in op.new if r.state is ReplicaState.READY]
        if op.strategy == "restart":
            if ready_new:
                op.report.drained_t = time.perf_counter()
            else:
                op.report.aborted = ("every replacement replica failed to "
                                     "provision")
            self._finish_reshard(op)
            return
        if not ready_new:
            # live abort: nothing to cut over to — the old generation never
            # stopped serving, so simply drop the dead new generation
            op.report.aborted = ("every replacement replica failed to "
                                 "provision; old topology keeps serving")
            self._finish_reshard(op)
            return
        # Hold the cutover for a tick when work is pending but nothing is
        # decoding: batch-admitted cohorts complete in lockstep, so the old
        # generation's running set can be momentarily empty exactly when
        # the replacements come READY. One deferred tick lets dispatch +
        # step put the pending work in flight so its decode state migrates
        # mid-stream instead of silently re-prefilling. Bounded so a
        # pathological case cannot stall the switch.
        old_ready = [r for r in op.old
                     if r.state is ReplicaState.READY and r.engine is not None]
        if old_ready and op.deferrals < 3:
            running = any(r.engine.scheduler.running for r in old_ready)
            pending = (bool(self.backlog)
                       or any(r.engine.scheduler.pending for r in old_ready))
            if pending and not running:
                op.deferrals += 1
                return
        try:
            self._cutover(op, ready_new)
        except Exception as e:
            # the cutover's own failure paths (torn export, refused adopt)
            # are contained per replica; anything that still escapes — the
            # reshard.cutover fault site fires before any mutation — aborts
            # the switch, and the old generation keeps serving
            log.warning("cutover to %s raised (%s: %s); aborting reshard",
                        op.report.to_mesh, type(e).__name__, e)
            self.abort_reshard(f"cutover failed: {type(e).__name__}: {e}")

    def _cutover(self, op: _ReshardOp, targets: List[Replica]):
        """CUTOVER -> DRAINED, atomically between decode steps: migrate
        every old replica's in-flight KV rows into the new generation's
        pools, flip the pool's identity to the new topology, release the
        old replicas."""
        # chaos hook BEFORE any mutation: a fault here unwinds into
        # advance_reshard's abort and the old generation keeps serving
        fault_point("reshard.cutover")
        rep = op.report
        rep.cutover_t = time.perf_counter()
        for old in op.old:
            if old.state is ReplicaState.PROVISIONING:
                old.join_provision()
            if old.state is ReplicaState.READY and old.engine is not None:
                try:
                    with old._ctx():
                        reqs, bundle, queued = old.engine.export_inflight()
                except Exception as e:
                    # torn export on ONE old replica must not strand the
                    # others: its requests retry from kept prefixes
                    log.warning("export_inflight failed on replica %d "
                                "during cutover (%s: %s); requeueing",
                                old.stats.replica_id, type(e).__name__, e)
                    _, rq, _ = self._requeue_crashed(old.engine)
                    rep.requeued_requests += rq
                    self._retire_replica(old)
                    rep.released_replicas += 1
                    continue
                for q in reversed(queued):
                    self.backlog.appendleft(q)
                while reqs:
                    cands = [t for t in targets
                             if t.engine is not None
                             and t.engine.max_batch - t.engine.pool.n_active > 0]
                    if not cands:
                        # no capacity anywhere on the new mesh: the tail
                        # requeues with its prefix kept (still zero drops)
                        for r in reversed(reqs):
                            self.backlog.appendleft(r)
                        rep.requeued_requests += len(reqs)
                        break
                    tgt = min(cands, key=lambda t: t.load)
                    try:
                        with tgt._ctx():
                            k = tgt.engine.adopt_inflight(reqs, bundle)
                    except Exception as e:
                        log.warning("adopt_inflight into replica %d failed "
                                    "during cutover (%s: %s); excluding it",
                                    tgt.stats.replica_id, type(e).__name__, e)
                        targets = [t for t in targets if t is not tgt]
                        continue
                    rep.migrated_requests += k
                    reqs = reqs[k:]
                    bundle = (bundle.select(range(k, bundle.n))
                              if reqs else None)
            self._retire_replica(old)
            rep.released_replicas += 1
        self.mesh = op.new_mesh
        self.engine_factory = op.factory
        rep.drained_t = time.perf_counter()
        # the reshard windows on the fleet timeline: SERVING->DUAL->CUTOVER
        # ->DRAINED (endpoints observed at different call sites, so they are
        # recorded as two back-to-back complete events at drain time)
        obs_trace.complete("reshard.dual", "fleet", rep.started_t,
                           rep.cutover_t, strategy=op.strategy,
                           to=rep.to_mesh, dual_ticks=rep.dual_ticks)
        obs_trace.complete("reshard.cutover", "fleet", rep.cutover_t,
                           rep.drained_t, migrated=rep.migrated_requests,
                           requeued=rep.requeued_requests)
        self._finish_reshard(op)

    def _finish_reshard(self, op: _ReshardOp):
        self.reshard_reports.append(op.report)
        self._reshard = None
        s = op.report
        _M_RESHARDS.inc(outcome="aborted" if s.aborted else "completed")
        if s.aborted:
            obs_trace.instant("reshard.aborted", cat="fleet",
                              to=s.to_mesh, reason=s.aborted)
            log.warning("reshard[%s] %s: %s -> %s: ABORTED (%s)",
                        s.strategy, self.label, s.from_mesh, s.to_mesh,
                        s.aborted)
        else:
            log.info("reshard[%s] %s: %s -> %s: done in %.1f ms (migrated "
                     "%d, requeued %d, dual %d ticks)",
                     s.strategy, self.label, s.from_mesh, s.to_mesh,
                     s.time_to_new_topology_s * 1e3, s.migrated_requests,
                     s.requeued_requests, s.dual_ticks)

    # -- accounting ------------------------------------------------------
    def drain_background(self, timeout: float = 300.0):
        """Join every replica LOAD's background workers (deterministic tests
        / benchmarks; serving itself never needs this)."""
        for r in self.replicas:
            if r.engine is not None and not r.discard_engine:
                r.drain_background(timeout)

    def publish_gauges(self):
        _M_BACKLOG.set(len(self.backlog), fleet=self.fleet_name,
                       pool=self.phase)
        _M_READY.set(len(self._ready()), fleet=self.fleet_name,
                     pool=self.phase)
        _M_INFLIGHT.set(self.inflight(), fleet=self.fleet_name,
                        pool=self.phase)
        _M_DEGRADED.set(1.0 if self.degraded else 0.0,
                        fleet=self.fleet_name, pool=self.phase)

"""Registry of the 10 assigned architectures (+ the paper's own eval model).

One module per architecture (``src/repro/configs/<arch>.py``); exact configs
from public literature with provenance recorded in ``ArchConfig.source``.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, SHAPE_CELLS, ShapeCell  # noqa: F401
from repro.configs.zamba2_2p7b import ZAMBA2_2P7B
from repro.configs.internvl2_2b import INTERNVL2_2B
from repro.configs.llama3_2_3b import LLAMA32_3B
from repro.configs.codeqwen1_5_7b import CODEQWEN15_7B
from repro.configs.yi_9b import YI_9B
from repro.configs.smollm_360m import SMOLLM_360M
from repro.configs.moonshot_v1_16b_a3b import MOONSHOT_16B_A3B
from repro.configs.arctic_480b import ARCTIC_480B
from repro.configs.falcon_mamba_7b import FALCON_MAMBA_7B
from repro.configs.hubert_xlarge import HUBERT_XLARGE
from repro.configs.qwen3_14b import QWEN3_14B

ASSIGNED = [
    ZAMBA2_2P7B, INTERNVL2_2B, LLAMA32_3B, CODEQWEN15_7B, YI_9B,
    SMOLLM_360M, MOONSHOT_16B_A3B, ARCTIC_480B, FALCON_MAMBA_7B, HUBERT_XLARGE,
]
EXTRA = [QWEN3_14B]

REGISTRY = {c.name: c for c in ASSIGNED + EXTRA}


def get_arch(name: str) -> ArchConfig:
    if name.endswith("-reduced"):
        return REGISTRY[name[: -len("-reduced")]].reduced()
    return REGISTRY[name]


def all_cells():
    """All 40 (assigned arch x shape) cells, with skip annotations."""
    for cfg in ASSIGNED:
        for shape_name in SHAPE_CELLS:
            yield cfg, SHAPE_CELLS[shape_name], cfg.skip_reason(shape_name)

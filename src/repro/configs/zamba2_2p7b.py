"""hybrid: Mamba2 + shared attention blocks [arXiv:2411.15242; hf]"""
from repro.configs.base import ArchConfig

ZAMBA2_2P7B = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000, ssm_state=64, ssm_version=2, ssm_head_dim=64,
    shared_attn_period=6,
    source="[arXiv:2411.15242; hf]",
)

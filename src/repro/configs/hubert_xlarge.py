"""audio: encoder-only, w2v2 arch [arXiv:2106.07447; unverified]"""
from repro.configs.base import ArchConfig

HUBERT_XLARGE = ArchConfig(
    name="hubert-xlarge", family="encoder",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504, causal=False,
    frontend="audio_stub", frontend_seq=0,  # all positions are frame embeddings
    source="[arXiv:2106.07447; unverified]",
)

"""moe: 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ArchConfig

ARCTIC_480B = ArchConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    num_experts=128, top_k=2, moe_dense_residual=True,
    zero_shard_params=True, opt_state_dtype="bfloat16",
    source="[hf:Snowflake/snowflake-arctic-base; hf]",
)

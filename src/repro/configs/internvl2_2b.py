"""vlm: InternViT + InternLM2 backbone [arXiv:2404.16821; hf]"""
from repro.configs.base import ArchConfig

INTERNVL2_2B = ArchConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553,
    frontend="vision_stub", frontend_seq=256,  # 256 patch embeddings per image
    source="[arXiv:2404.16821; hf]",
)

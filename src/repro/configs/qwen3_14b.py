"""paper's own eval model [arXiv:2505.09388; hf]"""
from repro.configs.base import ArchConfig

QWEN3_14B = ArchConfig(
    name="qwen3-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=17408, vocab_size=151936,
    source="[arXiv:2505.09388; hf]",
)

"""dense: qwen1.5-arch [hf:Qwen/CodeQwen1.5-7B; hf]"""
from repro.configs.base import ArchConfig

CODEQWEN15_7B = ArchConfig(
    name="codeqwen1.5-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=13440, vocab_size=92416,
    source="[hf:Qwen/CodeQwen1.5-7B; hf]",
)

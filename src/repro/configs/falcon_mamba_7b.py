"""ssm: mamba1 arch [arXiv:2410.05355; unverified]"""
from repro.configs.base import ArchConfig

FALCON_MAMBA_7B = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0, head_dim=1,
    d_ff=0, vocab_size=65024, ssm_state=16, ssm_version=1,
    source="[arXiv:2410.05355; unverified]",
)

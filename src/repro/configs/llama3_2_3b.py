"""dense: small llama3 [hf:meta-llama/Llama-3.2-1B; unverified]"""
from repro.configs.base import ArchConfig

LLAMA32_3B = ArchConfig(
    name="llama3.2-3b", family="dense",
    num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=8192, vocab_size=128256, tie_embeddings=True,
    source="[hf:meta-llama/Llama-3.2-1B; unverified]",
)

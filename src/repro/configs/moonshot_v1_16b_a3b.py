"""moe: kimi/moonlight 64e top-6 [hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.configs.base import ArchConfig

MOONSHOT_16B_A3B = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=163840,
    num_experts=64, top_k=6,
    source="[hf:moonshotai/Moonlight-16B-A3B; hf]",
)

"""Architecture configuration system.

Every assigned architecture is a frozen ``ArchConfig``. Configs are exact
public-literature numbers (see per-file citations); ``reduced()`` derives a
CPU-runnable smoke-test variant of the same family.

Input shapes are the assignment's four cells:
  train_4k     seq_len=4096   global_batch=256   (train_step)
  prefill_32k  seq_len=32768  global_batch=32    (prefill forward)
  decode_32k   seq_len=32768  global_batch=128   (serve_step, 1 new token)
  long_500k    seq_len=524288 global_batch=1     (serve_step, 1 new token)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class ShapeCell:
    """One (input shape) cell of the assignment grid."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ArchConfig:
    """A model architecture. One instance per assigned architecture.

    ``family`` selects the block structure:
      dense    — GQA decoder-only transformer (SwiGLU MLP)
      moe      — GQA decoder with top-k routed experts (+ optional dense residual)
      ssm      — Mamba-1 stack, attention-free
      hybrid   — Mamba-2 backbone with a shared attention block every
                 ``shared_attn_period`` layers (Zamba2 pattern)
      encoder  — bidirectional encoder (GELU MLP), no decode step
      vlm      — dense decoder with stubbed vision-embedding frontend
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False  # arctic: dense MLP in parallel with experts
    capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_version: int = 0  # 1 = mamba1, 2 = mamba2 (SSD)
    ssm_head_dim: int = 64  # mamba2 head dim
    shared_attn_period: int = 0  # hybrid: apply shared attn block every N layers
    # frontend stubs ([audio]/[vlm]: backbone only, embeddings precomputed)
    frontend: str = "none"  # "none" | "vision_stub" | "audio_stub"
    frontend_seq: int = 0  # number of stub embedding positions in prefill
    # misc
    causal: bool = True
    tie_embeddings: bool = False
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    param_dtype: str = "bfloat16"
    # training policy
    remat: bool = True
    zero_shard_params: bool = False  # FSDP-style param sharding over data axis
    opt_state_dtype: str = "float32"
    source: str = ""  # provenance [source; verified-tier]

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    # ---- derived ----
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over the model axis
        (Megatron-style vocab padding; logits over pad ids are masked)."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return _round_up(self.d_model // 16, 8)

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid; decode-time cost O(ctx) max)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return self.family != "encoder"

    def supports_shape(self, shape_name: str) -> bool:
        cell = SHAPE_CELLS[shape_name]
        if cell.kind == "decode" and not self.has_decode:
            return False  # encoder-only: no decode step
        if shape_name == "long_500k" and not self.is_subquadratic:
            return False  # needs sub-quadratic attention
        if cell.kind == "prefill" and self.family == "encoder":
            return True  # encode forward plays the prefill role
        return True

    def skip_reason(self, shape_name: str) -> Optional[str]:
        if self.supports_shape(shape_name):
            return None
        if not self.has_decode:
            return "encoder-only arch has no decode step"
        return "long_500k requires sub-quadratic attention (pure full-attention arch)"

    # ---- approximate parameter count (for roofline MODEL_FLOPS = 6ND) ----
    def param_count(self, active_only: bool = False) -> int:
        D, L, V = self.d_model, self.num_layers, self.padded_vocab
        H, Hkv, Dh = self.num_heads, self.num_kv_heads, self.head_dim
        emb = V * D * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "vlm", "moe"):
            attn = D * (H * Dh) + 2 * D * (Hkv * Dh) + (H * Dh) * D
            if self.family == "moe":
                n_e = self.top_k if active_only else self.num_experts
                mlp = n_e * 3 * D * self.d_ff
                if self.moe_dense_residual:
                    mlp += 3 * D * self.d_ff
            else:
                mlp = 3 * D * self.d_ff
            per_layer = attn + mlp + 2 * D
        elif self.family == "encoder":
            attn = D * (H * Dh) + 2 * D * (Hkv * Dh) + (H * Dh) * D
            per_layer = attn + 2 * D * self.d_ff + 2 * D
        elif self.family == "ssm":
            di, st, dr = self.d_inner, self.ssm_state, self.dt_rank
            per_layer = (D * 2 * di + self.ssm_conv * di + di * (dr + 2 * st)
                         + dr * di + di * st + 2 * di + di * D + D)
        elif self.family == "hybrid":
            di, st = self.d_inner, self.ssm_state
            nh = self.ssm_nheads
            m2 = (D * (2 * di + 2 * st + nh) + self.ssm_conv * (di + 2 * st)
                  + 2 * nh + di + di * D + D)
            per_layer = m2
        total = emb + L * per_layer
        if self.family == "hybrid" and self.shared_attn_period:
            attn = D * (H * Dh) + 2 * D * (Hkv * Dh) + (H * Dh) * D
            total += attn + 3 * D * self.d_ff + 2 * D  # one shared block (reused)
        return total

    # ---- reduced variant for smoke tests ----
    def reduced(self) -> "ArchConfig":
        """Small same-family variant: few layers, narrow width, tiny vocab."""
        nh = max(2, min(4, self.num_heads))
        nkv = max(1, min(self.num_kv_heads, nh))
        # keep the GQA ratio flavor: kv <= q, q % kv == 0
        while nh % nkv:
            nkv -= 1
        changes = dict(
            num_layers=max(2, min(4, self.num_layers)),
            d_model=64,
            num_heads=nh,
            num_kv_heads=nkv,
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=256,
            param_dtype="float32",
            remat=False,
            zero_shard_params=False,
        )
        if self.num_experts:
            changes["num_experts"] = 4
            changes["top_k"] = min(2, self.top_k)
        if self.ssm_state:
            changes["ssm_state"] = 8
            changes["ssm_head_dim"] = 16
        if self.shared_attn_period:
            changes["shared_attn_period"] = 2
        if self.frontend_seq:
            changes["frontend_seq"] = 8
        return dataclasses.replace(self, name=self.name + "-reduced", **changes)

"""Layer primitives shared by all model families.

Pure-JAX implementations (dry-run / roofline / CPU path). Perf-critical hot
spots have Pallas TPU twins in ``repro.kernels`` that swap in via
``use_pallas`` on real hardware.

All functions take a ``ShardCtx`` for logical-axis sharding constraints and
degrade to no-ops off-mesh.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import ShardCtx
from repro.models.tuning import FLAGS


def _dot_f32(spec, a, b):
    """Einsum with f32 accumulation. Baseline materializes f32 copies of the
    operands (the naive-but-faithful XLA path); with mixed_precision_attn the
    operands stay bf16 and only the MXU accumulator is f32."""
    if FLAGS.mixed_precision_attn:
        return jnp.einsum(spec, a, b, preferred_element_type=jnp.float32)
    return jnp.einsum(spec, a.astype(jnp.float32), b.astype(jnp.float32))


def _cast_for_pv(p, v):
    """Probability operand for the PV dot: bf16 under mixed precision."""
    if FLAGS.mixed_precision_attn:
        return p.astype(v.dtype)
    return p


def rms_norm(x, scale, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta: float):
    """Rotary embedding. x: [..., S, H, Dh]; positions: broadcastable [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # [..., S, 1, half]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _blk(x, n, b):
    """[B, n*b, H, D] -> [n, B, b, H, D] scan layout."""
    B, _, H, D = x.shape
    return jnp.moveaxis(x.reshape(B, n, b, H, D), 1, 0)


def _unblk(x):
    """[n, B, H, b, D] -> [B, n*b, H, D]."""
    n, B, H, b, D = x.shape
    return jnp.moveaxis(x, 0, 1).transpose(0, 1, 3, 2, 4).reshape(B, n * b, H, D)


def _flash_fwd_core(q, k, v, causal, qb, kb, skv_real):
    """Padded core. q: [B,Sq,H,Dh]; k,v: [B,Skv,H,Dh] (already GQA-repeated).
    Returns (out [B,Sq,H,Dh], lse [B,H,Sq])."""
    B, Sq, H, Dh = q.shape
    Skv = k.shape[1]
    nq, nk = Sq // qb, Skv // kb
    scale = 1.0 / math.sqrt(Dh)
    qs, ks, vs = _blk(q, nq, qb), _blk(k, nk, kb), _blk(v, nk, kb)

    def q_step(_, qi_blk):
        qi, q_blk_ = qi_blk
        q_pos = qi * qb + jnp.arange(qb)

        def kv_step(carry, kj_blk):
            m, l, acc = carry
            kj, k_blk, v_blk = kj_blk
            kpos = kj * kb + jnp.arange(kb)
            s = _dot_f32("bqhd,bkhd->bhqk", q_blk_, k_blk) * scale
            mask = kpos[None, None, None, :] < skv_real
            if causal:
                mask = mask & (q_pos[None, None, :, None]
                               >= kpos[None, None, None, :])
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + _dot_f32(
                "bhqk,bkhd->bhqd", _cast_for_pv(p, v_blk), v_blk)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, H, qb), -1e30, jnp.float32),
                jnp.zeros((B, H, qb), jnp.float32),
                jnp.zeros((B, H, qb, Dh), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init,
                                      (jnp.arange(nk), ks, vs))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), 0.0)
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    out = _unblk(outs)                      # [B, Sq, H, Dh]
    lse = jnp.moveaxis(lses, 0, 2)          # [nq,B,H,qb] -> [B,H,nq,qb]
    lse = lse.reshape(B, H, Sq)
    return out, lse


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, qb, kb, skv_real):
    out, _ = _flash_fwd_core(q, k, v, causal, qb, kb, skv_real)
    return out


def _flash_vjp_fwd(q, k, v, causal, qb, kb, skv_real):
    out, lse = _flash_fwd_core(q, k, v, causal, qb, kb, skv_real)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, qb, kb, skv_real, res, dout):
    """FlashAttention backward: blockwise recompute from (out, lse).
    Peak temp O(qb*kb) instead of O(Sq*Skv) saved probabilities."""
    q, k, v, out, lse = res
    B, Sq, H, Dh = q.shape
    Skv = k.shape[1]
    nq, nk = Sq // qb, Skv // kb
    scale = 1.0 / math.sqrt(Dh)
    delta = jnp.einsum("bqhd,bqhd->bhq", dout.astype(jnp.float32),
                       out.astype(jnp.float32))  # [B, H, Sq]
    qs, ks, vs = _blk(q, nq, qb), _blk(k, nk, kb), _blk(v, nk, kb)
    dos = _blk(dout, nq, qb)
    lses = jnp.moveaxis(lse.reshape(B, H, nq, qb), 2, 0)    # [nq,B,H,qb]
    deltas = jnp.moveaxis(delta.reshape(B, H, nq, qb), 2, 0)

    def block_dS(qi, kj, q_blk, k_blk, lse_blk):
        """Recompute P and return (P, positions mask) for block (qi, kj)."""
        q_pos = qi * qb + jnp.arange(qb)
        kpos = kj * kb + jnp.arange(kb)
        s = _dot_f32("bqhd,bkhd->bhqk", q_blk, k_blk) * scale
        mask = kpos[None, None, None, :] < skv_real
        if causal:
            mask = mask & (q_pos[None, None, :, None]
                           >= kpos[None, None, None, :])
        p = jnp.where(mask, jnp.exp(s - lse_blk[..., None]), 0.0)
        return p

    # pass A: dq (outer over q blocks, inner over kv blocks)
    def dq_step(_, xs):
        qi, q_blk, do_blk, lse_blk, delta_blk = xs

        def inner(dq_acc, ys):
            kj, k_blk, v_blk = ys
            p = block_dS(qi, kj, q_blk, k_blk, lse_blk)
            dp = _dot_f32("bqhd,bkhd->bhqk", do_blk, v_blk)
            ds = p * (dp - delta_blk[..., None]) * scale
            dq_acc = dq_acc + _dot_f32("bhqk,bkhd->bqhd",
                                       _cast_for_pv(ds, k_blk), k_blk)
            return dq_acc, None

        dq0 = jnp.zeros((B, qb, H, Dh), jnp.float32)
        dq_blk, _ = jax.lax.scan(inner, dq0, (jnp.arange(nk), ks, vs))
        return None, dq_blk

    _, dqs = jax.lax.scan(dq_step, None, (jnp.arange(nq), qs, dos, lses, deltas))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, Sq, H, Dh).astype(q.dtype)

    # pass B: dk, dv (outer over kv blocks, inner over q blocks)
    def dkv_step(_, xs):
        kj, k_blk, v_blk = xs

        def inner(carry, ys):
            dk_acc, dv_acc = carry
            qi, q_blk, do_blk, lse_blk, delta_blk = ys
            p = block_dS(qi, kj, q_blk, k_blk, lse_blk)
            dv_acc = dv_acc + _dot_f32("bhqk,bqhd->bkhd",
                                       _cast_for_pv(p, do_blk), do_blk)
            dp = _dot_f32("bqhd,bkhd->bhqk", do_blk, v_blk)
            ds = p * (dp - delta_blk[..., None]) * scale
            dk_acc = dk_acc + _dot_f32("bhqk,bqhd->bkhd",
                                       _cast_for_pv(ds, q_blk), q_blk)
            return (dk_acc, dv_acc), None

        z = jnp.zeros((B, kb, H, Dh), jnp.float32)
        (dk_blk, dv_blk), _ = jax.lax.scan(
            inner, (z, z), (jnp.arange(nq), qs, dos, lses, deltas))
        return None, (dk_blk, dv_blk)

    _, (dks, dvs) = jax.lax.scan(dkv_step, None, (jnp.arange(nk), ks, vs))
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, Skv, H, Dh).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Skv, H, Dh).astype(v.dtype)
    return dq, dk, dv


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention(q, k, v, *, causal: bool, q_block: int = 512,
                    kv_block: int = 512, ctx: Optional[ShardCtx] = None):
    """Blocked (FlashAttention-style) attention, pure XLA, custom VJP.

    q: [B, Sq, H, Dh]; k, v: [B, Skv, Hkv, Dh] with H % Hkv == 0.
    Online-softmax over KV blocks inside a scan over Q blocks: peak temp is
    O(q_block * kv_block) instead of O(Sq * Skv), forward AND backward (the
    backward recomputes P blockwise from the saved logsumexp).
    """
    B, Sq, H, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    if H != Hkv:  # GQA: broadcast KV across the query group (diff'able)
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)

    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    Sq_p, Skv_p = -(-Sq // qb) * qb, -(-Skv // kb) * kb
    q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
    out = _flash(q, k, v, causal, qb, kb, Skv)
    return out[:, :Sq]


def decode_attention_dense(q, k_cache, v_cache, lengths, layout: str = "bshd"):
    """Single-token attention against a full cache (head-sharded / replicated).

    q: [B, 1, H, Dh]; caches: [B, S, Hkv, Dh] ("bshd") or the head-major
    [B, Hkv, S, Dh] ("bhsd", transpose-free dots); lengths: [B] — the new
    token sits at position lengths[b] and must already be in the cache.
    """
    B, _, H, Dh = q.shape
    if layout == "bhsd":
        Hkv, S = k_cache.shape[1], k_cache.shape[2]
        qk, pv = "bkgd,bksd->bkgs", "bkgs,bksd->bkgd"
    else:
        S, Hkv = k_cache.shape[1], k_cache.shape[2]
        qk, pv = "bkgd,bskd->bkgs", "bkgs,bskd->bkgd"
    G = H // Hkv
    scale = 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, Hkv, G, Dh)
    s = _dot_f32(qk, qg, k_cache) * scale
    mask = jnp.arange(S)[None, :] <= lengths[:, None]  # [B, S]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = _dot_f32(pv, _cast_for_pv(p, v_cache), v_cache)
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


def _combined_axis_index(axes: tuple[str, ...]):
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def decode_attention_seqpar(q, k_cache, v_cache, k_new, v_new, lengths, *,
                            mesh, batch_axes: tuple[str, ...],
                            seq_axes: tuple[str, ...], layout: str = "bshd"):
    """Sequence-parallel flash-decode via shard_map (TPU adaptation for GQA
    archs whose KV heads don't divide the model axis).

    The KV cache is sharded along sequence over ``seq_axes``; each shard
    computes partial online-softmax statistics which are combined with a tiny
    psum (the flash-decode split-k trick, mapped onto ICI).

    Also performs the cache write: the owner shard inserts (k_new, v_new) at
    lengths[b]. Returns (out [B,1,H,Dh], k_cache', v_cache').
    Cache layout "bshd" [B,S,Hkv,Dh] or head-major "bhsd" [B,Hkv,S,Dh].
    """
    head_major = layout == "bhsd"
    if head_major:
        B, Hkv, S, Dh = k_cache.shape
        seq_axis_in_cache = 2
        qk, pv = "bkgd,bksd->bkgs", "bkgs,bksd->bkgd"
        cspec = lambda b, s: P(b, None, s, None)
    else:
        B, S, Hkv, Dh = k_cache.shape
        seq_axis_in_cache = 1
        qk, pv = "bkgd,bskd->bkgs", "bkgs,bskd->bkgd"
        cspec = lambda b, s: P(b, s, None, None)
    H = q.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(Dh)
    n_seq = math.prod(mesh.shape[a] for a in seq_axes)
    S_loc = S // n_seq
    bspec = batch_axes if batch_axes else None
    sspec = seq_axes if len(seq_axes) > 1 else (seq_axes[0] if seq_axes else None)
    waxis = seq_axis_in_cache - 1  # per-batch-row write axis

    def kernel(q_, kc, vc, kn, vn, lens):
        sid = _combined_axis_index(seq_axes)
        offset = sid * S_loc
        # --- owner-shard cache write at local position ---
        loc = lens - offset  # [B]
        own = (loc >= 0) & (loc < S_loc)
        locc = jnp.clip(loc, 0, S_loc - 1)

        def write_one(c, new, l, o):
            # c: per-row cache [S_loc, Hkv, Dh] or [Hkv, S_loc, Dh]
            nw = new if not head_major else new  # [Hkv, Dh] new row
            cur = jax.lax.dynamic_slice_in_dim(c, l, 1, axis=waxis)
            upd_new = (nw[None] if waxis == 0 else nw[:, None])
            upd = jnp.where(o, upd_new.astype(c.dtype), cur)
            return jax.lax.dynamic_update_slice_in_dim(c, upd, l, axis=waxis)

        kc = jax.vmap(write_one)(kc, kn, locc, own)
        vc = jax.vmap(write_one)(vc, vn, locc, own)
        # --- partial attention over the local KV slice ---
        qg = q_.reshape(-1, Hkv, G, Dh)
        s = _dot_f32(qk, qg, kc) * scale
        pos = offset + jnp.arange(S_loc)
        mask = pos[None, :] <= lens[:, None]
        s = jnp.where(mask[:, None, None, :], s, -1e30)
        m = s.max(axis=-1)  # [B,Hkv,G]
        m_g = jax.lax.pmax(m, seq_axes)
        p = jnp.exp(s - m_g[..., None])
        l_part = p.sum(axis=-1)
        acc = _dot_f32(pv, _cast_for_pv(p, vc), vc)
        l_g = jax.lax.psum(l_part, seq_axes)
        acc_g = jax.lax.psum(acc, seq_axes)
        out = (acc_g / jnp.maximum(l_g, 1e-30)[..., None])
        return out.reshape(-1, 1, H, Dh).astype(q_.dtype), kc, vc

    in_specs = (P(bspec, None, None, None), cspec(bspec, sspec),
                cspec(bspec, sspec), P(bspec, None, None),
                P(bspec, None, None), P(bspec))
    out_specs = (P(bspec, None, None, None), cspec(bspec, sspec),
                 cspec(bspec, sspec))
    if hasattr(jax, "shard_map"):  # jax >= 0.5
        mapped = jax.shard_map(kernel, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_vma=False)
    else:  # jax 0.4.x spelling (check_rep is check_vma's predecessor)
        from jax.experimental.shard_map import shard_map as _shard_map
        mapped = _shard_map(kernel, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=False)
    out, kc, vc = mapped(q, k_cache, v_cache, k_new, v_new, lengths)
    return out, kc, vc


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def _mlp_axes_for(h):
    return ("batch",) + (None,) * (h.ndim - 2) + ("mlp",)


def swiglu(x, w_gate, w_up, w_down, ctx: ShardCtx):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    h = ctx.constrain(h, *_mlp_axes_for(h))
    return h @ w_down


def gelu_mlp(x, w_up, b_up, w_down, b_down, ctx: ShardCtx):
    h = jax.nn.gelu(x @ w_up + b_up)
    h = ctx.constrain(h, *_mlp_axes_for(h))
    return h @ w_down + b_down


# ---------------------------------------------------------------------------
# Mixture of Experts (sort/gather-based capacity dispatch; EP over "experts")
# ---------------------------------------------------------------------------

def _moe_row(x, w_router, w_gate, w_up, w_down, *, top_k: int, capacity: int):
    """Route one sequence row. x: [T, D] -> (out [T, D], aux scalar).

    Capacity-based dispatch with gather/scatter (no O(T*E*C) one-hots):
    tokens are ranked within their expert via a stable sort; ranks >= capacity
    are dropped (standard capacity-factor semantics; pass capacity=T for
    lossless decode).
    """
    T, D = x.shape
    E, _, F = w_gate.shape
    C = capacity

    gate_logits = (x @ w_router).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(gate_logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)  # [T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # aux loss (Switch-style load balancing)
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (T * top_k)
    aux = E * jnp.sum(me * ce)

    flat_e = top_i.reshape(-1)  # [T*k]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank within expert group
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(T * top_k) - first
    valid = rank < C
    slot = jnp.where(valid, sorted_e * C + rank, E * C)  # E*C = drop bin
    tok = order // top_k
    wgt = top_p.reshape(-1)[order]

    slot_tok = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        jnp.where(valid, tok, T))[:-1]
    slot_wgt = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(
        jnp.where(valid, wgt, 0.0))[:-1]

    x_pad = jnp.concatenate([x, jnp.zeros((1, D), x.dtype)], axis=0)
    xe = x_pad[slot_tok].reshape(E, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w_up)
    ye = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(E * C, D)
    ye = ye * slot_wgt[:, None].astype(ye.dtype)

    out = jnp.zeros((T + 1, D), ye.dtype).at[slot_tok].add(ye)[:T]
    return out.astype(x.dtype), aux


def _moe_routing_row(x, w_router, *, top_k: int, capacity: int):
    """Routing for one row: returns (slot_tok [E*C], slot_wgt [E*C], aux)."""
    T = x.shape[0]
    E = w_router.shape[-1]
    C = capacity
    gate_logits = (x @ w_router).astype(jnp.float32)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (T * top_k)
    aux = E * jnp.sum(me * ce)

    flat_e = top_i.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(T * top_k) - first
    valid = rank < C
    slot = jnp.where(valid, sorted_e * C + rank, E * C)
    tok = order // top_k
    wgt = top_p.reshape(-1)[order]
    slot_tok = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        jnp.where(valid, tok, T))[:-1]
    slot_wgt = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(
        jnp.where(valid, wgt, 0.0))[:-1]
    return slot_tok, slot_wgt, aux


def _moe_batched(x, w_router, w_gate, w_up, w_down, *, top_k: int,
                 capacity: int, ctx: ShardCtx):
    """Batched dispatch: only the (cheap, index-valued) routing is vmapped;
    the gather / expert GEMMs / combine carry explicit batch dims with
    sharding constraints, so dispatch buffers stay (batch x experts)-sharded
    instead of being all-gathered across the model axis (baseline failure
    mode; see EXPERIMENTS.md §Perf B1)."""
    B, S, D = x.shape
    E, _, F = w_gate.shape
    C = capacity
    slot_tok, slot_wgt, aux = jax.vmap(
        partial(_moe_routing_row, top_k=top_k, capacity=capacity),
        in_axes=(0, None))(x, w_router)          # [B, E*C] each

    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], axis=1)
    xe = jnp.take_along_axis(x_pad, slot_tok[..., None], axis=1)  # [B, E*C, D]
    xe = xe.reshape(B, E, C, D)
    xe = ctx.constrain(xe, "batch", "experts", None, None)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, w_gate))
    h = h * jnp.einsum("becd,edf->becf", xe, w_up)
    h = ctx.constrain(h, "batch", "experts", None, None)
    ye = jnp.einsum("becf,efd->becd", h, w_down)
    ye = ctx.constrain(ye, "batch", "experts", None, None)
    ye = ye.reshape(B, E * C, D)  # dim1 stays expert-sharded (E | E*C)
    ye = ye * slot_wgt[..., None].astype(ye.dtype)

    out = ctx.constrain(jnp.zeros((B, S + 1, D), ye.dtype),
                        "batch", None, None)
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], slot_tok.shape)
    out = out.at[bidx, slot_tok].add(ye)
    out = ctx.constrain(out, "batch", None, None)[:, :S]
    return out, aux.mean()


def moe_ffn(x, w_router, w_gate, w_up, w_down, *, top_k: int, capacity: int,
            ctx: ShardCtx):
    """Top-k routed expert FFN over [B, S, D] activations.

    Baseline: routing AND dispatch vmapped over batch rows (gathers stay
    local to a data shard; capacity is per-row). Optimized
    (FLAGS.moe_batched_dispatch): batched dispatch with explicit sharding
    constraints — same math, far fewer collectives.
    """
    if FLAGS.moe_batched_dispatch:
        return _moe_batched(x, w_router, w_gate, w_up, w_down, top_k=top_k,
                            capacity=capacity, ctx=ctx)
    B, S, D = x.shape
    row = partial(_moe_row, top_k=top_k, capacity=capacity)
    out, aux = jax.vmap(row, in_axes=(0, None, None, None, None))(
        x, w_router, w_gate, w_up, w_down)
    out = ctx.constrain(out, "batch", None, None)
    return out, aux.mean()


def moe_capacity(cfg, tokens_per_shard: int, *, lossless: bool) -> int:
    if lossless:
        return tokens_per_shard
    c = int(math.ceil(tokens_per_shard * cfg.top_k / cfg.num_experts
                      * cfg.capacity_factor))
    return max(8, min(tokens_per_shard, -(-c // 8) * 8))

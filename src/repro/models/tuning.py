"""Performance tuning flags (§Perf hillclimb knobs).

Every flag is a *beyond-paper* optimization layered on the paper-faithful
baseline; EXPERIMENTS.md §Perf records each one as
hypothesis -> change -> before/after roofline terms. Flags default to the
optimized setting once validated; ``baseline()`` restores the faithful
baseline for comparison runs.

Env override: REPRO_TUNING="mixed_precision_attn=0,moe_batched_dispatch=1".
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field, fields


@dataclass
class PerfFlags:
    # A: attention — keep bf16 operands on the MXU, accumulate f32 via
    # preferred_element_type instead of materializing f32 copies of Q/K/V
    # and the KV cache (kills the convert streams seen in the baseline HLO).
    mixed_precision_attn: bool = False
    # B: MoE — batched (non-vmapped) dispatch: gather/scatter with explicit
    # batch dims + sharding constraints so GSPMD keeps dispatch buffers
    # sharded (batch over data, experts over model) instead of
    # all-gathering them across the model axis every layer.
    moe_batched_dispatch: bool = False
    # A2: decode — head-major KV-cache layout [L, B, Hkv, S, Dh]: both decode
    # dots (QK^T and PV) consume the cache without a materialized transpose
    # (baseline [L, B, S, Hkv, Dh] forces per-layer layout copies).
    kv_cache_head_major: bool = False
    # C: Mamba-1 — time-chunked selective scan: unroll the recurrence in
    # chunks so the state stays in registers within a fused chunk body and
    # HBM traffic drops from O(T * state) to O(T/chunk * inputs).
    mamba1_chunked: bool = False
    mamba1_chunk: int = 16


FLAGS = PerfFlags()


def set_flags(**kw):
    for k, v in kw.items():
        if not hasattr(FLAGS, k):
            raise KeyError(k)
        setattr(FLAGS, k, type(getattr(FLAGS, k))(v))
    return FLAGS


def baseline():
    """Paper-faithful baseline (all optimizations off)."""
    for f in fields(PerfFlags):
        setattr(FLAGS, f.name, f.default)
    return FLAGS


def optimized():
    """Validated wins only (EXPERIMENTS.md §Perf). Excluded after full-sweep
    measurement: mamba1_chunked (chunk relayout costs more than it saves
    under the TPU-target cost model) and moe_batched_dispatch (train-cell
    memory/compute win, but 3.3x prefill and 16x arctic-decode collective
    regressions — GSPMD replicates the batched combine scatter)."""
    set_flags(mixed_precision_attn=True, kv_cache_head_major=True)
    return FLAGS


def _from_env():
    spec = os.environ.get("REPRO_TUNING", "")
    for item in spec.split(","):
        if not item.strip():
            continue
        k, _, v = item.partition("=")
        set_flags(**{k.strip(): int(v)})


_from_env()

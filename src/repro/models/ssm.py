"""State-space model blocks: Mamba-1 (falcon-mamba) and Mamba-2/SSD (zamba2).

Prefill paths are written for compilability + roofline fidelity on the XLA
backend: Mamba-2 uses the chunked SSD matmul formulation (MXU-friendly);
Mamba-1 uses a time-step scan (its per-(channel,state) decay admits no shared
chunk decay matrix). The Pallas twin lives in repro/kernels/ssm_scan.

Projections are stored as separate leaves (in_proj_x / in_proj_z / ...) rather
than one fused matrix so each output segment can carry its own sharding
("ssm_inner" over the model axis; B/C/dt segments replicated).

State layout (decode):
  mamba1: h [B, d_inner, N],   conv buffer [B, K-1, d_inner]
  mamba2: h [B, nheads, P, N], conv buffers x/[B,K-1,d_inner], B,C/[B,K-1,N]
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.launch.mesh import ShardCtx
from repro.models.tuning import FLAGS


def causal_conv1d(x, w, b=None):
    """Depthwise causal conv. x: [B, T, C]; w: [K, C]. y_t = sum_i w_i x_{t-K+1+i}."""
    K = w.shape[0]
    y = x * w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        y = y + shifted * w[K - 1 - i]
    if b is not None:
        y = y + b
    return y


def causal_conv1d_step(x_t, conv_buf, w, b=None):
    """One decode step. x_t: [B, C]; conv_buf: [B, K-1, C] (previous inputs).
    Returns (y_t [B, C], new conv_buf)."""
    window = jnp.concatenate([conv_buf, x_t[:, None]], axis=1)  # [B, K, C]
    y = jnp.einsum("bkc,kc->bc", window, w)
    if b is not None:
        y = y + b
    return y, window[:, 1:]


def _tail_buf(x_raw, K):
    """Last K-1 positions of the raw (pre-conv) stream, left-padded."""
    return jnp.pad(x_raw, ((0, 0), (K - 1, 0), (0, 0)))[:, -(K - 1):]


# ---------------------------------------------------------------------------
# Mamba-1 (selective scan with per-(channel,state) decay)
# ---------------------------------------------------------------------------

MAMBA1_PARAM_AXES = {
    "in_proj_x": (None, "ssm_inner"), "in_proj_z": (None, "ssm_inner"),
    "conv_w": (None, "ssm_inner"), "conv_b": ("ssm_inner",),
    "x_proj_dt": ("ssm_inner", None), "x_proj_B": ("ssm_inner", None),
    "x_proj_C": ("ssm_inner", None),
    "dt_proj": (None, "ssm_inner"), "dt_bias": ("ssm_inner",),
    "A_log": ("ssm_inner", None), "D": ("ssm_inner",),
    "out_proj": ("ssm_inner", None),
}


def mamba1_param_shapes(cfg):
    di, N, dr, K, D = cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv, cfg.d_model
    return {
        "in_proj_x": (D, di), "in_proj_z": (D, di),
        "conv_w": (K, di), "conv_b": (di,),
        "x_proj_dt": (di, dr), "x_proj_B": (di, N), "x_proj_C": (di, N),
        "dt_proj": (dr, di), "dt_bias": (di,),
        "A_log": (di, N), "D": (di,),
        "out_proj": (di, D),
    }


def mamba1_prefill(x, p, cfg, ctx: ShardCtx):
    """x: [B, T, D] -> (y [B, T, D], state (h, conv_buf))."""
    B, T, D = x.shape
    di, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv

    x_in = x @ p["in_proj_x"]  # [B, T, di]
    z = x @ p["in_proj_z"]
    x_in = ctx.constrain(x_in, "batch", None, "ssm_inner")
    x_c = jax.nn.silu(causal_conv1d(x_in, p["conv_w"], p["conv_b"]))

    dt_raw = x_c @ p["x_proj_dt"]  # [B, T, dr]
    Bm = x_c @ p["x_proj_B"]       # [B, T, N]
    Cm = x_c @ p["x_proj_C"]
    dt = jax.nn.softplus(dt_raw @ p["dt_proj"] + p["dt_bias"])  # [B, T, di]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [di, N]

    def step(h, inp):
        dt_t, x_t, B_t, C_t = inp  # [B,di],[B,di],[B,N],[B,N]
        dt_f = dt_t.astype(jnp.float32)
        decay = jnp.exp(dt_f[..., None] * A)  # [B, di, N]
        h = decay * h + (dt_f * x_t.astype(jnp.float32))[..., None] \
            * B_t.astype(jnp.float32)[:, None, :]
        y_t = jnp.einsum("bdn,bn->bd", h, C_t.astype(jnp.float32))
        return h, y_t.astype(x_t.dtype)

    h0 = jnp.zeros((B, di, N), jnp.float32)
    if FLAGS.mamba1_chunked and T % FLAGS.mamba1_chunk == 0 \
            and T > FLAGS.mamba1_chunk:
        # time-chunked recurrence: the inner unrolled chunk fuses into one
        # kernel so h stays in registers; HBM traffic drops from
        # O(T * di * N) state round-trips to O(T) chunk I/O
        # (EXPERIMENTS.md §Perf C1; the Pallas ssm_scan kernel is the TPU
        # twin of exactly this blocking).
        Tc = FLAGS.mamba1_chunk
        nc = T // Tc

        def chunk_step(h, inp):
            dt_c, x_c_, B_c, C_c = inp  # [Tc, B, ...]
            ys = []
            for i in range(Tc):  # unrolled: fused chunk body
                h, y_t = step(h, (dt_c[i], x_c_[i], B_c[i], C_c[i]))
                ys.append(y_t)
            return h, jnp.stack(ys)

        resh = lambda a: jnp.moveaxis(a, 1, 0).reshape(
            (nc, Tc) + (B,) + a.shape[2:])
        xs = (resh(dt), resh(x_c), resh(Bm), resh(Cm))
        h, ys = jax.lax.scan(chunk_step, h0, xs)
        ys = ys.reshape((T, B) + ys.shape[3:])
    else:
        xs = (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(x_c, 1, 0),
              jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
        h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x_c * p["D"]  # [B, T, di]
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out, (h, _tail_buf(x_in, K))


def mamba1_decode(x_t, state, p, cfg, ctx: ShardCtx):
    """x_t: [B, D]; state (h [B,di,N], conv_buf [B,K-1,di])."""
    h, conv_buf = state
    x_in = x_t @ p["in_proj_x"]
    z = x_t @ p["in_proj_z"]
    xc, conv_buf = causal_conv1d_step(x_in, conv_buf, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    dt = jax.nn.softplus((xc @ p["x_proj_dt"]) @ p["dt_proj"] + p["dt_bias"])
    Bm = xc @ p["x_proj_B"]
    Cm = xc @ p["x_proj_C"]
    dt = dt.astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt[..., None] * A)
    h = decay * h + (dt * xc.astype(jnp.float32))[..., None] \
        * Bm.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cm.astype(jnp.float32)).astype(x_t.dtype)
    y = (y + xc * p["D"]) * jax.nn.silu(z)
    return y @ p["out_proj"], (h, conv_buf)


# ---------------------------------------------------------------------------
# Mamba-2 / SSD (scalar per-head decay; chunked matmul formulation)
# ---------------------------------------------------------------------------

MAMBA2_PARAM_AXES = {
    "in_proj_x": (None, "ssm_inner"), "in_proj_z": (None, "ssm_inner"),
    "in_proj_B": (None, None), "in_proj_C": (None, None),
    "in_proj_dt": (None, "ssm_heads"),
    "conv_w_x": (None, "ssm_inner"), "conv_b_x": ("ssm_inner",),
    "conv_w_B": (None, None), "conv_b_B": (None,),
    "conv_w_C": (None, None), "conv_b_C": (None,),
    "dt_bias": ("ssm_heads",), "A_log": ("ssm_heads",), "D": ("ssm_heads",),
    "norm": ("ssm_inner",), "out_proj": ("ssm_inner", None),
}


def mamba2_param_shapes(cfg):
    di, N, K, D = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv, cfg.d_model
    H = cfg.ssm_nheads
    return {
        "in_proj_x": (D, di), "in_proj_z": (D, di),
        "in_proj_B": (D, N), "in_proj_C": (D, N), "in_proj_dt": (D, H),
        "conv_w_x": (K, di), "conv_b_x": (di,),
        "conv_w_B": (K, N), "conv_b_B": (N,),
        "conv_w_C": (K, N), "conv_b_C": (N,),
        "dt_bias": (H,), "A_log": (H,), "D": (H,),
        "norm": (di,), "out_proj": (di, D),
    }


def mamba2_prefill(x, p, cfg, ctx: ShardCtx, chunk: int = 256):
    """SSD chunked prefill. x: [B, T, D] -> (y, state (h, conv bufs))."""
    from repro.models.layers import rms_norm
    B, T, D = x.shape
    di, N, P_, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_conv
    H = cfg.ssm_nheads
    Lc = min(chunk, T)
    assert T % Lc == 0, (T, Lc)
    nc = T // Lc

    z = x @ p["in_proj_z"]
    x_raw = x @ p["in_proj_x"]
    B_raw = x @ p["in_proj_B"]
    C_raw = x @ p["in_proj_C"]
    dt_raw = x @ p["in_proj_dt"]  # [B, T, H]
    x_raw = ctx.constrain(x_raw, "batch", None, "ssm_inner")

    xs = jax.nn.silu(causal_conv1d(x_raw, p["conv_w_x"], p["conv_b_x"]))
    Bm = jax.nn.silu(causal_conv1d(B_raw, p["conv_w_B"], p["conv_b_B"]))
    Cm = jax.nn.silu(causal_conv1d(C_raw, p["conv_w_C"], p["conv_b_C"]))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B, T, H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [H]

    xh = xs.reshape(B, nc, Lc, H, P_)
    dtc = dt.reshape(B, nc, Lc, H)
    Bc = Bm.reshape(B, nc, Lc, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, Lc, N).astype(jnp.float32)
    la = dtc * A  # log-decay per step [B, nc, Lc, H]

    def chunk_step(S, inp):
        xh_c, dt_c, B_c, C_c, la_c = inp  # [B,Lc,H,P],[B,Lc,H],[B,Lc,N],[B,Lc,N],[B,Lc,H]
        cs = jnp.cumsum(la_c, axis=1)  # [B, Lc, H] inclusive
        # intra-chunk: Lambda_ij = exp(cs_i - cs_j), i >= j
        lam = jnp.exp(cs[:, :, None, :] - cs[:, None, :, :])  # [B, Li, Lj, H]
        mask = jnp.tril(jnp.ones((Lc, Lc), bool))
        lam = jnp.where(mask[None, :, :, None], lam, 0.0)
        cb = jnp.einsum("bin,bjn->bij", C_c, B_c)  # [B, Li, Lj]
        w = cb[..., None] * lam * dt_c[:, None, :, :]  # [B, Li, Lj, H]
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xh_c.astype(jnp.float32))
        # inter-chunk: contribution of the incoming state
        y_inter = jnp.einsum("bhpn,bin->bihp", S, C_c) * jnp.exp(cs)[..., None]
        # state update
        tot = cs[:, -1]  # [B, H]
        decay_from = jnp.exp(tot[:, None, :] - cs)  # [B, Lc, H]
        S_new = (jnp.exp(tot)[:, :, None, None] * S
                 + jnp.einsum("bjhp,bjn,bjh->bhpn", xh_c.astype(jnp.float32),
                              B_c, dt_c * decay_from))
        return S_new, (y_intra + y_inter).astype(x.dtype)

    S0 = jnp.zeros((B, H, P_, N), jnp.float32)
    xs_scan = (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(dtc, 1, 0),
               jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0),
               jnp.moveaxis(la, 1, 0))
    S, ys = jax.lax.scan(chunk_step, S0, xs_scan)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, P_)
    y = y + xh.reshape(B, T, H, P_) * p["D"][None, None, :, None]
    y = y.reshape(B, T, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    bufs = (_tail_buf(x_raw, K), _tail_buf(B_raw, K), _tail_buf(C_raw, K))
    return out, (S, bufs)


def mamba2_decode(x_t, state, p, cfg, ctx: ShardCtx):
    """x_t: [B, D]; state (S [B,H,P,N], (buf_x, buf_B, buf_C))."""
    from repro.models.layers import rms_norm
    S, (buf_x, buf_B, buf_C) = state
    di, N, P_ = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    H = cfg.ssm_nheads
    z = x_t @ p["in_proj_z"]
    x_raw = x_t @ p["in_proj_x"]
    B_raw = x_t @ p["in_proj_B"]
    C_raw = x_t @ p["in_proj_C"]
    dt_raw = x_t @ p["in_proj_dt"]
    xc, buf_x = causal_conv1d_step(x_raw, buf_x, p["conv_w_x"], p["conv_b_x"])
    Bc, buf_B = causal_conv1d_step(B_raw, buf_B, p["conv_w_B"], p["conv_b_B"])
    Cc, buf_C = causal_conv1d_step(C_raw, buf_C, p["conv_w_C"], p["conv_b_C"])
    xc, Bc, Cc = jax.nn.silu(xc), jax.nn.silu(Bc), jax.nn.silu(Cc)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B, H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xc.reshape(-1, H, P_).astype(jnp.float32)
    decay = jnp.exp(dt * A)  # [B, H]
    S = (decay[:, :, None, None] * S
         + jnp.einsum("bhp,bn,bh->bhpn", xh, Bc.astype(jnp.float32), dt))
    y = jnp.einsum("bhpn,bn->bhp", S, Cc.astype(jnp.float32))
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(-1, di).astype(x_t.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], (S, (buf_x, buf_B, buf_C))

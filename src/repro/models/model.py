"""Model assembly: one generic implementation per family, driven by ArchConfig.

Public surface:
    m = Model(cfg, ctx)
    params   = m.init(rng)
    logits   = m.forward(params, batch)                  # train / full forward
    out, kv  = m.prefill(params, batch)                  # fill caches
    cache    = m.init_cache(batch_size, max_seq)
    cache, logits = m.decode_step(params, cache, tokens) # one token
    m.param_logical_axes() / m.param_shapes() / m.input_specs(cell)

Params are plain dict pytrees; per-layer weights are stacked on a leading
"layers" axis and consumed with lax.scan (keeps HLO size O(1) in depth,
enables deterministic arena layout of one contiguous buffer per leaf).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeCell, SHAPE_CELLS
from repro.launch.mesh import ShardCtx
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    decode_attention_dense, decode_attention_seqpar, flash_attention,
    gelu_mlp, moe_capacity, moe_ffn, rms_norm, rope, swiglu)

Params = Dict[str, Any]


def _split_tree(rng, tree):
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(treedef, list(keys))


class Model:
    def __init__(self, cfg: ArchConfig, ctx: Optional[ShardCtx] = None):
        self.cfg = cfg
        self.ctx = ctx or ShardCtx(mesh=None)
        H, Hkv = cfg.num_heads, cfg.num_kv_heads
        # attention sharding mode (see DESIGN.md §4)
        self.q_shard = self.ctx.divides("heads", H) if H else False
        self.kv_shard = self.q_shard and self.ctx.divides("kv_heads", Hkv)
        self.dtype = jnp.dtype(cfg.param_dtype)

    # ------------------------------------------------------------------
    # parameter structure
    # ------------------------------------------------------------------
    def _attn_shapes(self):
        c = self.cfg
        return {
            "ln_attn": (c.d_model,),
            "wq": (c.d_model, c.num_heads * c.head_dim),
            "wk": (c.d_model, c.num_kv_heads * c.head_dim),
            "wv": (c.d_model, c.num_kv_heads * c.head_dim),
            "wo": (c.num_heads * c.head_dim, c.d_model),
        }

    def _attn_axes(self):
        fsdp = "fsdp" if self.cfg.zero_shard_params else None
        if self.q_shard:
            return {
                "ln_attn": (None,),
                "wq": (fsdp, "heads"),
                "wk": (fsdp, "kv_heads" if self.kv_shard else None),
                "wv": (fsdp, "kv_heads" if self.kv_shard else None),
                "wo": ("heads", fsdp),
            }
        return {"ln_attn": (None,), "wq": (fsdp, None), "wk": (fsdp, None),
                "wv": (fsdp, None), "wo": (fsdp, None)}

    def _mlp_shapes(self):
        c = self.cfg
        if c.family == "encoder":
            return {"ln_mlp": (c.d_model,), "w_up": (c.d_model, c.d_ff),
                    "b_up": (c.d_ff,), "w_down": (c.d_ff, c.d_model),
                    "b_down": (c.d_model,)}
        return {"ln_mlp": (c.d_model,), "w_gate": (c.d_model, c.d_ff),
                "w_up": (c.d_model, c.d_ff), "w_down": (c.d_ff, c.d_model)}

    def _mlp_axes(self):
        c = self.cfg
        fsdp = "fsdp" if c.zero_shard_params else None
        if c.family == "encoder":
            return {"ln_mlp": (None,), "w_up": (fsdp, "mlp"), "b_up": ("mlp",),
                    "w_down": ("mlp", fsdp), "b_down": (None,)}
        return {"ln_mlp": (None,), "w_gate": (fsdp, "mlp"),
                "w_up": (fsdp, "mlp"), "w_down": ("mlp", fsdp)}

    def _layer_shapes(self):
        c = self.cfg
        if c.family in ("dense", "vlm"):
            return {**self._attn_shapes(), **self._mlp_shapes()}
        if c.family == "encoder":
            return {**self._attn_shapes(), **self._mlp_shapes()}
        if c.family == "moe":
            d = {**self._attn_shapes(), "ln_mlp": (c.d_model,),
                 "router": (c.d_model, c.num_experts),
                 "we_gate": (c.num_experts, c.d_model, c.d_ff),
                 "we_up": (c.num_experts, c.d_model, c.d_ff),
                 "we_down": (c.num_experts, c.d_ff, c.d_model)}
            if c.moe_dense_residual:
                d.update({"wd_gate": (c.d_model, c.d_ff),
                          "wd_up": (c.d_model, c.d_ff),
                          "wd_down": (c.d_ff, c.d_model)})
            return d
        if c.family == "ssm":
            return {"ln": (c.d_model,), **ssm_mod.mamba1_param_shapes(c)}
        if c.family == "hybrid":
            return {"ln": (c.d_model,), **ssm_mod.mamba2_param_shapes(c)}
        raise ValueError(c.family)

    def _layer_axes(self):
        c = self.cfg
        fsdp = "fsdp" if c.zero_shard_params else None
        if c.family in ("dense", "vlm", "encoder"):
            return {**self._attn_axes(), **self._mlp_axes()}
        if c.family == "moe":
            d = {**self._attn_axes(), "ln_mlp": (None,),
                 "router": (fsdp, None),
                 "we_gate": ("experts", fsdp, None),
                 "we_up": ("experts", fsdp, None),
                 "we_down": ("experts", None, fsdp)}
            if c.moe_dense_residual:
                d.update({"wd_gate": (fsdp, "mlp"), "wd_up": (fsdp, "mlp"),
                          "wd_down": ("mlp", fsdp)})
            return d
        if c.family == "ssm":
            return {"ln": (None,), **ssm_mod.MAMBA1_PARAM_AXES}
        if c.family == "hybrid":
            return {"ln": (None,), **ssm_mod.MAMBA2_PARAM_AXES}
        raise ValueError(c.family)

    def _top_shapes(self):
        c = self.cfg
        d = {"final_norm": (c.d_model,)}
        if c.family != "encoder" or True:  # all families embed something
            d["embed"] = (c.padded_vocab, c.d_model)
        if not c.tie_embeddings:
            d["lm_head"] = (c.d_model, c.padded_vocab)
        if c.family == "hybrid":  # shared attention block (weights reused)
            d["shared"] = {**self._attn_shapes(), **self._mlp_shapes()}
        if c.frontend == "audio_stub":
            d["front_proj"] = (c.d_model, c.d_model)
        return d

    def _top_axes(self):
        c = self.cfg
        d = {"final_norm": (None,), "embed": ("vocab", None)}
        if not c.tie_embeddings:
            d["lm_head"] = (None, "vocab")
        if c.family == "hybrid":
            d["shared"] = {**self._attn_axes(), **self._mlp_axes()}
        if c.frontend == "audio_stub":
            d["front_proj"] = (None, None)
        return d

    def param_shapes(self):
        """Pytree of jax.ShapeDtypeStruct (no allocation)."""
        c = self.cfg
        L = c.num_layers
        layer = {k: (L,) + s for k, s in self._layer_shapes().items()}
        tree = {"layers": layer, **self._top_shapes()}
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s, self.dtype), tree,
            is_leaf=lambda x: isinstance(x, tuple))

    def param_logical_axes(self):
        layer = {k: ("layers",) + a for k, a in self._layer_axes().items()}
        return {"layers": layer, **self._top_axes()}

    def param_shardings(self):
        if self.ctx.mesh is None:
            return None
        shapes = self.param_shapes()
        axes = self.param_logical_axes()
        return jax.tree.map(
            lambda sd, ax: self.ctx.sharding(ax, sd.shape),
            shapes, axes, is_leaf=lambda x: isinstance(x, (tuple, jax.ShapeDtypeStruct)))

    def param_specs(self):
        """ShapeDtypeStructs with shardings attached (dry-run stand-ins)."""
        shapes = self.param_shapes()
        if self.ctx.mesh is None:
            return shapes
        shardings = self.param_shardings()
        return jax.tree.map(
            lambda sd, sh: jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=sh),
            shapes, shardings,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct) or x is None)

    def init(self, rng) -> Params:
        shapes = self.param_shapes()
        keys = _split_tree(rng, shapes)

        def one(key, sd):
            if len(sd.shape) <= 1:
                # vectors default to 0; norms/A_log/D are fixed up below
                return jnp.zeros(sd.shape, sd.dtype)
            fan_in = sd.shape[-2] if len(sd.shape) >= 2 else sd.shape[-1]
            std = 0.02
            return (jax.random.normal(key, sd.shape, jnp.float32) * std).astype(sd.dtype)

        params = jax.tree.map(one, keys, shapes)
        # norm scales start at 1; mamba dt_bias/A_log get sane starts
        def fix(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if name.startswith(("ln", "norm", "final_norm")):
                return jnp.ones_like(leaf)
            if name == "A_log":
                return jnp.zeros_like(leaf)  # A = -exp(0) = -1
            if name == "dt_bias":
                return jnp.full_like(leaf, math.log(math.e - 1))  # softplus->1.. mild
            if name == "D":
                return jnp.ones_like(leaf)
            return leaf
        params = jax.tree_util.tree_map_with_path(fix, params)
        if self.ctx.mesh is not None:
            params = jax.tree.map(jax.device_put, params, self.param_shardings())
        return params

    # ------------------------------------------------------------------
    # embedding / logits
    # ------------------------------------------------------------------
    def _embed(self, params, tokens):
        x = params["embed"][tokens]  # gather over vocab-sharded table
        return self.ctx.constrain(x, "batch", None, None)

    def _logits(self, params, x):
        head = params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        logits = (x @ head).astype(jnp.float32)
        return self.ctx.constrain(logits, "batch", None, "vocab")

    def _inputs_to_x(self, params, batch):
        """Map a batch dict to embedded inputs [B, S, D] (frontend stubs)."""
        c = self.cfg
        if c.family == "encoder":
            x = batch["frames"].astype(self.dtype) @ params["front_proj"]
            return self.ctx.constrain(x, "batch", None, None)
        x = self._embed(params, batch["tokens"])
        if c.family == "vlm" and "vision_embeds" in batch:
            v = batch["vision_embeds"].astype(x.dtype)
            x = jnp.concatenate([v, x], axis=1)
            x = self.ctx.constrain(x, "batch", None, None)
        return x

    # ------------------------------------------------------------------
    # attention block (full-sequence)
    # ------------------------------------------------------------------
    def _attn_full(self, x, lw, positions, with_cache: bool):
        c, ctx = self.cfg, self.ctx
        B, S, D = x.shape
        H, Hkv, Dh = c.num_heads, c.num_kv_heads, c.head_dim
        h = rms_norm(x, lw["ln_attn"], c.norm_eps)
        q = (h @ lw["wq"]).reshape(B, S, H, Dh)
        k = (h @ lw["wk"]).reshape(B, S, Hkv, Dh)
        v = (h @ lw["wv"]).reshape(B, S, Hkv, Dh)
        if self.q_shard:
            q = ctx.constrain(q, "batch", None, "heads", None)
        if self.kv_shard:
            k = ctx.constrain(k, "batch", None, "kv_heads", None)
            v = ctx.constrain(v, "batch", None, "kv_heads", None)
        q = rope(q, positions, c.rope_theta)
        k = rope(k, positions, c.rope_theta)
        attn = flash_attention(q, k, v, causal=c.causal, ctx=ctx)
        out = attn.reshape(B, S, H * Dh) @ lw["wo"]
        out = ctx.constrain(out, "batch", None, None)
        if with_cache:
            return out, (k, v)
        return out, None

    def _mlp(self, x, lw):
        c, ctx = self.cfg, self.ctx
        h = rms_norm(x, lw["ln_mlp"], c.norm_eps)
        if c.family == "encoder":
            return gelu_mlp(h, lw["w_up"], lw["b_up"], lw["w_down"],
                            lw["b_down"], ctx)
        return swiglu(h, lw["w_gate"], lw["w_up"], lw["w_down"], ctx)

    def _moe(self, x, lw, lossless: bool):
        c, ctx = self.cfg, self.ctx
        B, S, D = x.shape
        h = rms_norm(x, lw["ln_mlp"], c.norm_eps)
        cap = moe_capacity(c, S, lossless=lossless)
        out, aux = moe_ffn(h, lw["router"], lw["we_gate"], lw["we_up"],
                           lw["we_down"], top_k=c.top_k, capacity=cap, ctx=ctx)
        if c.moe_dense_residual:
            out = out + swiglu(h, lw["wd_gate"], lw["wd_up"], lw["wd_down"], ctx)
        return out, aux

    # ------------------------------------------------------------------
    # full-sequence forward (training / prefill)
    # ------------------------------------------------------------------
    def forward(self, params, batch, *, collect_cache: bool = False,
                cache_len: Optional[int] = None):
        """Returns (logits [B, S, Vp], aux_loss, cache_or_None)."""
        c, ctx = self.cfg, self.ctx
        x = self._inputs_to_x(params, batch)
        B, S, D = x.shape
        positions = jnp.arange(S)[None, :]

        if c.family in ("dense", "vlm", "encoder", "moe"):
            def block(carry, lw):
                x, aux = carry
                attn_out, kv = self._attn_full(
                    x, lw, positions, with_cache=collect_cache)
                x = x + attn_out
                if c.family == "moe":
                    mlp_out, a = self._moe(x, lw, lossless=False)
                    aux = aux + a
                else:
                    mlp_out = self._mlp(x, lw)
                x = ctx.constrain(x + mlp_out, "batch", None, None)
                return (x, aux), kv

            body = jax.checkpoint(block) if c.remat else block
            (x, aux), kvs = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                         params["layers"])
            cache = None
            if collect_cache:
                k_all, v_all = kvs  # [L, B, S, Hkv, Dh]
                cache = self._pack_attn_cache(k_all, v_all, S, cache_len)
            return self._logits(params, x), aux / c.num_layers, cache

        if c.family == "ssm":
            def block(carry, lw):
                x = carry
                h = rms_norm(x, lw["ln"], c.norm_eps)
                y, st = ssm_mod.mamba1_prefill(h, lw, c, ctx)
                x = ctx.constrain(x + y, "batch", None, None)
                return x, st if collect_cache else None

            body = jax.checkpoint(block) if c.remat else block
            x, sts = jax.lax.scan(body, x, params["layers"])
            cache = None
            if collect_cache:
                h_all, buf_all = sts
                cache = {"ssm_h": h_all, "conv": buf_all,
                         "lengths": jnp.full((B,), S, jnp.int32)}
            return self._logits(params, x), jnp.zeros((), jnp.float32), cache

        if c.family == "hybrid":
            return self._hybrid_forward(params, x, positions, collect_cache,
                                        cache_len)
        raise ValueError(c.family)

    def _hybrid_forward(self, params, x, positions, collect_cache, cache_len):
        """Zamba2: scan over super-blocks = (period mamba2 layers + shared attn)."""
        c, ctx = self.cfg, self.ctx
        B, S, D = x.shape
        period = c.shared_attn_period
        n_super = c.num_layers // period
        shared = params["shared"]

        # reshape stacked layers [L, ...] -> [n_super, period, ...]
        sup_layers = jax.tree.map(
            lambda a: a.reshape((n_super, period) + a.shape[1:]),
            params["layers"])

        def mamba_block(carry, lw):
            x = carry
            h = rms_norm(x, lw["ln"], c.norm_eps)
            y, st = ssm_mod.mamba2_prefill(h, lw, c, ctx)
            x = ctx.constrain(x + y, "batch", None, None)
            return x, st if collect_cache else None

        mb = jax.checkpoint(mamba_block) if c.remat else mamba_block

        def super_block(carry, slw):
            x = carry
            x, sts = jax.lax.scan(mb, x, slw)
            attn_out, kv = self._attn_full(x, shared, positions,
                                           with_cache=collect_cache)
            x = x + attn_out
            x = x + self._mlp(x, shared)
            x = ctx.constrain(x, "batch", None, None)
            return x, (sts, kv)

        x, (sts, kvs) = jax.lax.scan(super_block, x, sup_layers)
        cache = None
        if collect_cache:
            S_all, bufs = sts  # [n_super, period, ...]
            flat = lambda a: a.reshape((n_super * period,) + a.shape[2:])
            k_all, v_all = kvs  # [n_super, B, S, Hkv, Dh]
            attn_cache = self._pack_attn_cache(k_all, v_all, S, cache_len,
                                               n_layers=n_super)
            cache = {"ssm_h": flat(S_all),
                     "conv": jax.tree.map(flat, bufs),
                     **attn_cache}
        return self._logits(params, x), jnp.zeros((), jnp.float32), cache

    def _pack_attn_cache(self, k_all, v_all, S, cache_len, n_layers=None):
        """Pad prefill K/V [L,B,S,Hkv,Dh] to cache capacity, reorder to the
        cache layout, apply cache shardings."""
        cap = cache_len or S
        pad = cap - S
        if pad:
            pz = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
            k_all = jnp.pad(k_all, pz)
            v_all = jnp.pad(v_all, pz)
        if self.cache_layout == "bhsd":
            k_all = k_all.transpose(0, 1, 3, 2, 4)
            v_all = v_all.transpose(0, 1, 3, 2, 4)
        B = k_all.shape[1]
        axes = self.cache_logical_axes()
        k_all = self.ctx.constrain(k_all, *axes)
        v_all = self.ctx.constrain(v_all, *axes)
        return {"k": k_all, "v": v_all,
                "lengths": jnp.full((B,), S, jnp.int32)}

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    @property
    def cache_layout(self) -> str:
        """"bshd" [L,B,S,Hkv,Dh] (baseline) or head-major "bhsd"
        [L,B,Hkv,S,Dh] (transpose-free decode dots; FLAGS.kv_cache_head_major)."""
        from repro.models.tuning import FLAGS
        return "bhsd" if FLAGS.kv_cache_head_major else "bshd"

    def cache_logical_axes(self):
        if self.cache_layout == "bhsd":  # [L, B, Hkv, S, Dh]
            if self.kv_shard:
                return ("layers", "batch", "kv_heads", None, None)
            return ("layers", "batch", None, "kv_seq", None)
        # [L, B, S, Hkv, Dh]
        if self.kv_shard:
            return ("layers", "batch", None, "kv_heads", None)
        return ("layers", "batch", "kv_seq", None, None)

    def init_cache(self, batch_size: int, max_seq: int):
        """Zero-initialized cache pytree (engine path; dry-run uses specs)."""
        specs = self.cache_specs(batch_size, max_seq)
        def mk(sd):
            if sd.sharding is not None:
                return jax.device_put(jnp.zeros(sd.shape, sd.dtype), sd.sharding)
            return jnp.zeros(sd.shape, sd.dtype)
        return jax.tree.map(mk, specs)

    def cache_specs(self, B: int, S: int):
        """ShapeDtypeStructs (with shardings) for the decode cache."""
        c, ctx = self.cfg, self.ctx
        L, Hkv, Dh = c.num_layers, c.num_kv_heads, c.head_dim
        out = {}
        def sds(shape, axes, dtype=None):
            sh = ctx.sharding(axes, shape) if ctx.mesh is not None else None
            return jax.ShapeDtypeStruct(shape, dtype or self.dtype, sharding=sh)

        if c.family in ("dense", "vlm", "moe", "hybrid"):
            n_l = (c.num_layers // c.shared_attn_period
                   if c.family == "hybrid" else L)
            shape = ((n_l, B, Hkv, S, Dh) if self.cache_layout == "bhsd"
                     else (n_l, B, S, Hkv, Dh))
            axes = self.cache_logical_axes()
            out["k"] = sds(shape, axes)
            out["v"] = sds(shape, axes)
        if c.family == "ssm":
            di, N, K = c.d_inner, c.ssm_state, c.ssm_conv
            out["ssm_h"] = sds((L, B, di, N), ("layers", "batch", "ssm_inner", None),
                               jnp.float32)
            out["conv"] = sds((L, B, K - 1, di),
                              ("layers", "batch", None, "ssm_inner"))
        if c.family == "hybrid":
            di, N, K, H, P_ = (c.d_inner, c.ssm_state, c.ssm_conv,
                               c.ssm_nheads, c.ssm_head_dim)
            out["ssm_h"] = sds((L, B, H, P_, N),
                               ("layers", "batch", "ssm_heads", None, None),
                               jnp.float32)
            out["conv"] = (
                sds((L, B, K - 1, di), ("layers", "batch", None, "ssm_inner")),
                sds((L, B, K - 1, N), ("layers", "batch", None, None)),
                sds((L, B, K - 1, N), ("layers", "batch", None, None)))
        out["lengths"] = sds((B,), ("batch",), jnp.int32)
        return out

    # ------------------------------------------------------------------
    # paged decode (block-table KV; serving/blockpool.py)
    # ------------------------------------------------------------------
    def paged_cache_logical_axes(self):
        """Axes for the paged K/V pools [L, NB, bs, Hkv, Dh]. The pools
        carry no batch dim (blocks are shared across requests), so only the
        kv-head axis can shard; seqpar layouts stay on the slot pool."""
        if self.kv_shard:
            return ("layers", None, None, "kv_heads", None)
        return ("layers", None, None, None, None)

    def paged_cache_specs(self, B: int, S: int, n_blocks: int,
                          block_size: int):
        """ShapeDtypeStructs for the paged decode cache. Only block_tables
        and lengths are bucket-sized ([B, ...]); the K/V pools are identical
        across buckets, so every bucket's captured program closes over the
        same pool shapes and templates group exactly as before."""
        c, ctx = self.cfg, self.ctx
        if c.family not in ("dense", "vlm", "moe"):
            raise ValueError(f"{c.family} has no paged decode cache")
        L, Hkv, Dh = c.num_layers, c.num_kv_heads, c.head_dim
        MB = -(-S // block_size)

        def sds(shape, axes, dtype=None):
            sh = ctx.sharding(axes, shape) if ctx.mesh is not None else None
            return jax.ShapeDtypeStruct(shape, dtype or self.dtype, sharding=sh)

        axes = self.paged_cache_logical_axes()
        return {"block_tables": sds((B, MB), ("batch", None), jnp.int32),
                "k": sds((L, n_blocks, block_size, Hkv, Dh), axes),
                "lengths": sds((B,), ("batch",), jnp.int32),
                "v": sds((L, n_blocks, block_size, Hkv, Dh), axes)}

    def init_cache_paged(self, B: int, S: int, n_blocks: int,
                         block_size: int):
        """Zero-initialized paged cache pytree with valid dense block
        tables: row b owns consecutive physical blocks (scratch block 0
        backs any overflow). Benchmark/test-harness path — the serving
        engine builds its pool through ``PagedKVCachePool`` instead."""
        import numpy as np
        specs = self.paged_cache_specs(B, S, n_blocks, block_size)

        def mk(sd):
            z = jnp.zeros(sd.shape, sd.dtype)
            return jax.device_put(z, sd.sharding) if sd.sharding is not None \
                else z
        cache = jax.tree.map(mk, specs)
        MB = -(-S // block_size)
        bt = np.zeros((B, MB), np.int32)
        nb = 1
        for b in range(B):
            for j in range(MB):
                if nb < n_blocks:
                    bt[b, j] = nb
                    nb += 1
        tables = jnp.asarray(bt)
        sh = specs["block_tables"].sharding
        if sh is not None:
            tables = jax.device_put(tables, sh)
        return {**cache, "block_tables": tables}

    def _attn_decode_paged(self, x_t, lw, k_pool, v_pool, block_tables,
                           lengths):
        """One-token attention against a per-layer paged pool. The new K/V
        scatters into each row's current write slot (block_tables[row,
        length//bs], offset length%bs); attention gathers each row's blocks
        into a dense [B, MB*bs] view and reuses the masked dense kernel —
        padded rows point every table entry at the scratch block and their
        garbage is masked by ``pos <= length`` before the softmax."""
        c, ctx = self.cfg, self.ctx
        B, D = x_t.shape
        H, Hkv, Dh = c.num_heads, c.num_kv_heads, c.head_dim
        NB, bs = k_pool.shape[0], k_pool.shape[1]  # per-layer [NB,bs,Hkv,Dh]
        MB = block_tables.shape[1]
        h = rms_norm(x_t, lw["ln_attn"], c.norm_eps)
        q = (h @ lw["wq"]).reshape(B, 1, H, Dh)
        k = (h @ lw["wk"]).reshape(B, 1, Hkv, Dh)
        v = (h @ lw["wv"]).reshape(B, 1, Hkv, Dh)
        pos = lengths[:, None]
        q = rope(q, pos, c.rope_theta)
        k = rope(k, pos, c.rope_theta)
        # scatter new K/V: flatten blocks to [NB*bs, Hkv, Dh] positions.
        # Inactive rows all target scratch slot 0 — duplicate writes race
        # but the result is never read unmasked.
        wblk = block_tables[jnp.arange(B), jnp.clip(lengths // bs, 0, MB - 1)]
        widx = wblk * bs + lengths % bs
        kf = k_pool.reshape((NB * bs,) + k_pool.shape[2:])
        vf = v_pool.reshape((NB * bs,) + v_pool.shape[2:])
        kf = kf.at[widx].set(k[:, 0].astype(kf.dtype))
        vf = vf.at[widx].set(v[:, 0].astype(vf.dtype))
        k_pool = kf.reshape(k_pool.shape)
        v_pool = vf.reshape(v_pool.shape)
        # gather each row's table into a dense bshd view and mask-attend
        gidx = ((block_tables * bs)[:, :, None]
                + jnp.arange(bs)[None, None, :]).reshape(B, MB * bs)
        kd, vd = kf[gidx], vf[gidx]
        if self.kv_shard:
            kd = ctx.constrain(kd, "batch", None, "kv_heads", None)
            vd = ctx.constrain(vd, "batch", None, "kv_heads", None)
        out = decode_attention_dense(q, kd, vd, lengths, layout="bshd")
        out = out.reshape(B, H * Dh) @ lw["wo"]
        return ctx.constrain(out, "batch", None), k_pool, v_pool

    def decode_step_paged(self, params, cache, tokens):
        """Paged-layout decode step: same contract as ``decode_step`` but
        the cache pytree is {block_tables, k, lengths, v} with block-major
        pools. tokens: [B] int32 -> (cache', logits [B, Vp])."""
        c, ctx = self.cfg, self.ctx
        if c.family not in ("dense", "vlm", "moe"):
            raise ValueError(f"{c.family} has no paged decode step")
        lengths = cache["lengths"]
        bt = cache["block_tables"]
        x = self._embed(params, tokens[:, None])[:, 0]  # [B, D]

        def block(carry, xs):
            x = carry
            lw, kc, vc = xs
            a, kc, vc = self._attn_decode_paged(x, lw, kc, vc, bt, lengths)
            x = x + a
            if c.family == "moe":
                mo, _ = self._moe(x[:, None, :], lw, lossless=True)
                x = x + mo[:, 0, :]
            else:
                x = x + self._mlp(x, lw)
            return ctx.constrain(x, "batch", None), (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            block, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {**cache, "k": k_new, "v": v_new, "lengths": lengths + 1}
        logits = self._logits(params, x[:, None, :])[:, 0]
        return new_cache, logits

    def _attn_decode(self, x_t, lw, k_cache, v_cache, lengths):
        """One-token attention vs per-layer cache. x_t: [B, D].
        Returns (out [B, D], k_cache', v_cache')."""
        c, ctx = self.cfg, self.ctx
        B, D = x_t.shape
        H, Hkv, Dh = c.num_heads, c.num_kv_heads, c.head_dim
        h = rms_norm(x_t, lw["ln_attn"], c.norm_eps)
        q = (h @ lw["wq"]).reshape(B, 1, H, Dh)
        k = (h @ lw["wk"]).reshape(B, 1, Hkv, Dh)
        v = (h @ lw["wv"]).reshape(B, 1, Hkv, Dh)
        pos = lengths[:, None]  # new token position
        q = rope(q, pos, c.rope_theta)
        k = rope(k, pos, c.rope_theta)
        layout = self.cache_layout
        if self.kv_shard or ctx.mesh is None or not self._seqpar_axes():
            # write then attend (head-sharded or replicated cache)
            waxis = 1 if layout == "bhsd" else 0

            def write(cache, new, l):
                # new: [1, Hkv, Dh] -> bhsd update [Hkv, 1, Dh]
                upd = new.transpose(1, 0, 2) if layout == "bhsd" else new
                return jax.lax.dynamic_update_slice_in_dim(
                    cache, upd.astype(cache.dtype), l, axis=waxis)
            k_cache = jax.vmap(write)(k_cache, k, lengths)
            v_cache = jax.vmap(write)(v_cache, v, lengths)
            out = decode_attention_dense(q, k_cache, v_cache, lengths,
                                         layout=layout)
        else:
            out, k_cache, v_cache = decode_attention_seqpar(
                q, k_cache, v_cache, k[:, 0], v[:, 0], lengths,
                mesh=ctx.mesh, batch_axes=self._batch_axes(k_cache.shape[0]),
                seq_axes=self._seqpar_axes(), layout=layout)
        out = out.reshape(B, H * Dh) @ lw["wo"]
        return ctx.constrain(out, "batch", None), k_cache, v_cache

    def _batch_axes(self, B):
        axes = list(self.ctx.data_axes)
        import math as _m
        while axes and B % _m.prod(self.ctx.mesh.shape[a] for a in axes):
            axes.pop(0)
        return tuple(axes)

    def _seqpar_axes(self):
        """Mesh axes carrying the KV sequence dim in seqpar mode."""
        if self.ctx.mesh is None or self.kv_shard:
            return ()
        spec = self.ctx._resolve_dim("kv_seq", 1 << 30)  # divisibility-free probe
        if spec is None:
            return ()
        return (spec,) if isinstance(spec, str) else tuple(spec)

    def decode_step(self, params, cache, tokens):
        """tokens: [B] int32. Returns (cache', logits [B, Vp])."""
        c, ctx = self.cfg, self.ctx
        lengths = cache["lengths"]
        B = tokens.shape[0]
        x = self._embed(params, tokens[:, None])[:, 0]  # [B, D]

        if c.family in ("dense", "vlm", "moe"):
            def block(carry, xs):
                x = carry
                lw, kc, vc = xs
                a, kc, vc = self._attn_decode(x, lw, kc, vc, lengths)
                x = x + a
                if c.family == "moe":
                    mo, _ = self._moe(x[:, None, :], lw, lossless=True)
                    x = x + mo[:, 0, :]
                else:
                    x = x + self._mlp(x, lw)
                return ctx.constrain(x, "batch", None), (kc, vc)

            x, (k_new, v_new) = jax.lax.scan(
                block, x, (params["layers"], cache["k"], cache["v"]))
            new_cache = {**cache, "k": k_new, "v": v_new,
                         "lengths": lengths + 1}
        elif c.family == "ssm":
            def block(carry, xs):
                x = carry
                lw, h_l, buf_l = xs
                hN = rms_norm(x, lw["ln"], c.norm_eps)
                y, (h_l, buf_l) = ssm_mod.mamba1_decode(hN, (h_l, buf_l), lw, c, ctx)
                return ctx.constrain(x + y, "batch", None), (h_l, buf_l)

            x, (h_new, buf_new) = jax.lax.scan(
                block, x, (params["layers"], cache["ssm_h"], cache["conv"]))
            new_cache = {**cache, "ssm_h": h_new, "conv": buf_new,
                         "lengths": lengths + 1}
        elif c.family == "hybrid":
            x, new_cache = self._hybrid_decode(params, cache, x, lengths)
        else:
            raise ValueError(f"{c.family} has no decode step")

        logits = self._logits(params, x[:, None, :])[:, 0]
        return new_cache, logits

    def _hybrid_decode(self, params, cache, x, lengths):
        c, ctx = self.cfg, self.ctx
        period = c.shared_attn_period
        n_super = c.num_layers // period
        shared = params["shared"]
        resh = lambda a: a.reshape((n_super, period) + a.shape[1:])
        sup_layers = jax.tree.map(resh, params["layers"])
        sup_h = resh(cache["ssm_h"])
        sup_conv = jax.tree.map(resh, cache["conv"])

        def mamba_block(carry, xs):
            x = carry
            lw, h_l, bufs = xs
            hN = rms_norm(x, lw["ln"], c.norm_eps)
            y, (h_l, bufs) = ssm_mod.mamba2_decode(hN, (h_l, bufs), lw, c, ctx)
            return ctx.constrain(x + y, "batch", None), (h_l, bufs)

        def super_block(carry, xs):
            x = carry
            slw, h_s, conv_s, kc, vc = xs
            x, (h_s, conv_s) = jax.lax.scan(mamba_block, x, (slw, h_s, conv_s))
            a, kc, vc = self._attn_decode(x, shared, kc, vc, lengths)
            x = x + a
            x = x + self._mlp(x, shared)
            return ctx.constrain(x, "batch", None), (h_s, conv_s, kc, vc)

        x, (h_new, conv_new, k_new, v_new) = jax.lax.scan(
            super_block, x, (sup_layers, sup_h, sup_conv, cache["k"], cache["v"]))
        flat = lambda a: a.reshape((c.num_layers,) + a.shape[2:])
        new_cache = {**cache,
                     "ssm_h": flat(h_new),
                     "conv": jax.tree.map(flat, conv_new),
                     "k": k_new, "v": v_new,
                     "lengths": lengths + 1}
        return x, new_cache

    # ------------------------------------------------------------------
    # prefill wrapper + loss + input specs
    # ------------------------------------------------------------------
    def prefill(self, params, batch, cache_len: Optional[int] = None):
        logits, _, cache = self.forward(params, batch, collect_cache=True,
                                        cache_len=cache_len)
        return logits[:, -1], cache

    def loss_fn(self, params, batch):
        c = self.cfg
        logits, aux, _ = self.forward(params, batch)
        labels = batch["labels"]
        if c.family == "vlm":  # logits cover vision prefix + text
            logits = logits[:, -labels.shape[1]:]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        mask = (batch.get("loss_mask") if "loss_mask" in batch
                else jnp.ones_like(labels, jnp.float32))
        nll = ((lse - ll) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        return nll + 0.01 * aux, {"nll": nll, "aux": aux}

    def input_specs(self, shape_name: str):
        """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
        c, ctx = self.cfg, self.ctx
        cell = SHAPE_CELLS[shape_name]
        B, S = cell.global_batch, cell.seq_len

        def sds(shape, axes, dtype=jnp.int32):
            sh = ctx.sharding(axes, shape) if ctx.mesh is not None else None
            return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)

        if cell.kind in ("train", "prefill"):
            if c.family == "encoder":
                batch = {"frames": sds((B, S, c.d_model), ("batch", None, None),
                                       self.dtype)}
            elif c.family == "vlm":
                sv = c.frontend_seq
                batch = {"tokens": sds((B, S - sv), ("batch", None)),
                         "vision_embeds": sds((B, sv, c.d_model),
                                              ("batch", None, None), self.dtype)}
            else:
                batch = {"tokens": sds((B, S), ("batch", None))}
            if cell.kind == "train":
                lab_s = S - c.frontend_seq if c.family == "vlm" else S
                batch["labels"] = sds((B, lab_s), ("batch", None))
            return batch
        # decode: cache + one token
        return {"cache": self.cache_specs(B, S),
                "tokens": sds((B,), ("batch",))}

"""Production mesh + logical-axis sharding rules.

``make_production_mesh`` builds the assignment's target meshes:
  single-pod  (16, 16)      axes ("data", "model")        — 256 chips
  multi-pod   (2, 16, 16)   axes ("pod", "data", "model") — 512 chips

Importing this module never touches jax device state; meshes are built by
functions only (placeholder-device counts are set by the dry-run entrypoint
before any jax initialization).

Sharding is expressed through *logical axes* (MaxText-style): model code tags
tensor dims with names like "batch" / "heads" / "experts"; ``ShardCtx``
resolves them to mesh axes with divisibility fallbacks, so one model
implementation serves every (arch x mesh) combination.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh() -> Mesh:
    """1-device mesh for smoke tests / examples on this host."""
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def make_capture_mesh() -> Mesh:
    """The paper's single-device offline capture topology (§4.3): a (1, 1)
    ("data", "model") mesh on this host's first device. Archives captured on
    it are rank-stampable onto any shape-compatible deployment mesh
    (core/rank_stamp.py)."""
    return make_host_mesh()


def make_tp_mesh(n_model: int, n_data: int = 1) -> Mesh:
    """Tensor-parallel deployment mesh: (n_data, n_model) over
    ("data", "model")."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def mesh_device_count(mesh: Optional[Mesh]) -> int:
    """Total ranks of a deployment mesh (None -> 1: single-process serving)."""
    return 1 if mesh is None else int(mesh.devices.size)


@dataclass(frozen=True)
class MeshSpec:
    """Buildable description of a deployment mesh: (shape, axes) without
    committed devices. Policies that *switch* parallelism at runtime (the
    router's ``ReshardPolicy``, ``Fleet.reshard``) hold specs rather than
    concrete meshes so a topology can be named before — and independently
    of — the moment its devices are claimed. ``shape=()`` describes the
    un-meshed single-process topology (builds to ``None``)."""

    shape: tuple = ()
    axes: tuple = ("data", "model")

    def build(self) -> Optional[Mesh]:
        if not self.shape:
            return None
        return jax.make_mesh(tuple(self.shape), tuple(self.axes[:len(self.shape)]))

    def describe(self) -> str:
        if not self.shape:
            return "unmeshed"
        return "x".join(str(s) for s in self.shape)


def resolve_mesh(mesh_or_spec) -> Optional[Mesh]:
    """Accept a concrete ``Mesh``, a ``MeshSpec``, or ``None`` (un-meshed)
    wherever a deployment topology is taken (``Fleet.reshard``,
    router reshard policies)."""
    if isinstance(mesh_or_spec, MeshSpec):
        return mesh_or_spec.build()
    return mesh_or_spec


def describe_mesh(mesh: Optional[Mesh]) -> str:
    """Human-readable topology tag for reports ("unmeshed", "1x2", ...)."""
    if mesh is None:
        return "unmeshed"
    if isinstance(mesh, MeshSpec):
        return mesh.describe()
    return "x".join(str(s) for s in mesh.devices.shape)


# Default logical-axis -> mesh-axis candidates. Each entry is a tuple of mesh
# axes the logical axis WANTS to occupy; axes missing from the mesh or failing
# divisibility are dropped (in order), falling back to replication.
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),     # data parallelism
    "seq": (),                     # activations: unsharded by default
    "kv_seq": ("model",),          # KV-cache sequence (seqpar decode fallback)
    "embed": (),                   # d_model of activations
    "heads": ("model",),           # attention heads (tensor parallel)
    "kv_heads": ("model",),
    "mlp": ("model",),             # FFN intermediate
    "experts": ("model",),         # expert parallelism
    "vocab": ("model",),           # embedding / logits vocab
    "layers": (),                  # stacked-scan leading axis
    "fsdp": ("data",),             # ZeRO-3 param shard (contraction dim)
    "ssm_inner": ("model",),       # mamba d_inner channels
    "ssm_heads": ("model",),       # mamba2 heads
    "none": (),
}


@dataclass
class ShardCtx:
    """Resolves logical axes to shardings for a concrete mesh.

    mesh=None (or 1-device) degrades to no-op constraints so the same model
    code runs in smoke tests.
    """

    mesh: Optional[Mesh] = None
    rules: dict[str, tuple[str, ...]] = field(default_factory=lambda: dict(DEFAULT_RULES))

    def axis_size(self, names: tuple[str, ...]) -> int:
        if self.mesh is None:
            return 1
        return math.prod(self.mesh.shape.get(a, 1) for a in names)

    def _resolve_dim(self, logical: Optional[str], size: int):
        if self.mesh is None or logical is None:
            return None
        want = self.rules.get(logical, ())
        axes = [a for a in want if a in self.mesh.axis_names]
        # drop trailing axes until the product divides the dim size
        while axes and size % math.prod(self.mesh.shape[a] for a in axes):
            axes.pop()
        if not axes:
            return None
        return tuple(axes) if len(axes) > 1 else axes[0]

    def spec(self, logical_axes: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        assert len(logical_axes) == len(shape), (logical_axes, shape)
        resolved = [self._resolve_dim(l, s) for l, s in zip(logical_axes, shape)]
        # a mesh axis may appear at most once in a PartitionSpec
        seen: set[str] = set()
        out = []
        for r in resolved:
            names = (r,) if isinstance(r, str) else (r or ())
            if any(n in seen for n in names):
                out.append(None)
                continue
            seen.update(names)
            out.append(r)
        return P(*out)

    def sharding(self, logical_axes: Sequence[Optional[str]], shape: Sequence[int]):
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))

    def constrain(self, x, *logical_axes: Optional[str]):
        """with_sharding_constraint keyed by logical axes (no-op off-mesh)."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(logical_axes, x.shape)))

    # convenience predicates used by the model to pick attention modes
    def divides(self, logical: str, size: int) -> bool:
        want = self.rules.get(logical, ())
        axes = [a for a in want if self.mesh is not None and a in self.mesh.axis_names]
        if not axes:
            return False
        return size % math.prod(self.mesh.shape[a] for a in axes) == 0

    @property
    def model_axis_size(self) -> int:
        if self.mesh is None or "model" not in self.mesh.axis_names:
            return 1
        return self.mesh.shape["model"]

    @property
    def data_axes(self) -> tuple[str, ...]:
        if self.mesh is None:
            return ()
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

"""§Perf hillclimb driver: lower one (arch x shape) cell under a tuning-flag
configuration and print the roofline terms.

  PYTHONPATH=src python -m repro.launch.perf_lab --arch yi-9b \
      --shape decode_32k --flags mixed_precision_attn=1

Each EXPERIMENTS.md §Perf iteration is one baseline/flagged pair of runs.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse
import json

import jax

from repro.configs.base import SHAPE_CELLS
from repro.configs.registry import get_arch
from repro.launch.mesh import ShardCtx, make_production_mesh
from repro.models import tuning


def measure(arch: str, shape: str, flag_spec: str = "") -> dict:
    tuning.baseline()
    if flag_spec:
        for item in flag_spec.split(","):
            if item.strip():
                k, _, v = item.partition("=")
                tuning.set_flags(**{k.strip(): int(v)})
    jax.clear_caches()
    from repro.launch.dryrun import run_cell
    mesh = make_production_mesh()
    rec = run_cell(arch, shape, mesh, verbose=False)
    assert rec["status"] == "ok", rec
    r = rec["roofline"]
    return {
        "arch": arch, "shape": shape, "flags": flag_spec or "baseline",
        "compute_s": r["compute_s"], "memory_s": r["memory_s"],
        "collective_s": r["collective_s"], "dominant": r["dominant"],
        "bound_s": r["step_time_lower_bound_s"],
        "roofline_fraction": r["roofline_fraction"],
        "live_gb": rec["memory_analysis"]["live_bytes_per_device"] / 1e9,
        "fits_16g": rec["fits_16g_hbm"],
        "wire_by_kind": r["wire_bytes_by_kind"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPE_CELLS))
    ap.add_argument("--flags", default="")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rec = measure(args.arch, args.shape, args.flags)
    if args.json:
        print(json.dumps(rec, indent=1))
    else:
        print(f"[{rec['arch']} x {rec['shape']}] flags={rec['flags']}")
        print(f"  compute {rec['compute_s']:.4e}s  memory {rec['memory_s']:.4e}s"
              f"  collective {rec['collective_s']:.4e}s  -> dominant "
              f"{rec['dominant']}, bound {rec['bound_s']:.4e}s, "
              f"roofline {100 * rec['roofline_fraction']:.2f}%, "
              f"live {rec['live_gb']:.1f}GB fits16G={rec['fits_16g']}")


if __name__ == "__main__":
    main()

"""Serving launcher.

    # offline SAVE (one capture host; archive is rank-independent)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b-reduced \
        --save /tmp/qwen.fndry

    # online LOAD + serve a synthetic request stream
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b-reduced \
        --load /tmp/qwen.fndry --requests 16
"""
from __future__ import annotations

import argparse
import random
import time

import jax

from repro.configs.registry import get_arch
from repro.core import Archive
from repro.models.model import Model
from repro.serving.engine import ServingEngine


def build(arch: str, max_batch: int, max_seq: int) -> ServingEngine:
    cfg = get_arch(arch)
    eng = ServingEngine(Model(cfg), max_batch=max_batch, max_seq=max_seq,
                        bucket_mode="pow2")
    eng.load_weights(rng=jax.random.PRNGKey(0))
    return eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--save", default=None, help="write archive and exit")
    ap.add_argument("--load", default=None, help="archive to LOAD")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=96)
    args = ap.parse_args()

    eng = build(args.arch, args.max_batch, args.max_seq)
    if args.save:
        ar, rep = eng.save_archive(args.save, verbose=True)
        print(f"archive -> {args.save} "
              f"({rep['specs']['decode']['n_templates']} templates)")
        return

    t0 = time.perf_counter()
    if args.load:
        eng.cold_start_foundry(Archive.load(args.load), verbose=True)
        mode = "foundry"
    else:
        eng.cold_start_vanilla()
        mode = "vanilla"
    print(f"cold start ({mode}): {time.perf_counter() - t0:.3f}s")

    rng = random.Random(0)
    cfg = eng.cfg
    for _ in range(args.requests):
        prompt = [rng.randrange(1, cfg.vocab_size)
                  for _ in range(rng.randrange(2, 10))]
        eng.submit(prompt, rng.randrange(4, 12))
    t0 = time.perf_counter()
    steps = eng.run_until_drained()
    done = eng.scheduler.done
    toks = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in "
          f"{time.perf_counter() - t0:.2f}s ({steps} steps); "
          f"dispatch={eng.programs.stats if eng.programs else {}}")


if __name__ == "__main__":
    main()

"""Serving launcher.

    # offline SAVE (one capture host; archive is rank-independent)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b-reduced \
        --save /tmp/qwen.fndry

    # online LOAD + serve a synthetic request stream
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b-reduced \
        --load /tmp/qwen.fndry --requests 16

    # autoscaling fleet replaying a load spike against one shared archive
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b-reduced \
        --load /tmp/qwen.fndry --fleet --max-replicas 4 \
        --trace 10:25:30:1:6

    # multi-model gateway: a zoo of models behind one front door, each
    # scaling to zero when idle and reactivating from one shared depot
    PYTHONPATH=src python -m repro.launch.serve \
        --models qwen3-14b-reduced,smollm-360m-reduced --depot /tmp/depot \
        --zoo-rounds 2

    # phase-disaggregated fleet: wide prefill pool + narrow decode pool,
    # per-request KV handoff after the first token (docs §14); both pools
    # LOAD the same archive (the wide pool via rank stamping)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b-reduced \
        --load /tmp/qwen.fndry --fleet \
        --pools prefill=2:wide,decode=1:narrow --trace 10:25:30:1:6
"""
from __future__ import annotations

import argparse
import json
import random
import time

import jax

from repro.configs.registry import get_arch
from repro.core import Archive, TemplateDepot
from repro.models.model import Model
from repro.obs import configure_logging
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving.engine import ServingEngine
from repro.serving.fleet import AutoscalePolicy, Fleet, spike_trace
from repro.serving.router import ModelPolicy, ModelRouter


def build(arch: str, max_batch: int, max_seq: int,
          mesh=None) -> ServingEngine:
    cfg = get_arch(arch)
    if mesh is None:
        model = Model(cfg)
    else:
        from repro.launch.mesh import ShardCtx, resolve_mesh
        model = Model(cfg, ShardCtx(mesh=resolve_mesh(mesh)))
    eng = ServingEngine(model, max_batch=max_batch, max_seq=max_seq,
                        bucket_mode="pow2")
    eng.load_weights(rng=jax.random.PRNGKey(0))
    return eng


def parse_pools(spec: str):
    """``prefill=2:wide,decode=1:narrow`` -> [PoolSpec, ...].

    Each entry is ``phase=count[:mesh]`` where mesh is ``wide`` (every
    local device, via make_host_mesh — LOADed from the shared archive by
    rank stamping), ``narrow`` (un-meshed single device, the exact LOAD
    path), or an explicit ``AxB`` data x model shape."""
    from repro.launch.mesh import MeshSpec, make_host_mesh
    from repro.serving.fleet import PoolSpec
    pools = []
    for entry in spec.split(","):
        entry = entry.strip()
        if not entry:
            continue
        phase, eq, rest = entry.partition("=")
        count_s, _, mesh_s = rest.partition(":")
        if not eq or not count_s.isdigit():
            raise ValueError(
                f"bad --pools entry {entry!r}: want phase=count[:mesh]")
        n = int(count_s)
        mesh_s = mesh_s.strip().lower()
        if mesh_s in ("", "narrow"):
            mesh = None
        elif mesh_s == "wide":
            mesh = make_host_mesh()
        elif "x" in mesh_s:
            a, _, b = mesh_s.partition("x")
            mesh = MeshSpec((int(a), int(b)))
        else:
            raise ValueError(f"bad --pools mesh {mesh_s!r}: want "
                             f"wide | narrow | AxB")
        pools.append(PoolSpec(
            phase.strip(),
            AutoscalePolicy(min_replicas=n, max_replicas=n), mesh))
    if not pools:
        raise ValueError("--pools parsed to an empty pool list")
    return pools


def run_fleet(args):
    """--fleet: replay a spike trace against an autoscaling replica fleet.

    With --load, replicas cold-start from the shared (lazily-opened)
    archive; without it, a SAVE runs first in-process so the fleet still
    exercises the foundry path. --fleet-mode vanilla/eager selects the
    baseline cold starts instead."""
    if args.fleet_mode == "foundry":
        if args.load:
            archive = Archive.load(args.load)  # lazy: manifest-only parse
        else:
            print("[fleet] no --load given: running offline SAVE first")
            archive, _ = build(args.arch, args.max_batch,
                               args.max_seq).save_archive()
    else:
        archive = None

    warm, spike, cool, base, rate = (int(x) for x in args.trace.split(":"))
    trace = spike_trace(warm_ticks=warm, spike_ticks=spike, cool_ticks=cool,
                        base_rate=base, spike_rate=rate)
    if args.pools:
        # phase-disaggregated pools (docs §14): requests enter on the
        # prefill pool and migrate to decode via per-request KV handoff
        fleet = Fleet(
            factory_for_mesh=lambda m: build(args.arch, args.max_batch,
                                             args.max_seq, mesh=m),
            mode=args.fleet_mode, archive=archive,
            pools=parse_pools(args.pools), verbose=True)
    else:
        fleet = Fleet(lambda: build(args.arch, args.max_batch, args.max_seq),
                      mode=args.fleet_mode, archive=archive,
                      policy=AutoscalePolicy(min_replicas=args.min_replicas,
                                             max_replicas=args.max_replicas),
                      verbose=True)
    if args.chaos > 0:
        # supervised-fleet demo: kill N decode steps spread over the trace
        # and watch the fleet salvage + respawn (serving/faults.py)
        from repro.serving.faults import FaultPlan, FaultSpec
        span = max(1, (len(trace) * 2) // (args.chaos + 1))
        plan = FaultPlan(*[
            FaultSpec(site="engine.decode_step", nth=span * (k + 1), times=1,
                      message=f"chaos kill #{k + 1}")
            for k in range(args.chaos)])
        plan.activate()
        print(f"[fleet] chaos: {args.chaos} decode-step faults armed")
    try:
        fleet.run_trace(trace, seed=0)
    finally:
        if args.chaos > 0:
            plan.deactivate()
    fleet.drain_background()  # then re-report to pick up background_errors
    rep = fleet.report()
    s = rep.summary()
    print(json.dumps(s, indent=1, default=str))
    if fleet.disaggregated:
        w50, w95 = s["handoff_wait_p50_s"], s["handoff_wait_p95_s"]
        print(f"  handoffs: {rep.handoffs} adopted, "
              f"{rep.handoff_requeued} requeued"
              + (f", wait p50={w50 * 1e3:.1f}ms p95={w95 * 1e3:.1f}ms"
                 if w50 is not None else ""))
        for p in s["pools"]:
            p99 = p["step_wall_p99_s"]
            tail = f" step_p99={p99 * 1e3:.2f}ms" if p99 is not None else ""
            print(f"  pool {p['phase']}: replicas={p['ready']} "
                  f"mesh={p['mesh']} steps={p['steps']}{tail}")
    for r in rep.replicas:
        cs = r.cold_start_to_first_token_s
        print(f"  replica {r.replica_id}: mode={r.mode} "
              f"provision={r.provision_s and f'{r.provision_s:.2f}s'} "
              f"cold-start->first-token="
              f"{cs and f'{cs:.2f}s'} served={r.served_requests}")


def run_zoo(args):
    """--models a,b,c --depot PATH: multi-model gateway with scale-to-zero.

    Each model's archive is SAVEd into the depot if not already there
    (content-addressed: blobs shared across models are stored once), then a
    popularity-shifting workload runs through the ModelRouter as
    completion-paced phases with a post-phase quiet gap longer than the
    idle threshold — the hot model rotates, idle models deterministically
    drain to zero, and the next round's request for a cold model
    reactivates it from the shared depot (run_phases docstring)."""
    models = [m.strip() for m in args.models.split(",") if m.strip()]
    depot = TemplateDepot(args.depot)
    for name in models:
        if name not in depot:
            print(f"[zoo] SAVE {name} -> depot")
            ar, _ = build(name, args.max_batch, args.max_seq).save_archive()
            depot.put_archive(name, ar)
    st = depot.stats()
    print(f"[zoo] depot: {st['archives']} archives, {st['blobs']} blobs, "
          f"dedup {st['dedup_ratio']:.2f}x "
          f"({st['physical_comp_bytes'] / 1e6:.2f} MB on disk)")

    router = ModelRouter(verbose=True)
    for name in models:
        router.add_model(
            name, lambda n=name: build(n, args.max_batch, args.max_seq),
            archive=depot.open(name),
            policy=ModelPolicy(
                autoscale=AutoscalePolicy(min_replicas=args.min_replicas,
                                          max_replicas=args.max_replicas),
                idle_ticks_to_zero=args.zoo_idle_ticks))
    phases = [(name, args.zoo_requests) for _ in range(args.zoo_rounds)
              for name in models]
    router.run_phases(phases, seed=0, gap_ticks=args.zoo_idle_ticks + 20)
    router.deactivate_all()  # fold every fleet's accounting into the report
    print(json.dumps(router.report().summary(), indent=1, default=str))


def _serve_metrics_http(port: int):
    """Serve the live Prometheus exposition at /metrics on a daemon thread.
    Stdlib only; dies with the process (this is a demo endpoint, not a
    production server)."""
    import threading
    from http.server import BaseHTTPRequestHandler, HTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path.split("?")[0].rstrip("/") in ("", "/metrics"):
                body = obs_metrics.render().encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)
            else:
                self.send_error(404)

        def log_message(self, *a):  # keep serving output clean
            pass

    srv = HTTPServer(("127.0.0.1", port), Handler)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    print(f"metrics endpoint: http://127.0.0.1:{srv.server_address[1]}"
          f"/metrics")
    return srv


def _obs_setup(args):
    if args.metrics_port is not None and args.metrics is None:
        args.metrics = "-"
    if args.metrics is not None:
        obs_metrics.enable()
    if args.trace_out and not obs_trace.active():
        obs_trace.start()
    if args.metrics is not None or args.trace_out:
        configure_logging()
    if args.metrics_port is not None:
        _serve_metrics_http(args.metrics_port)


def _obs_finish(args):
    if args.trace_out and obs_trace.active():
        obs_trace.save(args.trace_out)
        obs_trace.stop()
        print(f"trace -> {args.trace_out}")
    if args.metrics is not None:
        text = obs_metrics.render()
        if args.metrics == "-":
            print("---- metrics ----")
            print(text, end="")
        else:
            with open(args.metrics, "w") as f:
                f.write(text)
            print(f"metrics -> {args.metrics}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch",
                    help="single-model serving (one of the registry names)")
    ap.add_argument("--save", default=None, help="write archive and exit")
    ap.add_argument("--load", default=None, help="archive to LOAD")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--fleet", action="store_true",
                    help="autoscaling replica fleet replaying --trace")
    ap.add_argument("--fleet-mode", default="foundry",
                    choices=("foundry", "vanilla", "eager"))
    ap.add_argument("--min-replicas", type=int, default=1)
    ap.add_argument("--max-replicas", type=int, default=4)
    ap.add_argument("--pools", default=None, metavar="SPEC",
                    help="with --fleet: phase-disaggregated pools, e.g. "
                         "'prefill=2:wide,decode=1:narrow' "
                         "(phase=count[:mesh]; mesh is wide | narrow | AxB; "
                         "requests prefill on one pool and migrate to the "
                         "other via per-request KV handoff, overriding "
                         "--min/--max-replicas)")
    ap.add_argument("--trace", default="10:25:30:1:6",
                    help="warm:spike:cool:base_rate:spike_rate ticks")
    ap.add_argument("--chaos", type=int, default=0,
                    help="with --fleet: inject N decode-step crashes spread "
                         "over the trace (supervision demo; replicas are "
                         "salvaged and respawned from the shared archive)")
    ap.add_argument("--models", default=None,
                    help="comma-separated model names: multi-model gateway "
                         "with per-model scale-to-zero (needs --depot)")
    ap.add_argument("--depot", default=None,
                    help="template depot directory (content-addressed, "
                         "shared across models)")
    ap.add_argument("--zoo-rounds", type=int, default=2,
                    help="popularity cycles over the model list (round 2+ "
                         "reactivates scaled-to-zero models)")
    ap.add_argument("--zoo-requests", type=int, default=4,
                    help="requests per hot-model phase")
    ap.add_argument("--zoo-idle-ticks", type=int, default=20,
                    help="idle ticks before a model scales to zero")
    ap.add_argument("--check", action="store_true",
                    help="run the static verifier (repro.analysis.check) "
                         "over --load/--depot before serving; refuse to "
                         "serve artifacts with error findings")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="enable the metrics registry and dump the "
                         "Prometheus text exposition to PATH at exit "
                         "('-' for stdout)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="N",
                    help="also serve the live exposition at "
                         "http://127.0.0.1:N/metrics (implies --metrics -)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record structured spans and write a "
                         "Chrome/Perfetto trace-event JSON to PATH at exit "
                         "(open in ui.perfetto.dev or chrome://tracing)")
    args = ap.parse_args()

    _obs_setup(args)
    try:
        _run(args, ap)
    finally:
        _obs_finish(args)


def _run(args, ap):
    if args.check:
        from repro.analysis.check import main as check_main
        targets = [t for t in (args.load, args.depot) if t]
        if not targets:
            ap.error("--check needs --load and/or --depot")
        code = check_main(targets + (["--depot", args.depot]
                                     if args.depot and args.load else []))
        if code >= 2:
            raise SystemExit(f"refusing to serve: static verification "
                             f"found errors (exit {code}); see findings "
                             f"above")
        print(f"[check] static verification passed ({len(targets)} "
              f"target(s))")

    if args.models:
        if not args.depot:
            ap.error("--models needs --depot")
        run_zoo(args)
        return
    if not args.arch:
        ap.error("--arch is required (or use --models/--depot)")

    if args.save:
        eng = build(args.arch, args.max_batch, args.max_seq)
        ar, rep = eng.save_archive(args.save, verbose=True)
        print(f"archive -> {args.save} "
              f"({rep['specs']['decode']['n_templates']} templates)")
        return

    if args.fleet:
        run_fleet(args)
        return

    eng = build(args.arch, args.max_batch, args.max_seq)
    t0 = time.perf_counter()
    if args.load:
        eng.cold_start_foundry(Archive.load(args.load), verbose=True)
        mode = "foundry"
    else:
        eng.cold_start_vanilla()
        mode = "vanilla"
    print(f"cold start ({mode}): {time.perf_counter() - t0:.3f}s")

    rng = random.Random(0)
    cfg = eng.cfg
    for _ in range(args.requests):
        prompt = [rng.randrange(1, cfg.vocab_size)
                  for _ in range(rng.randrange(2, 10))]
        eng.submit(prompt, rng.randrange(4, 12))
    t0 = time.perf_counter()
    steps = eng.run_until_drained()
    done = eng.scheduler.done
    toks = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in "
          f"{time.perf_counter() - t0:.2f}s ({steps} steps); "
          f"dispatch={eng.programs.stats if eng.programs else {}}")


if __name__ == "__main__":
    main()

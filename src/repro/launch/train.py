"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m-reduced \
        --steps 100 [--batch 8] [--seq 64] [--ckpt-dir /tmp/ckpt] [--resume]

Full-size configs target the production mesh (run under a pod launcher that
sets jax.distributed + real devices); reduced configs run on this host.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.registry import get_arch
from repro.launch.mesh import ShardCtx, make_host_mesh
from repro.models.model import Model
from repro.training.checkpoint import Checkpointer
from repro.training.data import DataConfig, SyntheticLMData
from repro.training.elastic import ElasticController, StragglerWatchdog
from repro.training.optimizer import OptConfig
from repro.training.train_loop import run_train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    model = Model(cfg, ShardCtx(mesh=None))
    opt = OptConfig(lr=args.lr, state_dtype=cfg.opt_state_dtype)
    data = SyntheticLMData(DataConfig(cfg.vocab_size, args.batch, args.seq))

    state = None
    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and ck and ck.latest_step() is not None:
        ec = ElasticController(cfg, opt, ck)
        model, state, extra = ec.resume(None)
        data.load_state_dict(extra["data"])
        print(f"resumed from step {ck.latest_step()}")

    class Shim:
        def save(self, s, step):
            ck.save(s, step, extra={"data": data.state_dict()}, async_=True)

    wd = StragglerWatchdog()
    state, hist = run_train_loop(
        model, opt, iter(data), num_steps=args.steps, state=state,
        rng=jax.random.PRNGKey(0),
        checkpointer=Shim() if ck else None,
        checkpoint_every=args.ckpt_every if ck else 0, watchdog=wd)
    if ck:
        ck.wait()
    if wd.flagged:
        print(f"straggler steps flagged: {wd.flagged}")


if __name__ == "__main__":
    main()

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

Proves the distribution config is coherent without real hardware: the SPMD
program for the production mesh is traced, lowered and compiled on this host
with placeholder devices (this single-host capture is also exactly Foundry's
offline SAVE topology — see DESIGN.md §1).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out f.json]

Per cell it records memory_analysis, cost_analysis, and the HLO-derived
roofline terms (repro.analysis.roofline) into a JSON report consumed by
EXPERIMENTS.md §Dry-run / §Roofline.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPE_CELLS
from repro.configs.registry import REGISTRY, ASSIGNED, get_arch
from repro.launch.mesh import ShardCtx, make_production_mesh
from repro.models.model import Model
from repro.training.optimizer import OptConfig
from repro.training.train_loop import make_train_step, train_state_specs


def build_step_and_specs(cfg, shape_name: str, ctx: ShardCtx):
    """Returns (step_fn, kwargs_of_specs, donate_argnums)."""
    model = Model(cfg, ctx)
    cell = SHAPE_CELLS[shape_name]
    if cell.kind == "train":
        opt_cfg = OptConfig(state_dtype=cfg.opt_state_dtype)
        step = make_train_step(model, opt_cfg)
        specs = {"state": train_state_specs(model, opt_cfg),
                 "batch": model.input_specs(shape_name)}
        return step, specs, (0,)
    if cell.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch)
        specs = {"params": model.param_specs(),
                 "batch": model.input_specs(shape_name)}
        return prefill_step, specs, ()
    # decode
    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    dec = model.input_specs(shape_name)
    specs = {"params": model.param_specs(), "cache": dec["cache"],
             "tokens": dec["tokens"]}
    return serve_step, specs, (1,)


def run_cell(arch: str, shape_name: str, mesh, *, verbose: bool = True,
             compute_roofline: bool = True) -> dict:
    cfg = get_arch(arch)
    skip = cfg.skip_reason(shape_name)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": dict(zip(mesh.axis_names, mesh.devices.shape))}
    if skip:
        rec["status"] = "skip"
        rec["skip_reason"] = skip
        return rec
    ctx = ShardCtx(mesh=mesh)
    step, specs, donate = build_step_and_specs(cfg, shape_name, ctx)
    args = tuple(specs.values())
    t0 = time.perf_counter()
    with mesh:
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax<=0.4.x: one dict per program
        ca = ca[0] if ca else {}
    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": {
            "argument_size_bytes": ma.argument_size_in_bytes,
            "output_size_bytes": ma.output_size_in_bytes,
            "temp_size_bytes": ma.temp_size_in_bytes,
            "alias_size_bytes": ma.alias_size_in_bytes,
            "generated_code_size_bytes": ma.generated_code_size_in_bytes,
        },
        "cost_analysis_raw": {
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
            "transcendentals": ca.get("transcendentals", 0.0),
        },
    })
    # per-device live bytes (args are donated where possible)
    live = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
            + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    rec["memory_analysis"]["live_bytes_per_device"] = live
    rec["fits_16g_hbm"] = bool(live <= 16 * 1024**3)
    if compute_roofline:
        from repro.analysis.roofline import roofline_from_compiled
        rec["roofline"] = roofline_from_compiled(
            compiled, cfg, SHAPE_CELLS[shape_name], mesh)
    if verbose:
        print(f"[{arch} x {shape_name}] lower {t_lower:.1f}s "
              f"compile {t_compile:.1f}s live/dev "
              f"{live / 1e9:.2f} GB fits16G={rec['fits_16g_hbm']}")
        print("  memory_analysis:", rec["memory_analysis"])
        print("  cost_analysis:", rec["cost_analysis_raw"])
        if compute_roofline:
            r = rec["roofline"]
            print(f"  roofline: compute {r['compute_s']:.3e}s "
                  f"memory {r['memory_s']:.3e}s collective "
                  f"{r['collective_s']:.3e}s dominant={r['dominant']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id")
    ap.add_argument("--shape", default=None, choices=list(SHAPE_CELLS))
    ap.add_argument("--all", action="store_true",
                    help="sweep all assigned (arch x shape) cells")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2-pod (2,16,16) mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="JSON report path")
    ap.add_argument("--no-roofline", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(), make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    cells = []
    if args.all:
        for cfg in ASSIGNED:
            for shape in SHAPE_CELLS:
                cells.append((cfg.name, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells.append((args.arch, args.shape))

    records = []
    failures = 0
    for mesh in meshes:
        for arch, shape in cells:
            try:
                rec = run_cell(arch, shape, mesh,
                               compute_roofline=not args.no_roofline)
            except Exception as e:  # a failure here is a bug in the system
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape,
                       "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
                       "status": "fail", "error": f"{type(e).__name__}: {e}"}
                failures += 1
            records.append(rec)
            if args.out:
                with open(args.out, "w") as f:
                    json.dump(records, f, indent=1)
    ok = sum(1 for r in records if r["status"] == "ok")
    sk = sum(1 for r in records if r["status"] == "skip")
    print(f"\ndry-run: {ok} ok, {sk} documented skips, {failures} failures")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

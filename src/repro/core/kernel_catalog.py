"""Kernel binary extraction + reload (paper §4.1.2 / §5.2).

CUDA side: kernel modules are lazily loaded on first launch; a process that
skips warmup can't replay a graph whose nodes reference unloaded kernels.
Foundry extracts module binaries at SAVE and restores them by
(content_hash, mangled_name) at LOAD, skipping warmup, torch.compile and
Triton autotuning.

JAX/TPU side: the analogous lazily-created state is the per-kernel lowering +
autotuning work of custom (Pallas) kernels — block-shape autotuning and
StableHLO lowering happen on first use. The catalog stores, per kernel
instance:
    payload  : the lowered kernel artifact (StableHLO bytes), content-hashed
    name     : entry name mangled with the shape/dtype signature
    options  : tuning decisions (block sizes) — the "load options" the paper
               replays so LOAD issues the same driver call
    needs_device_init : kernels that require collective/mesh state before use
               (paper: NVSHMEM's nvshmemx_cumodule_init; here: shard_map'd
               kernels that must be bound to a live mesh)

``repro.kernels.ops`` consults the catalog before autotuning: a primed
catalog turns first-use tuning+lowering into a dict lookup.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from repro.core.archive import Archive, content_hash


def mangle(kernel: str, shapes, dtypes, **static) -> str:
    sig = ",".join(f"{tuple(s)}" for s in shapes)
    dt = ",".join(str(d) for d in dtypes)
    st = ",".join(f"{k}={v}" for k, v in sorted(static.items()))
    return f"{kernel}({sig};{dt};{st})"


@dataclass
class CatalogEntry:
    name: str
    payload_hash: str
    options: Dict[str, Any]
    needs_device_init: bool = False


class KernelCatalog:
    def __init__(self):
        self.entries: Dict[str, CatalogEntry] = {}   # name -> entry
        self._payloads: Dict[str, bytes] = {}        # hash -> payload
        self.stats = {"hits": 0, "misses": 0, "autotune_skipped": 0}

    # -- SAVE side --------------------------------------------------------
    def record(self, name: str, payload: bytes, options: Dict[str, Any],
               needs_device_init: bool = False) -> CatalogEntry:
        h = content_hash(payload)
        self._payloads[h] = payload
        e = CatalogEntry(name, h, dict(options), needs_device_init)
        self.entries[name] = e
        return e

    def to_manifest(self) -> dict:
        return {"entries": {n: {"payload_hash": e.payload_hash,
                                "options": e.options,
                                "needs_device_init": e.needs_device_init}
                            for n, e in self.entries.items()}}

    def add_blobs(self, archive: Archive):
        for h, p in self._payloads.items():
            archive.add_blob(p)

    # -- LOAD side ---------------------------------------------------------
    def prime(self, manifest: dict, archive: Archive):
        """Restore all entries from an archive (paper: load binaries into the
        driver up front so graph reconstruction resolves (hash, name) keys
        without lazy loading)."""
        for name, m in manifest.get("entries", {}).items():
            e = CatalogEntry(name, m["payload_hash"], dict(m["options"]),
                             m.get("needs_device_init", False))
            self.entries[name] = e
            if e.payload_hash in archive.blobs:
                self._payloads[e.payload_hash] = archive.get_blob(e.payload_hash)

    def resolve(self, name: str) -> Optional[CatalogEntry]:
        e = self.entries.get(name)
        if e is None:
            self.stats["misses"] += 1
            return None
        self.stats["hits"] += 1
        return e

    def payload(self, e: CatalogEntry) -> Optional[bytes]:
        data = self._payloads.get(e.payload_hash)
        if data is not None and content_hash(data) != e.payload_hash:
            raise ValueError(f"kernel payload {e.name} corrupt")
        return data

    def options_for(self, name: str) -> Optional[Dict[str, Any]]:
        e = self.resolve(name)
        if e is None:
            return None
        self.stats["autotune_skipped"] += 1
        return e.options


# process-global catalog used by repro.kernels.ops (engine wires archives in)
GLOBAL_CATALOG = KernelCatalog()

"""Rank-stamping LOAD: one capture serves every rank (paper §4.3).

The paper's headline distributed result is that a *single-GPU* offline
capture can materialize the serving context of every rank of a multi-GPU
deployment: the compiled graph is rank-invariant, and only communication
state — NCCL peer tables, mesh coordinates, communication-buffer offsets —
differs per rank, so LOAD patches ("stamps") those deltas into the shared
template instead of recompiling per deployment shape.

The JAX analogue implemented here:

  * ``RankDelta`` is the per-rank record of rank-dependent state: the rank's
    mesh coordinates, its collective peer group per mesh axis (the
    communicator membership; core/collective_stub.py derives it from the
    mesh), and its rank-relative buffer table (core/memory_plan.py
    ``rank_extents``). SAVE writes the *capture* deltas into the archive
    manifest (v2, ``rank_delta`` section); LOAD re-derives them for the
    deployment mesh.
  * ``StampedExecutable`` wraps the template executable deserialized from
    the archive and rebinds it to the deployment: dispatch re-lays inputs
    onto the template's recorded shardings (the XLA counterpart of patching
    kernel pointer arguments in cuGraphExecUpdate) and carries the
    deployment's rank deltas. No compiler or trace work happens — the
    template's serialized program is reused byte-identically, which is why
    shape-compatible rebinds keep ``LoadReport.fallback_compiles == 0``.

Stamp compatibility (``collective_stub.stamp_compatible``): a 1-rank capture
stamps onto any deployment shape, and an N-rank capture stamps onto any
N-rank re-arrangement (TP<->EP style switches). A true scale change of a
multi-rank capture still takes the compile-from-StableHLO fallback.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.collective_stub import (identity_device_count, mesh_identity,
                                        peer_groups, rank_coords)
from repro.core.memory_plan import MemoryPlan


@dataclass
class RankDelta:
    """Everything about one rank that the shared template does NOT encode.

    Fields:
        rank          flat rank id in row-major mesh order.
        coords        this rank's coordinates in the deployment mesh.
        peer_groups   mesh axis -> the peer group (flat ranks) this rank
                      performs collectives with over that axis.
        comm_buffers  rank-relative buffer table: [{name, offset, size,
                      scope}] where "per_rank"-scoped allocations are this
                      rank's 1/n shard of the capture-recorded buffer.
    """
    rank: int
    coords: Tuple[int, ...] = ()
    peer_groups: Dict[str, List[int]] = field(default_factory=dict)
    comm_buffers: List[dict] = field(default_factory=list)

    def to_manifest(self) -> dict:
        return {"rank": self.rank, "coords": list(self.coords),
                "peer_groups": {k: list(v) for k, v in self.peer_groups.items()},
                "comm_buffers": [dict(b) for b in self.comm_buffers]}

    @classmethod
    def from_manifest(cls, m: dict) -> "RankDelta":
        return cls(rank=int(m["rank"]), coords=tuple(m.get("coords", ())),
                   peer_groups={str(k): [int(r) for r in v]
                                for k, v in m.get("peer_groups", {}).items()},
                   comm_buffers=[dict(b) for b in m.get("comm_buffers", [])])


def build_rank_deltas(identity: dict,
                      memory_plan: Optional[MemoryPlan] = None) -> List[RankDelta]:
    """Derive the per-rank deltas for a mesh identity ({"axes", "shape"}).

    SAVE calls this with the capture mesh (recording which state is
    rank-dependent); LOAD calls it with the deployment mesh (producing the
    state to stamp). An empty/absent mesh yields the single rank 0.
    """
    shape = list(identity.get("shape") or [])
    axes = list(identity.get("axes") or [])
    n = identity_device_count(identity)
    coords = rank_coords(shape)
    groups = peer_groups(shape, axes)
    buffers = memory_plan.rank_extents(n) if memory_plan is not None else []
    deltas = []
    for r in range(n):
        mine = {ax: next(g for g in rows if r in g)
                for ax, rows in groups.items()}
        deltas.append(RankDelta(rank=r, coords=coords[r],
                                peer_groups=mine, comm_buffers=buffers))
    return deltas


def deployment_deltas(mesh, manifest: dict) -> List[RankDelta]:
    """Re-derive rank deltas for the deployment mesh from an archive
    manifest (uses the archived memory plan for rank-relative offsets)."""
    plan = None
    if manifest.get("memory_plan"):
        plan = MemoryPlan.from_manifest(manifest["memory_plan"])
    return build_rank_deltas(mesh_identity(mesh), plan)


class ReshardingExecutable:
    """Dispatch wrapper that re-lays positional args onto the shardings the
    wrapped executable was compiled with (``Compiled.input_shardings``)
    before calling it — the thing that lets an executable compiled under one
    mesh accept deployment-mesh-committed arrays under another.

    Donated args (``donate_argnums``, recorded in the archive manifest at
    SAVE) are materialized through ``jnp.copy`` so the wrapped executable
    only ever donates buffers this wrapper owns. This mirrors the paper's
    replay discipline (parameters are patched into graph-owned buffers,
    cuGraphExecUpdate-style, never borrowed from the caller) and is also
    load-bearing here: XLA-CPU (jax 0.4.x) crashes — heap corruption /
    segfault, reproduced 200/200 trials without the copy — when a
    *deserialized* executable donates a buffer produced by ``device_put`` or
    aliased by the caller. Copies of XLA-computation outputs donate safely,
    and non-donated args need no copy (verified 300 trials). When the donate
    set is unknown (``donate_argnums=None``), every arg is copied.

    Feedback fast path (device-resident decode): leaves of the wrapper's own
    *previous* outputs are provably XLA-computation outputs with the exact
    shardings this executable produces, so when the caller feeds them back
    (cache' of step k donated into step k+1) they are passed through with no
    copy and no device_put. Steady-state decode therefore donates the KV
    cache truly in place; the copy only triggers for host-touched leaves
    (fresh pools, ``device_put``-resharded rows, prefill-mutated leaves).
    Ownership is tracked by identity of the last call's output leaves — the
    engine holds those same objects until it passes them back, so the ids
    cannot have been recycled.
    """

    is_stamped = False

    def __init__(self, executable: Any,
                 donate_argnums: Optional[Sequence[int]] = None):
        self._exe = executable
        self._donate = (None if donate_argnums is None
                        else frozenset(int(i) for i in donate_argnums))
        self._owned: Dict[int, Any] = {}  # id -> leaf of the last output
        try:
            self._in_shardings = executable.input_shardings[0]
        except Exception:
            self._in_shardings = None

    def _owns(self, leaf) -> bool:
        return self._owned.get(id(leaf)) is leaf

    def _rebind(self, i, arg, sharding):
        if not (self._donate is None or i in self._donate):
            return jax.device_put(arg, sharding) if sharding is not None else arg
        leaves, treedef = jax.tree.flatten(arg)
        if all(map(self._owns, leaves)):
            return arg  # pure feedback of our own output: donation-safe as-is
        put = jax.device_put(arg, sharding) if sharding is not None else arg
        out = [pl if (pl is ol and self._owns(ol)) else jnp.copy(pl)
               for ol, pl in zip(leaves, jax.tree.leaves(put))]
        return jax.tree.unflatten(treedef, out)

    def __call__(self, *args):
        shardings = (self._in_shardings if self._in_shardings is not None
                     else (None,) * len(args))
        args = tuple(self._rebind(i, a, s)
                     for i, (a, s) in enumerate(zip(args, shardings)))
        out = self._exe(*args)
        # Remember only the latest outputs: they are the only buffers the
        # caller can legally feed back for donation (older ones were already
        # donated away). Strong refs are free — the previous outputs are the
        # current inputs, already consumed.
        self._owned = {id(l): l for l in jax.tree.leaves(out)}
        return out


class StampedExecutable(ReshardingExecutable):
    """A template executable rebound to a deployment mesh by rank stamping.

    Dispatch re-lays each positional argument onto the sharding the template
    was compiled with, then replays the template program unchanged — the
    data-movement analogue of patching pointer arguments into a captured
    CUDA graph, with zero compiler work. The deployment's ``rank_deltas``
    ride along for introspection and for the serving engine's cold-start
    report.
    """

    is_stamped = True

    def __init__(self, executable: Any, rank_deltas: Sequence[RankDelta],
                 capture_identity: dict, deploy_identity: dict,
                 donate_argnums: Optional[Sequence[int]] = None):
        super().__init__(executable, donate_argnums)
        self.rank_deltas = list(rank_deltas)
        self.capture_identity = dict(capture_identity)
        self.deploy_identity = dict(deploy_identity)
        self.stamp_dispatches = 0

    @property
    def n_ranks(self) -> int:
        return len(self.rank_deltas)

    def __call__(self, *args):
        self.stamp_dispatches += 1
        return super().__call__(*args)


def stamp_template(executable: Any, rank_deltas: Sequence[RankDelta],
                   capture_identity: dict, mesh,
                   donate_argnums: Optional[Sequence[int]] = None
                   ) -> StampedExecutable:
    """Stamp a deserialized template for the deployment ``mesh``."""
    return StampedExecutable(executable, rank_deltas, capture_identity,
                             mesh_identity(mesh) if mesh is not None
                             else {"axes": [], "shape": []},
                             donate_argnums)

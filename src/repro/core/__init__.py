"""Foundry core: template-based compiled-graph context materialization.

Paper mechanism -> module map (see DESIGN.md §1 for the full table):
    archive.py          portable SAVE output (manifest + content-hashed blobs)
    depot.py            content-addressed multi-archive store (dedup + GC)
    topology.py         topology keys over jaxprs (templating)
    templates.py        grouping + template dispatch (pad / exact swap)
    memory_plan.py      deterministic monotonic arena (VMM interposition)
    kernel_catalog.py   kernel binary extraction/reload ((hash, name) keyed)
    collective_stub.py  single-host multi-device capture topology + peer state
    rank_stamp.py       single-capture -> multi-rank template stamping (§4.3)
    materialize.py      SAVE
    restore.py          LOAD (exact / stamped / fallback rebind decision)
"""
from repro.core.archive import Archive, content_hash
from repro.core.depot import TemplateDepot
from repro.core.collective_stub import (mesh_identity, peer_groups,
                                        rank_coords, same_topology,
                                        stamp_compatible)
from repro.core.kernel_catalog import GLOBAL_CATALOG, KernelCatalog, mangle
from repro.core.materialize import CaptureSpec, foundry_save
from repro.core.memory_plan import MemoryPlan, PlanMismatch
from repro.core.rank_stamp import (RankDelta, ReshardingExecutable,
                                   StampedExecutable, build_rank_deltas,
                                   deployment_deltas, stamp_template)
from repro.core.restore import LoadReport, foundry_load, wait_for_background
from repro.core.templates import (ProgramSet, TopologyGroup,
                                  default_bucket_ladder, group_buckets,
                                  pad_batch_arg)
from repro.core.topology import jaxpr_topology_key, topology_key

__all__ = [
    "Archive", "TemplateDepot", "content_hash",
    "KernelCatalog", "GLOBAL_CATALOG", "mangle",
    "CaptureSpec", "foundry_save", "MemoryPlan", "PlanMismatch",
    "LoadReport", "foundry_load", "wait_for_background", "ProgramSet",
    "TopologyGroup", "default_bucket_ladder", "group_buckets",
    "pad_batch_arg", "jaxpr_topology_key", "topology_key",
    "RankDelta", "ReshardingExecutable", "StampedExecutable",
    "build_rank_deltas", "deployment_deltas", "stamp_template",
    "mesh_identity", "peer_groups", "rank_coords", "same_topology",
    "stamp_compatible",
]

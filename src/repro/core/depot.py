"""Template depot: a content-addressed, on-disk repository for MANY archives.

One ``Archive`` file is the unit the paper's SAVE produces; a serving fleet
hosting a model zoo has dozens of them — and their blobs repeat heavily
(kernel binaries, topology templates and StableHLO exports are identical
across meshes, bucket ladders and often across models of the same family).
The depot stores every archive's blobs in ONE shared store, keyed by content
hash, so each distinct blob exists exactly once on disk no matter how many
archives reference it (HydraServe / "Breaking the Ice": the many-model,
shifting-popularity serving scenario where per-model state must be cheap).

On-disk layout (``docs/architecture.md`` §7):

    <root>/
      blobs/<hash>            one individually-compressed blob per file
                              (codec sniffed on read, like archive blobs)
      manifests/<name>.fndry  thin v2 container per archive: manifest +
                              blob index, ``depot`` flag, NO blob section
      index.json              {blobs: {hash: {comp_len, raw_len, refs}},
                               archives: {name: {file, blob_hashes, ...}}}

Sharing semantics: the depot owns ONE ``BlobStore`` (``self.store``) whose
index spans every deposited blob and whose source reads ``blobs/<hash>``
files. Every archive opened through the depot binds to that store, so the
fetch-once guarantee of ``core/archive.py`` becomes depot-wide: N fleets
serving N models from one depot read + decompress + verify each shared blob
at most once per process, under the store's single-flight lock.

Garbage collection is ref-counted at archive granularity: each archive file
holds one reference on each of its blobs; ``remove_archive`` drops them and
``gc()`` deletes blob files nothing references. Blob writes are atomic
(tmp + rename) and idempotent (content-addressed), so concurrent writers of
the same blob race harmlessly.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Callable, Dict, List

from repro.core.archive import (Archive, BlobStore, _compress, content_hash,
                                io_retries)
from repro.obs import metrics as obs_metrics

_INDEX_VERSION = 1

# docs/architecture.md §13 has the full metric catalog
_M_DEDUP_HITS = obs_metrics.counter(
    "depot_dedup_hits_total",
    "ensure_blob calls satisfied by an already-deposited blob "
    "(data_fn never called, nothing written).")
_M_DEPOSITS = obs_metrics.counter(
    "depot_blobs_written_total",
    "Blobs compressed and deposited into the content-addressed store.")
_M_DEDUP_RATIO = obs_metrics.gauge(
    "depot_dedup_ratio",
    "Logical raw bytes over physical raw bytes (refreshed by stats()).")


class _DepotSource:
    """BlobStore source over a depot's ``blobs/`` directory. The content
    hash is the address (``read_hash``); there are no offsets."""

    def __init__(self, blob_dir: str):
        self._dir = blob_dir

    def read_hash(self, h: str) -> bytes:
        # the depot's network-storage analogue: a blob mid-replication (or a
        # flaky mount) reads again with bounded backoff before the failure
        # surfaces to the (also retrying) BlobStore fetch
        def _read():
            with open(os.path.join(self._dir, h), "rb") as f:
                return f.read()
        return io_retries(_read, f"depot blob {h}")


class TemplateDepot:
    """Content-addressed multi-archive repository (module docstring).

    Mutating calls (``put_archive``/``remove_archive``/``gc``/``ensure_blob``)
    are serialized by an in-process lock and persist the index atomically;
    reads go through the shared lock-protected ``BlobStore`` and need no
    depot lock.
    """

    def __init__(self, root: str):
        self.root = os.path.abspath(root)
        self.blob_dir = os.path.join(self.root, "blobs")
        self.manifest_dir = os.path.join(self.root, "manifests")
        os.makedirs(self.blob_dir, exist_ok=True)
        os.makedirs(self.manifest_dir, exist_ok=True)
        self._lock = threading.RLock()
        self._index = self._read_index()
        self.store = BlobStore(
            index={h: (0, meta["comp_len"], meta["raw_len"])
                   for h, meta in self._index["blobs"].items()},
            source=_DepotSource(self.blob_dir))

    # -- index persistence ----------------------------------------------
    @property
    def _index_path(self) -> str:
        return os.path.join(self.root, "index.json")

    def _read_index(self) -> Dict[str, Any]:
        try:
            with open(self._index_path) as f:
                doc = json.load(f)
            if doc.get("version") == _INDEX_VERSION:
                return doc
        except (OSError, ValueError):
            pass
        return {"version": _INDEX_VERSION, "blobs": {}, "archives": {}}

    def _flush(self) -> None:
        # Unique temp per writer (pid + thread), fsync'd before the rename:
        # two processes flushing one depot must not interleave writes into a
        # shared ".tmp", and a crash between write and rename must leave the
        # published index either old or new, never torn. The fsck pass
        # (repro.analysis.checker.check_depot) flags a torn index.json as
        # "depot-index"; tests/test_checker.py regression-tests both cases.
        tmp = (f"{self._index_path}.tmp.{os.getpid()}"
               f".{threading.get_ident()}")
        try:
            with open(tmp, "w") as f:
                json.dump(self._index, f, indent=1, sort_keys=True)
                f.write("\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self._index_path)  # atomic publish
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)

    # -- blob plane ------------------------------------------------------
    def ensure_blob(self, h: str, data_fn: Callable[[], bytes],
                    level: int = 3) -> tuple:
        """Deposit blob ``h`` unless already present (the dedup hit: presence
        is a dict lookup; ``data_fn`` is only called on a miss). Returns
        ``(comp_len, raw_len)``."""
        with self._lock:
            meta = self._index["blobs"].get(h)
            if meta is not None:
                _M_DEDUP_HITS.inc()
                return meta["comp_len"], meta["raw_len"]
        data = data_fn()
        if content_hash(data) != h:
            raise ValueError(f"depot blob {h} failed content verification")
        comp = _compress(data, level)
        path = os.path.join(self.blob_dir, h)
        if not os.path.exists(path):
            tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
            with open(tmp, "wb") as f:
                f.write(comp)
            os.replace(tmp, path)  # atomic + idempotent (content-addressed)
        with self._lock:
            # no flush here: Archive.save's trailing register_ref persists
            # the whole batch once (one index write per archive, not per blob)
            self._index["blobs"].setdefault(
                h, {"comp_len": len(comp), "raw_len": len(data), "refs": []})
            meta = self._index["blobs"][h]
            self.store.register(h, (0, meta["comp_len"], meta["raw_len"]))
            _M_DEPOSITS.inc()
            return meta["comp_len"], meta["raw_len"]

    def has_blob(self, h: str) -> bool:
        """Blob present in this depot (indexed, or the content-addressed
        file exists even if index.json was lost)."""
        with self._lock:
            if h in self._index["blobs"]:
                return True
        return os.path.exists(os.path.join(self.blob_dir, h))

    def register_ref(self, ref: str, hashes: List[str]) -> None:
        """Hold one reference per blob on behalf of ``ref`` (an archive
        name or thin-archive path). Called by ``Archive.save(depot=...)``."""
        with self._lock:
            for h in set(hashes):
                meta = self._index["blobs"].get(h)
                if meta is not None and ref not in meta["refs"]:
                    meta["refs"].append(ref)
            self._flush()

    def release_ref(self, ref: str) -> None:
        with self._lock:
            for meta in self._index["blobs"].values():
                if ref in meta["refs"]:
                    meta["refs"].remove(ref)
            self._flush()

    # -- archive plane ---------------------------------------------------
    def put_archive(self, name: str, archive: Archive,
                    level: int = 3) -> str:
        """Deposit ``archive`` under ``name``: blobs into the shared store
        (deduplicated), manifest as a thin container in ``manifests/``.
        Re-putting a name replaces it (old blob refs released)."""
        path = os.path.join(self.manifest_dir, f"{name}.fndry")
        if archive.blobs is self.store:
            raise ValueError(
                "cannot re-deposit an archive opened from this depot")
        with self._lock:
            if name in self._index["archives"]:
                self.remove_archive(name)
            archive.save(path, level=level, depot=self)  # registers path ref
            hashes = sorted(set(archive.blobs))
            raw = sum(self._index["blobs"][h]["raw_len"] for h in hashes)
            self._index["archives"][name] = {
                "file": os.path.relpath(path, self.root),
                "blob_hashes": hashes,
                "logical_raw_bytes": raw,
                "manifest_bytes": os.path.getsize(path),
            }
            self._flush()
        return path

    def open(self, name: str) -> Archive:
        """Open a deposited archive. The returned Archive's blob store IS
        the depot's shared store (lazy, fetch-once depot-wide)."""
        with self._lock:
            try:
                entry = self._index["archives"][name]
            except KeyError:
                raise KeyError(
                    f"depot has no archive {name!r} "
                    f"(have: {sorted(self._index['archives'])})") from None
            path = os.path.join(self.root, entry["file"])
        return Archive.load(path, depot=self)

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._index["archives"]

    def archives(self) -> List[str]:
        with self._lock:
            return sorted(self._index["archives"])

    def remove_archive(self, name: str) -> None:
        """Drop ``name`` and its blob references (blob files stay on disk
        until ``gc()``; the shared store keeps serving already-open users)."""
        with self._lock:
            entry = self._index["archives"].pop(name, None)
            if entry is None:
                raise KeyError(name)
            path = os.path.join(self.root, entry["file"])
            self.release_ref(os.path.abspath(path))
            try:
                os.remove(path)
            except OSError:
                pass
            self._flush()

    def gc(self) -> Dict[str, int]:
        """Delete blob files with zero references. Returns accounting."""
        deleted = freed = 0
        with self._lock:
            for h in [h for h, m in self._index["blobs"].items()
                      if not m["refs"]]:
                meta = self._index["blobs"].pop(h)
                try:
                    os.remove(os.path.join(self.blob_dir, h))
                except OSError:
                    pass
                try:
                    del self.store[h]
                except KeyError:
                    pass
                deleted += 1
                freed += meta["comp_len"]
            self._flush()
        return {"deleted_blobs": deleted, "freed_comp_bytes": freed}

    def fsck(self, *, gc_orphans: bool = False, deep: bool = False):
        """Static consistency check of this depot (index vs disk, refcounts,
        thin manifests; ``repro.analysis.checker.check_depot``). Returns
        ``(findings, actions)``; read-only unless ``gc_orphans``."""
        from repro.analysis.checker import check_depot
        return check_depot(self.root, gc_orphans=gc_orphans, deep=deep)

    # -- accounting ------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """Storage accounting across the whole depot. ``dedup_ratio`` is the
        headline: logical bytes (every archive counted in full) over the
        physical bytes the shared store actually holds — 1.0x means nothing
        was shared; the reduced-config zoo lands well above it."""
        with self._lock:
            blobs = self._index["blobs"]
            physical_raw = sum(m["raw_len"] for m in blobs.values())
            physical_comp = sum(m["comp_len"] for m in blobs.values())
            logical_raw = logical_blobs = 0
            per_archive = {}
            for name, entry in self._index["archives"].items():
                logical_raw += entry["logical_raw_bytes"]
                logical_blobs += len(entry["blob_hashes"])
                per_archive[name] = {
                    "blobs": len(entry["blob_hashes"]),
                    "raw_bytes": entry["logical_raw_bytes"],
                    "manifest_bytes": entry["manifest_bytes"],
                }
            _M_DEDUP_RATIO.set(logical_raw / physical_raw
                               if physical_raw else 1.0)
            return {
                "archives": len(per_archive),
                "blobs": len(blobs),
                "logical_blobs": logical_blobs,
                "physical_raw_bytes": physical_raw,
                "physical_comp_bytes": physical_comp,
                "logical_raw_bytes": logical_raw,
                "dedup_ratio": (logical_raw / physical_raw
                                if physical_raw else 1.0),
                "per_archive": per_archive,
            }

"""Single-host offline capture topology (paper §4.2.2).

The paper captures multi-GPU graphs on ONE GPU by stubbing NCCL/NVSHMEM with
dummy communication, then patches rank state at LOAD. On TPU/JAX the stub is
structural: SPMD programs are traced/lowered/compiled against a *device
topology*, not live communicators, so a single CPU host with
``--xla_force_host_platform_device_count=N`` placeholder devices produces the
byte-identical SPMD program a real N-chip pod would compile — collectives are
real HLO ops that are simply never executed offline. Rank identity
(partition-id / channel assignment) is resolved by the runtime at execution,
which is exactly the "patch only rank-dependent communication state" step.

This module holds the helpers that make that explicit and testable.
"""
from __future__ import annotations

import os
import subprocess
import sys
from typing import Optional, Sequence

PLACEHOLDER_FLAG = "--xla_force_host_platform_device_count"


def placeholder_env(n_devices: int, extra_env: Optional[dict] = None) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"{PLACEHOLDER_FLAG}={n_devices}"
    env.update(extra_env or {})
    return env


def capture_devices_available(n: int) -> bool:
    """True if this process was started with >= n placeholder devices."""
    import jax
    return len(jax.devices()) >= n


def run_in_capture_process(script: str, n_devices: int, *,
                           timeout: float = 1200.0,
                           pythonpath: str = "src") -> subprocess.CompletedProcess:
    """Run a python snippet in a fresh process with the capture topology.
    (jax pins the device count at first init, so capture topology must be
    established before any jax import — the same reason dryrun.py sets
    XLA_FLAGS on its first two lines.)"""
    env = placeholder_env(n_devices)
    env["PYTHONPATH"] = pythonpath + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)


def mesh_identity(mesh) -> dict:
    return {"axes": list(mesh.axis_names), "shape": list(mesh.devices.shape)}


def same_topology(identity: dict, mesh) -> bool:
    return (list(mesh.axis_names) == identity["axes"]
            and list(mesh.devices.shape) == identity["shape"])

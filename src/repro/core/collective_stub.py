"""Single-host offline capture topology (paper §4.2.2).

The paper captures multi-GPU graphs on ONE GPU by stubbing NCCL/NVSHMEM with
dummy communication, then patches rank state at LOAD. On TPU/JAX the stub is
structural: SPMD programs are traced/lowered/compiled against a *device
topology*, not live communicators, so a single CPU host with
``--xla_force_host_platform_device_count=N`` placeholder devices produces the
byte-identical SPMD program a real N-chip pod would compile — collectives are
real HLO ops that are simply never executed offline. Rank identity
(partition-id / channel assignment) is resolved by the runtime at execution,
which is exactly the "patch only rank-dependent communication state" step.

This module holds the helpers that make that explicit and testable: the
placeholder-device capture environment, mesh-identity predicates used by the
LOAD decision (exact / stamped / fallback; core/restore.py), and the
rank-parameterized peer state — per-axis collective peer groups and per-rank
mesh coordinates — that core/rank_stamp.py records at SAVE and re-derives for
the deployment mesh at LOAD (paper §4.3: "patch only rank-dependent
communication state").
"""
from __future__ import annotations

import math
import os
import subprocess
import sys
from typing import Dict, List, Optional, Sequence

import numpy as np

PLACEHOLDER_FLAG = "--xla_force_host_platform_device_count"


def placeholder_env(n_devices: int, extra_env: Optional[dict] = None) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"{PLACEHOLDER_FLAG}={n_devices}"
    env.update(extra_env or {})
    return env


def capture_devices_available(n: int) -> bool:
    """True if this process was started with >= n placeholder devices."""
    import jax
    return len(jax.devices()) >= n


def run_in_capture_process(script: str, n_devices: int, *,
                           timeout: float = 1200.0,
                           pythonpath: str = "src") -> subprocess.CompletedProcess:
    """Run a python snippet in a fresh process with the capture topology.
    (jax pins the device count at first init, so capture topology must be
    established before any jax import — the same reason dryrun.py sets
    XLA_FLAGS on its first two lines.)"""
    env = placeholder_env(n_devices)
    env["PYTHONPATH"] = pythonpath + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)


def mesh_identity(mesh) -> dict:
    return {"axes": list(mesh.axis_names), "shape": list(mesh.devices.shape)}


def same_topology(identity: dict, mesh) -> bool:
    return (list(mesh.axis_names) == identity["axes"]
            and list(mesh.devices.shape) == identity["shape"])


# ---------------------------------------------------------------------------
# rank-parameterized peer state (paper §4.3)
# ---------------------------------------------------------------------------
def identity_device_count(identity: dict) -> int:
    """Total ranks of a recorded mesh identity ({} / no mesh counts as 1)."""
    return math.prod(identity.get("shape") or [1])


def stamp_compatible(capture_identity: dict, mesh) -> bool:
    """True when a capture taken under ``capture_identity`` can serve ``mesh``
    by rank stamping instead of recompilation (paper §4.3):

      * single-capture -> many ranks: a 1-device offline capture serves any
        deployment shape (the SPMD program is rank-independent; only peer
        tables / coordinates / buffer offsets differ per rank), or
      * axis re-arrangement at fixed rank count (TP<->EP style switches,
        e.g. (2,4) <-> (4,2)): same device set, different collective peers.

    A genuine scale change of a multi-rank capture (8-rank capture -> 2-rank
    deployment) is NOT stampable — the per-rank program shape itself changes —
    and must take the compile-from-StableHLO fallback.
    """
    if mesh is None:
        return False
    n_cap = identity_device_count(capture_identity)
    n_dep = mesh.devices.size
    return n_cap == 1 or n_cap == n_dep


def rank_coords(shape: Sequence[int]) -> List[tuple]:
    """rank -> mesh coordinates, ranks enumerated in row-major mesh order."""
    if not shape:
        return [()]
    grid = np.arange(math.prod(shape)).reshape(tuple(shape))
    coords = [None] * grid.size
    for idx in np.ndindex(grid.shape):
        coords[int(grid[idx])] = tuple(int(i) for i in idx)
    return coords


def peer_groups(shape: Sequence[int], axes: Sequence[str]) -> Dict[str, List[List[int]]]:
    """Per-mesh-axis collective peer tables: for each axis, the groups of
    flat ranks that participate in a collective over that axis (the NCCL
    communicator membership the paper patches per rank). Row-major rank
    order, matching ``jax.make_mesh``'s device assignment."""
    if not shape:
        return {}
    grid = np.arange(math.prod(shape)).reshape(tuple(shape))
    out: Dict[str, List[List[int]]] = {}
    for i, axis in enumerate(axes):
        moved = np.moveaxis(grid, i, -1).reshape(-1, grid.shape[i])
        out[str(axis)] = [[int(r) for r in row] for row in moved]
    return out

"""Portable Foundry archive (paper §3: the output of SAVE).

One file, zstd-compressed msgpack container:
    manifest : json-able dict (graph metadata, topology groups, memory plan,
               kernel catalog index, mesh/arch identity)
    blobs    : content-hash-keyed bytes (serialized executables, exported
               StableHLO, kernel artifacts)

Hashes are verified on load (a corrupted archive must fail loudly, not
produce a silently-wrong engine). The binary format keeps parse time in the
milliseconds even for hundreds of graphs (paper §5.3 moved from JSON to a
binary format for exactly this reason; we benchmark both in
benchmarks/tab1_storage.py).
"""
from __future__ import annotations

import hashlib
import io
import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import msgpack
import zstandard

MAGIC = b"FNDRYJX1"


def content_hash(data: bytes) -> str:
    return hashlib.blake2b(data, digest_size=16).hexdigest()


@dataclass
class Archive:
    manifest: Dict[str, Any] = field(default_factory=dict)
    blobs: Dict[str, bytes] = field(default_factory=dict)

    def add_blob(self, data: bytes) -> str:
        h = content_hash(data)
        self.blobs[h] = data
        return h

    def get_blob(self, h: str) -> bytes:
        data = self.blobs[h]
        if content_hash(data) != h:
            raise ValueError(f"archive blob {h} failed content verification")
        return data

    # ------------------------------------------------------------------
    def to_bytes(self, level: int = 3) -> bytes:
        payload = msgpack.packb(
            {"manifest": self.manifest, "blobs": self.blobs},
            use_bin_type=True)
        comp = zstandard.ZstdCompressor(level=level).compress(payload)
        return MAGIC + comp

    @classmethod
    def from_bytes(cls, raw: bytes) -> "Archive":
        if not raw.startswith(MAGIC):
            raise ValueError("not a Foundry archive (bad magic)")
        payload = zstandard.ZstdDecompressor().decompress(raw[len(MAGIC):])
        obj = msgpack.unpackb(payload, raw=False, strict_map_key=False)
        ar = cls(manifest=obj["manifest"], blobs=obj["blobs"])
        for h in ar.blobs:
            if content_hash(ar.blobs[h]) != h:
                raise ValueError(f"archive blob {h} corrupt")
        return ar

    def save(self, path: str, level: int = 3) -> int:
        data = self.to_bytes(level)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)  # atomic
        return len(data)

    @classmethod
    def load(cls, path: str) -> "Archive":
        with open(path, "rb") as f:
            return cls.from_bytes(f.read())

    # -- debugging / storage accounting --------------------------------
    def blob_bytes(self) -> int:
        return sum(len(b) for b in self.blobs.values())

    def manifest_json(self) -> str:
        return json.dumps(self.manifest, indent=1, default=str)

"""Portable Foundry archive (paper §3: the output of SAVE).

One file, zstd-compressed msgpack container:
    manifest : json-able dict (graph metadata, topology groups, memory plan,
               kernel catalog index, mesh/arch identity)
    blobs    : content-hash-keyed bytes (serialized executables, exported
               StableHLO, kernel artifacts)

Hashes are verified on load (a corrupted archive must fail loudly, not
produce a silently-wrong engine). The binary format keeps parse time in the
milliseconds even for hundreds of graphs (paper §5.3 moved from JSON to a
binary format for exactly this reason; we benchmark both in
benchmarks/tab1_storage.py).

Compression codec: zstd when the ``zstandard`` package is available, stdlib
``zlib`` otherwise. The codec is sniffed from the compressed stream's own
magic on read (zstd frames begin with 0x28B52FFD; zlib streams with 0x78),
so archives written under either codec load under both, and the container
MAGIC stays stable.
"""
from __future__ import annotations

import hashlib
import json
import os
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict

import msgpack

try:
    import zstandard
except ImportError:  # archives remain readable/writable via stdlib zlib
    zstandard = None

MAGIC = b"FNDRYJX1"
_ZSTD_FRAME_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(payload: bytes, level: int) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=level).compress(payload)
    return zlib.compress(payload, min(level, 9))


def _decompress(comp: bytes) -> bytes:
    if comp.startswith(_ZSTD_FRAME_MAGIC):
        if zstandard is None:
            raise ValueError(
                "archive is zstd-compressed but the zstandard package is "
                "not installed; re-save it with zlib or install zstandard")
        return zstandard.ZstdDecompressor().decompress(comp)
    return zlib.decompress(comp)


def content_hash(data: bytes) -> str:
    return hashlib.blake2b(data, digest_size=16).hexdigest()


@dataclass
class Archive:
    manifest: Dict[str, Any] = field(default_factory=dict)
    blobs: Dict[str, bytes] = field(default_factory=dict)

    def add_blob(self, data: bytes) -> str:
        h = content_hash(data)
        self.blobs[h] = data
        return h

    def get_blob(self, h: str) -> bytes:
        data = self.blobs[h]
        if content_hash(data) != h:
            raise ValueError(f"archive blob {h} failed content verification")
        return data

    # ------------------------------------------------------------------
    def to_bytes(self, level: int = 3) -> bytes:
        payload = msgpack.packb(
            {"manifest": self.manifest, "blobs": self.blobs},
            use_bin_type=True)
        return MAGIC + _compress(payload, level)

    @classmethod
    def from_bytes(cls, raw: bytes) -> "Archive":
        if not raw.startswith(MAGIC):
            raise ValueError("not a Foundry archive (bad magic)")
        payload = _decompress(raw[len(MAGIC):])
        obj = msgpack.unpackb(payload, raw=False, strict_map_key=False)
        ar = cls(manifest=obj["manifest"], blobs=obj["blobs"])
        for h in ar.blobs:
            if content_hash(ar.blobs[h]) != h:
                raise ValueError(f"archive blob {h} corrupt")
        return ar

    def save(self, path: str, level: int = 3) -> int:
        data = self.to_bytes(level)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)  # atomic
        return len(data)

    @classmethod
    def load(cls, path: str) -> "Archive":
        with open(path, "rb") as f:
            return cls.from_bytes(f.read())

    # -- debugging / storage accounting --------------------------------
    def blob_bytes(self) -> int:
        return sum(len(b) for b in self.blobs.values())

    def manifest_json(self) -> str:
        return json.dumps(self.manifest, indent=1, default=str)

"""Portable Foundry archive (paper §3: the output of SAVE).

One file, two container layouts:

    v2 (``FNDRYJX2``, written by ``save``/``to_bytes``)
        MAGIC + u64 header length + compressed msgpack header
        {manifest, blob index} + a blob section of individually-compressed
        blobs. The header is all LOAD has to parse up front; blobs are
        fetched by (offset, length) on demand. This is what makes a fleet of
        replicas cold-starting against ONE archive cheap: the manifest is
        parsed once, and each blob is read + decompressed + hash-verified at
        most once no matter how many concurrent LOADs share the ``Archive``
        object (``BlobStore`` is lock-protected and caches fetched blobs).

    v1 (``FNDRYJX1``, legacy)
        MAGIC + one compressed msgpack blob {manifest, blobs}. Still
        readable; necessarily eager (one stream, no random access).

Hashes are verified on first fetch (a corrupted archive must fail loudly,
not produce a silently-wrong engine). The binary format keeps parse time in
the milliseconds even for hundreds of graphs (paper §5.3 moved from JSON to
a binary format for exactly this reason; we benchmark both in
benchmarks/tab1_storage.py).

Compression codec: zstd when the ``zstandard`` package is available, stdlib
``zlib`` otherwise. The codec is sniffed from each compressed stream's own
magic on read (zstd frames begin with 0x28B52FFD; zlib streams with 0x78),
so archives written under either codec load under both, and the container
MAGIC stays stable.
"""
from __future__ import annotations

import hashlib
import json
import os
import struct
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, Optional

import msgpack

from repro.obs import metrics as obs_metrics
from repro.serving.faults import fault_point

try:
    import zstandard
except ImportError:  # archives remain readable/writable via stdlib zlib
    zstandard = None

MAGIC = b"FNDRYJX1"
MAGIC2 = b"FNDRYJX2"
_ZSTD_FRAME_MAGIC = b"\x28\xb5\x2f\xfd"

# docs/architecture.md §13 has the full metric catalog
_M_BLOB_FETCH = obs_metrics.counter(
    "depot_blob_fetch_total",
    "BlobStore reads by result: hit = served from the in-memory cache, "
    "miss = read + decompressed + verified from the backing source.",
    ("result",))


def io_retries(fn, what: str, *, attempts: int = 3,
               base_delay_s: float = 0.005, retry_on=(OSError,)):
    """Bounded exponential-backoff retry for transient IO (flaky NFS mount,
    depot blob mid-replication, torn read). Retries ``fn()`` on ``retry_on``
    up to ``attempts`` total tries with 1x/2x/4x... ``base_delay_s`` sleeps
    between them, then re-raises the last failure — bounded, so a genuinely
    dead backing store still fails fast enough for the caller's own
    degradation (strict-LOAD refusal, replica FAILED) to engage."""
    for k in range(attempts):
        try:
            return fn()
        except retry_on:
            if k + 1 >= attempts:
                raise
            time.sleep(base_delay_s * (2 ** k))
    raise AssertionError(f"unreachable: io_retries({what})")


def _compress(payload: bytes, level: int) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=level).compress(payload)
    return zlib.compress(payload, min(level, 9))


def _decompress(comp: bytes) -> bytes:
    if comp.startswith(_ZSTD_FRAME_MAGIC):
        if zstandard is None:
            raise ValueError(
                "archive is zstd-compressed but the zstandard package is "
                "not installed; re-save it with zlib or install zstandard")
        return zstandard.ZstdDecompressor().decompress(comp)
    return zlib.decompress(comp)


def content_hash(data: bytes) -> str:
    return hashlib.blake2b(data, digest_size=16).hexdigest()


# ---------------------------------------------------------------------------
# blob backing
# ---------------------------------------------------------------------------
class _BytesSource:
    """Random access over an in-memory v2 container."""

    def __init__(self, raw: bytes, base: int):
        self._raw = raw
        self._base = base

    def read(self, offset: int, length: int) -> bytes:
        return bytes(self._raw[self._base + offset:
                               self._base + offset + length])


class _FileSource:
    """Random access over an on-disk v2 container (handle opened lazily so a
    loaded Archive stays picklable/forkable until first fetch)."""

    def __init__(self, path: str, base: int):
        self._path = path
        self._base = base
        self._f = None
        self._lock = threading.Lock()

    def read(self, offset: int, length: int) -> bytes:
        with self._lock:
            if self._f is None:
                # held for the Archive's lifetime (positioned reads), not a
                # with-block scope
                self._f = open(self._path, "rb")  # noqa: SIM115
            if not hasattr(os, "pread"):  # no positioned read: serialize
                self._f.seek(self._base + offset)
                return self._f.read(length)
            fd = self._f.fileno()
        return os.pread(fd, length, self._base + offset)


class BlobStore:
    """Content-hash-keyed blob mapping with optional lazy backing.

    Composes an in-memory dict (SAVE-side additions, v1 archives, fetch
    cache) with an index ``{hash: (offset, comp_len, raw_len)}`` over a
    random-access source (v2 archives). A blob reachable only through the
    index is read, decompressed and hash-verified on first access, then
    cached — concurrent LOADs sharing one store each pay the fetch at most
    once fleet-wide.
    """

    def __init__(self, data: Optional[Dict[str, bytes]] = None, *,
                 index: Optional[Dict[str, Any]] = None, source=None):
        self._data: Dict[str, bytes] = dict(data or {})
        self._index: Dict[str, tuple] = {k: tuple(v)
                                         for k, v in (index or {}).items()}
        self._source = source
        self._lock = threading.Lock()
        self._inflight: Dict[str, threading.Event] = {}
        self._verified: set = set()  # hashes checked at fetch time

    # -- mapping protocol ------------------------------------------------
    def __getitem__(self, h: str) -> bytes:
        """Single-flight fetch: concurrent readers of an unfetched blob
        elect one fetcher (per-hash event); the rest wait for the cached
        result, so each blob is read + decompressed + verified at most once
        no matter how many LOADs share the store. I/O and decompression run
        OUTSIDE the lock, so distinct blobs fetch concurrently."""
        while True:
            with self._lock:
                if h in self._data:
                    _M_BLOB_FETCH.inc(result="hit")
                    return self._data[h]
                if h not in self._index:
                    raise KeyError(h)
                entry = self._index[h]
                event = self._inflight.get(h)
                if event is None:
                    event = threading.Event()
                    self._inflight[h] = event
                    fetching = True
                else:
                    fetching = False
            if not fetching:
                event.wait()
                continue  # cached now — or the fetcher failed and we retry
            try:
                def _fetch():
                    if hasattr(self._source, "read_hash"):
                        # content-addressed backing (core/depot.py): the hash
                        # IS the address; (offset, comp_len) are bookkeeping
                        comp = self._source.read_hash(h)
                    else:
                        offset, comp_len, _ = entry
                        comp = self._source.read(offset, comp_len)
                    comp = fault_point("depot.fetch", payload=comp, tag=h)
                    try:
                        data = _decompress(comp)
                    except ValueError:
                        raise  # zstd-missing diagnostic: not a torn read
                    except Exception as e:
                        raise ValueError(
                            f"archive blob {h} corrupt "
                            f"(undecompressable: {type(e).__name__})") from e
                    if content_hash(data) != h:
                        raise ValueError(f"archive blob {h} corrupt")
                    return data
                # transient IO (OSError) and torn/bit-rotted reads
                # (ValueError: the re-read may verify) retry with bounded
                # backoff; a persistently corrupt blob still fails loudly
                data = io_retries(_fetch, f"blob {h}",
                                  retry_on=(OSError, ValueError))
                with self._lock:
                    self._data[h] = data
                    self._verified.add(h)
                _M_BLOB_FETCH.inc(result="miss")
                return data
            finally:
                with self._lock:
                    self._inflight.pop(h, None)
                event.set()

    def __setitem__(self, h: str, data: bytes):
        with self._lock:
            self._data[h] = data
            self._index.pop(h, None)  # fresh bytes supersede the backing
            self._verified.discard(h)

    def __delitem__(self, h: str):
        with self._lock:
            found = h in self._data or h in self._index
            self._data.pop(h, None)
            self._index.pop(h, None)
        if not found:
            raise KeyError(h)

    def __contains__(self, h) -> bool:
        with self._lock:
            return h in self._data or h in self._index

    def __iter__(self) -> Iterator[str]:
        with self._lock:
            keys = list(self._data)
            keys += [k for k in self._index if k not in self._data]
        return iter(keys)

    def __len__(self) -> int:
        with self._lock:
            return len(set(self._data) | set(self._index))

    def keys(self):
        return list(self)

    def values(self):
        return [self[h] for h in self]

    def items(self):
        return [(h, self[h]) for h in self]

    def register(self, h: str, entry) -> None:
        """Add/refresh a lazy index entry ``(offset, comp_len, raw_len)``
        without touching cached bytes. Used by depot-shared stores when a new
        archive's blobs join the (already open) store."""
        with self._lock:
            if h not in self._data:
                self._index[h] = tuple(entry)

    # -- accounting ------------------------------------------------------
    def raw_bytes(self) -> int:
        """Total uncompressed blob bytes, WITHOUT fetching lazy blobs."""
        with self._lock:
            total = sum(raw_len for h, (_, _, raw_len) in self._index.items()
                        if h not in self._data)
            total += sum(len(b) for b in self._data.values())
        return total

    def fetched(self) -> int:
        """Blobs materialized in memory (cache hits are free below this)."""
        with self._lock:
            return len(self._data)

    def is_verified(self, h: str) -> bool:
        """True if ``h`` was hash-checked when fetched from the backing
        (repeat reads need no re-hash; directly-set bytes are not exempt)."""
        with self._lock:
            return h in self._verified


@dataclass
class Archive:
    manifest: Dict[str, Any] = field(default_factory=dict)
    blobs: BlobStore = field(default_factory=BlobStore)

    def __post_init__(self):
        if isinstance(self.blobs, dict):  # plain-dict construction (tests)
            self.blobs = BlobStore(self.blobs)

    def add_blob(self, data: bytes) -> str:
        h = content_hash(data)
        self.blobs[h] = data
        return h

    def get_blob(self, h: str) -> bytes:
        data = self.blobs[h]
        # source-fetched blobs were verified once at fetch; only bytes that
        # never passed through the backing need checking here
        if not self.blobs.is_verified(h) and content_hash(data) != h:
            raise ValueError(f"archive blob {h} failed content verification")
        return data

    # ------------------------------------------------------------------
    def to_bytes(self, level: int = 3) -> bytes:
        index: Dict[str, list] = {}
        parts = []
        offset = 0
        for h in self.blobs:
            data = self.blobs[h]
            comp = _compress(data, level)
            index[h] = [offset, len(comp), len(data)]
            parts.append(comp)
            offset += len(comp)
        header = _compress(msgpack.packb(
            {"manifest": self.manifest, "index": index}, use_bin_type=True),
            level)
        return b"".join([MAGIC2, struct.pack("<Q", len(header)), header]
                        + parts)

    @classmethod
    def from_bytes(cls, raw: bytes, lazy: bool = False) -> "Archive":
        if raw.startswith(MAGIC2):
            head, base = cls._parse_v2_header(raw)
            ar = cls(manifest=head["manifest"],
                     blobs=BlobStore(index=head["index"],
                                     source=_BytesSource(raw, base)))
            if not lazy:
                for h in ar.blobs:
                    ar.blobs[h]  # fetch + verify everything up front
            return ar
        if raw.startswith(MAGIC):  # legacy v1: one stream, necessarily eager
            payload = _decompress(raw[len(MAGIC):])
            obj = msgpack.unpackb(payload, raw=False, strict_map_key=False)
            ar = cls(manifest=obj["manifest"], blobs=BlobStore(obj["blobs"]))
            for h in ar.blobs:
                if content_hash(ar.blobs[h]) != h:
                    raise ValueError(f"archive blob {h} corrupt")
            return ar
        raise ValueError("not a Foundry archive (bad magic)")

    @staticmethod
    def _parse_v2_header(raw: bytes) -> tuple:
        (hlen,) = struct.unpack_from("<Q", raw, len(MAGIC2))
        base = len(MAGIC2) + 8
        head = msgpack.unpackb(_decompress(bytes(raw[base:base + hlen])),
                               raw=False, strict_map_key=False)
        return head, base + hlen

    def save(self, path: str, level: int = 3, depot=None) -> int:
        """Write the archive to ``path``. With ``depot`` (a
        ``core.depot.TemplateDepot``), the file is a *thin* manifest: the
        same v2 header (manifest + blob index) with a ``depot`` flag and NO
        blob section — every blob is deposited into the depot's
        content-addressed store instead, deduplicated against whatever other
        archives already live there. Thin archives are reopened with
        ``Archive.load(path, depot=...)``."""
        if depot is not None:
            data = self._to_bytes_thin(depot, level)
        else:
            data = self.to_bytes(level)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)  # atomic
        if depot is not None:
            depot.register_ref(os.path.abspath(path), list(self.blobs))
        return len(data)

    def _to_bytes_thin(self, depot, level: int = 3) -> bytes:
        if self.blobs is depot.store:
            # a depot-opened archive shares the depot-wide store: iterating
            # it would sweep EVERY depot blob into this manifest
            raise ValueError(
                "cannot re-deposit an archive opened from this depot; "
                "its thin manifest already lives there")
        index: Dict[str, list] = {}
        for h in self.blobs:
            comp_len, raw_len = depot.ensure_blob(h, lambda h=h: self.blobs[h],
                                                  level=level)
            index[h] = [0, comp_len, raw_len]
        header = _compress(msgpack.packb(
            {"manifest": self.manifest, "index": index, "depot": True},
            use_bin_type=True), level)
        return b"".join([MAGIC2, struct.pack("<Q", len(header)), header])

    @classmethod
    def load(cls, path: str, lazy: bool = True, depot=None) -> "Archive":
        """Open an archive file. ``lazy=True`` (default) parses only the
        header; blobs are fetched on demand — the cheap path for N replicas
        LOADing one shared archive. ``lazy=False`` restores the old behavior
        of materializing and verifying every blob up front.

        A *thin* archive (written with ``save(..., depot=...)``) resolves its
        blobs through ``depot``'s shared store: pass the same (or an
        equivalent) depot, or opening fails. The returned Archive's blob
        store IS the depot store, so blobs shared across models are fetched
        at most once depot-wide."""
        # archive open is the first IO of every cold start: transient
        # failures (archive still replicating onto this host) retry with
        # bounded backoff before the replica is declared FAILED
        f = io_retries(lambda: open(path, "rb"),  # noqa: SIM115
                       f"archive {path}")
        with f:
            magic = f.read(len(MAGIC2))
            if magic == MAGIC2:
                (hlen,) = struct.unpack("<Q", f.read(8))
                head = msgpack.unpackb(_decompress(f.read(hlen)),
                                       raw=False, strict_map_key=False)
                base = len(MAGIC2) + 8 + hlen
                if head.get("depot"):
                    if depot is None:
                        raise ValueError(
                            f"{path} is a depot-backed (thin) archive; "
                            f"reopen it with Archive.load(path, depot=...)")
                    missing = [h for h in head["index"]
                               if not depot.has_blob(h)]
                    if missing:
                        # fail at open with the real cause, not with a
                        # FileNotFoundError from some later blob fetch
                        raise ValueError(
                            f"{path} references {len(missing)} blob(s) the "
                            f"depot at {depot.root} does not hold (first: "
                            f"{missing[0]}); wrong depot?")
                    for h, entry in head["index"].items():
                        depot.store.register(h, entry)
                    ar = cls(manifest=head["manifest"], blobs=depot.store)
                    if not lazy:
                        for h in head["index"]:
                            ar.blobs[h]
                    return ar
                ar = cls(manifest=head["manifest"],
                         blobs=BlobStore(index=head["index"],
                                         source=_FileSource(path, base)))
                if not lazy:
                    for h in ar.blobs:  # fetch + verify everything up front
                        ar.blobs[h]
                return ar
            f.seek(0)
            return cls.from_bytes(f.read(), lazy=lazy)

    # -- debugging / storage accounting --------------------------------
    def blob_bytes(self) -> int:
        return self.blobs.raw_bytes()

    def manifest_json(self) -> str:
        return json.dumps(self.manifest, indent=1, default=str)

"""SAVE: offline context materialization (paper §3, Figure 4 left).

Runs the engine's capture set once — on the *offline capture topology*
(single host, placeholder devices; core/collective_stub.py) — and produces a
portable archive containing:

  * per-bucket topology keys and topology groups (templates),
  * the template buckets' *instantiated executables*
    (jax.experimental.serialize_executable — topology + execution context),
  * every bucket's pre-lowered StableHLO (jax.export) for on-demand exact
    reconstruction without Python re-tracing,
  * the kernel catalog (content-hash-keyed lowered kernel artifacts),
  * the memory plan (deterministic arena layout incl. capture-window events),
  * the rank-delta section (manifest v2, paper §4.3): the capture topology's
    per-rank communication state — peer tables, mesh coordinates,
    rank-relative buffer offsets — plus an index of which manifest fields
    are rank-dependent, so LOAD can stamp a shape-compatible deployment's
    deltas into the shared templates instead of recompiling
    (core/rank_stamp.py),
  * a manifest binding all of it to (arch, step name, mesh shape, dtype).

Phase timings are recorded for the paper's Figure 8 breakdown.
"""
from __future__ import annotations

import dataclasses
import logging
import pickle
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.export  # not re-exported by bare `import jax` on jax<=0.4.x

from repro.core.archive import Archive
from repro.core.memory_plan import MemoryPlan
from repro.core.rank_stamp import build_rank_deltas
from repro.core.templates import TopologyGroup, group_buckets
from repro.core.topology import topology_key

log = logging.getLogger("repro.core.materialize")


@dataclass
class CaptureSpec:
    """One family of graphs to capture (e.g. the decode step).

    make_args(bucket) must return the positional arg specs
    (ShapeDtypeStructs with shardings) for ``step_fn`` at that bucket.
    ``tags`` is an arbitrary json-able dict persisted into the manifest spec
    entry — the engine records the step's calling convention there (e.g.
    ``decode_loop``/``fused_sampling``: whether sampling is fused into the
    captured graph and the step returns token ids instead of logits), so a
    LOADing engine can bind the right serving loop without re-tracing.
    """
    name: str
    step_fn: Callable
    make_args: Callable[[int], tuple]
    buckets: Sequence[int]
    donate_argnums: tuple = ()
    tags: dict = field(default_factory=dict)


def _mesh_identity(mesh) -> dict:
    if mesh is None:
        return {"axes": [], "shape": []}
    return {"axes": list(mesh.axis_names), "shape": list(mesh.devices.shape)}


def canonical_export_bytes(exp) -> bytes:
    """Serialize a ``jax.export.Exported`` with MLIR debug locations
    stripped from its StableHLO module.

    The raw serialization embeds the full call-site location chain of the
    export (file:line of every frame), so the same program exported from two
    places — two SAVE invocations, two engines, even two statements in one
    script — differs by a few location bytes. That defeats content-addressed
    dedup in the TemplateDepot (core/depot.py), where identical bucket
    programs across archives/ladders/versions should collapse to one blob.
    Round-tripping the module through its location-free textual form makes
    the blob a pure function of the program; ``jax.export.deserialize``
    accepts it unchanged (locations become "unknown").

    Uses private jax internals (the Exported dataclass layout and
    ``_module_to_bytecode``); any drift falls back to the raw — still
    loadable, just dedup-hostile — serialization.
    """
    try:
        from jax._src.export import _export
        from jax._src.interpreters import mlir as _mlir
        from jax._src.lib.mlir import ir as _ir
        with _mlir.make_ir_context():
            mod = _ir.Module.parse(exp.mlir_module())
            text = mod.operation.get_asm(enable_debug_info=False)
            ser = _export._module_to_bytecode(_ir.Module.parse(text))
        exp = dataclasses.replace(exp, mlir_module_serialized=ser)
    except Exception:
        pass
    return exp.serialize()


def foundry_save(specs: Sequence[CaptureSpec], mesh, *,
                 memory_plan: Optional[MemoryPlan] = None,
                 kernel_catalog=None,
                 meta: Optional[dict] = None,
                 serialize_all_executables: bool = False,
                 verbose: bool = False) -> tuple[Archive, dict]:
    """Capture + materialize. Returns (archive, save_report).

    serialize_all_executables=True is the "no templating" ablation (the
    CUDA-checkpoint-like baseline): every bucket's executable goes into the
    archive. Default stores executables only for templates.
    """
    ar = Archive()
    report: Dict[str, Any] = {"phases": {}, "specs": {}}
    t_all = time.perf_counter()
    manifest_specs = {}

    for spec in specs:
        srep: Dict[str, Any] = {}
        t0 = time.perf_counter()
        # --- capture: trace every bucket, compute topology keys ----------
        keys: Dict[int, str] = {}
        lowered: Dict[int, Any] = {}
        extra = _mesh_identity(mesh)
        for b in spec.buckets:
            args = spec.make_args(b)
            keys[b] = topology_key(spec.step_fn, *args, extra=extra)
        srep["trace_s"] = time.perf_counter() - t0

        # --- group ------------------------------------------------------
        t0 = time.perf_counter()
        groups = group_buckets(keys)
        srep["group_s"] = time.perf_counter() - t0
        srep["n_buckets"] = len(spec.buckets)
        srep["n_templates"] = len(groups)

        # --- lower + export every bucket (graph metadata) ----------------
        t0 = time.perf_counter()
        jitted = jax.jit(spec.step_fn, donate_argnums=spec.donate_argnums)
        for g in groups:
            for b in g.buckets:
                args = spec.make_args(b)
                exp = jax.export.export(jitted)(*args)
                g.bucket_export_blobs[b] = ar.add_blob(
                    canonical_export_bytes(exp))
        srep["export_s"] = time.perf_counter() - t0

        # --- compile + serialize template executables ---------------------
        t0 = time.perf_counter()
        from jax.experimental import serialize_executable as se
        for g in groups:
            todo = g.buckets if serialize_all_executables else [g.template_bucket]
            for b in todo:
                args = spec.make_args(b)
                compiled = jitted.lower(*args).compile()
                payload = se.serialize(compiled)
                blob = ar.add_blob(pickle.dumps(payload))
                if b == g.template_bucket:
                    g.executable_blob = blob
                if serialize_all_executables:
                    g.bucket_executable_blobs[b] = blob
        srep["compile_serialize_s"] = time.perf_counter() - t0

        manifest_specs[spec.name] = {
            "buckets": list(spec.buckets),
            "donate_argnums": list(spec.donate_argnums),
            "tags": dict(spec.tags),
            "groups": [g.to_manifest() for g in groups],
        }
        report["specs"][spec.name] = srep
        if verbose:
            from repro.obs import configure_logging
            configure_logging()
            log.info("[SAVE:%s] %d buckets -> %d templates "
                     "(trace %.2fs export %.2fs compile+ser %.2fs)",
                     spec.name, len(spec.buckets), len(groups),
                     srep["trace_s"], srep["export_s"],
                     srep["compile_serialize_s"])

    capture_identity = _mesh_identity(mesh)
    ar.manifest = {
        "version": 2,
        "mesh": capture_identity,
        "meta": meta or {},
        "specs": manifest_specs,
        "memory_plan": memory_plan.to_manifest() if memory_plan else None,
        "kernel_catalog": (kernel_catalog.to_manifest()
                           if kernel_catalog is not None else None),
        # §4.3: per-rank communication state of the capture topology, plus
        # an index of the manifest fields LOAD must re-derive per deployment
        # rank (everything else in the archive is rank-invariant and reused
        # byte-identically by the stamped restore path).
        "rank_delta": {
            "capture_ranks": [d.to_manifest() for d in
                              build_rank_deltas(capture_identity, memory_plan)],
            "rank_dependent_fields": [
                "mesh",
                "rank_delta.capture_ranks[*].coords",
                "rank_delta.capture_ranks[*].peer_groups",
                "memory_plan.allocations[scope=per_rank]",
            ],
        },
    }
    report["total_s"] = time.perf_counter() - t_all
    return ar, report

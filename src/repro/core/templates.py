"""Topology-based graph grouping + template dispatch (paper §4.2.1).

Inference engines capture one graph per batch-size bucket (vLLM: 512 of
them); reconstructing every one through the compiler at LOAD is the cost the
paper kills with templates. Here:

  * buckets are grouped by jaxpr topology key (core/topology.py);
  * only each group's *template* (its largest bucket) is materialized as an
    instantiated executable in the archive (serialize_executable) and
    restored with zero compile at LOAD;
  * every other bucket is servable immediately through the template by
    padding the batch to the template bucket — the XLA counterpart of
    cuGraphExecUpdate's in-place parameter update (same program, new
    parameters, zero driver/compiler work);
  * exact-bucket executables are realized on demand (or in the background)
    from the archived pre-lowered StableHLO — no Python re-trace — and
    hot-swapped in, eliminating the padding waste exactly like the paper's
    one-time on-demand template specialization at replay time;
  * a template may be a rank-STAMPED rebind of a capture taken on a
    different (shape-compatible) mesh (core/rank_stamp.py, paper §4.3);
    dispatch through such a template is counted separately in
    ``stats["stamped_dispatches"]`` and reported as path "stamped".
"""
from __future__ import annotations

import bisect
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class TopologyGroup:
    key: str
    buckets: List[int]
    template_bucket: int
    executable_blob: Optional[str] = None          # serialize_executable blob
    bucket_export_blobs: Dict[int, str] = field(default_factory=dict)
    # ablation ("checkpoint image"): executables for EVERY bucket
    bucket_executable_blobs: Dict[int, str] = field(default_factory=dict)

    def to_manifest(self) -> dict:
        return {"key": self.key, "buckets": self.buckets,
                "template_bucket": self.template_bucket,
                "executable_blob": self.executable_blob,
                "bucket_export_blobs": {str(k): v for k, v in
                                        self.bucket_export_blobs.items()},
                "bucket_executable_blobs": {str(k): v for k, v in
                                            self.bucket_executable_blobs.items()}}

    @classmethod
    def from_manifest(cls, m: dict) -> "TopologyGroup":
        return cls(key=m["key"], buckets=list(m["buckets"]),
                   template_bucket=m["template_bucket"],
                   executable_blob=m.get("executable_blob"),
                   bucket_export_blobs={int(k): v for k, v in
                                        m.get("bucket_export_blobs", {}).items()},
                   bucket_executable_blobs={int(k): v for k, v in
                                            m.get("bucket_executable_blobs", {}).items()})


def group_buckets(keys_by_bucket: Dict[int, str]) -> List[TopologyGroup]:
    """Group buckets sharing a topology key; template = largest bucket of the
    group (so any group member is pad-servable through it)."""
    by_key: Dict[str, List[int]] = {}
    for b in sorted(keys_by_bucket):
        by_key.setdefault(keys_by_bucket[b], []).append(b)
    return [TopologyGroup(key=k, buckets=bs, template_bucket=max(bs))
            for k, bs in by_key.items()]


def default_bucket_ladder(max_batch: int = 512, mode: str = "all") -> List[int]:
    """vLLM-style capture set. mode="all" captures every size 1..max (the
    paper's eval setting); "pow2" captures {1,2,4,...,max}."""
    if mode == "all":
        return list(range(1, max_batch + 1))
    out, b = [], 1
    while b <= max_batch:
        out.append(b)
        b *= 2
    return out


class ProgramSet:
    """Dispatchable set of per-bucket programs with template fallback.

    ``programs[bucket]`` may be an exact executable or absent; dispatch pads
    the active batch to the smallest bucket that has *any* path (exact or
    template) and reports which path served it.
    """

    def __init__(self, groups: List[TopologyGroup]):
        self.groups = {g.key: g for g in groups}
        self.bucket_to_key = {b: g.key for g in groups for b in g.buckets}
        self.buckets = sorted(self.bucket_to_key)
        self.templates: Dict[str, Any] = {}       # key -> executable
        self.exact: Dict[int, Any] = {}           # bucket -> executable
        self._lock = threading.Lock()
        self.stats = {"pad_dispatches": 0, "exact_dispatches": 0,
                      "template_dispatches": 0, "stamped_dispatches": 0}
        # n_active -> (bucket, executable, path, stat_keys): steady-state
        # decode resolves its program with one dict hit instead of walking
        # the bucket ladder + group tables every token. Invalidated on any
        # hot-swap (set_template / set_exact).
        self._lookup_cache: Dict[int, tuple] = {}

    # -- population -----------------------------------------------------
    def set_template(self, key: str, executable):
        with self._lock:
            self.templates[key] = executable
            self._lookup_cache.clear()

    def set_exact(self, bucket: int, executable):
        with self._lock:
            self.exact[bucket] = executable
            self._lookup_cache.clear()

    # -- dispatch ---------------------------------------------------------
    def pick_bucket(self, n_active: int) -> int:
        i = bisect.bisect_left(self.buckets, n_active)
        if i == len(self.buckets):
            raise ValueError(f"batch {n_active} exceeds largest bucket "
                             f"{self.buckets[-1]}")
        return self.buckets[i]

    def lookup(self, n_active: int) -> Tuple[int, Any, str]:
        """Returns (execution_bucket, executable, path) where path is one of
        "exact" | "template" (padded to the group template) | "stamped"
        (template is a rank-stamped cross-mesh rebind)."""
        hit = self._lookup_cache.get(n_active)
        if hit is not None:
            eb, exe, path, stat_keys = hit
            with self._lock:
                for k in stat_keys:
                    self.stats[k] += 1
            return eb, exe, path
        b = self.pick_bucket(n_active)
        with self._lock:
            if b in self.exact:
                self.stats["exact_dispatches"] += 1
                self._lookup_cache[n_active] = (b, self.exact[b], "exact",
                                                ("exact_dispatches",))
                return b, self.exact[b], "exact"
            g = self.groups[self.bucket_to_key[b]]
            t = self.templates.get(g.key)
            if t is not None:
                path = "template"
                stat_keys: tuple = ()
                if getattr(t, "is_stamped", False):
                    path = "stamped"
                    stat_keys = ("stamped_dispatches",)
                    self.stats["stamped_dispatches"] += 1
                if g.template_bucket == b:
                    self.stats["template_dispatches"] += 1
                    self._lookup_cache[n_active] = (
                        b, t, path, stat_keys + ("template_dispatches",))
                    return b, t, path
                self.stats["pad_dispatches"] += 1
                self._lookup_cache[n_active] = (
                    g.template_bucket, t, path, stat_keys + ("pad_dispatches",))
                return g.template_bucket, t, path
        raise RuntimeError(f"no executable available for bucket {b}")

    def coverage(self) -> dict:
        with self._lock:
            return {
                "buckets": len(self.buckets),
                "groups": len(self.groups),
                "templates_loaded": len(self.templates),
                "exact_loaded": len(self.exact),
            }


def pad_batch_arg(x, from_n: int, to_n: int):
    """Pad dim 0 of a batch-major array from from_n to to_n rows."""
    if from_n == to_n:
        return x
    pad = [(0, to_n - from_n)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)

"""Deterministic memory layout (paper §4.1.1).

The paper interposes the CUDA driver's VMM API and redirects every allocation
into a reserved virtual range, placing allocations contiguously so that a
SAVE run and a LOAD run produce bit-identical address layouts; LOAD then
premaps the whole extent in one call and each allocation becomes a pointer
bump. Capture-window allocations (made only during graph capture) are
recorded and replayed because LOAD skips capture.

On TPU/JAX the runtime owns device pointers, but the same contract exists one
level up: a restored executable binds to buffers by (shape, dtype, layout,
donation) slots, and the serving engine's long-lived objects (weights, KV
pool, IO staging) must be *plan-identical* between SAVE and LOAD or restore
fails / silently reallocates. ``MemoryPlan`` is that plan: a monotonic arena
planner that (a) assigns deterministic offsets from the allocation sequence,
(b) records capture-window allocations for replay, (c) lets LOAD preallocate
the full extent and verify every replayed allocation lands at its recorded
offset. The engine sizes its KV pool from the plan *before* LOAD (paper §5.4
pins the vLLM KV-cache size for the same reason).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DEFAULT_BASE = 0x7F00_0000_0000  # reserved VA base, conflict-free by fiat
DEFAULT_ALIGN = 512


@dataclass(frozen=True)
class Allocation:
    """One arena reservation.

    ``scope`` distinguishes rank-invariant state from rank-relative state
    (paper §4.3): "global" allocations (weights replicas, IO staging) have the
    same size on every rank; "per_rank" allocations (sharded KV pool,
    collective staging buffers) are recorded at their full capture-topology
    size and divided across deployment ranks by ``MemoryPlan.rank_extents`` —
    the buffer-offset half of a RankDelta.
    """
    name: str
    offset: int
    size: int
    phase: str  # "init" | "capture"
    scope: str = "global"  # "global" | "per_rank"

    @property
    def end(self) -> int:
        return self.offset + self.size


class PlanMismatch(RuntimeError):
    pass


class MemoryPlan:
    """Monotonic arena planner. SAVE: record. LOAD: preallocate + replay."""

    def __init__(self, base: int = DEFAULT_BASE, align: int = DEFAULT_ALIGN):
        self.base = base
        self.align = align
        self.allocations: List[Allocation] = []
        self._cursor = 0
        self._phase = "init"
        self._prealloc_extent: Optional[int] = None

    # ---- SAVE-side recording -----------------------------------------
    def set_phase(self, phase: str):
        assert phase in ("init", "capture")
        self._phase = phase

    def alloc(self, name: str, size: int, scope: str = "global") -> int:
        """Reserve the next aligned offset. Returns the absolute address.
        ``scope="per_rank"`` marks the allocation rank-relative (sharded
        across deployment ranks; see ``rank_extents``)."""
        size = int(size)
        if size < 0:
            raise ValueError(f"negative allocation {name}: {size}")
        if scope not in ("global", "per_rank"):
            raise ValueError(f"unknown allocation scope {scope!r}")
        off = self._cursor
        a = Allocation(name, off, size, self._phase, scope)
        self.allocations.append(a)
        pad = (-size) % self.align
        self._cursor = off + size + pad
        if self._prealloc_extent is not None and self._cursor > self._prealloc_extent:
            raise PlanMismatch(
                f"allocation {name} ({size}B at +{off}) exceeds preallocated "
                f"extent {self._prealloc_extent}")
        return self.base + off

    @property
    def extent(self) -> int:
        return self._cursor

    def capture_window(self) -> List[Allocation]:
        return [a for a in self.allocations if a.phase == "capture"]

    def scoped_extent(self, scope: str) -> int:
        """Total recorded bytes under ``scope`` ("global" | "per_rank") —
        the pool-sizing view of §5.4: long-lived pools (KV slot rows, paged
        block pools) register per_rank, so LOAD can pin the deployment's
        per-rank footprint before restore and benchmarks can report it."""
        if scope not in ("global", "per_rank"):
            raise ValueError(f"unknown allocation scope {scope!r}")
        return sum(a.size for a in self.allocations if a.scope == scope)

    # ---- rank-relative view (paper §4.3) ------------------------------
    def rank_extents(self, n_ranks: int) -> List[dict]:
        """Per-rank layout for an ``n_ranks`` deployment of this (capture)
        plan: "per_rank" allocations contribute a 1/n_ranks shard (aligned
        up), "global" allocations their full size. Offsets are re-packed in
        recorded order, so every rank gets the same deterministic layout —
        the comm-buffer-offset table a RankDelta stamps at LOAD."""
        if n_ranks < 1:
            raise ValueError(f"n_ranks must be >= 1, got {n_ranks}")
        out, cursor = [], 0
        for a in self.allocations:
            size = a.size
            if a.scope == "per_rank":
                size = -(-size // n_ranks)  # ceil division: shard per rank
            out.append({"name": a.name, "offset": cursor, "size": size,
                        "scope": a.scope})
            cursor += size + (-size) % self.align
        return out

    def rank_extent_total(self, n_ranks: int) -> int:
        ext = self.rank_extents(n_ranks)
        if not ext:
            return 0
        last = ext[-1]
        return last["offset"] + last["size"]

    # ---- LOAD-side ----------------------------------------------------
    def preallocate(self) -> Tuple[int, int]:
        """One-shot mapping of the full recorded extent (paper: LOAD maps the
        range up to the final SAVE offset in a single VMM call; every later
        allocation is a pointer bump)."""
        if self._prealloc_extent is None:  # SAVE side: extent so far
            self._prealloc_extent = self._cursor
        return self.base, self._prealloc_extent

    @classmethod
    def for_load(cls, recorded: "MemoryPlan | dict") -> "MemoryPlan":
        """Fresh plan primed with the recorded extent; allocations made during
        LOAD are verified against the recorded sequence."""
        rec = recorded.to_manifest() if isinstance(recorded, MemoryPlan) else recorded
        p = cls(base=rec["base"], align=rec["align"])
        p._expected = [Allocation(**a) for a in rec["allocations"]]
        p._prealloc_extent = rec["extent"]
        return p

    def verify_alloc(self, name: str, size: int) -> int:
        """LOAD-side allocation: must match the recorded sequence exactly
        (same name order, same sizes -> same offsets)."""
        i = len(self.allocations)
        exp = getattr(self, "_expected", None)
        if exp is None or i >= len(exp):
            raise PlanMismatch(f"unexpected allocation #{i} {name}")
        e = exp[i]
        if e.name != name or e.size != int(size):
            raise PlanMismatch(
                f"allocation #{i} mismatch: recorded ({e.name}, {e.size}) "
                f"vs requested ({name}, {size}) — SAVE/LOAD sequences diverge")
        a = Allocation(name, e.offset, e.size, e.phase, e.scope)
        self.allocations.append(a)
        self._cursor = max(self._cursor, e.end)
        return self.base + e.offset

    def replay_capture_window(self) -> List[Allocation]:
        """LOAD skips graph capture, so transient capture-window buffers never
        get re-requested; replay them from the record so the executable's
        expected address space is fully populated (paper §4.1.1)."""
        exp = getattr(self, "_expected", [])
        replayed = []
        for e in exp[len(self.allocations):]:
            if e.phase != "capture":
                break
            self.allocations.append(e)
            self._cursor = max(self._cursor, e.end)
            replayed.append(e)
        return replayed

    # ---- (de)serialization ---------------------------------------------
    def to_manifest(self) -> dict:
        return {
            "base": self.base, "align": self.align, "extent": self._cursor,
            "allocations": [vars(a) for a in self.allocations],
        }

    @classmethod
    def from_manifest(cls, m: dict) -> "MemoryPlan":
        p = cls(base=m["base"], align=m["align"])
        p.allocations = [Allocation(**a) for a in m["allocations"]]
        p._cursor = m["extent"]
        return p

    def layout_equal(self, other: "MemoryPlan") -> bool:
        return (self.base == other.base
                and [vars(a) for a in self.allocations]
                == [vars(a) for a in other.allocations])

"""Topology keys for compiled-graph templating (paper §4.2.1).

The paper groups CUDA graphs by "node types in the same order with the same
dependency structure", treating kernel arguments and launch dimensions as
per-node *parameters* outside the key. The JAX analogue of a graph's
topology is the jaxpr structure; the analogue of launch dims / pointer args
is concrete shapes. A topology key therefore hashes:

  * the primitive sequence and dataflow arity (jaxpr eqn order encodes a
    deterministic topological order of the DAG),
  * dtypes and *ranks* (not sizes) of all operands/results,
  * structural params (dimension_numbers, scan structure, shardings,
    shard_map specs, custom-call targets), recursing into sub-jaxprs,

and excludes dimension sizes, so serve-step graphs for different batch-size
buckets collapse to one key — unless batching changes the *program* (e.g. a
bucket stops dividing the data axis and the sharding spec changes), which is
precisely when the paper would also need a new template.
"""
from __future__ import annotations

import hashlib
from functools import partial
from typing import Any

import jax
import numpy as np
from jax.extend import core as jex_core


def _norm_param(v: Any, h) -> None:
    """Feed a normalized representation of one eqn param into the hash."""
    # recurse into sub-jaxprs (scan/cond/custom_vjp bodies)
    if isinstance(v, jex_core.ClosedJaxpr):
        _hash_jaxpr(v.jaxpr, h)
        return
    if isinstance(v, jex_core.Jaxpr):
        _hash_jaxpr(v, h)
        return
    if isinstance(v, (tuple, list)):
        h.update(b"(")
        for x in v:
            _norm_param(x, h)
        h.update(b")")
        return
    if isinstance(v, dict):
        for k in sorted(v, key=str):
            h.update(str(k).encode())
            _norm_param(v[k], h)
        return
    if isinstance(v, (bool, str, bytes)):
        h.update(str(v).encode())
        return
    if isinstance(v, (np.dtype, type)):
        h.update(str(v).encode())
        return
    if isinstance(v, (int, np.integer)):
        # sizes are per-node parameters, not topology -> rank-only marker.
        # Small ints (< 16) are structural (dim indices, axis ids, arity).
        h.update(b"i" if int(v) >= 16 else str(int(v)).encode())
        return
    if isinstance(v, (float, np.floating)):
        h.update(b"f")
        return
    if v is None:
        h.update(b"N")
        return
    # partition specs, shardings, callables, avals: use stable str forms
    h.update(type(v).__name__.encode())
    try:
        h.update(str(v).encode())
    except Exception:
        pass


def _hash_aval(aval, h) -> None:
    dt = getattr(aval, "dtype", None)
    shape = getattr(aval, "shape", ())
    h.update(str(dt).encode())
    h.update(bytes([len(shape) & 0xFF]))


_PARAM_SKIP = {
    # purely size-like params that scale with the bucket
    "shape", "new_sizes", "sizes", "limit_indices", "start_indices",
    "strides", "broadcast_sizes", "slice_sizes", "padding_config",
    "dimensions_to_pad",
}


def _hash_jaxpr(jaxpr, h) -> None:
    h.update(b"J")
    for v in jaxpr.invars:
        _hash_aval(v.aval, h)
    for eqn in jaxpr.eqns:
        h.update(eqn.primitive.name.encode())
        h.update(bytes([len(eqn.invars) & 0xFF, len(eqn.outvars) & 0xFF]))
        for v in eqn.invars:
            if hasattr(v, "aval"):
                _hash_aval(v.aval, h)
        for v in eqn.outvars:
            _hash_aval(v.aval, h)
        for k in sorted(eqn.params):
            if k in _PARAM_SKIP:
                continue
            h.update(k.encode())
            _norm_param(eqn.params[k], h)
    for v in jaxpr.outvars:
        if hasattr(v, "aval"):
            _hash_aval(v.aval, h)


def jaxpr_topology_key(closed_jaxpr) -> str:
    h = hashlib.blake2b(digest_size=16)
    _hash_jaxpr(closed_jaxpr.jaxpr, h)
    return h.hexdigest()


def topology_key(fn, *args, extra: Any = None, **kwargs) -> str:
    """Topology key of ``fn`` traced at the given (Shape/DtypeStruct or
    concrete) args. ``extra`` folds deployment identity (mesh shape, sharding
    mode) into the key — the paper's analogue is that graphs from different
    parallelism configs never share templates."""
    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs)
    h = hashlib.blake2b(digest_size=16)
    _hash_jaxpr(jaxpr.jaxpr, h)
    if extra is not None:
        h.update(str(extra).encode())
    return h.hexdigest()

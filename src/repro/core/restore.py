"""LOAD: online graph reconstruction from a Foundry archive (paper Figure 4,
right side).

Critical-path work:
  1. parse the archive (binary format -> ms, paper §5.3),
  2. preallocate the memory-plan extent + replay capture-window allocations,
  3. prime the kernel catalog (binaries resolvable by (hash, name) without
     warmup),
  4. deserialize each topology group's template executable
     (zero trace, zero compile),
and the engine is servable: every bucket dispatches through its group
template by batch padding. Off the critical path, worker threads realize
exact-bucket executables from the archived StableHLO (no Python re-trace) and
hot-swap them into the ProgramSet — template construction and on-demand
specialization run concurrently exactly as in the paper (§4.2.1), except the
"driver contention" (here: compiler) stays off the serving path entirely.

Mesh rebinding (paper §4.2.2): the archive stores the mesh *shape*; LOAD
binds programs to the deployment's concrete device mesh. If the runtime
topology differs from the capture topology, template deserialization falls
back to compile-from-StableHLO (documented; on a real fleet the per-topology
compile happens once per rollout and is shared by all ranks of the SPMD
program — the single-capture/many-ranks economics the paper targets).
"""
from __future__ import annotations

import pickle
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax

from repro.core.archive import Archive
from repro.core.memory_plan import MemoryPlan
from repro.core.templates import ProgramSet, TopologyGroup


@dataclass
class LoadReport:
    phases: Dict[str, float] = field(default_factory=dict)
    n_templates: int = 0
    n_buckets: int = 0
    fallback_compiles: int = 0
    background_exact: int = 0

    @property
    def critical_path_s(self) -> float:
        return sum(v for k, v in self.phases.items()
                   if not k.startswith("background"))


def _deserialize_template(blob: bytes):
    from jax.experimental import serialize_executable as se
    payload = pickle.loads(blob)
    if isinstance(payload, tuple):
        return se.deserialize_and_load(*payload)
    return se.deserialize_and_load(payload)


def foundry_load(archive: Archive, mesh, *,
                 make_args: Optional[Dict[str, Callable[[int], tuple]]] = None,
                 spec_names: Optional[Sequence[str]] = None,
                 background_exact: bool = True,
                 background_threads: int = 2,
                 kernel_catalog=None,
                 verbose: bool = False) -> tuple[Dict[str, ProgramSet], LoadReport, Optional[MemoryPlan]]:
    """Restore executables from an archive. Returns
    ({spec_name: ProgramSet}, report, load_side_memory_plan)."""
    rep = LoadReport()
    t0 = time.perf_counter()
    manifest = archive.manifest
    rep.phases["parse_s"] = time.perf_counter() - t0

    # --- memory plan: preallocate + capture-window replay -----------------
    t0 = time.perf_counter()
    plan = None
    if manifest.get("memory_plan"):
        plan = MemoryPlan.for_load(manifest["memory_plan"])
        plan.preallocate()
    rep.phases["prealloc_s"] = time.perf_counter() - t0

    # --- kernel catalog prime ---------------------------------------------
    t0 = time.perf_counter()
    if kernel_catalog is not None and manifest.get("kernel_catalog"):
        kernel_catalog.prime(manifest["kernel_catalog"], archive)
    rep.phases["kernel_load_s"] = time.perf_counter() - t0

    # --- templates ---------------------------------------------------------
    program_sets: Dict[str, ProgramSet] = {}
    names = spec_names or list(manifest["specs"])
    t0 = time.perf_counter()
    pending_exact: List[tuple] = []
    for name in names:
        spec_m = manifest["specs"][name]
        groups = [TopologyGroup.from_manifest(g) for g in spec_m["groups"]]
        ps = ProgramSet(groups)
        rep.n_buckets += len(ps.buckets)
        for g in groups:
            exe = None
            if g.executable_blob:
                try:
                    exe = _deserialize_template(
                        archive.get_blob(g.executable_blob))
                except Exception:
                    # topology mismatch: rebind via compile-from-StableHLO
                    rep.fallback_compiles += 1
                    exe = _compile_from_export(
                        archive, g.bucket_export_blobs[g.template_bucket],
                        spec_m, mesh)
            if exe is not None:
                ps.set_template(g.key, exe)
            rep.n_templates += 1
            for b in g.buckets:
                if b != g.template_bucket and b in g.bucket_export_blobs:
                    pending_exact.append((ps, g, b))
        program_sets[name] = ps
    rep.phases["templates_s"] = time.perf_counter() - t0

    # --- background exact-bucket realization --------------------------------
    if background_exact and pending_exact:
        t_bg = time.perf_counter()

        def worker(chunk):
            for ps, g, b in chunk:
                try:
                    exe = _compile_from_export(
                        archive, g.bucket_export_blobs[b],
                        manifest["specs"], mesh)
                    ps.set_exact(b, exe)
                    rep.background_exact += 1
                except Exception:
                    pass  # bucket stays pad-served through its template

        chunks = [pending_exact[i::background_threads]
                  for i in range(background_threads)]
        threads = [threading.Thread(target=worker, args=(c,), daemon=True)
                   for c in chunks if c]
        for t in threads:
            t.start()
        rep._bg_threads = threads  # joinable by callers/tests
        rep.phases["background_spawn_s"] = time.perf_counter() - t_bg

    if verbose:
        print(f"[LOAD] {rep.n_templates} templates over {rep.n_buckets} "
              f"buckets in {rep.critical_path_s * 1e3:.1f} ms "
              f"(parse {rep.phases['parse_s']*1e3:.1f} ms, templates "
              f"{rep.phases['templates_s']*1e3:.1f} ms, "
              f"fallback_compiles={rep.fallback_compiles})")
    return program_sets, rep, plan


def _compile_from_export(archive: Archive, blob_hash: str, spec_m, mesh):
    """Exact-bucket reconstruction: deserialize pre-lowered StableHLO and
    compile — no Python tracing of the model (the paper's 'graph construction
    via driver APIs', 2-3x cheaper than stream capture; Figure 10)."""
    exp = jax.export.deserialize(bytearray(archive.get_blob(blob_hash)))
    fn = jax.jit(exp.call)
    flat = [jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s)
            for a, s in zip(exp.in_avals, _exp_shardings(exp, mesh))]
    args, kwargs = jax.tree.unflatten(exp.in_tree, flat)
    return fn.lower(*args, **kwargs).compile()


def _exp_shardings(exp, mesh):
    """Rebind the export's recorded HloShardings onto the deployment mesh."""
    try:
        return list(exp.in_shardings_jax(mesh))
    except Exception:
        return [None] * len(exp.in_avals)


def wait_for_background(rep: LoadReport, timeout: float = 300.0):
    for t in getattr(rep, "_bg_threads", []):
        t.join(timeout)

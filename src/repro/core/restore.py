"""LOAD: online graph reconstruction from a Foundry archive (paper Figure 4,
right side).

Critical-path work, run as a pipelined stage graph:

    parse ─▶ rebind decision ─▶ rank deltas
                 │
                 ├─▶ [fetch worker]   blob read + decompress + verify
                 │        │                    (stage 1, thread)
                 ├─▶ prealloc          overlaps stage 1
                 ├─▶ kernel prime      overlaps stage 1
                 │        │
                 │   [deserialize worker]  pickle + deserialize_and_load
                 │        │                    (stage 2, thread)
                 └─▶ install           stamp + hot-swap into ProgramSet
                                           (stage 3, caller thread)

The stages are connected by bounded queues (``pipeline_depth`` groups in
flight), so group k's template is installed — and its buckets servable —
while group k+1 deserializes and group k+2's blob is still being fetched.
With a lazy v2 archive (core/archive.py) the fetch stage is also where the
blob is decompressed for the first (and only) time; concurrent LOADs of one
shared archive de-duplicate that work through the archive's blob cache.
``LoadReport.phases`` keeps the same keys as the sequential implementation
(parse_s, prealloc_s, kernel_load_s, rank_delta_s, templates_s): overlap
shows up as a smaller ``templates_s``, and per-stage busy time is reported
separately in ``LoadReport.pipeline``.

Off the critical path, worker threads realize exact-bucket executables from
the archived StableHLO (no Python re-trace) and hot-swap them into the
ProgramSet — template construction and on-demand specialization run
concurrently exactly as in the paper (§4.2.1), except the "driver
contention" (here: compiler) stays off the serving path entirely. A
background compile that fails is recorded in
``LoadReport.background_errors`` (count) and ``background_first_error``
(first message) — never swallowed silently; the affected bucket simply
stays pad-served through its template.

Mesh rebinding (paper §4.2.2 + §4.3): the archive stores the capture mesh
identity; LOAD binds programs to the deployment's concrete device mesh by a
three-way decision (docs/architecture.md has the full diagram):

    exact     deployment mesh == capture mesh: deserialize templates,
              zero trace, zero compile;
    stamped   shape-compatible rebind (1-rank capture -> any deployment, or
              same rank count with re-arranged axes, e.g. TP<->EP): reuse the
              template program byte-identically and stamp only rank-dependent
              state — peer tables, mesh coordinates, rank-relative buffer
              offsets (core/rank_stamp.py). Still zero compile;
    fallback  incompatible topology (true scale change of a multi-rank
              capture): compile-from-StableHLO, counted in
              ``LoadReport.fallback_compiles``.
"""
from __future__ import annotations

import logging
import pickle
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.export  # not re-exported by bare `import jax` on jax<=0.4.x

from repro.core.archive import Archive
from repro.core.collective_stub import (mesh_identity, same_topology,
                                        stamp_compatible)
from repro.core.memory_plan import MemoryPlan
from repro.core.rank_stamp import (ReshardingExecutable, deployment_deltas,
                                   stamp_template)
from repro.core.templates import ProgramSet, TopologyGroup
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.trace import span
from repro.serving.faults import fault_point

log = logging.getLogger("repro.core.restore")

# docs/architecture.md §13 has the full metric catalog
_M_LOADS = obs_metrics.counter(
    "foundry_load_total", "Completed LOADs by mesh-rebind decision.",
    ("rebind",))
_M_PHASE = obs_metrics.histogram(
    "foundry_load_phase_seconds",
    "Critical-path LOAD phase durations (same measurement as "
    "LoadReport.phases).", ("phase",))
_M_PIPE_BUSY = obs_metrics.counter(
    "foundry_load_pipeline_busy_seconds_total",
    "Busy seconds per LOAD template stage-graph stage.", ("stage",))
_M_FALLBACK = obs_metrics.counter(
    "foundry_load_fallback_compiles_total",
    "Critical-path compile-from-StableHLO events (template economics lost).")
_M_STAMPED = obs_metrics.counter(
    "foundry_load_rank_stamped_total",
    "Template x deployment-rank stampings on the stamped rebind path.")
_M_BG_ERRORS = obs_metrics.counter(
    "foundry_load_background_errors_total",
    "Background exact-bucket realizations that failed (bucket stays "
    "pad-served).")
_M_TEMPLATES_REUSED = obs_metrics.counter(
    "foundry_load_templates_reused_total",
    "Templates served from the archive's deserialized-template cache "
    "(no fetch, no deserialize).")


@dataclass
class LoadReport:
    """What LOAD did and what it cost.

    Fields:
        phases            phase name -> seconds. Keys not prefixed
                          "background" are on the cold-start critical path
                          (parse_s, verify_s, prealloc_s, kernel_load_s,
                          rank_delta_s, templates_s — verify_s is the strict
                          pre-flight of repro.analysis.checker, metadata-only
                          and negligible); background_spawn_s only covers thread
                          spawn, not the background compiles themselves.
                          templates_s is the caller-thread wall time of the
                          install stage — fetch/deserialize work hidden under
                          prealloc/kernel-prime by the pipeline shrinks it.
        pipeline          per-stage busy seconds of the template stage graph
                          (fetch_s, deserialize_s, install_s) + "depth".
        restore_path      the mesh-rebind decision taken for this archive:
                          "exact" | "stamped" | "fallback" (module docstring).
        n_templates       topology-group templates processed.
        n_buckets         total capture buckets covered by those templates.
        rank_stamped      number of (template x deployment-rank) stampings
                          performed on the stamped path — every rank's
                          ProgramSet reconstructed without touching the
                          compiler. 0 on the exact path.
        fallback_compiles critical-path compile-from-StableHLO events; the
                          template economics are lost for each one. Stays 0
                          on exact and shape-compatible stamped loads.
        background_exact  exact-bucket executables realized off the critical
                          path by worker threads (join via
                          ``wait_for_background``).
        background_errors background exact-bucket realizations that FAILED.
                          The bucket stays pad-served through its template,
                          but a systematically failing compile must be
                          visible: happy-path tests assert this is 0.
        background_first_error
                          message of the first background failure (or None).
        warm              this was a LOAD into an already-warm serving
                          process (live reshard): prealloc was skipped —
                          the plan extent is already mapped — and templates
                          deserialized by an earlier LOAD of the same
                          Archive object were reused.
        templates_reused  templates taken from the archive's deserialized-
                          template cache instead of being fetched +
                          deserialized again (counted toward n_templates).
    """
    phases: Dict[str, float] = field(default_factory=dict)
    pipeline: Dict[str, float] = field(default_factory=dict)
    restore_path: str = "exact"
    n_templates: int = 0
    n_buckets: int = 0
    rank_stamped: int = 0
    fallback_compiles: int = 0
    background_exact: int = 0
    background_errors: int = 0
    background_first_error: Optional[str] = None
    warm: bool = False
    templates_reused: int = 0

    @property
    def critical_path_s(self) -> float:
        return sum(v for k, v in self.phases.items()
                   if not k.startswith("background"))


def _deserialize_template(blob: bytes):
    from jax.experimental import serialize_executable as se
    fault_point("archive.deserialize")
    payload = pickle.loads(blob)
    if isinstance(payload, tuple):
        return se.deserialize_and_load(*payload)
    return se.deserialize_and_load(payload)


def _template_cache(archive: Archive) -> dict:
    """Per-Archive cache of *unwrapped* deserialized template executables,
    keyed by blob hash. Scoped to the Archive object on purpose: a fleet (or
    a live reshard) shares ONE archive across every replica LOAD, so the
    second and later LOADs skip fetch + deserialize entirely, while separate
    Archive instances (benchmark legs, tests) stay independent. Sharing the
    underlying loaded executable is safe — calls are functional and each
    LOAD wraps it in its own Resharding/StampedExecutable — and a racing
    first-LOAD pair at worst deserializes twice (last write wins)."""
    cache = getattr(archive, "_loaded_template_cache", None)
    if cache is None:
        cache = archive._loaded_template_cache = {}
    return cache


# ---------------------------------------------------------------------------
# template stage graph
# ---------------------------------------------------------------------------
@dataclass
class _TemplateJob:
    """One topology group flowing through the LOAD pipeline."""
    ps: ProgramSet
    group: TopologyGroup
    donate: Any
    blob_hash: Optional[str]      # blob stage 1 must fetch (None: no exe)
    deserialize: bool             # stage 2 work (False on the fallback path)
    blob: Optional[bytes] = None  # stage 1 -> 2
    exe: Any = None               # stage 2 -> 3
    error: Optional[BaseException] = None
    error_stage: Optional[str] = None  # "fetch" | "deserialize" | "stamp"


_DONE = object()


class _TemplatePipeline:
    """fetch (thread) -> deserialize (thread) -> install (caller).

    Bounded queues cap in-flight groups at ``depth``; jobs come out in
    submission order per stage, so installation order (and therefore
    LoadReport accounting) is deterministic. Stage exceptions ride on the
    job — the caller decides (deserialize failure -> fallback compile),
    nothing is swallowed.
    """

    def __init__(self, archive: Archive, jobs: Sequence[_TemplateJob],
                 depth: int = 4):
        self.archive = archive
        self.jobs = list(jobs)
        self.busy = {"fetch_s": 0.0, "deserialize_s": 0.0, "install_s": 0.0}
        self._fetched: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self._ready: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self._aborted = False
        self._threads = [
            threading.Thread(target=self._fetch_stage, daemon=True),
            threading.Thread(target=self._deserialize_stage, daemon=True),
        ]

    def start(self) -> "_TemplatePipeline":
        for t in self._threads:
            t.start()
        return self

    def abort(self):
        """Unblock and wind down the stage threads after a consumer-side
        failure (without this they would sit on the bounded queues forever,
        pinning fetched blobs)."""
        self._aborted = True

    def _put(self, q: "queue.Queue", item) -> bool:
        """Bounded put that gives up once the pipeline is aborted."""
        while not self._aborted:
            try:
                q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _fetch_stage(self):
        obs_trace.set_thread_name("load.fetch")
        for job in self.jobs:
            if self._aborted:
                return
            with span("load.fetch", cat="load",
                      group=job.group.key[:12]) as sp:
                try:
                    if job.blob_hash is not None:
                        job.blob = self.archive.get_blob(job.blob_hash)
                except BaseException as e:
                    job.error, job.error_stage = e, "fetch"
            self.busy["fetch_s"] += sp.seconds
            if not self._put(self._fetched, job):
                return
        self._put(self._fetched, _DONE)

    def _deserialize_stage(self):
        obs_trace.set_thread_name("load.deserialize")
        while True:
            try:
                job = self._fetched.get(timeout=0.05)
            except queue.Empty:
                if self._aborted:
                    return
                continue
            if job is _DONE:
                self._put(self._ready, _DONE)
                return
            with span("load.deserialize", cat="load",
                      group=job.group.key[:12]) as sp:
                if job.error is None and job.deserialize and \
                        job.blob is not None:
                    try:
                        job.exe = _deserialize_template(job.blob)
                    except BaseException as e:
                        job.error, job.error_stage = e, "deserialize"
                job.blob = None  # stage 2 owns the last ref to the bytes
            self.busy["deserialize_s"] += sp.seconds
            if not self._put(self._ready, job):
                return

    def __iter__(self):
        """Yield jobs in submission order as stage 2 completes them."""
        while True:
            job = self._ready.get()
            if job is _DONE:
                return
            yield job


def foundry_load(archive: Archive, mesh, *,
                 make_args: Optional[Dict[str, Callable[[int], tuple]]] = None,
                 spec_names: Optional[Sequence[str]] = None,
                 background_exact: bool = True,
                 background_threads: int = 2,
                 kernel_catalog=None,
                 allow_stamping: bool = True,
                 pipeline_depth: int = 4,
                 warm: bool = False,
                 reuse_templates: bool = True,
                 strict: bool = True,
                 verbose: bool = False,
                 trace_path: Optional[str] = None) -> tuple[Dict[str, ProgramSet], LoadReport, Optional[MemoryPlan]]:
    """Restore executables from an archive. Returns
    ({spec_name: ProgramSet}, report, load_side_memory_plan).

    ``allow_stamping=False`` disables the rank-stamping rebind path, forcing
    mesh mismatches down the compile-from-StableHLO fallback (the paper's
    no-stamping ablation; benchmarks/fig12_rank_stamp.py).
    ``pipeline_depth`` bounds how many topology groups the LOAD stage graph
    keeps in flight (module docstring); 0 degrades to depth 1.
    ``warm=True`` is the live-reshard case — a LOAD racing an already-warm
    serving process (paper §4.3 "dynamic parallelism switching"): the
    memory-plan extent is already mapped by the serving replicas, so
    preallocation is skipped (the plan itself is still parsed and returned
    for verification). ``reuse_templates`` (default on) consults the
    archive's deserialized-template cache so repeat LOADs of one shared
    Archive — fleet scale-out, reshard — skip fetch + deserialize for
    templates an earlier LOAD already realized.

    ``strict`` (default on) runs the static pre-flight verification of
    ``repro.analysis.checker.verify_for_load`` over the manifest before any
    restore work: a structurally-bad archive raises
    ``ArchiveVerificationError`` with the findings instead of silently
    degrading into per-template fallback compiles, and a blob whose bytes
    fail content verification during the fetch stage raises instead of
    fallback-compiling that template. The pre-flight is metadata-only (no
    blob fetches, no IR deserialization) so its cost — recorded as
    ``phases["verify_s"]`` — is negligible next to the LOAD critical path
    (the fig13 --quick gate asserts < 5%).

    ``trace_path`` writes a Chrome/Perfetto trace-event JSON file of this
    LOAD on return (starting tracing for the call if it was not already
    active); load it at https://ui.perfetto.dev to see the fetch /
    deserialize / install stages overlap on their threads."""
    if verbose:
        from repro.obs import configure_logging
        configure_logging()
    trace_started_here = False
    if trace_path is not None and not obs_trace.active():
        obs_trace.start()
        trace_started_here = True
    try:
        return _foundry_load(
            archive, mesh, make_args=make_args, spec_names=spec_names,
            background_exact=background_exact,
            background_threads=background_threads,
            kernel_catalog=kernel_catalog, allow_stamping=allow_stamping,
            pipeline_depth=pipeline_depth, warm=warm,
            reuse_templates=reuse_templates, strict=strict)
    finally:
        if trace_path is not None:
            obs_trace.save(trace_path)
        if trace_started_here:
            obs_trace.stop()


def _foundry_load(archive: Archive, mesh, *, make_args, spec_names,
                  background_exact, background_threads, kernel_catalog,
                  allow_stamping, pipeline_depth, warm, reuse_templates,
                  strict):
    rep = LoadReport(warm=warm)
    obs_trace.set_thread_name("load.install+main")
    with span("load.parse", cat="load") as sp:
        manifest = archive.manifest
    rep.phases["parse_s"] = sp.seconds

    if strict:
        from repro.analysis.checker import (ArchiveVerificationError, errors,
                                            verify_for_load)
        with span("load.verify", cat="load") as sp:
            findings = verify_for_load(archive)
        rep.phases["verify_s"] = sp.seconds
        if errors(findings):
            raise ArchiveVerificationError(findings, rep)

    # --- mesh-rebind decision (module docstring: exact/stamped/fallback) --
    capture_identity = manifest.get("mesh") or {"axes": [], "shape": []}
    if mesh is None or same_topology(capture_identity, mesh):
        rep.restore_path = "exact"
    elif allow_stamping and stamp_compatible(capture_identity, mesh):
        rep.restore_path = "stamped"
    else:
        rep.restore_path = "fallback"

    rank_deltas = None
    if rep.restore_path == "stamped":
        with span("load.rank_delta", cat="load") as sp:
            rank_deltas = deployment_deltas(mesh, manifest)
        rep.phases["rank_delta_s"] = sp.seconds

    # --- enumerate template jobs and start the stage graph ----------------
    # (fetch + deserialize overlap the prealloc / kernel-prime phases below)
    program_sets: Dict[str, ProgramSet] = {}
    names = spec_names or list(manifest["specs"])
    jobs: List[_TemplateJob] = []
    pending_exact: List[tuple] = []
    tcache = _template_cache(archive) if reuse_templates else {}
    for name in names:
        spec_m = manifest["specs"][name]
        donate = spec_m.get("donate_argnums")
        groups = [TopologyGroup.from_manifest(g) for g in spec_m["groups"]]
        ps = ProgramSet(groups)
        rep.n_buckets += len(ps.buckets)
        for g in groups:
            blob_hash = None
            deserialize = False
            cached = None
            if g.executable_blob:
                if rep.restore_path == "fallback":
                    # prefetch the StableHLO the fallback compile will read
                    blob_hash = g.bucket_export_blobs[g.template_bucket]
                elif reuse_templates and (cached := tcache.get(
                        g.executable_blob)) is not None:
                    rep.templates_reused += 1  # no fetch, no deserialize
                else:
                    blob_hash = g.executable_blob
                    deserialize = True
            job = _TemplateJob(ps, g, donate, blob_hash, deserialize)
            job.exe = cached
            jobs.append(job)
            for b in g.buckets:
                if b != g.template_bucket and b in g.bucket_export_blobs:
                    pending_exact.append((ps, g, b, donate))
        program_sets[name] = ps
    pipe = _TemplatePipeline(archive, jobs,
                             depth=max(1, pipeline_depth)).start()

    try:
        # --- memory plan: preallocate + capture-window replay -------------
        with span("load.prealloc", cat="load") as sp:
            plan = None
            if manifest.get("memory_plan"):
                plan = MemoryPlan.for_load(manifest["memory_plan"])
                if not warm:
                    # a warm process (live reshard) already has the recorded
                    # extent mapped; re-preallocating would double the
                    # footprint
                    plan.preallocate()
        rep.phases["prealloc_s"] = sp.seconds

        # --- kernel catalog prime -----------------------------------------
        with span("load.kernel_load", cat="load") as sp:
            if kernel_catalog is not None and manifest.get("kernel_catalog"):
                kernel_catalog.prime(manifest["kernel_catalog"], archive)
        rep.phases["kernel_load_s"] = sp.seconds

        # --- install stage: stamp + hot-swap as groups leave the pipe -----
        t0 = time.perf_counter()
        for job in pipe:
            g, exe = job.group, job.exe
            with span("load.install", cat="load", group=g.key[:12]):
                fault_point("restore.install", tag=g.key)
                if g.executable_blob:
                    if (reuse_templates and job.deserialize
                            and exe is not None
                            and g.executable_blob not in tcache):
                        tcache[g.executable_blob] = exe  # unwrapped: wrappers
                        # below are per-LOAD (donation ownership per engine)
                    if exe is not None and rep.restore_path == "stamped":
                        try:
                            exe = stamp_template(exe, rank_deltas,
                                                 capture_identity, mesh,
                                                 job.donate)
                            rep.rank_stamped += len(rank_deltas)
                        except Exception as e:
                            job.error, job.error_stage = e, "stamp"
                            exe = None  # degrade to fallback below
                    if exe is None:
                        if strict and job.error_stage == "fetch":
                            # a fetch failure is the archive lying about its
                            # own contents (hash mismatch, truncated section,
                            # missing depot blob) — strict LOAD refuses it
                            # rather than hiding the corruption behind a
                            # fallback compile. Deserialize/stamp failures
                            # still degrade: they are environment-side
                            # (capture devices unavailable).
                            from repro.analysis.checker import (
                                ArchiveVerificationError, Finding)
                            raise ArchiveVerificationError([Finding(
                                "blob-integrity", "error",
                                f"blob/{(job.blob_hash or '?')[:12]}",
                                f"template blob for group {g.key[:12]} "
                                f"failed to fetch: "
                                f"{type(job.error).__name__}: {job.error}",
                                "the archive is corrupt; re-run SAVE")], rep)
                        # fallback decision, deserialize/stamp failure, or
                        # capture devices unavailable: last-resort rebind via
                        # compile-from-StableHLO (the blob is already
                        # cache-hot when the fetch stage prefetched it)
                        if job.error is not None:
                            log.warning(
                                "template for group %s unusable (%s: %s); "
                                "falling back to compile", g.key[:12],
                                type(job.error).__name__, job.error)
                        rep.fallback_compiles += 1
                        _M_FALLBACK.inc()
                        exe = ReshardingExecutable(_compile_from_export(
                            archive,
                            g.bucket_export_blobs[g.template_bucket],
                            mesh, capture_identity,
                            donate_argnums=job.donate), job.donate)
                    elif not isinstance(exe, ReshardingExecutable):
                        # exact path: a DESERIALIZED template must never
                        # donate a caller buffer produced by device_put
                        # (XLA-CPU crash; rank_stamp.ReshardingExecutable
                        # docstring). The wrapper copies host-touched donated
                        # leaves once and passes its own fed-back outputs
                        # through untouched, so the donated KV cache of
                        # steady-state decode stays zero-copy.
                        exe = ReshardingExecutable(exe, job.donate)
                    job.ps.set_template(g.key, exe)
                rep.n_templates += 1
        rep.phases["templates_s"] = time.perf_counter() - t0
    except BaseException:
        pipe.abort()  # unblock stage threads; they exit, dropping blobs
        raise
    pipe.busy["install_s"] = rep.phases["templates_s"]
    rep.pipeline = dict(pipe.busy, depth=float(max(1, pipeline_depth)))

    # --- background exact-bucket realization --------------------------------
    if background_exact and pending_exact:
        t_bg = time.perf_counter()
        err_lock = threading.Lock()

        def worker(chunk):
            obs_trace.set_thread_name("load.background")
            for ps, g, b, donate in chunk:
                try:
                    exe = _compile_from_export(
                        archive, g.bucket_export_blobs[b],
                        mesh, capture_identity, donate_argnums=donate)
                    if rep.restore_path != "exact":
                        # exact exes must accept deployment-sharded args too
                        exe = ReshardingExecutable(exe, donate)
                    ps.set_exact(b, exe)
                    rep.background_exact += 1
                except Exception as e:
                    # bucket stays pad-served through its template, but the
                    # failure must be visible (LoadReport.background_errors)
                    with err_lock:
                        rep.background_errors += 1
                        if rep.background_first_error is None:
                            rep.background_first_error = (
                                f"bucket {b}: {type(e).__name__}: {e}")
                    _M_BG_ERRORS.inc()
                    log.warning("background exact realization FAILED for "
                                "bucket %s: %s: %s", b, type(e).__name__, e)

        chunks = [pending_exact[i::background_threads]
                  for i in range(background_threads)]
        threads = [threading.Thread(target=worker, args=(c,), daemon=True)
                   for c in chunks if c]
        for t in threads:
            t.start()
        rep._bg_threads = threads  # joinable by callers/tests
        rep.phases["background_spawn_s"] = time.perf_counter() - t_bg

    # --- registry feed: same measurements the report just recorded --------
    if obs_metrics.enabled():
        _M_LOADS.inc(rebind="stamped" if rep.rank_stamped else "compatible")
        for k, v in rep.phases.items():
            _M_PHASE.observe(v, phase=k[:-2] if k.endswith("_s") else k)
        for stage in ("fetch", "deserialize", "install"):
            _M_PIPE_BUSY.inc(rep.pipeline[f"{stage}_s"], stage=stage)
        if rep.rank_stamped:
            _M_STAMPED.inc(rep.rank_stamped)
        if rep.templates_reused:
            _M_TEMPLATES_REUSED.inc(rep.templates_reused)

    log.info("[LOAD:%s] %d templates over %d buckets in %.1f ms "
             "(parse %.1f ms, install %.1f ms, pipeline fetch %.1f ms / "
             "deserialize %.1f ms, rank_stamped=%d, fallback_compiles=%d)",
             rep.restore_path, rep.n_templates, rep.n_buckets,
             rep.critical_path_s * 1e3, rep.phases["parse_s"] * 1e3,
             rep.phases["templates_s"] * 1e3, rep.pipeline["fetch_s"] * 1e3,
             rep.pipeline["deserialize_s"] * 1e3, rep.rank_stamped,
             rep.fallback_compiles)
    return program_sets, rep, plan


def _compile_from_export(archive: Archive, blob_hash: str, mesh,
                         capture_identity: Optional[dict] = None,
                         donate_argnums: Optional[Sequence[int]] = None):
    """Exact-bucket reconstruction: deserialize pre-lowered StableHLO and
    compile — no Python tracing of the model (the paper's 'graph construction
    via driver APIs', 2-3x cheaper than stream capture; Figure 10).

    ``donate_argnums`` (the capture spec's, from the manifest) is re-applied
    so reconstructed executables keep the in-place buffer discipline of the
    capture — without it, the decode cache would be copied every step on any
    bucket served by an exact realization. Fresh compiles donate
    ``device_put``-produced buffers safely (the XLA-CPU crash is specific to
    *deserialized* executables; rank_stamp.ReshardingExecutable docstring).

    A jax.export program is pinned to its capture-time device count. When the
    deployment mesh's count differs, the program is bound onto a
    capture-shaped submesh of the deployment (serving from a subset of ranks;
    a true re-shape needs a fresh SAVE for that topology). A deployment
    smaller than the capture cannot host the program at all and raises."""
    exp = jax.export.deserialize(bytearray(archive.get_blob(blob_hash)))
    call_mesh = mesh
    n_exp = getattr(exp, "nr_devices", 1)
    if mesh is not None and n_exp != mesh.devices.size and capture_identity:
        devs = mesh.devices.reshape(-1)[:n_exp]
        if len(devs) < n_exp:
            raise RuntimeError(
                f"archive was captured for {n_exp} ranks but the deployment "
                f"mesh has only {mesh.devices.size}; a multi-rank capture "
                f"cannot be scaled down — re-run SAVE for this topology")
        import numpy as np
        from jax.sharding import Mesh
        shape = capture_identity.get("shape") or [n_exp]
        call_mesh = Mesh(np.asarray(devs).reshape(tuple(shape)),
                         tuple(capture_identity.get("axes") or ["devices"]))
    fn = jax.jit(exp.call, donate_argnums=tuple(donate_argnums or ()))
    flat = [jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s)
            for a, s in zip(exp.in_avals, _exp_shardings(exp, call_mesh))]
    args, kwargs = jax.tree.unflatten(exp.in_tree, flat)
    return fn.lower(*args, **kwargs).compile()


def _exp_shardings(exp, mesh):
    """Rebind the export's recorded HloShardings onto the deployment mesh."""
    try:
        return list(exp.in_shardings_jax(mesh))
    except Exception:
        return [None] * len(exp.in_avals)


def wait_for_background(rep: LoadReport, timeout: float = 300.0,
                        verbose: bool = False):
    """Join the background exact-bucket worker threads of a LOAD.

    Join contract: ``foundry_load`` returns while daemon workers may still be
    hot-swapping exact executables into the returned ProgramSets. Serving
    does NOT need this join — every bucket is already pad-servable through
    its (possibly stamped) template, and ``ProgramSet`` hot-swap is
    lock-protected. Call it only when you need completion of exact
    realization: deterministic tests, benchmarks measuring
    ``background_exact``, or before process exit if archive file handles
    must be released. ``timeout`` is per thread (seconds); on timeout the
    thread keeps running as a daemon and any buckets it has not yet swapped
    simply stay pad-served — there is no error and no partial state, so the
    call is safe to repeat. With ``verbose`` a summary of background
    failures (``LoadReport.background_errors``) is printed after the join.
    """
    for t in getattr(rep, "_bg_threads", []):
        t.join(timeout)
    if verbose and rep.background_errors:
        log.warning("%d background exact realization(s) failed; first: %s",
                    rep.background_errors, rep.background_first_error)

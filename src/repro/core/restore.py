"""LOAD: online graph reconstruction from a Foundry archive (paper Figure 4,
right side).

Critical-path work:
  1. parse the archive (binary format -> ms, paper §5.3),
  2. preallocate the memory-plan extent + replay capture-window allocations,
  3. prime the kernel catalog (binaries resolvable by (hash, name) without
     warmup),
  4. deserialize each topology group's template executable
     (zero trace, zero compile),
and the engine is servable: every bucket dispatches through its group
template by batch padding. Off the critical path, worker threads realize
exact-bucket executables from the archived StableHLO (no Python re-trace) and
hot-swap them into the ProgramSet — template construction and on-demand
specialization run concurrently exactly as in the paper (§4.2.1), except the
"driver contention" (here: compiler) stays off the serving path entirely.

Mesh rebinding (paper §4.2.2 + §4.3): the archive stores the capture mesh
identity; LOAD binds programs to the deployment's concrete device mesh by a
three-way decision (docs/architecture.md has the full diagram):

    exact     deployment mesh == capture mesh: deserialize templates,
              zero trace, zero compile;
    stamped   shape-compatible rebind (1-rank capture -> any deployment, or
              same rank count with re-arranged axes, e.g. TP<->EP): reuse the
              template program byte-identically and stamp only rank-dependent
              state — peer tables, mesh coordinates, rank-relative buffer
              offsets (core/rank_stamp.py). Still zero compile;
    fallback  incompatible topology (true scale change of a multi-rank
              capture): compile-from-StableHLO, counted in
              ``LoadReport.fallback_compiles``.
"""
from __future__ import annotations

import pickle
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.export  # not re-exported by bare `import jax` on jax<=0.4.x

from repro.core.archive import Archive
from repro.core.collective_stub import (mesh_identity, same_topology,
                                        stamp_compatible)
from repro.core.memory_plan import MemoryPlan
from repro.core.rank_stamp import (ReshardingExecutable, deployment_deltas,
                                   stamp_template)
from repro.core.templates import ProgramSet, TopologyGroup


@dataclass
class LoadReport:
    """What LOAD did and what it cost.

    Fields:
        phases            phase name -> seconds. Keys not prefixed
                          "background" are on the cold-start critical path
                          (parse_s, prealloc_s, kernel_load_s, rank_delta_s,
                          templates_s); background_spawn_s only covers thread
                          spawn, not the background compiles themselves.
        restore_path      the mesh-rebind decision taken for this archive:
                          "exact" | "stamped" | "fallback" (module docstring).
        n_templates       topology-group templates processed.
        n_buckets         total capture buckets covered by those templates.
        rank_stamped      number of (template x deployment-rank) stampings
                          performed on the stamped path — every rank's
                          ProgramSet reconstructed without touching the
                          compiler. 0 on the exact path.
        fallback_compiles critical-path compile-from-StableHLO events; the
                          template economics are lost for each one. Stays 0
                          on exact and shape-compatible stamped loads.
        background_exact  exact-bucket executables realized off the critical
                          path by worker threads (join via
                          ``wait_for_background``).
    """
    phases: Dict[str, float] = field(default_factory=dict)
    restore_path: str = "exact"
    n_templates: int = 0
    n_buckets: int = 0
    rank_stamped: int = 0
    fallback_compiles: int = 0
    background_exact: int = 0

    @property
    def critical_path_s(self) -> float:
        return sum(v for k, v in self.phases.items()
                   if not k.startswith("background"))


def _deserialize_template(blob: bytes):
    from jax.experimental import serialize_executable as se
    payload = pickle.loads(blob)
    if isinstance(payload, tuple):
        return se.deserialize_and_load(*payload)
    return se.deserialize_and_load(payload)


def foundry_load(archive: Archive, mesh, *,
                 make_args: Optional[Dict[str, Callable[[int], tuple]]] = None,
                 spec_names: Optional[Sequence[str]] = None,
                 background_exact: bool = True,
                 background_threads: int = 2,
                 kernel_catalog=None,
                 allow_stamping: bool = True,
                 verbose: bool = False) -> tuple[Dict[str, ProgramSet], LoadReport, Optional[MemoryPlan]]:
    """Restore executables from an archive. Returns
    ({spec_name: ProgramSet}, report, load_side_memory_plan).

    ``allow_stamping=False`` disables the rank-stamping rebind path, forcing
    mesh mismatches down the compile-from-StableHLO fallback (the paper's
    no-stamping ablation; benchmarks/fig12_rank_stamp.py)."""
    rep = LoadReport()
    t0 = time.perf_counter()
    manifest = archive.manifest
    rep.phases["parse_s"] = time.perf_counter() - t0

    # --- mesh-rebind decision (module docstring: exact/stamped/fallback) --
    capture_identity = manifest.get("mesh") or {"axes": [], "shape": []}
    if mesh is None or same_topology(capture_identity, mesh):
        rep.restore_path = "exact"
    elif allow_stamping and stamp_compatible(capture_identity, mesh):
        rep.restore_path = "stamped"
    else:
        rep.restore_path = "fallback"

    rank_deltas = None
    if rep.restore_path == "stamped":
        t0 = time.perf_counter()
        rank_deltas = deployment_deltas(mesh, manifest)
        rep.phases["rank_delta_s"] = time.perf_counter() - t0

    # --- memory plan: preallocate + capture-window replay -----------------
    t0 = time.perf_counter()
    plan = None
    if manifest.get("memory_plan"):
        plan = MemoryPlan.for_load(manifest["memory_plan"])
        plan.preallocate()
    rep.phases["prealloc_s"] = time.perf_counter() - t0

    # --- kernel catalog prime ---------------------------------------------
    t0 = time.perf_counter()
    if kernel_catalog is not None and manifest.get("kernel_catalog"):
        kernel_catalog.prime(manifest["kernel_catalog"], archive)
    rep.phases["kernel_load_s"] = time.perf_counter() - t0

    # --- templates ---------------------------------------------------------
    program_sets: Dict[str, ProgramSet] = {}
    names = spec_names or list(manifest["specs"])
    t0 = time.perf_counter()
    pending_exact: List[tuple] = []
    for name in names:
        spec_m = manifest["specs"][name]
        donate = spec_m.get("donate_argnums")
        groups = [TopologyGroup.from_manifest(g) for g in spec_m["groups"]]
        ps = ProgramSet(groups)
        rep.n_buckets += len(ps.buckets)
        for g in groups:
            exe = None
            if g.executable_blob:
                if rep.restore_path == "fallback":
                    rep.fallback_compiles += 1
                    exe = ReshardingExecutable(_compile_from_export(
                        archive, g.bucket_export_blobs[g.template_bucket],
                        mesh, capture_identity), donate)
                else:
                    try:
                        exe = _deserialize_template(
                            archive.get_blob(g.executable_blob))
                        if rep.restore_path == "stamped":
                            exe = stamp_template(exe, rank_deltas,
                                                 capture_identity, mesh,
                                                 donate)
                            rep.rank_stamped += len(rank_deltas)
                    except Exception:
                        # capture devices unavailable here: last-resort
                        # rebind via compile-from-StableHLO
                        rep.fallback_compiles += 1
                        exe = ReshardingExecutable(_compile_from_export(
                            archive, g.bucket_export_blobs[g.template_bucket],
                            mesh, capture_identity), donate)
            if exe is not None:
                ps.set_template(g.key, exe)
            rep.n_templates += 1
            for b in g.buckets:
                if b != g.template_bucket and b in g.bucket_export_blobs:
                    pending_exact.append((ps, g, b, donate))
        program_sets[name] = ps
    rep.phases["templates_s"] = time.perf_counter() - t0

    # --- background exact-bucket realization --------------------------------
    if background_exact and pending_exact:
        t_bg = time.perf_counter()

        def worker(chunk):
            for ps, g, b, donate in chunk:
                try:
                    exe = _compile_from_export(
                        archive, g.bucket_export_blobs[b],
                        mesh, capture_identity)
                    if rep.restore_path != "exact":
                        # exact exes must accept deployment-sharded args too
                        exe = ReshardingExecutable(exe, donate)
                    ps.set_exact(b, exe)
                    rep.background_exact += 1
                except Exception:
                    pass  # bucket stays pad-served through its template

        chunks = [pending_exact[i::background_threads]
                  for i in range(background_threads)]
        threads = [threading.Thread(target=worker, args=(c,), daemon=True)
                   for c in chunks if c]
        for t in threads:
            t.start()
        rep._bg_threads = threads  # joinable by callers/tests
        rep.phases["background_spawn_s"] = time.perf_counter() - t_bg

    if verbose:
        print(f"[LOAD:{rep.restore_path}] {rep.n_templates} templates over "
              f"{rep.n_buckets} buckets in {rep.critical_path_s * 1e3:.1f} ms "
              f"(parse {rep.phases['parse_s']*1e3:.1f} ms, templates "
              f"{rep.phases['templates_s']*1e3:.1f} ms, "
              f"rank_stamped={rep.rank_stamped}, "
              f"fallback_compiles={rep.fallback_compiles})")
    return program_sets, rep, plan


def _compile_from_export(archive: Archive, blob_hash: str, mesh,
                         capture_identity: Optional[dict] = None):
    """Exact-bucket reconstruction: deserialize pre-lowered StableHLO and
    compile — no Python tracing of the model (the paper's 'graph construction
    via driver APIs', 2-3x cheaper than stream capture; Figure 10).

    A jax.export program is pinned to its capture-time device count. When the
    deployment mesh's count differs, the program is bound onto a
    capture-shaped submesh of the deployment (serving from a subset of ranks;
    a true re-shape needs a fresh SAVE for that topology). A deployment
    smaller than the capture cannot host the program at all and raises."""
    exp = jax.export.deserialize(bytearray(archive.get_blob(blob_hash)))
    call_mesh = mesh
    n_exp = getattr(exp, "nr_devices", 1)
    if mesh is not None and n_exp != mesh.devices.size and capture_identity:
        devs = mesh.devices.reshape(-1)[:n_exp]
        if len(devs) < n_exp:
            raise RuntimeError(
                f"archive was captured for {n_exp} ranks but the deployment "
                f"mesh has only {mesh.devices.size}; a multi-rank capture "
                f"cannot be scaled down — re-run SAVE for this topology")
        import numpy as np
        from jax.sharding import Mesh
        shape = capture_identity.get("shape") or [n_exp]
        call_mesh = Mesh(np.asarray(devs).reshape(tuple(shape)),
                         tuple(capture_identity.get("axes") or ["devices"]))
    fn = jax.jit(exp.call)
    flat = [jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s)
            for a, s in zip(exp.in_avals, _exp_shardings(exp, call_mesh))]
    args, kwargs = jax.tree.unflatten(exp.in_tree, flat)
    return fn.lower(*args, **kwargs).compile()


def _exp_shardings(exp, mesh):
    """Rebind the export's recorded HloShardings onto the deployment mesh."""
    try:
        return list(exp.in_shardings_jax(mesh))
    except Exception:
        return [None] * len(exp.in_avals)


def wait_for_background(rep: LoadReport, timeout: float = 300.0):
    """Join the background exact-bucket worker threads of a LOAD.

    Join contract: ``foundry_load`` returns while daemon workers may still be
    hot-swapping exact executables into the returned ProgramSets. Serving
    does NOT need this join — every bucket is already pad-servable through
    its (possibly stamped) template, and ``ProgramSet`` hot-swap is
    lock-protected. Call it only when you need completion of exact
    realization: deterministic tests, benchmarks measuring
    ``background_exact``, or before process exit if archive file handles
    must be released. ``timeout`` is per thread (seconds); on timeout the
    thread keeps running as a daemon and any buckets it has not yet swapped
    simply stay pad-served — there is no error and no partial state, so the
    call is safe to repeat.
    """
    for t in getattr(rep, "_bg_threads", []):
        t.join(timeout)

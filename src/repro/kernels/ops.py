"""Jit'd public wrappers for the Pallas kernels + Foundry kernel-catalog
integration (paper §4.1.2: binary extraction/reload skips first-use work).

First use of a kernel instance normally pays (a) block-shape autotuning and
(b) lowering. ``_tuned_call`` consults the process catalog
(repro.core.kernel_catalog.GLOBAL_CATALOG) first: a primed catalog supplies
the recorded options and the call skips autotune entirely — the measurable
analogue of Foundry skipping Triton autotune + cuModuleLoad at LOAD. On SAVE
the chosen options and the lowered StableHLO payload are recorded.
"""
from __future__ import annotations

import functools
import time
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.kernel_catalog import GLOBAL_CATALOG, mangle
from repro.kernels import decode_attention as _da
from repro.kernels import moe_gemm as _mg
from repro.kernels import ssm_scan as _ss
from repro.kernels import ref as _ref

INTERPRET = True  # CPU container: interpret mode; flip on real TPU.


def _autotune(kernel_name: str, fn_for, candidates, probe_args) -> Dict[str, Any]:
    """Pick the fastest candidate options by timing small probes (the
    first-use cost the catalog eliminates)."""
    best, best_t = None, float("inf")
    for opts in candidates:
        try:
            f = jax.jit(functools.partial(fn_for, **opts))
            f(*probe_args)  # compile
            t0 = time.perf_counter()
            jax.block_until_ready(f(*probe_args))
            dt = time.perf_counter() - t0
        except Exception:
            continue
        if dt < best_t:
            best, best_t = opts, dt
    return best or candidates[0]


def _tuned_call(kernel_name: str, fn_for: Callable, candidates, args,
                catalog=None):
    cat = catalog if catalog is not None else GLOBAL_CATALOG
    name = mangle(kernel_name, [a.shape for a in args],
                  [a.dtype for a in args])
    opts = cat.options_for(name)
    if opts is None:  # first use: autotune + record (SAVE-side path)
        opts = _autotune(kernel_name, fn_for, candidates, args)
        lowered = jax.jit(functools.partial(fn_for, **opts)).lower(*args)
        payload = lowered.as_text().encode()
        cat.record(name, payload, opts)
    return jax.jit(functools.partial(fn_for, **opts))(*args)


# ---------------------------------------------------------------------------
# public ops
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, lengths, catalog=None):
    """Flash-decode. q: [B, H, Dh]; caches: [B, S, Hkv, Dh]; lengths: [B]."""
    S = k_cache.shape[1]
    cands = [{"blk": b, "interpret": INTERPRET}
             for b in (256, 512, 1024) if S % b == 0 and b <= S]
    cands = cands or [{"blk": S, "interpret": INTERPRET}]
    return _tuned_call("decode_attention", _da.decode_attention_kernel,
                       cands, (q, k_cache, v_cache, lengths), catalog)


def mamba1_scan(dt, x, Bm, Cm, A, catalog=None):
    """Selective scan. dt/x: [B, T, C]; Bm/Cm: [B, T, N]; A: [C, N]."""
    T, C = x.shape[1], x.shape[2]
    cands = [{"c_blk": cb, "t_chunk": tc, "interpret": INTERPRET}
             for cb in (128, 256) for tc in (8, 16)
             if C % cb == 0 and T % tc == 0]
    cands = cands or [{"c_blk": C, "t_chunk": min(8, T),
                       "interpret": INTERPRET}]
    return _tuned_call("mamba1_scan", _ss.mamba1_scan_kernel, cands,
                       (dt, x, Bm, Cm, A), catalog)


def moe_grouped_gemm(xe, w, activation: str = "none", catalog=None):
    """Grouped expert GEMM. xe: [E, C, D]; w: [E, D, F]."""
    E, C, D = xe.shape
    F = w.shape[-1]
    cands = [{"bc": bc, "bf": 128, "bd": 128, "activation": activation,
              "interpret": INTERPRET}
             for bc in (64, 128)
             if C % bc == 0 and F % 128 == 0 and D % 128 == 0]
    cands = cands or [{"bc": C, "bf": F, "bd": D, "activation": activation,
                       "interpret": INTERPRET}]
    return _tuned_call("moe_gemm", _mg.moe_grouped_gemm_kernel, cands,
                       (xe, w), catalog)

"""Pallas TPU kernel: Mamba-1 selective scan (prefill).

The recurrence h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t is sequential in t
but embarrassingly parallel over channels: the TPU mapping blocks channels
into VPU-width tiles kept in VMEM and walks time in chunks, carrying the
state h [Cblk, N] in VMEM scratch across grid steps (grid iterates time
innermost). This replaces the CUDA kernel's warp-parallel scan with a
lane-parallel scan — no cross-lane communication is needed because B_t/C_t
are shared across channels (broadcast along sublanes).

Grid: (B, C/Cblk, T/Tc); carry h in VMEM persists over the T dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(dt_ref, x_ref, B_ref, C_ref, A_ref, o_ref, h_ref,
                 *, t_chunk: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = A_ref[...]  # [Cblk, N]

    def body(i, h):
        dt_t = dt_ref[0, i, :].astype(jnp.float32)   # [Cblk]
        x_t = x_ref[0, i, :].astype(jnp.float32)     # [Cblk]
        B_t = B_ref[0, i, :].astype(jnp.float32)     # [N]
        C_t = C_ref[0, i, :].astype(jnp.float32)     # [N]
        decay = jnp.exp(dt_t[:, None] * A)           # [Cblk, N]
        h = decay * h + (dt_t * x_t)[:, None] * B_t[None, :]
        y_t = jnp.sum(h * C_t[None, :], axis=1)      # [Cblk]
        o_ref[0, i, :] = y_t.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, t_chunk, body, h_ref[...])


def mamba1_scan_kernel(dt, x, Bm, Cm, A, *, c_blk: int = 128,
                       t_chunk: int = 16, interpret: bool = True):
    """dt, x: [B, T, C]; Bm, Cm: [B, T, N]; A: [C, N] (negative).
    Returns y: [B, T, C] with y_t = C_t . h_t (caller adds D*x and gating)."""
    B, T, C = x.shape
    N = Bm.shape[-1]
    c_blk = min(c_blk, C)
    t_chunk = min(t_chunk, T)
    assert C % c_blk == 0 and T % t_chunk == 0

    grid = (B, C // c_blk, T // t_chunk)
    return pl.pallas_call(
        functools.partial(_scan_kernel, t_chunk=t_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t_chunk, c_blk), lambda b, c, t: (b, t, c)),
            pl.BlockSpec((1, t_chunk, c_blk), lambda b, c, t: (b, t, c)),
            pl.BlockSpec((1, t_chunk, N), lambda b, c, t: (b, t, 0)),
            pl.BlockSpec((1, t_chunk, N), lambda b, c, t: (b, t, 0)),
            pl.BlockSpec((c_blk, N), lambda b, c, t: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, t_chunk, c_blk), lambda b, c, t: (b, t, c)),
        out_shape=jax.ShapeDtypeStruct((B, T, C), x.dtype),
        scratch_shapes=[pltpu.VMEM((c_blk, N), jnp.float32)],
        interpret=interpret,
    )(dt, x, Bm, Cm, A)

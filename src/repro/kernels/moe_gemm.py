"""Pallas TPU kernel: grouped expert GEMM (MoE FFN).

Computes out[e] = act(x[e] @ w_in[e]) for capacity-dispatched expert inputs
xe [E, C, D] against per-expert weights [E, D, F]. Grid iterates experts
outermost and the contraction innermost; a VMEM fp32 accumulator carries
partial products across D-blocks, so each [bc, bf] output tile is written to
HBM exactly once (the XLA path materializes per-expert intermediates).
Tiles are 128-aligned for the MXU; expert tokens-per-capacity C is padded by
the caller (ops.py) to a sublane multiple.

Grid: (E, C/bc, F/bf, D/bd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref, *, activation: str):
    d = pl.program_id(3)

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(d == pl.num_programs(3) - 1)
    def _finalize():
        acc = acc_ref[...]
        if activation == "silu":
            acc = acc * jax.nn.sigmoid(acc)
        o_ref[0] = acc.astype(o_ref.dtype)


def moe_grouped_gemm_kernel(xe, w, *, activation: str = "none",
                            bc: int = 128, bf: int = 128, bd: int = 128,
                            interpret: bool = True):
    """xe: [E, C, D]; w: [E, D, F] -> [E, C, F] (optionally silu-activated)."""
    E, C, D = xe.shape
    _, _, F = w.shape
    bc, bf, bd = min(bc, C), min(bf, F), min(bd, D)
    assert C % bc == 0 and F % bf == 0 and D % bd == 0

    grid = (E, C // bc, F // bf, D // bd)
    return pl.pallas_call(
        functools.partial(_gemm_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, c, f, d: (e, c, d)),
            pl.BlockSpec((1, bd, bf), lambda e, c, f, d: (e, d, f)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, c, f, d: (e, c, f)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), xe.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(xe, w)

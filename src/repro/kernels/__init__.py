"""Pallas kernel layer: decode attention, MoE grouped GEMM, SSM scan.

OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY for compute
hot-spots the paper itself optimizes with a custom kernel; every kernel has
a pure-jnp oracle in ref.py and is validated in interpret mode on CPU
(tests/test_kernels.py). ops.py routes through the kernel catalog so SAVE
archives the lowered artifacts (core/kernel_catalog.py).
"""

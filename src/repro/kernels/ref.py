"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q: [B, H, Dh]; caches: [B, S, Hkv, Dh]; lengths: [B] -> [B, H, Dh]."""
    B, H, Dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, Hkv, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg,
                   k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, :] <= lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, Dh).astype(q.dtype)


def decode_attention_paged_ref(q, k_pool, v_pool, block_tables, lengths):
    """Paged oracle: gather each sequence's blocks into a contiguous cache
    ([B, MB*bs, Hkv, Dh]) and defer to ``decode_attention_ref``.
    q: [B, H, Dh]; pools: [NB, bs, Hkv, Dh]; block_tables: [B, MB] int32."""
    B = q.shape[0]
    MB = block_tables.shape[1]
    bs = k_pool.shape[1]
    kd = k_pool[block_tables].reshape(B, MB * bs, *k_pool.shape[2:])
    vd = v_pool[block_tables].reshape(B, MB * bs, *v_pool.shape[2:])
    return decode_attention_ref(q, kd, vd, lengths)


def mamba1_scan_ref(dt, x, Bm, Cm, A):
    """dt, x: [B, T, C]; Bm, Cm: [B, T, N]; A: [C, N] -> y [B, T, C]."""
    B, T, C = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        dt_t, x_t, B_t, C_t = inp
        dt_f = dt_t.astype(jnp.float32)
        decay = jnp.exp(dt_f[..., None] * A)
        h = decay * h + (dt_f * x_t.astype(jnp.float32))[..., None] \
            * B_t.astype(jnp.float32)[:, None, :]
        y = jnp.einsum("bcn,bn->bc", h, C_t.astype(jnp.float32))
        return h, y.astype(x.dtype)

    h0 = jnp.zeros((B, C, N), jnp.float32)
    xs = (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(x, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)


def moe_grouped_gemm_ref(xe, w, activation: str = "none"):
    """xe: [E, C, D]; w: [E, D, F] -> [E, C, F]."""
    out = jnp.einsum("ecd,edf->ecf", xe.astype(jnp.float32),
                     w.astype(jnp.float32))
    if activation == "silu":
        out = out * jax.nn.sigmoid(out)
    return out.astype(xe.dtype)

"""Pallas TPU kernel: GQA flash-decode over a long KV cache.

The decode hot spot: one query token per sequence attending to a cache of
S_ctx positions. Memory-bound — the whole KV cache streams HBM->VMEM once;
the kernel's job is to keep scores/softmax state resident in VMEM (the XLA
path materializes every score block to HBM; see EXPERIMENTS.md §Roofline).

TPU adaptation (vs. the CUDA flash-decode it mirrors):
  * the query group (G = H/Hkv heads sharing one KV head) forms the MXU
    row-block: scores[G, blk] = q[G, Dh] @ K[blk, Dh]^T — Dh=64..128 aligns
    the contraction with the 128-wide systolic array;
  * grid = (B, Hkv, S/blk) with the KV-block dim innermost: online-softmax
    carry (m, l, acc) lives in VMEM scratch across grid steps — the
    TPU-idiomatic replacement for CUDA's split-K + shared-memory reduction;
  * per-sequence lengths sit in SMEM; out-of-range blocks are masked (the
    compiler still streams them — a block-level early-exit via
    pl.when(program_id) keeps the bandwidth roofline).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, blk: int, scale: float):
    b = pl.program_id(0)
    s = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[0]  # current token position for this sequence
    q = q_ref[0, 0].astype(jnp.float32)         # [G, Dh]
    k = k_ref[0, :, 0, :].astype(jnp.float32)   # [blk, Dh]
    v = v_ref[0, :, 0, :].astype(jnp.float32)   # [blk, Dh]

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [G, blk]
    pos = s * blk + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(pos <= length, scores, NEG_INF)

    m_prev = m_ref[...]                  # [G, 1]
    m_new = jnp.maximum(m_prev, scores.max(axis=1, keepdims=True))
    p = jnp.exp(scores - m_new)          # [G, blk]
    corr = jnp.exp(m_prev - m_new)       # [G, 1]
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_kernel(q, k_cache, v_cache, lengths, *, blk: int = 512,
                            interpret: bool = True):
    """q: [B, H, Dh]; caches: [B, S, Hkv, Dh]; lengths: [B] (new-token pos;
    the new token's K/V must already be written at lengths[b]).
    Returns [B, H, Dh]."""
    B, H, Dh = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = H // Hkv
    blk = min(blk, S)
    assert S % blk == 0, (S, blk)
    scale = 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, Hkv, G, Dh)

    grid = (B, Hkv, S // blk)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, blk=blk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, blk, 1, Dh), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, blk, 1, Dh), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),   # m
            pltpu.VMEM((G, 1), jnp.float32),   # l
            pltpu.VMEM((G, Dh), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    return out.reshape(B, H, Dh)


# ---------------------------------------------------------------------------
# paged variant: KV lives in a shared block pool, indirected by block tables
# ---------------------------------------------------------------------------
def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, bs: int, scale: float):
    b = pl.program_id(0)
    s = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)      # [G, Dh]
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # [bs, Dh] — one pool block
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [G, bs]
    # logical position of pool slot j within THIS sequence is table-relative
    # (block s of the table holds positions s*bs..s*bs+bs-1), independent of
    # which physical block the table entry points at
    pos = s * bs + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(pos <= length, scores, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, scores.max(axis=1, keepdims=True))
    p = jnp.exp(scores - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_paged_kernel(q, k_pool, v_pool, block_tables, lengths,
                                  *, interpret: bool = True):
    """Flash-decode over the paged pool layout (serving/blockpool.py).

    q: [B, H, Dh]; pools: [NB, bs, Hkv, Dh] (no batch dim — blocks are
    shared across sequences via ref-counted prefix caching); block_tables:
    [B, MB] int32 mapping each sequence's logical block s to a physical
    pool block (unused tail entries point at the scratch block 0 and are
    masked by ``lengths``); lengths: [B]. Returns [B, H, Dh].

    The indirection is the TPU analogue of PagedAttention's gather: the
    block table and lengths ride in as scalar-prefetch operands
    (``PrefetchScalarGridSpec``), so the k/v BlockSpec index_map can pick
    the physical block ``bt[b, s]`` for grid step (b, h, s) and the DMA
    engine streams exactly one pool block per step — no [B, S] contiguous
    materialization of the cache ever exists.
    """
    B, H, Dh = q.shape
    NB, bs, Hkv, _ = k_pool.shape
    MB = block_tables.shape[1]
    G = H // Hkv
    scale = 1.0 / math.sqrt(Dh)
    qg = q.reshape(B, Hkv, G, Dh)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, lengths
        grid=(B, Hkv, MB),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, s, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, Dh),
                         lambda b, h, s, bt, ln: (bt[b, s], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, Dh),
                         lambda b, h, s, bt, ln: (bt[b, s], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh),
                               lambda b, h, s, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),   # m
            pltpu.VMEM((G, 1), jnp.float32),   # l
            pltpu.VMEM((G, Dh), jnp.float32),  # acc
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, bs=bs, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pool, v_pool)
    return out.reshape(B, H, Dh)

"""Optimizers: AdamW (mixed-precision, ZeRO-sharded states) and Adafactor.

Mixed-precision contract:
  * live params are cfg.param_dtype (bf16 for full configs) -> gradients are
    bf16 too, so the data-parallel gradient all-reduce moves half the bytes
    (the "gradient compression" trick; see DESIGN.md §4).
  * the optimizer holds fp32 master weights; m/v in cfg.opt_state_dtype.
  * optimizer states are additionally ZeRO-sharded: each state leaf picks the
    first unsharded, divisible dim and shards it over the data axis.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.launch.mesh import ShardCtx


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    master_dtype: str = "float32"
    zero_shard: bool = True


def zero_logical_axes(param_axes, shapes, ctx: ShardCtx):
    """Add a 'fsdp' (data-axis) shard to the first free divisible dim of each
    leaf's logical axes (ZeRO optimizer-state sharding)."""
    data = ctx.axis_size(("data",))

    def one(axes, sd):
        if ctx.mesh is None or data <= 1:
            return axes
        axes = list(axes)
        for i, (a, s) in enumerate(zip(axes, sd.shape)):
            if a is None and s % data == 0:
                axes[i] = "fsdp"
                return tuple(axes)
        return tuple(axes)

    return jax.tree.map(one, param_axes, shapes,
                        is_leaf=lambda x: isinstance(x, tuple))


def opt_state_shapes(model, opt_cfg: OptConfig):
    """ShapeDtypeStructs (with shardings) for the optimizer state pytree."""
    ctx = model.ctx
    pshapes = model.param_shapes()
    paxes = model.param_logical_axes()
    zaxes = (zero_logical_axes(paxes, pshapes, ctx) if opt_cfg.zero_shard
             else paxes)

    def sds(sd, axes, dtype):
        sh = ctx.sharding(axes, sd.shape) if ctx.mesh is not None else None
        return jax.ShapeDtypeStruct(sd.shape, jnp.dtype(dtype), sharding=sh)

    is_ax = lambda x: isinstance(x, tuple)
    return {
        "master": jax.tree.map(lambda sd, a: sds(sd, a, opt_cfg.master_dtype),
                               pshapes, zaxes, is_leaf=is_ax),
        "m": jax.tree.map(lambda sd, a: sds(sd, a, opt_cfg.state_dtype),
                          pshapes, zaxes, is_leaf=is_ax),
        "v": jax.tree.map(lambda sd, a: sds(sd, a, opt_cfg.state_dtype),
                          pshapes, zaxes, is_leaf=is_ax),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def init_opt_state(params, model, opt_cfg: OptConfig):
    shapes = opt_state_shapes(model, opt_cfg)

    def like(sd, src=None):
        # copy=True: master must never alias the live params (double-donation)
        val = (jnp.zeros(sd.shape, sd.dtype) if src is None
               else jnp.copy(src).astype(sd.dtype))
        if sd.sharding is not None:
            val = jax.device_put(val, sd.sharding)
        return val

    return {
        "master": jax.tree.map(lambda sd, p: like(sd, p), shapes["master"], params),
        "m": jax.tree.map(like, shapes["m"]),
        "v": jax.tree.map(like, shapes["v"]),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def adamw_apply(params, grads, opt_state, opt_cfg: OptConfig):
    """One AdamW step. Returns (new_params, new_opt_state, grad_norm)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt_cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = opt_cfg.b1, opt_cfg.b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(master, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m_new / c1
        vhat = v_new / c2
        step = mhat / (jnp.sqrt(vhat) + opt_cfg.eps)
        master32 = master.astype(jnp.float32)
        if master.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + opt_cfg.weight_decay * master32
        master_new = master32 - opt_cfg.lr * step
        return (master_new.astype(master.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree.map(upd, opt_state["master"], grads, opt_state["m"],
                       opt_state["v"])
    master_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))

    new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype),
                              master_new, params)
    new_state = {"master": master_new, "m": m_new, "v": v_new, "count": count}
    return new_params, new_state, gnorm

"""Elasticity + straggler mitigation for long-running distributed jobs.

Pieces that must exist for 1000+-node runnability:

  * StragglerWatchdog — per-step wall-time tracking with robust outlier
    detection (median * threshold); fires a callback so the launcher can
    deschedule/replace the slow host. On real fleets the signal comes from
    per-host heartbeats; here the watchdog wraps the train loop (the hook is
    the contract, the detector is real).

  * ElasticController — restart-into-a-different-mesh: a checkpoint taken on
    mesh A restores onto mesh B (fewer/more hosts) because checkpoints store
    global tensors (training/checkpoint.py) and sharding is re-derived from
    the model's logical axes on the new mesh. Batch is re-whole: the data
    pipeline is counter-based so the token stream stays exactly-once.

  * failure simulation helpers used by tests: kill-step (drop state mid-run)
    and verify bitwise-resumable training.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax

from repro.launch.mesh import ShardCtx
from repro.models.model import Model
from repro.training.checkpoint import Checkpointer
from repro.training.optimizer import OptConfig, opt_state_shapes
from repro.training.train_loop import train_state_specs


@dataclass
class StragglerWatchdog:
    threshold: float = 3.0           # x median
    warmup_steps: int = 5
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    durations: List[float] = field(default_factory=list)
    flagged: List[int] = field(default_factory=list)
    _last: Optional[float] = None

    def tick(self):
        now = time.perf_counter()
        if self._last is not None:
            dt = now - self._last
            self.durations.append(dt)
            n = len(self.durations)
            if n > self.warmup_steps:
                med = statistics.median(self.durations[:-1])
                if med > 0 and dt > self.threshold * med:
                    self.flagged.append(n - 1)
                    if self.on_straggler:
                        self.on_straggler(n - 1, dt, med)
        self._last = now

    def observe(self, dt: float):
        """Direct-injection path for tests/simulators."""
        self.durations.append(dt)
        n = len(self.durations)
        if n > self.warmup_steps:
            med = statistics.median(self.durations[:-1])
            if med > 0 and dt > self.threshold * med:
                self.flagged.append(n - 1)
                if self.on_straggler:
                    self.on_straggler(n - 1, dt, med)


class ElasticController:
    """Restores a training job onto a (possibly different) mesh."""

    def __init__(self, arch_cfg, opt_cfg: OptConfig, ckpt: Checkpointer):
        self.arch_cfg = arch_cfg
        self.opt_cfg = opt_cfg
        self.ckpt = ckpt

    def state_shardings(self, model: Model):
        specs = train_state_specs(model, self.opt_cfg)
        return jax.tree.map(
            lambda sd: getattr(sd, "sharding", None), specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    def resume(self, mesh, step: Optional[int] = None):
        """Build a model bound to ``mesh`` and restore the latest (or given)
        checkpoint onto it, resharding every tensor. Returns
        (model, state, extra)."""
        ctx = ShardCtx(mesh=mesh)
        model = Model(self.arch_cfg, ctx)
        specs = train_state_specs(model, self.opt_cfg)
        shardings = self.state_shardings(model) if mesh is not None else None
        state, extra = self.ckpt.restore(step, like=specs, shardings=shardings)
        return model, state, extra

"""Deterministic, resumable synthetic data pipeline.

Counter-based generation (step index seeds the RNG) gives:
  * determinism across restarts — a restored step produces the same batch,
  * O(1) skip-to-step on checkpoint resume (no replaying the stream),
  * shard-independence — each data shard derives its slice from the global
    batch deterministically, so reshaping the mesh (elastic scaling) keeps
    the token stream consistent.

Tokens follow a noisy affine Markov chain so small models can actually learn
it (examples/train_lm.py shows loss decreasing).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    noise: float = 0.1  # fraction of uniform-random next-tokens


class SyntheticLMData:
    """Iterator of {"tokens": [B, S], "labels": [B, S]} int32 batches."""

    def __init__(self, cfg: DataConfig, sharding=None, start_step: int = 0):
        self.cfg = cfg
        self.sharding = sharding
        self.step = start_step

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, s: dict):
        assert s["seed"] == self.cfg.seed, "data seed mismatch on restore"
        self.step = s["step"]

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        V = c.vocab_size
        toks = np.empty((c.global_batch, c.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, V, c.global_batch)
        noise = rng.random((c.global_batch, c.seq_len)) < c.noise
        rand = rng.integers(0, V, (c.global_batch, c.seq_len))
        for t in range(c.seq_len):
            nxt = (5 * toks[:, t] + 7) % V
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.sharding is not None:
            batch = {k: jax.device_put(v, self.sharding)
                     for k, v in batch.items()}
        else:
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
        return batch

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = self.batch_at(self.step)
        self.step += 1
        return b

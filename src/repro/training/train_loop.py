"""Training step + loop.

``make_train_step(model, opt_cfg)`` builds the jit-able step used by both the
training launcher and the multi-pod dry-run. Gradients flow in param dtype
(bf16 for full configs => compressed all-reduce); masters/updates in fp32.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training.optimizer import (OptConfig, adamw_apply, init_opt_state,
                                      opt_state_shapes)


def make_train_step(model: Model, opt_cfg: OptConfig,
                    microbatches: int = 1) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params": ..., "opt": ..., "step": i32[]}
    Supports gradient accumulation over ``microbatches`` along the batch dim.
    """
    pshardings = model.param_shardings()

    def constrain_params(params):
        if pshardings is None:
            return params
        return jax.tree.map(jax.lax.with_sharding_constraint, params, pshardings)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(state, batch):
        params = state["params"]
        if microbatches == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def mb_slice(x, i):
                mb = x.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def acc_step(carry, i):
                loss_a, grads_a = carry
                mb = jax.tree.map(lambda x: mb_slice(x, i), batch)
                loss, metrics, grads = grads_of(params, mb)
                grads_a = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype), grads_a, grads)
                return (loss_a + loss, grads_a), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros(()), zeros), jnp.arange(microbatches))
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = {"nll": loss, "aux": jnp.zeros(())}

        new_params, new_opt, gnorm = adamw_apply(
            params, grads, state["opt"], opt_cfg)
        new_params = constrain_params(new_params)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {**metrics, "loss": loss, "grad_norm": gnorm}
        return new_state, metrics

    return train_step


def init_train_state(model: Model, opt_cfg: OptConfig, rng):
    params = model.init(rng)
    opt = init_opt_state(params, model, opt_cfg)
    return {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}


def train_state_specs(model: Model, opt_cfg: OptConfig):
    """ShapeDtypeStruct stand-ins for the full train state (dry-run)."""
    return {"params": model.param_specs(),
            "opt": opt_state_shapes(model, opt_cfg),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def run_train_loop(model: Model, opt_cfg: OptConfig, data_iter, num_steps: int,
                   *, state=None, rng=None, log_every: int = 10,
                   checkpointer=None, checkpoint_every: int = 0,
                   watchdog=None, log=print):
    """Synchronous training loop with optional async checkpointing and a
    straggler watchdog (see repro.training.elastic)."""
    if state is None:
        state = init_train_state(
            model, opt_cfg, rng if rng is not None else jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0,))
    history = []
    t_last = time.perf_counter()
    start = int(state["step"])
    for i in range(start, num_steps):
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        if watchdog is not None:
            watchdog.tick()
        if (i + 1) % log_every == 0 or i + 1 == num_steps:
            loss = float(metrics["loss"])
            dt = (time.perf_counter() - t_last) / log_every
            t_last = time.perf_counter()
            history.append((i + 1, loss))
            log(f"step {i + 1:5d} loss {loss:8.4f} "
                f"grad_norm {float(metrics['grad_norm']):7.3f} "
                f"({dt * 1e3:.0f} ms/step)")
        if checkpointer is not None and checkpoint_every and \
                (i + 1) % checkpoint_every == 0:
            checkpointer.save(state, step=i + 1)
    return state, history

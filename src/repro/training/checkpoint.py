"""Distributed checkpointing: atomic, content-verified, reshardable, async.

Fault-tolerance contract for 1000+-node runs:
  * atomicity — a checkpoint directory appears only when complete (write to
    step_NNN.tmp, fsync manifest, rename);
  * integrity — every tensor file carries a content hash verified on load;
  * resharding — tensors are stored as *global* arrays with their logical
    identity (tree path); restore device_puts onto the target mesh/sharding,
    so a checkpoint taken on (16,16) restores onto (2,16,16) or a degraded
    (15x16) replacement mesh (elastic scaling / failed-node replacement);
  * async — save() can run on a background thread (training continues; the
    paper-world analogue is off-critical-path materialization);
  * the data-pipeline cursor rides along, so restarts are exactly-once over
    the token stream.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np


def _hash(b: bytes) -> str:
    return hashlib.blake2b(b, digest_size=16).hexdigest()


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, state, step: int, extra: Optional[dict] = None,
             async_: bool = False):
        if async_:
            # snapshot to host first (cheap on CPU; device->host on TPU),
            # then write in the background
            host_state = jax.tree.map(np.asarray, state)
            self.wait()
            self._async_thread = threading.Thread(
                target=self._write, args=(host_state, step, extra), daemon=True)
            self._async_thread.start()
            return
        self._write(jax.tree.map(np.asarray, state), step, extra)

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _write(self, host_state, step: int, extra: Optional[dict]):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = jax.tree.flatten(host_state)
        index = {"step": step, "extra": extra or {},
                 "treedef": str(treedef), "leaves": []}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            fname = f"leaf_{i:05d}.npy"
            path = os.path.join(tmp, fname)
            with open(path, "wb") as f:
                np.lib.format.write_array(f, arr, allow_pickle=False)
            with open(path, "rb") as f:
                h = _hash(f.read())
            index["leaves"].append({
                "file": fname, "hash": h, "shape": list(arr.shape),
                "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", d)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *, like=None,
                shardings=None) -> tuple[Any, dict]:
        """Restore (state, extra). ``like`` provides the target pytree
        structure; ``shardings`` (same structure, optional) reshards each
        global tensor onto the deployment mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "index.json")) as f:
            index = json.load(f)
        arrays = []
        for meta in index["leaves"]:
            path = os.path.join(d, meta["file"])
            with open(path, "rb") as f:
                raw = f.read()
            if _hash(raw) != meta["hash"]:
                raise ValueError(f"checkpoint tensor {meta['file']} corrupt")
            import io
            arr = np.lib.format.read_array(io.BytesIO(raw), allow_pickle=False)
            arrays.append(arr)
        if like is not None:
            leaves, treedef = jax.tree.flatten(like)
            assert len(leaves) == len(arrays), \
                f"checkpoint has {len(arrays)} leaves, target has {len(leaves)}"
            if shardings is not None:
                # keep None leaves (replicated/scalar entries) aligned
                shard_leaves = jax.tree.flatten(
                    shardings, is_leaf=lambda x: x is None)[0]
                arrays = [jax.device_put(a, s) if s is not None else jax.numpy.asarray(a)
                          for a, s in zip(arrays, shard_leaves)]
            else:
                arrays = [jax.numpy.asarray(a) for a in arrays]
            state = jax.tree.unflatten(treedef, arrays)
        else:
            state = arrays
        return state, index["extra"]

"""foundry-check: offline static verifier for Foundry state (no execution).

A serialized graph context is only valid if its invariants hold — a
deterministic memory layout, complete rank-delta coverage of every piece of
rank-dependent state, and a calling convention the serving engine actually
speaks (paper §4.1-4.3). Enforcing those only dynamically means a corrupted
blob, an incomplete ``RankDelta`` or a tag drift surfaces as a silent
fallback compile, a wedged LOAD, or token divergence at serve time. This
module analyzes archives, depots and capture manifests *statically* and
emits machine-readable findings; ``python -m repro.analysis.check`` is the
CLI front end and ``foundry_load(strict=True)`` (core/restore.py) runs the
manifest-level subset as a pre-flight pass on every LOAD.

Pass families (docs/architecture.md §11 has the full table):

    container / manifest    ``container-structure`` ``manifest-schema``
                            ``blob-index`` ``blob-integrity`` ``tags-schema``
    StableHLO IR lint       ``ir-parse`` ``donation-aliasing``
                            ``ir-determinism`` ``rank-delta-coverage``
    memory plan             ``memory-plan-overlap`` ``memory-plan-alignment``
                            ``memory-plan-extent`` ``memory-plan-leak``
                            ``memory-plan-scope`` ``capture-window-order``
    depot fsck              ``depot-index`` ``depot-missing-blob``
                            ``depot-blob-size`` ``depot-orphan-blob``
                            ``depot-orphan-manifest`` ``depot-refcount``
                            ``depot-dangling-ref`` ``depot-manifest``
                            ``depot-missing-manifest``

Severity contract: ``error`` findings mean the artifact must not be served
(strict LOAD refuses it); ``warning`` means it serves but something is
degraded (dedup lost, exact realization impossible, stale refs pinning
storage); ``info`` is advisory. The CLI exit code is the worst severity
found: 0 clean, 1 warnings only, 2 errors, 3 fatal (unreadable target /
bad invocation).

Everything here is read-only (the one exception: ``check_depot(...,
gc_orphans=True)`` deletes *unreferenced* blob files, the depot analogue of
``git fsck`` + ``git prune``). No pass executes archived programs.
"""
from __future__ import annotations

import json
import os
import re
import struct
import zlib
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

from repro.core.archive import (MAGIC, MAGIC2, Archive, _decompress,
                                content_hash)
from repro.core.collective_stub import (identity_device_count, peer_groups,
                                        rank_coords)
from repro.core.memory_plan import MemoryPlan

SEVERITIES = ("info", "warning", "error")

#: pass id -> one-line description (the docs/CLI pass table; stable ids —
#: CI gates and tests match on them, so renames are breaking changes)
PASSES: Dict[str, str] = {
    "container-structure": "container magic/header/section structure",
    "manifest-schema": "manifest required fields, spec/group consistency",
    "blob-index": "every referenced blob resolvable, extents sane",
    "blob-integrity": "blob bytes match their content hash",
    "tags-schema": "CaptureSpec.tags vs the engine convention matrix",
    "ir-parse": "exported StableHLO deserializes",
    "donation-aliasing": "spec donate_argnums vs exported donor/alias attrs",
    "ir-determinism": "no call-site debug locations (depot dedup)",
    "rank-delta-coverage": "rank-dependent state covered by RankDeltas",
    "memory-plan-overlap": "no overlapping arena allocations",
    "memory-plan-alignment": "offsets respect the recorded alignment",
    "memory-plan-extent": "recorded extent covers the allocation sequence",
    "memory-plan-leak": "no unaccounted gaps beyond alignment padding",
    "memory-plan-scope": "scoped extents vs rank_extents/comm_buffers",
    "capture-window-order": "capture-phase allocations form the tail",
    "depot-index": "index.json readable, right version (torn writes)",
    "depot-missing-blob": "indexed blob file present on disk",
    "depot-blob-size": "blob file size matches indexed comp_len",
    "depot-orphan-blob": "on-disk blob unknown to the index",
    "depot-orphan-manifest": "manifest file unknown to the index",
    "depot-refcount": "archive blob references all ref-held",
    "depot-dangling-ref": "blob refs point at live archives",
    "depot-manifest": "thin manifests parse and resolve in this depot",
    "depot-missing-manifest": "indexed archive's manifest file present",
}


@dataclass(frozen=True)
class Finding:
    """One verifier finding: which pass, how bad, where, what, and how to
    fix it. ``location`` is ``<target>:<path.into.artifact>``."""
    pass_id: str
    severity: str
    location: str
    message: str
    fix_hint: str = ""

    def __post_init__(self):
        assert self.pass_id in PASSES, f"unknown pass id {self.pass_id!r}"
        assert self.severity in SEVERITIES, self.severity

    def render(self) -> str:
        hint = f" (fix: {self.fix_hint})" if self.fix_hint else ""
        return (f"{self.severity.upper():7s} {self.pass_id:22s} "
                f"{self.location}: {self.message}{hint}")


class ArchiveVerificationError(ValueError):
    """Raised by ``foundry_load(strict=True)`` when the pre-flight pass
    finds error-severity problems. Carries the findings and the partial
    ``LoadReport`` (so tests can assert ``fallback_compiles == 0`` was
    attempted before the refusal)."""

    def __init__(self, findings: Sequence[Finding], report=None):
        self.findings = list(findings)
        self.report = report
        lines = [f.render() for f in self.findings[:8]]
        more = len(self.findings) - len(lines)
        if more > 0:
            lines.append(f"... and {more} more")
        super().__init__(
            "archive failed static verification; refusing to serve it "
            "(run `python -m repro.analysis.check` for the full report):\n  "
            + "\n  ".join(lines))


def errors(findings: Sequence[Finding]) -> List[Finding]:
    return [f for f in findings if f.severity == "error"]


def summarize(findings: Sequence[Finding]) -> Dict[str, int]:
    out = {s: 0 for s in SEVERITIES}
    for f in findings:
        out[f.severity] += 1
    return out


# ---------------------------------------------------------------------------
# pass 1a: container structure (raw file/bytes level)
# ---------------------------------------------------------------------------
@dataclass
class ContainerInfo:
    """Parsed container header, as far as parsing got."""
    version: int = 0                  # 1 | 2; 0 = unparseable
    thin: bool = False
    manifest: Optional[dict] = None
    index: Dict[str, tuple] = field(default_factory=dict)
    blob_base: int = 0                # v2: file offset of the blob section


def check_container_bytes(raw: bytes, loc: str
                          ) -> Tuple[List[Finding], ContainerInfo]:
    """Structural validation of a raw container: magic, header framing,
    header decode, blob-extent sanity. Never raises — a truncated or
    bit-flipped header becomes a ``container-structure`` finding."""
    out: List[Finding] = []
    info = ContainerInfo()

    def bad(msg: str, hint: str = "re-run SAVE; the file is not a usable "
            "Foundry container") -> Tuple[List[Finding], ContainerInfo]:
        out.append(Finding("container-structure", "error", loc, msg, hint))
        return out, info

    if raw.startswith(MAGIC2):
        if len(raw) < len(MAGIC2) + 8:
            return bad(f"v2 container truncated at {len(raw)} bytes "
                       "(header length field missing)")
        (hlen,) = struct.unpack_from("<Q", raw, len(MAGIC2))
        base = len(MAGIC2) + 8
        if base + hlen > len(raw):
            return bad(f"v2 header claims {hlen} bytes but only "
                       f"{len(raw) - base} follow (truncated write?)")
        try:
            import msgpack
            head = msgpack.unpackb(_decompress(bytes(raw[base:base + hlen])),
                                   raw=False, strict_map_key=False)
        except Exception as e:
            return bad(f"v2 header does not decode: "
                       f"{type(e).__name__}: {e}")
        if not isinstance(head, dict) or "manifest" not in head \
                or "index" not in head:
            return bad("v2 header missing manifest/index sections")
        info.version = 2
        info.thin = bool(head.get("depot"))
        info.manifest = head["manifest"]
        info.blob_base = base + hlen
        section = len(raw) - info.blob_base
        spans = []
        for h, entry in head["index"].items():
            if (not isinstance(entry, (list, tuple)) or len(entry) != 3
                    or any(not isinstance(v, int) or v < 0 for v in entry)):
                out.append(Finding(
                    "blob-index", "error", f"{loc}:index[{h[:12]}]",
                    f"malformed index entry {entry!r} (want [offset, "
                    f"comp_len, raw_len] of non-negative ints)"))
                continue
            info.index[h] = tuple(entry)
            off, comp_len, _ = entry
            if not info.thin:
                if off + comp_len > section:
                    out.append(Finding(
                        "blob-index", "error", f"{loc}:index[{h[:12]}]",
                        f"blob extent [{off}, {off + comp_len}) exceeds the "
                        f"{section}-byte blob section (truncated file?)",
                        "re-copy or re-run SAVE"))
                else:
                    spans.append((off, off + comp_len, h))
        spans.sort()
        for (s0, e0, h0), (s1, _, h1) in zip(spans, spans[1:]):
            if s1 < e0:
                out.append(Finding(
                    "blob-index", "error", f"{loc}:index[{h1[:12]}]",
                    f"blob extents overlap ({h0[:12]} ends at {e0}, "
                    f"{h1[:12]} starts at {s1})", "re-run SAVE"))
        return out, info

    if raw.startswith(MAGIC):  # legacy v1: one compressed msgpack stream
        try:
            import msgpack
            obj = msgpack.unpackb(_decompress(raw[len(MAGIC):]),
                                  raw=False, strict_map_key=False)
            info.version = 1
            info.manifest = obj.get("manifest")
            if not isinstance(obj.get("blobs"), dict):
                return bad("v1 payload has no blob map")
            for h, data in obj["blobs"].items():
                if content_hash(data) != h:
                    out.append(Finding(
                        "blob-integrity", "error", f"{loc}:blob/{h[:12]}",
                        "v1 blob bytes do not match their content hash",
                        "the archive is corrupt; re-run SAVE"))
        except Exception as e:
            return bad(f"v1 payload does not decode: {type(e).__name__}: {e}")
        return out, info

    return bad("not a Foundry archive (bad magic)")


def check_container_file(path: str) -> Tuple[List[Finding], ContainerInfo]:
    try:
        with open(path, "rb") as f:
            raw = f.read()
    except OSError as e:
        return ([Finding("container-structure", "error", path,
                         f"unreadable: {e}")], ContainerInfo())
    return check_container_bytes(raw, os.path.basename(path))


# ---------------------------------------------------------------------------
# pass 1b: manifest schema + blob index completeness + tags
# ---------------------------------------------------------------------------
def _spec_entries(manifest: dict) -> Iterator[Tuple[str, dict]]:
    specs = manifest.get("specs")
    if isinstance(specs, dict):
        yield from specs.items()


def check_manifest_schema(manifest: dict, loc: str,
                          blobs=None) -> List[Finding]:
    """Manifest required fields + spec/group internal consistency + (when a
    blob mapping is given) completeness of every blob reference. ``blobs``
    only needs ``__contains__`` — membership is an index lookup, no fetch."""
    out: List[Finding] = []
    if not isinstance(manifest, dict):
        return [Finding("manifest-schema", "error", loc,
                        f"manifest is {type(manifest).__name__}, not a dict")]
    if not isinstance(manifest.get("version"), int):
        out.append(Finding("manifest-schema", "error", f"{loc}:version",
                           "missing/non-int manifest version",
                           "re-run SAVE with a current foundry_save"))
    mesh = manifest.get("mesh")
    if mesh is not None:
        axes, shape = mesh.get("axes"), mesh.get("shape")
        if (not isinstance(axes, list) or not isinstance(shape, list)
                or len(axes) != len(shape)):
            out.append(Finding(
                "manifest-schema", "error", f"{loc}:mesh",
                f"capture mesh identity malformed: axes={axes!r} "
                f"shape={shape!r} (want equal-length lists)"))
    specs = manifest.get("specs")
    if not isinstance(specs, dict) or not specs:
        out.append(Finding("manifest-schema", "error", f"{loc}:specs",
                           "no capture specs in manifest"))
        return out

    def ref(h: Optional[str], where: str, what: str, sev: str = "error"):
        if h is None or blobs is None:
            return
        if h not in blobs:
            out.append(Finding(
                "blob-index", sev, where,
                f"{what} references blob {h[:12]}… absent from the blob "
                f"index", "the container lost a blob; re-run SAVE (or pass "
                "the right --depot for a thin archive)"))

    for name, spec_m in _spec_entries(manifest):
        sloc = f"{loc}:specs.{name}"
        buckets = spec_m.get("buckets")
        if (not isinstance(buckets, list) or not buckets
                or any(not isinstance(b, int) or b < 1 for b in buckets)):
            out.append(Finding("manifest-schema", "error", f"{sloc}.buckets",
                               f"buckets must be a non-empty list of "
                               f"positive ints, got {buckets!r}"))
            continue
        if sorted(set(buckets)) != buckets:
            out.append(Finding("manifest-schema", "error", f"{sloc}.buckets",
                               "buckets must be strictly increasing "
                               f"(got {buckets})"))
        donate = spec_m.get("donate_argnums", [])
        if any(not isinstance(i, int) or i < 0 for i in donate):
            out.append(Finding("manifest-schema", "error",
                               f"{sloc}.donate_argnums",
                               f"donate_argnums must be non-negative ints, "
                               f"got {donate!r}"))
        out.extend(check_tags(spec_m.get("tags") or {}, f"{sloc}.tags"))

        groups = spec_m.get("groups")
        if not isinstance(groups, list) or not groups:
            out.append(Finding("manifest-schema", "error", f"{sloc}.groups",
                               "spec has no topology groups"))
            continue
        covered: Dict[int, int] = {}
        for gi, g in enumerate(groups):
            gloc = f"{sloc}.groups[{gi}]"
            gb = g.get("buckets") or []
            for b in gb:
                covered[b] = covered.get(b, 0) + 1
            tb = g.get("template_bucket")
            if tb not in gb:
                out.append(Finding(
                    "manifest-schema", "error", gloc,
                    f"template_bucket {tb} not a member of the group's "
                    f"buckets {gb}"))
            elif gb and tb != max(gb):
                out.append(Finding(
                    "manifest-schema", "error", gloc,
                    f"template_bucket {tb} < max group bucket {max(gb)}: "
                    f"larger buckets cannot be pad-served through the "
                    f"template", "re-run SAVE (group_buckets picks max)"))
            if g.get("executable_blob") is None:
                out.append(Finding(
                    "manifest-schema", "warning", gloc,
                    "group has no template executable; every bucket of it "
                    "LOADs via compile-from-StableHLO",
                    "re-run SAVE with template serialization on"))
            ref(g.get("executable_blob"), gloc, "template executable")
            exports = g.get("bucket_export_blobs") or {}
            for b, h in exports.items():
                ref(h, f"{gloc}.bucket_export_blobs[{b}]",
                    f"bucket {b} StableHLO export")
            for b, h in (g.get("bucket_executable_blobs") or {}).items():
                ref(h, f"{gloc}.bucket_executable_blobs[{b}]",
                    f"bucket {b} executable")
            missing = [b for b in gb if str(b) not in
                       {str(k) for k in exports}]
            if missing:
                out.append(Finding(
                    "blob-index", "warning", gloc,
                    f"buckets {missing} have no StableHLO export: exact "
                    f"realization and fallback compile are impossible for "
                    f"them", "re-run SAVE"))
        for b, n in sorted(covered.items()):
            if n > 1:
                out.append(Finding(
                    "manifest-schema", "error", f"{sloc}.groups",
                    f"bucket {b} appears in {n} topology groups"))
        uncovered = [b for b in buckets if b not in covered]
        if uncovered:
            out.append(Finding(
                "manifest-schema", "error", f"{sloc}.groups",
                f"spec buckets {uncovered} not covered by any group"))

    kc = manifest.get("kernel_catalog")
    if kc:
        for name, e in (kc.get("entries") or {}).items():
            ref(e.get("payload_hash"), f"{loc}:kernel_catalog.{name}",
                f"kernel {name} payload", sev="warning")
    return out


def check_tags(tags: dict, loc: str) -> List[Finding]:
    """``CaptureSpec.tags`` vs the engine's supported-convention matrix
    (serving/engine.py ``TAG_CONVENTIONS``). The tags version the captured
    calling convention; a key or value the engine does not speak means the
    archive would be served through the wrong loop/pool — token corruption,
    not a graceful fallback — so every violation is an error."""
    out: List[Finding] = []
    if not isinstance(tags, dict):
        return [Finding("tags-schema", "error", loc,
                        f"tags must be a dict, got {type(tags).__name__}")]
    from repro.serving.engine import TAG_CONVENTIONS, validate_tags
    for problem in validate_tags(tags):
        out.append(Finding(
            "tags-schema", "error", loc, problem,
            f"supported conventions: {sorted(TAG_CONVENTIONS)}; re-run SAVE "
            f"with a current engine or upgrade the serving engine"))
    if ("fused_sampling" in tags and "decode_loop" in tags
            and tags.get("fused_sampling")
            != (tags.get("decode_loop") == "device")):
        out.append(Finding(
            "tags-schema", "error", loc,
            f"fused_sampling={tags['fused_sampling']!r} inconsistent with "
            f"decode_loop={tags['decode_loop']!r} (device loop <=> fused)",
            "re-run SAVE; the engine always captures them together"))
    return out


# ---------------------------------------------------------------------------
# pass 1c: deep blob integrity
# ---------------------------------------------------------------------------
def check_blob_integrity(archive: Archive, loc: str) -> List[Finding]:
    """Fetch + hash-verify every blob (the deep pass: reads and decompresses
    the full container — offline cost, never on the LOAD critical path)."""
    out: List[Finding] = []
    for h in archive.blobs:
        try:
            archive.get_blob(h)
        except Exception as e:
            out.append(Finding(
                "blob-integrity", "error", f"{loc}:blob/{h[:12]}",
                f"blob fetch failed: {type(e).__name__}: {e}",
                "the container is corrupt; re-run SAVE or restore the blob "
                "from a replica/depot"))
    return out


# ---------------------------------------------------------------------------
# pass 2: StableHLO IR lint
# ---------------------------------------------------------------------------
_LOC_RE = re.compile(r'loc\("([^"]*)"')
_REPLICA_GROUPS_RE = re.compile(r"replica_groups\s*=\s*dense<([^>]*)>")
_ARG_RE = re.compile(r"%arg(\d+):")


def _main_signature(txt: str) -> str:
    """The argument list of ``@main`` (paren-matched: attrs contain
    parens in loc(...))."""
    i = txt.find("@main(")
    if i < 0:
        return ""
    j = i + len("@main(")
    depth = 1
    for k in range(j, len(txt)):
        c = txt[k]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return txt[j:k]
    return txt[j:]


def _donor_arg_indices(txt: str) -> set:
    """Module-arg indices carrying donation/aliasing attributes."""
    sig = _main_signature(txt)
    hits = list(_ARG_RE.finditer(sig))
    out = set()
    for m, nxt in zip(hits, hits[1:] + [None]):
        seg = sig[m.end(): nxt.start() if nxt else len(sig)]
        if "jax.buffer_donor" in seg or "tf.aliasing_output" in seg:
            out.add(int(m.group(1)))
    return out


def _expected_donated_flat(exp, donate_argnums) -> Optional[set]:
    """Flat in_aval indices covered by the spec's positional donate set."""
    import jax
    try:
        args, _kwargs = jax.tree_util.tree_unflatten(
            exp.in_tree, list(range(len(exp.in_avals))))
    except Exception:
        return None
    want = set()
    for i in donate_argnums or ():
        if i < len(args):
            want |= set(jax.tree_util.tree_leaves(args[i]))
    return want


def _parse_replica_groups(text: str) -> List[List[int]]:
    rows = []
    for row in re.findall(r"\[([0-9,\s]*?)\]", text):
        vals = [int(v) for v in row.replace(" ", "").split(",") if v != ""]
        if vals:
            rows.append(vals)
    return rows


def _covered_peer_rows(manifest: dict) -> set:
    """Every peer-group row any RankDelta in the manifest covers."""
    cover = set()
    rd = (manifest.get("rank_delta") or {}).get("capture_ranks") or []
    for d in rd:
        for rows in (d.get("peer_groups") or {}).values():
            cover.add(tuple(sorted(int(r) for r in rows)))
    return cover


def check_ir(archive: Archive, loc: str,
             manifest: Optional[dict] = None) -> List[Finding]:
    """Lint every archived StableHLO export (``canonical_export_bytes``
    output): determinism (no call-site debug locations — they make every
    blob byte-unique and defeat depot content-addressing), donation/aliasing
    consistency with the spec's ``donate_argnums``, and the §4.3 correctness
    condition — every multi-rank communication constant in the program text
    (replica groups, partition/replica id use) must be covered by a
    ``RankDelta``, else the stamped restore path would serve a program whose
    rank-dependent state was never patched."""
    import jax
    import jax.export  # noqa: F401  (not re-exported on jax<=0.4.x)
    out: List[Finding] = []
    manifest = manifest if manifest is not None else archive.manifest
    cover = _covered_peer_rows(manifest)
    have_deltas = bool(
        (manifest.get("rank_delta") or {}).get("capture_ranks"))

    for name, spec_m in _spec_entries(manifest):
        donate = spec_m.get("donate_argnums") or []
        for gi, g in enumerate(spec_m.get("groups") or []):
            for b, h in sorted((g.get("bucket_export_blobs") or {}).items(),
                               key=lambda kv: str(kv[0])):
                bloc = f"{loc}:specs.{name}.groups[{gi}].export[{b}]"
                try:
                    blob = archive.get_blob(h)
                except Exception:
                    continue  # blob-index/integrity passes own this
                try:
                    exp = jax.export.deserialize(bytearray(blob))
                    txt = exp.mlir_module()
                except Exception as e:
                    out.append(Finding(
                        "ir-parse", "error", bloc,
                        f"export blob does not deserialize: "
                        f"{type(e).__name__}: {e}",
                        "re-run SAVE; the export is unusable for exact "
                        "realization or fallback compile"))
                    continue
                out.extend(_lint_one_module(exp, txt, donate, bloc,
                                            cover, have_deltas))
    return out


def _lint_one_module(exp, txt: str, donate, bloc: str, cover: set,
                     have_deltas: bool) -> List[Finding]:
    out: List[Finding] = []
    # determinism: canonical exports carry only synthetic locations; a
    # file/frame location means SAVE skipped canonical_export_bytes
    dirty = sorted({n for n in _LOC_RE.findall(txt)
                    if "/" in n or "\\" in n or ".py" in n
                    or "<" in n or n.startswith("jit(")})
    if dirty:
        out.append(Finding(
            "ir-determinism", "warning", bloc,
            f"module embeds call-site debug locations ({dirty[0]!r}"
            f"{' …' if len(dirty) > 1 else ''}): byte-identical programs "
            f"exported elsewhere will not dedup in the depot",
            "SAVE through materialize.canonical_export_bytes"))

    # donation/aliasing vs the manifest's donate_argnums
    want_flat = _expected_donated_flat(exp, donate)
    if want_flat is not None:
        kept = list(getattr(exp, "module_kept_var_idx", None)
                    or range(len(exp.in_avals)))
        expect = {k for k, flat in enumerate(kept) if flat in want_flat}
        have = {k for k in _donor_arg_indices(txt) if k < len(kept)}
        if expect != have:
            missing, extra = sorted(expect - have), sorted(have - expect)
            out.append(Finding(
                "donation-aliasing", "error", bloc,
                f"donation mismatch between spec donate_argnums={list(donate)} "
                f"and exported module "
                f"(args missing donor attrs: {missing}, unexpected donors: "
                f"{extra})", "re-run SAVE so the export and manifest agree; "
                "a LOAD would re-apply the manifest's donation onto a "
                "program compiled for a different aliasing contract"))

    # §4.3: rank/peer-table constants must be covered by a RankDelta
    for mtext in _REPLICA_GROUPS_RE.findall(txt):
        for row in _parse_replica_groups(mtext):
            if len(row) < 2:
                continue  # single-member group: no communication to patch
            if tuple(sorted(row)) not in cover:
                out.append(Finding(
                    "rank-delta-coverage", "error", bloc,
                    f"replica group {row} appears in the program but no "
                    f"RankDelta covers it: the stamped restore path would "
                    f"never patch this collective's peer state",
                    "re-run SAVE with the memory plan/mesh wired so "
                    "build_rank_deltas records every peer table"))
    if (("partition_id" in txt or "replica_id" in txt)
            and not have_deltas):
        out.append(Finding(
            "rank-delta-coverage", "error", bloc,
            "program reads partition/replica id but the archive has no "
            "rank_delta section", "re-run SAVE with a current foundry_save"))
    return out


# ---------------------------------------------------------------------------
# pass 3: memory plan
# ---------------------------------------------------------------------------
def check_memory_plan(mp: Optional[dict], loc: str) -> List[Finding]:
    """Deterministic-layout invariants of a recorded ``MemoryPlan`` manifest
    (§4.1.1): the allocation sequence must replay to the recorded offsets
    (overlap/alignment/extent), capture-window events must form the tail of
    the sequence (``replay_capture_window`` replays a contiguous suffix),
    and scope accounting must be internally consistent."""
    out: List[Finding] = []
    if mp is None:
        return out
    loc = f"{loc}:memory_plan"
    align = mp.get("align")
    if not isinstance(align, int) or align < 1:
        return [Finding("memory-plan-alignment", "error", loc,
                        f"bad alignment {align!r}")]
    allocs = mp.get("allocations")
    if not isinstance(allocs, list):
        return [Finding("memory-plan-extent", "error", loc,
                        "allocations section missing")]
    cursor = 0
    seen_capture = False
    prev = None
    for i, a in enumerate(allocs):
        aloc = f"{loc}.allocations[{i}]({a.get('name', '?')})"
        size, off = a.get("size"), a.get("offset")
        if (not isinstance(size, int) or size < 0
                or not isinstance(off, int) or off < 0):
            out.append(Finding("memory-plan-extent", "error", aloc,
                               f"malformed allocation size={size!r} "
                               f"offset={off!r}"))
            continue
        if a.get("scope") not in ("global", "per_rank"):
            out.append(Finding(
                "memory-plan-scope", "error", aloc,
                f"unknown scope {a.get('scope')!r} (want global|per_rank): "
                f"rank_extents cannot shard it", "re-run SAVE"))
        phase = a.get("phase")
        if phase not in ("init", "capture"):
            out.append(Finding("capture-window-order", "error", aloc,
                               f"unknown phase {phase!r}"))
        elif phase == "capture":
            seen_capture = True
        elif seen_capture:
            out.append(Finding(
                "capture-window-order", "error", aloc,
                "init-phase allocation after a capture-window allocation: "
                "LOAD's capture-window replay is a contiguous tail, so the "
                "replayed sequence would diverge from the recording",
                "keep init allocations before set_phase('capture')"))
        if off % align:
            out.append(Finding(
                "memory-plan-alignment", "error", aloc,
                f"offset {off} not {align}-byte aligned"))
        if prev is not None and off < prev[0] + prev[1]:
            out.append(Finding(
                "memory-plan-overlap", "error", aloc,
                f"allocation [{off}, {off + size}) overlaps "
                f"{prev[2]!r} ending at {prev[0] + prev[1]}",
                "the SAVE-side arena is monotonic; this record was "
                "hand-edited or corrupted — re-run SAVE"))
        elif off > cursor:
            out.append(Finding(
                "memory-plan-leak", "warning", aloc,
                f"{off - cursor} unaccounted bytes before this allocation "
                f"(beyond alignment padding): space LOAD premaps but "
                f"nothing owns"))
        cursor = max(cursor, off + size + ((-size) % align))
        prev = (off, size, a.get("name"))
    extent = mp.get("extent")
    if not isinstance(extent, int) or extent < (prev[0] + prev[1] if prev
                                                else 0):
        out.append(Finding(
            "memory-plan-extent", "error", f"{loc}.extent",
            f"recorded extent {extent!r} does not cover the allocation "
            f"sequence (ends at {prev[0] + prev[1] if prev else 0}): LOAD "
            f"would preallocate too little and fail mid-replay",
            "re-run SAVE"))
    return out


# ---------------------------------------------------------------------------
# pass 2/3 joint: rank-delta section vs mesh + memory plan
# ---------------------------------------------------------------------------
def check_rank_delta_section(manifest: dict, loc: str) -> List[Finding]:
    """Completeness of the archive's ``rank_delta`` section (§4.3): one
    delta per capture rank, a peer table per mesh axis containing the rank
    itself, coordinates matching the mesh, and ``comm_buffers`` equal to the
    memory plan's ``rank_extents`` re-derivation. Every drift here is state
    the stamped restore path would silently fail to patch."""
    out: List[Finding] = []
    rd = manifest.get("rank_delta")
    mesh = manifest.get("mesh") or {"axes": [], "shape": []}
    if not isinstance(rd, dict) or not rd.get("capture_ranks"):
        out.append(Finding(
            "rank-delta-coverage", "warning", f"{loc}:rank_delta",
            "archive has no rank_delta section (pre-§4.3 SAVE?): the "
            "stamped restore path is unavailable, every mesh rebind "
            "falls back to compile-from-StableHLO",
            "re-run SAVE with a current foundry_save"))
        return out
    shape = [int(s) for s in mesh.get("shape") or []]
    axes = [str(a) for a in mesh.get("axes") or []]
    n = identity_device_count(mesh)
    deltas = rd["capture_ranks"]
    rloc = f"{loc}:rank_delta.capture_ranks"
    got_ranks = [d.get("rank") for d in deltas]
    if sorted(got_ranks) != list(range(n)):
        out.append(Finding(
            "rank-delta-coverage", "error", rloc,
            f"capture mesh has {n} rank(s) but deltas cover {got_ranks}: "
            f"every rank's communication state must be recorded",
            "re-run SAVE; build_rank_deltas emits one delta per rank"))
    truth_groups = peer_groups(shape, axes)
    truth_coords = rank_coords(shape)
    plan_extents = None
    if manifest.get("memory_plan"):
        try:
            plan_extents = MemoryPlan.from_manifest(
                manifest["memory_plan"]).rank_extents(max(n, 1))
        except Exception:
            plan_extents = None  # memory-plan pass owns malformed plans
    for d in deltas:
        r = d.get("rank")
        dloc = f"{rloc}[{r}]"
        if not isinstance(r, int) or not 0 <= r < n:
            continue  # covered by the range check above
        coords = tuple(d.get("coords") or ())
        if shape and coords != truth_coords[r]:
            out.append(Finding(
                "rank-delta-coverage", "error", f"{dloc}.coords",
                f"rank {r} coords {coords} != mesh-derived "
                f"{truth_coords[r]}"))
        pg = d.get("peer_groups") or {}
        for ax in axes:
            if ax not in pg:
                out.append(Finding(
                    "rank-delta-coverage", "error", f"{dloc}.peer_groups",
                    f"rank {r} has no peer table for mesh axis {ax!r}: "
                    f"collectives over it would replay with unpatched "
                    f"peer state", "re-run SAVE; every axis needs a table"))
                continue
            mine = [int(x) for x in pg[ax]]
            want = next(g for g in truth_groups[ax] if r in g)
            if r not in mine:
                out.append(Finding(
                    "rank-delta-coverage", "error", f"{dloc}.peer_groups",
                    f"rank {r} missing from its own {ax!r} peer group "
                    f"{mine}"))
            elif sorted(mine) != sorted(want):
                out.append(Finding(
                    "rank-delta-coverage", "error", f"{dloc}.peer_groups",
                    f"{ax!r} peer group {mine} != mesh-derived {want}"))
        for ax in pg:
            if ax not in axes:
                out.append(Finding(
                    "rank-delta-coverage", "error", f"{dloc}.peer_groups",
                    f"peer table for unknown mesh axis {ax!r}"))
        if plan_extents is not None:
            got = [dict(b) for b in d.get("comm_buffers") or []]
            if got != plan_extents:
                out.append(Finding(
                    "memory-plan-scope", "error", f"{dloc}.comm_buffers",
                    f"rank {r} buffer table diverges from the memory "
                    f"plan's rank_extents({max(n, 1)}) re-derivation "
                    f"({len(got)} vs {len(plan_extents)} entries or "
                    f"offset/size drift)",
                    "re-run SAVE so deltas and plan agree"))
    fields = rd.get("rank_dependent_fields") or []
    if "mesh" not in fields:
        out.append(Finding(
            "rank-delta-coverage", "warning", f"{loc}:rank_delta",
            "rank_dependent_fields does not list 'mesh'",
            "re-run SAVE with a current foundry_save"))
    return out


# ---------------------------------------------------------------------------
# archive-level drivers
# ---------------------------------------------------------------------------
def verify_for_load(archive: Archive, loc: str = "archive") -> List[Finding]:
    """The strict-LOAD pre-flight: every metadata-level pass, no blob
    fetches and no IR deserialization — cost is microseconds to low
    milliseconds regardless of archive size, which is what lets
    ``foundry_load(strict=True)`` stay under the <5% LOAD budget
    (benchmarks/fig13_autoscale.py asserts it)."""
    m = archive.manifest
    out = check_manifest_schema(m, loc, blobs=archive.blobs)
    out += check_memory_plan(m.get("memory_plan"), loc)
    out += check_rank_delta_section(m, loc)
    return out


def check_archive(archive: Archive, loc: str = "archive", *,
                  deep: bool = True, ir: bool = True) -> List[Finding]:
    """Full offline verification of an (already opened) archive."""
    out = verify_for_load(archive, loc)
    if deep:
        out += check_blob_integrity(archive, loc)
    if ir:
        out += check_ir(archive, loc)
    return out


def check_archive_file(path: str, depot=None, *, deep: bool = True,
                       ir: bool = True) -> List[Finding]:
    """Full offline verification of an archive file: container structure
    first, then (if the container parses) every content pass. ``depot`` is
    required to resolve a thin archive's blobs; without it only the
    structural and manifest passes run."""
    loc = os.path.basename(path)
    out, info = check_container_file(path)
    if info.manifest is None:
        return out
    if info.thin and depot is None:
        out.append(Finding(
            "blob-index", "warning", loc,
            "thin (depot-backed) archive checked without --depot: blob "
            "presence/integrity not verifiable",
            "pass --depot <root>"))
        out += check_manifest_schema(info.manifest, loc, blobs=None)
        out += check_memory_plan(info.manifest.get("memory_plan"), loc)
        out += check_rank_delta_section(info.manifest, loc)
        return out
    try:
        archive = Archive.load(path, depot=depot)
    except Exception as e:
        out.append(Finding(
            "container-structure", "error", loc,
            f"container parses but Archive.load failed: "
            f"{type(e).__name__}: {e}"))
        return out
    return out + check_archive(archive, loc, deep=deep, ir=ir)


# ---------------------------------------------------------------------------
# pass 4: depot fsck
# ---------------------------------------------------------------------------
def check_depot(root: str, *, gc_orphans: bool = False,
                deep: bool = False) -> Tuple[List[Finding], Dict[str, int]]:
    """fsck for a ``TemplateDepot`` directory: ``index.json`` readability
    (the torn-write case), index-vs-disk agreement in both directions,
    refcount consistency between the archive and blob planes, and thin
    manifests that actually resolve. Read-only unless ``gc_orphans`` —
    which deletes only blob *files* the index does not know (the crash
    residue of a SAVE that died between blob deposit and index flush)."""
    loc = os.path.basename(os.path.abspath(root)) or root
    out: List[Finding] = []
    actions = {"gc_removed_blobs": 0, "gc_freed_bytes": 0}
    blob_dir = os.path.join(root, "blobs")
    manifest_dir = os.path.join(root, "manifests")
    index_path = os.path.join(root, "index.json")

    index = None
    if not os.path.exists(index_path):
        sev = ("error" if os.path.isdir(blob_dir) and os.listdir(blob_dir)
               else "warning")
        out.append(Finding(
            "depot-index", sev, f"{loc}/index.json",
            "index.json missing" + (" but blobs exist on disk" if
                                    sev == "error" else " (empty depot?)"),
            "re-put the archives to rebuild the index"))
    else:
        try:
            with open(index_path) as f:
                index = json.load(f)
        except ValueError as e:
            out.append(Finding(
                "depot-index", "error", f"{loc}/index.json",
                f"index.json does not parse ({e}): torn write — a crash "
                f"mid-flush, or a non-atomic writer",
                "restore index.json from backup or re-put every archive; "
                "TemplateDepot._flush writes tmp+rename exactly to prevent "
                "this"))
        except OSError as e:
            out.append(Finding("depot-index", "error", f"{loc}/index.json",
                               f"unreadable: {e}"))
    if index is not None and index.get("version") != 1:
        out.append(Finding(
            "depot-index", "error", f"{loc}/index.json",
            f"unknown index version {index.get('version')!r}",
            "upgrade this checker or the depot"))
        index = None

    blobs = (index or {}).get("blobs", {})
    archives = (index or {}).get("archives", {})
    known_refs = {os.path.abspath(os.path.join(root, e.get("file", "")))
                  for e in archives.values()}

    # blob plane: index -> disk
    for h, meta in sorted(blobs.items()):
        p = os.path.join(blob_dir, h)
        if not os.path.exists(p):
            out.append(Finding(
                "depot-missing-blob", "error", f"{loc}/blobs/{h[:12]}",
                f"indexed blob missing on disk (held by "
                f"{len(meta.get('refs', []))} ref(s))",
                "restore the blob file or remove+re-put the archives that "
                "reference it"))
            continue
        size = os.path.getsize(p)
        if size != meta.get("comp_len"):
            out.append(Finding(
                "depot-blob-size", "error", f"{loc}/blobs/{h[:12]}",
                f"file is {size} bytes, index says {meta.get('comp_len')} "
                f"(partial write?)", "delete the file and re-put an "
                "archive that carries this blob"))
        elif deep:
            try:
                with open(p, "rb") as f:
                    data = _decompress(f.read())
                if content_hash(data) != h:
                    raise ValueError("content hash mismatch")
            except Exception as e:
                out.append(Finding(
                    "blob-integrity", "error", f"{loc}/blobs/{h[:12]}",
                    f"blob does not verify: {type(e).__name__}: {e}",
                    "delete the file and re-put a carrying archive"))
        for ref in meta.get("refs", []):
            if ref not in known_refs:
                out.append(Finding(
                    "depot-dangling-ref", "warning",
                    f"{loc}/blobs/{h[:12]}",
                    f"ref {ref!r} does not match any indexed archive: the "
                    f"blob can never be garbage-collected",
                    "TemplateDepot.release_ref(ref) then gc()"))

    # blob plane: disk -> index (the SAVE-crash residue gc_orphans prunes)
    if os.path.isdir(blob_dir):
        for fn in sorted(os.listdir(blob_dir)):
            p = os.path.join(blob_dir, fn)
            if fn in blobs or not os.path.isfile(p):
                continue
            size = os.path.getsize(p)
            if gc_orphans:
                os.remove(p)
                actions["gc_removed_blobs"] += 1
                actions["gc_freed_bytes"] += size
                out.append(Finding(
                    "depot-orphan-blob", "info", f"{loc}/blobs/{fn[:12]}",
                    f"orphan blob ({size} bytes) removed"))
            else:
                out.append(Finding(
                    "depot-orphan-blob", "warning", f"{loc}/blobs/{fn[:12]}",
                    f"blob file not in the index ({size} bytes): dead "
                    f"space from a crashed SAVE or an index rollback",
                    "re-run with --gc-orphans to delete"))

    # archive plane
    for name, entry in sorted(archives.items()):
        aloc = f"{loc}/manifests/{name}"
        p = os.path.join(root, entry.get("file", ""))
        if not os.path.isfile(p):
            out.append(Finding(
                "depot-missing-manifest", "error", aloc,
                f"archive {name!r} indexed but its manifest file "
                f"{entry.get('file')!r} is missing",
                "remove_archive(name) or restore the file"))
            continue
        cf, cinfo = check_container_file(p)
        out += [f for f in cf if f.severity == "error"]
        if cinfo.manifest is None:
            continue
        if not cinfo.thin:
            out.append(Finding(
                "depot-manifest", "error", aloc,
                "manifest file is not a thin (depot-flagged) container"))
        missing = [h for h in cinfo.index if h not in blobs]
        if missing:
            out.append(Finding(
                "depot-refcount", "error", aloc,
                f"archive references {len(missing)} blob(s) the index does "
                f"not hold (first: {missing[0][:12]}…)",
                "re-put the archive"))
        me = os.path.abspath(p)
        unheld = [h for h in cinfo.index
                  if h in blobs and me not in blobs[h].get("refs", [])]
        if unheld:
            out.append(Finding(
                "depot-refcount", "error", aloc,
                f"{len(unheld)} blob(s) used by {name!r} hold no ref for "
                f"it (first: {unheld[0][:12]}…): gc() would delete state "
                f"a live archive needs",
                "re-put the archive to re-register its refs"))
        listed = set(entry.get("blob_hashes", []))
        if listed != set(cinfo.index):
            out.append(Finding(
                "depot-refcount", "error", aloc,
                f"index blob_hashes disagree with the manifest's own blob "
                f"index ({len(listed)} vs {len(cinfo.index)})",
                "re-put the archive"))

    # manifest plane: disk -> index
    if os.path.isdir(manifest_dir):
        indexed_files = {os.path.basename(e.get("file", ""))
                         for e in archives.values()}
        for fn in sorted(os.listdir(manifest_dir)):
            if fn not in indexed_files:
                out.append(Finding(
                    "depot-orphan-manifest", "warning",
                    f"{loc}/manifests/{fn}",
                    "manifest file not in the index: crash between "
                    "archive save and index flush",
                    "delete it or re-put the archive under its name"))
    return out, actions


# ---------------------------------------------------------------------------
# serialization for the CLI / CI gates
# ---------------------------------------------------------------------------
def findings_to_json(findings: Sequence[Finding],
                     actions: Optional[Dict[str, int]] = None) -> dict:
    doc = {"findings": [asdict(f) for f in findings],
           "summary": summarize(findings)}
    if actions:
        doc["actions"] = dict(actions)
    return doc


def exit_code(findings: Sequence[Finding]) -> int:
    s = summarize(findings)
    if s["error"]:
        return 2
    if s["warning"]:
        return 1
    return 0

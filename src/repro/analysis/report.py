"""Render EXPERIMENTS.md tables from dry-run sweep JSON reports.

    PYTHONPATH=src python -m repro.analysis.report reports/dryrun_*.json
"""
from __future__ import annotations

import json
import sys
from typing import Optional


def _fmt_s(x: float) -> str:
    return f"{x:.3g}s" if x >= 1e-3 else f"{x * 1e3:.3g}ms"


def mesh_tag(mesh: dict) -> str:
    return "x".join(str(v) for v in mesh.values())


def dryrun_table(records, mesh_axes: int = 2) -> str:
    lines = [
        "| arch | shape | status | lower | compile | live GB/dev | fits 16G | "
        "HLO flops/dev | collectives (AR/AG/RS/A2A bytes/dev) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if len(r["mesh"]) != mesh_axes:
            continue
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP: "
                         f"{r['skip_reason']} | | | | | | |")
            continue
        rf = r.get("roofline", {})
        by = rf.get("wire_bytes_by_kind", {})
        coll = "/".join(f"{by.get(k, 0):.2g}" for k in
                        ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all"))
        ma = r["memory_analysis"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['lower_s']}s | "
            f"{r['compile_s']}s | {ma['live_bytes_per_device'] / 1e9:.2f} | "
            f"{'yes' if r['fits_16g_hbm'] else 'NO'} | "
            f"{rf.get('hlo_flops_per_dev', 0):.3g} | {coll} |")
    return "\n".join(lines)


def roofline_table(records) -> str:
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL_FLOPS/dev | useful/HLO | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if len(r["mesh"]) != 2 or r["status"] != "ok":
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(rf['compute_s'])} | "
            f"{_fmt_s(rf['memory_s'])} | {_fmt_s(rf['collective_s'])} | "
            f"**{rf['dominant']}** | {rf['model_flops_per_dev']:.3g} | "
            f"{rf['useful_flops_ratio']:.2f} | "
            f"{100 * rf['roofline_fraction']:.2f}% | "
            f"{_lever(r['arch'], r['shape'], rf)} |")
    return "\n".join(lines)


def _lever(arch: str, shape: str, rf: dict) -> str:
    d = rf["dominant"]
    if d == "collective":
        return "shard_map'd dispatch / bf16 collectives"
    if d == "memory":
        if "decode" in shape or "long" in shape:
            return "Pallas flash-decode (VMEM-resident scores)"
        if "prefill" in shape or "train" in shape:
            return "Pallas flash/scan kernels; larger fusion regions"
    return "MXU-aligned tiling"


def compare_table(base, opt) -> str:
    key = lambda r: (r["arch"], r["shape"])
    b = {key(r): r for r in base if len(r["mesh"]) == 2 and r["status"] == "ok"}
    o = {key(r): r for r in opt if len(r["mesh"]) == 2 and r["status"] == "ok"}
    lines = [
        "| arch | shape | bound (baseline) | bound (optimized) | gain | "
        "dominant (opt) |",
        "|---|---|---|---|---|---|",
    ]
    for k in b:
        if k not in o:
            continue
        tb = b[k]["roofline"]["step_time_lower_bound_s"]
        to = o[k]["roofline"]["step_time_lower_bound_s"]
        lines.append(f"| {k[0]} | {k[1]} | {_fmt_s(tb)} | {_fmt_s(to)} | "
                     f"{tb / to:.2f}x | {o[k]['roofline']['dominant']} |")
    return "\n".join(lines)


def main():
    paths = sys.argv[1:]
    recs = {p: json.load(open(p)) for p in paths}
    for p, r in recs.items():
        print(f"\n## {p} — single-pod (16,16)\n")
        print(roofline_table(r))
        print(f"\n### dry-run detail\n")
        print(dryrun_table(r))
        print(f"\n### multi-pod (2,16,16) detail\n")
        print(dryrun_table(r, mesh_axes=3))
    if len(paths) == 2:
        print("\n## baseline vs optimized\n")
        print(compare_table(recs[paths[0]], recs[paths[1]]))


if __name__ == "__main__":
    main()

"""foundry-check CLI: ``python -m repro.analysis.check <targets...>``.

Targets are archive files (``.fndry``) and/or depot root directories; each
is verified with the full offline pass set of ``repro.analysis.checker``
(container structure, manifest/blob/tags, StableHLO IR lint, memory plan,
depot fsck — nothing is executed). Examples:

    # full verification of one archive (deep blob integrity + IR lint)
    python -m repro.analysis.check model.fndry

    # thin (depot-backed) archive: resolve blobs through its depot
    python -m repro.analysis.check model.fndry --depot /var/foundry/depot

    # depot fsck; then again, deleting unreferenced blob files
    python -m repro.analysis.check /var/foundry/depot
    python -m repro.analysis.check /var/foundry/depot --gc-orphans

    # fast metadata-only pass (what foundry_load(strict=True) runs)
    python -m repro.analysis.check model.fndry --no-deep --no-ir

    # machine-readable findings for CI gates
    python -m repro.analysis.check model.fndry --json

Exit codes (stable; CI gates key off them):
    0  clean — no findings above info
    1  warnings only (servable but degraded: dedup lost, orphaned storage)
    2  errors — the artifact must not be served; strict LOAD would refuse it
    3  fatal — unusable invocation or unreadable target
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.analysis.checker import (Finding, check_archive_file, check_depot,
                                    exit_code, findings_to_json, summarize)

EXIT_CLEAN, EXIT_WARNINGS, EXIT_ERRORS, EXIT_FATAL = 0, 1, 2, 3


class _Parser(argparse.ArgumentParser):
    """argparse exits 2 on bad usage — that slot means "errors found" here,
    so usage problems exit with the fatal code instead."""

    def error(self, message):
        self.print_usage(sys.stderr)
        print(f"{self.prog}: error: {message}", file=sys.stderr)
        raise SystemExit(EXIT_FATAL)


def _build_parser() -> argparse.ArgumentParser:
    ap = _Parser(
        prog="python -m repro.analysis.check",
        description="Offline static verifier for Foundry archives, depots "
                    "and capture manifests (no execution).",
        epilog="exit codes: 0 clean, 1 warnings only, 2 errors, 3 fatal")
    ap.add_argument("targets", nargs="+",
                    help="archive file(s) and/or depot root directorie(s)")
    ap.add_argument("--depot", metavar="ROOT",
                    help="depot root used to resolve thin archives' blobs")
    ap.add_argument("--no-deep", dest="deep", action="store_false",
                    help="skip blob fetch + content-hash verification "
                         "(metadata-only, the strict-LOAD pre-flight scope)")
    ap.add_argument("--no-ir", dest="ir", action="store_false",
                    help="skip the StableHLO IR lint passes")
    ap.add_argument("--gc-orphans", action="store_true",
                    help="depot targets: delete blob files the index does "
                         "not reference (crash residue)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON on stdout")
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = _build_parser().parse_args(argv)

    depot = None
    if args.depot:
        if not os.path.isdir(args.depot):
            print(f"fatal: --depot {args.depot!r} is not a directory",
                  file=sys.stderr)
            return EXIT_FATAL
        from repro.core.depot import TemplateDepot
        depot = TemplateDepot(args.depot)

    findings: List[Finding] = []
    actions = {}
    for target in args.targets:
        if os.path.isdir(target):
            fs, acts = check_depot(target, gc_orphans=args.gc_orphans,
                                   deep=args.deep)
            findings += fs
            for k, v in acts.items():
                actions[k] = actions.get(k, 0) + v
        elif os.path.isfile(target):
            findings += check_archive_file(target, depot, deep=args.deep,
                                           ir=args.ir)
        else:
            print(f"fatal: no such file or directory: {target}",
                  file=sys.stderr)
            return EXIT_FATAL

    if args.json:
        print(json.dumps(findings_to_json(findings, actions), indent=1))
    else:
        for f in findings:
            print(f.render())
        s = summarize(findings)
        gc = (f", gc removed {actions['gc_removed_blobs']} blob(s) "
              f"({actions['gc_freed_bytes']} bytes)"
              if actions.get("gc_removed_blobs") else "")
        print(f"foundry-check: {len(args.targets)} target(s): "
              f"{s['error']} error(s), {s['warning']} warning(s), "
              f"{s['info']} info{gc}")
    return exit_code(findings)


if __name__ == "__main__":
    raise SystemExit(main())

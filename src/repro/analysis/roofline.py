"""Roofline analysis from compiled SPMD artifacts.

The assignment's three terms (TPU v5e):
    compute    = HLO_FLOPs / (chips * 197e12 bf16 FLOP/s)
    memory     = HLO_bytes / (chips * 819e9 B/s HBM)
    collective = wire_bytes / (chips * 50e9 B/s link)

``compiled.cost_analysis()`` undercounts programs with ``lax.scan``: XLA's
cost analysis counts a while-loop body ONCE, not x trip-count (verified
empirically; see EXPERIMENTS.md §Dry-run). Since every model here scans over
layers, we implement a trip-count-aware analyzer over the *optimized HLO
text*: it builds the computation call graph (fusion / call / while edges),
extracts while trip counts from their condition computations, and multiplies
per-op costs by the product of enclosing loop trips.

  * FLOPs: every ``dot`` (wherever it lives, incl. inside fusions),
    2 * prod(out_shape) * prod(contracting dims).
  * HBM bytes: operand+result sizes of ops at fusion *boundaries* (fusion
    internals stay in registers/VMEM), a standard materialization-traffic
    model.
  * Wire bytes: ring-model cost of every collective, per device:
      all-reduce 2*S*(g-1)/g, all-gather/all-to-all S*(g-1)/g,
      reduce-scatter S_in*(g-1)/g, collective-permute S.

All shapes in the partitioned module are per-device, so every figure below is
per-device; terms use the per-chip numerator over the per-chip denominator.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

# TPU v5e hardware constants (assignment-specified)
PEAK_FLOPS = 197e12   # bf16 FLOP/s per chip
HBM_BW = 819e9        # B/s per chip
LINK_BW = 50e9        # B/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*\{\s*$")


def _parse_shape(s: str) -> Tuple[str, Tuple[int, ...]]:
    m = _SHAPE_RE.match(s.strip())
    if not m:
        return "f32", ()
    dt, dims = m.group(1), m.group(2)
    shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
    return dt, shape


def _shape_bytes(s: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        total += _DTYPE_BYTES[dt] * (math.prod(dims) if dims else 1)
    return total


@dataclass
class _Op:
    name: str
    result_type: str
    opcode: str
    rest: str  # operand list + attributes


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[_Op]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self.defs: Dict[str, Dict[str, str]] = {
            cname: {op.name: op.result_type for op in ops}
            for cname, ops in self.comps.items()
        }
        self.trips: Dict[str, int] = {}  # body computation -> trip count
        self._find_trips()
        self.mult: Dict[str, float] = {}
        self._propagate_multipliers()

    # -- parsing ---------------------------------------------------------
    def _parse(self, text: str):
        cur: Optional[str] = None
        for line in text.splitlines():
            mc = _COMP_RE.match(line)
            if mc:
                cur = mc.group(1)
                self.comps[cur] = []
                if line.startswith("ENTRY"):
                    self.entry = cur
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            mo = _OP_RE.match(line)
            if mo:
                name, rtype, opcode, rest = mo.groups()
                self.comps[cur].append(_Op(name, rtype.strip(), opcode, rest))
        if self.entry is None and self.comps:  # fall back: last computation
            self.entry = list(self.comps)[-1]

    def _attr(self, rest: str, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w\.\-]+)", rest)
        return m.group(1) if m else None

    def _find_trips(self):
        for cname, ops in self.comps.items():
            for op in ops:
                if op.opcode != "while":
                    continue
                cond = self._attr(op.rest, "condition")
                body = self._attr(op.rest, "body")
                trip = self._trip_from_cond(cond) if cond else None
                if body:
                    self.trips[body] = trip if trip is not None else 1
                if cond:
                    self.trips[cond] = self.trips.get(body, 1)

    def _trip_from_cond(self, cond_name: str) -> Optional[int]:
        ops = self.comps.get(cond_name)
        if not ops:
            return None
        consts = {}
        for op in ops:
            if op.opcode == "constant":
                m = re.match(r"\s*([\-\d]+)", op.rest)
                if m:
                    consts[op.name] = int(m.group(1))
        for op in ops:
            if op.opcode == "compare" and "direction=LT" in op.rest:
                for operand in re.findall(r"%([\w\.\-]+)", op.rest):
                    if operand in consts:
                        return consts[operand]
        # nested tuple-compare conds (rare): max constant as upper bound
        return max(consts.values()) if consts else None

    def _callees(self, op: _Op) -> List[Tuple[str, float, str]]:
        """(callee, multiplier, kind) edges of one op."""
        out = []
        if op.opcode == "while":
            body = self._attr(op.rest, "body")
            cond = self._attr(op.rest, "condition")
            trip = self.trips.get(body, 1) or 1
            if body:
                out.append((body, float(trip), "while"))
            if cond:
                out.append((cond, float(trip), "while"))
        elif op.opcode == "fusion":
            c = self._attr(op.rest, "calls")
            if c:
                out.append((c, 1.0, "fusion"))
        elif op.opcode in ("call", "custom-call", "async-start"):
            c = self._attr(op.rest, "to_apply") or self._attr(op.rest, "called_computations")
            if c:
                out.append((c, 1.0, "call"))
        elif op.opcode == "conditional":
            for c in re.findall(r"%([\w\.\-]+)", op.rest.split("branch_computations=")[-1]) \
                    if "branch_computations" in op.rest else []:
                if c in self.comps:
                    out.append((c, 1.0, "call"))
            tc = self._attr(op.rest, "true_computation")
            fc = self._attr(op.rest, "false_computation")
            for c in (tc, fc):
                if c:
                    out.append((c, 1.0, "call"))
        elif op.opcode in ("reduce", "reduce-window", "scatter", "sort",
                           "all-reduce", "reduce-scatter", "map", "select-and-scatter"):
            pass  # to_apply bodies are tiny elementwise lambdas
        return out

    def _propagate_multipliers(self):
        from collections import deque
        self.mult = {self.entry: 1.0}
        # fusion-context flag: bytes only counted outside fusion computations
        self.in_fusion: Dict[str, bool] = {self.entry: False}
        q = deque([self.entry])
        seen_edges = set()
        while q:
            cname = q.popleft()
            for op in self.comps.get(cname, []):
                for callee, m, kind in self._callees(op):
                    if callee not in self.comps:
                        continue
                    new_mult = self.mult[cname] * m
                    new_fus = self.in_fusion.get(cname, False) or kind == "fusion"
                    key = (cname, callee)
                    if key in seen_edges and self.mult.get(callee, 0) >= new_mult:
                        continue
                    seen_edges.add(key)
                    self.mult[callee] = max(self.mult.get(callee, 0.0), new_mult)
                    self.in_fusion[callee] = (self.in_fusion.get(callee, True)
                                              and new_fus)
                    q.append(callee)

    # -- costs -----------------------------------------------------------
    # f32 dots run ~4x slower than bf16 on the v5e MXU (documented estimate);
    # counting them at 4x bf16-equivalent flops makes the compute term
    # reflect the real cost of f32-materialized attention math.
    F32_DOT_PENALTY = 4.0

    def dot_flops(self) -> float:
        total = 0.0
        for cname, ops in self.comps.items():
            mult = self.mult.get(cname, 0.0)
            if mult == 0.0:
                continue
            for op in ops:
                if op.opcode not in ("dot", "convolution"):
                    continue
                dt, out_shape = _parse_shape(op.result_type)
                out_elems = math.prod(out_shape) if out_shape else 1
                k = self._contraction_size(cname, op)
                w = 1.0
                if dt == "f32" and self._dot_operand_dtype(cname, op) == "f32":
                    w = self.F32_DOT_PENALTY
                total += 2.0 * out_elems * k * mult * w
        return total

    def _dot_operand_dtype(self, cname: str, op: _Op) -> str:
        """Ultimate source dtype of the dot's lhs, seen through convert
        chains (the CPU backend converts bf16 operands to f32 because it
        lacks native bf16 dots; the TPU MXU would consume bf16 directly, so
        a dot is only 'really' f32 when its source data is f32)."""
        operands = self._operand_names(op)
        if not operands:
            return "f32"
        name = self._see_through_converts(cname, operands[0])
        t = self.defs.get(cname, {}).get(name)
        return _parse_shape(t)[0] if t else "f32"

    def _contraction_size(self, cname: str, op: _Op) -> int:
        if op.opcode == "convolution":
            # rough: kernel spatial * in-features
            m = re.search(r"dim_labels=([\w\?]+)_([\w\?]+)->", op.rest)
            return 1  # convs are negligible in these models
        mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
        if not mdims:
            return 1
        dims = [int(d) for d in mdims.group(1).split(",") if d]
        lhs_name = None
        m = re.match(r"([^)]*)\)", op.rest)
        operands = re.findall(r"%([\w\.\-]+)", m.group(1)) if m else []
        if operands:
            lhs_name = operands[0]
        lhs_type = self.defs.get(cname, {}).get(lhs_name)
        if lhs_type is None:
            return 1
        _, lhs_shape = _parse_shape(lhs_type)
        try:
            return math.prod(lhs_shape[d] for d in dims)
        except Exception:
            return 1

    _BYTES_SKIP = {
        "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
        "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
        # while carries are buffer-aliased (resident), not re-streamed; the
        # body's real traffic is counted inside the body computation
        "while", "conditional", "call", "optimization-barrier",
    }

    def _operand_names(self, op: _Op) -> List[str]:
        m = re.match(r"([^)]*)\)", op.rest)
        return re.findall(r"%([\w\.\-]+)", m.group(1)) if m else []

    _SLICE_OPS = ("dynamic-slice", "gather", "slice")

    def _fusion_param_bytes(self, callee: str, idx: int, full_bytes: int) -> int:
        """Bytes actually read from a fusion operand: if every use of the
        corresponding parameter inside the fused computation is a (dynamic-)
        slice/gather, count only the slice results (the fused loop reads the
        slice, not the whole stacked array)."""
        ops = self.comps.get(callee, [])
        pname = None
        for op in ops:
            if op.opcode == "parameter" and re.match(rf"\s*{idx}\b", op.rest):
                pname = op.name
                break
        if pname is None:
            return full_bytes
        uses = [op for op in ops if pname in self._operand_names(op)]
        if not uses:
            return 0
        if all(u.opcode in self._SLICE_OPS for u in uses):
            return sum(_shape_bytes(u.result_type) for u in uses)
        return full_bytes

    _TPU_FREE = {"convert", "bitcast", "copy", "parameter", "broadcast"}

    def _fusion_convert_only(self, callee: Optional[str]) -> bool:
        """A fusion whose body is only converts/copies would fuse into its
        dot consumer/producer on the TPU backend (the CPU backend
        materializes bf16<->f32 converts because it lacks native bf16 dots).
        Counted as free under the TPU-target cost model."""
        if not callee:
            return False
        ops = self.comps.get(callee, [])
        return bool(ops) and all(o.opcode in self._TPU_FREE for o in ops)

    def _see_through_converts(self, callee: str, name: str) -> str:
        """Follow single-operand convert/copy/bitcast chains backwards."""
        by_name = {o.name: o for o in self.comps.get(callee, [])}
        while name in by_name and by_name[name].opcode in ("convert", "copy",
                                                           "bitcast"):
            ops = self._operand_names(by_name[name])
            if len(ops) != 1:
                break
            name = ops[0]
        return name

    def _fusion_dus_param(self, callee: Optional[str]):
        """If the fused computation's root is (possibly convert-wrapped)
        dynamic-update-slice writing into one of the fusion's parameters,
        return (param_index, update bytes at the parameter dtype); else
        None. Models XLA's in-place aliased cache updates."""
        if not callee:
            return None
        ops = self.comps.get(callee, [])
        if not ops:
            return None
        by_name = {o.name: o for o in ops}
        root = ops[-1]
        rname = self._see_through_converts(callee, root.name)
        root = by_name.get(rname, root)
        if root.opcode != "dynamic-update-slice":
            return None
        opnds = self._operand_names(root)
        if len(opnds) < 2:
            return None
        dest = self._see_through_converts(callee, opnds[0])
        upd = opnds[1]
        upd_src = self._see_through_converts(callee, upd)
        pidx, pdtype, uidx = None, None, None
        for o in ops:
            if o.opcode != "parameter":
                continue
            m = re.match(r"\s*(\d+)", o.rest)
            idx = int(m.group(1)) if m else None
            if o.name == dest:
                pidx = idx
                pdtype = _parse_shape(o.result_type)[0]
            if o.name == upd_src:
                uidx = idx  # update fed straight from an operand: its read
                # is already covered by the 2x update-slice accounting
        if pidx is None:
            return None
        upd_t = self.defs.get(callee, {}).get(upd)
        if not upd_t:
            return (pidx, 0, uidx)
        _, upd_shape = _parse_shape(upd_t)
        # count the update at the destination param's dtype (the in-place
        # buffer's real width; converts around it are dot-feed artifacts)
        b = _DTYPE_BYTES.get(pdtype, 4) * math.prod(upd_shape or (1,))
        return (pidx, b, uidx)

    def hbm_bytes(self) -> float:
        """Materialization traffic: operand+result bytes of ops at fusion
        boundaries, x loop multipliers. Slice-aware: dynamic-slice / gather
        (including when fused) count only the transferred slice; in-place
        dynamic-update-slice / scatter count 2x the update size."""
        total = 0.0
        for cname, ops in self.comps.items():
            mult = self.mult.get(cname, 0.0)
            if mult == 0.0 or self.in_fusion.get(cname, False):
                continue
            for op in ops:
                if op.opcode in self._BYTES_SKIP:
                    continue
                out_b = _shape_bytes(op.result_type)
                operands = self._operand_names(op)
                types = [self.defs.get(cname, {}).get(o) for o in operands]
                sizes = [(_shape_bytes(t) if t else 0) for t in types]
                if op.opcode in ("dynamic-slice", "gather", "slice"):
                    total += 2 * out_b * mult  # read slice + write result
                    continue
                if op.opcode == "dynamic-update-slice":
                    upd = sizes[1] if len(sizes) > 1 else out_b
                    total += 2 * upd * mult  # in-place: read + write the slice
                    continue
                if op.opcode == "scatter":
                    upd = sizes[-1] if sizes else out_b
                    total += (3 * upd) * mult  # read idx+upd, rmw dest region
                    continue
                if op.opcode == "fusion":
                    callee = self._attr(op.rest, "calls")
                    if self._fusion_convert_only(callee):
                        continue  # TPU: fuses into the adjacent dot
                    # in-place pattern: fusion whose root is a dynamic-update-
                    # slice into a pass-through parameter (scan cache updates).
                    # XLA aliases the destination; only the slice moves.
                    dus_dest = self._fusion_dus_param(callee)
                    if dus_dest is not None:
                        dest_idx, upd_bytes, upd_idx = dus_dest
                        in_b = 0
                        for i, s in enumerate(sizes):
                            if i in (dest_idx, upd_idx):
                                continue  # aliased dest / counted update
                            in_b += self._fusion_param_bytes(callee, i, s)
                        total += (2 * upd_bytes + in_b) * mult
                        continue
                    in_b = 0
                    for i, s in enumerate(sizes):
                        if callee:
                            in_b += self._fusion_param_bytes(callee, i, s)
                        else:
                            in_b += s
                    total += (out_b + in_b) * mult
                    continue
                total += (out_b + sum(sizes)) * mult
        return total

    _COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                    "collective-permute")

    def _group_size(self, rest: str, default: int) -> int:
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([\d,]+)\}", rest)
        if m:
            return len(m.group(1).split(","))
        return default

    def collective_wire_bytes(self, n_devices: int) -> Tuple[float, Dict[str, float]]:
        """Ring-model wire bytes per device, by collective kind."""
        by_kind: Dict[str, float] = {}
        for cname, ops in self.comps.items():
            mult = self.mult.get(cname, 0.0)
            if mult == 0.0:
                continue
            for op in ops:
                kind = op.opcode.replace("-start", "")
                if kind not in self._COLLECTIVES:
                    continue
                out_b = _shape_bytes(op.result_type)
                g = self._group_size(op.rest, n_devices)
                if g <= 1:
                    continue
                if kind == "all-reduce":
                    wire = 2.0 * out_b * (g - 1) / g
                elif kind == "all-gather":
                    wire = out_b * (g - 1) / g
                elif kind == "reduce-scatter":
                    wire = out_b * (g - 1)  # input = out * g
                elif kind == "all-to-all":
                    wire = out_b * (g - 1) / g
                else:  # collective-permute
                    wire = out_b
                by_kind[kind] = by_kind.get(kind, 0.0) + wire * mult
        return sum(by_kind.values()), by_kind


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS (per assignment: 6*N*D train, fwd variants for serving)
# ---------------------------------------------------------------------------

def model_flops(cfg, cell) -> float:
    """Useful model FLOPs per step, whole job (not per device)."""
    N = cfg.param_count(active_only=True) - cfg.padded_vocab * cfg.d_model * (
        1 if cfg.tie_embeddings else 2)  # matmul params (embeddings excluded)
    B, S = cell.global_batch, cell.seq_len
    H, Hkv, Dh, L = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    lm_head = 2 * cfg.d_model * cfg.padded_vocab  # logits matmul per token

    if cell.kind == "train":
        tokens = B * S
        attn = 0.0
        if cfg.num_heads:
            n_attn = (cfg.num_layers // cfg.shared_attn_period
                      if cfg.family == "hybrid" else L)
            # qk+pv = 4*H*Dh flops per (token, context) pair; causal avg
            # context S/2; x3 for fwd+bwd
            attn = 3 * n_attn * 4 * H * Dh * (S / 2) * tokens
        return 6.0 * N * tokens + 3 * lm_head * tokens + attn
    if cell.kind == "prefill":
        tokens = B * S
        attn = 0.0
        if cfg.num_heads:
            n_attn = (cfg.num_layers // cfg.shared_attn_period
                      if cfg.family == "hybrid" else L)
            attn = n_attn * 4 * H * Dh * (S / 2) * tokens
        return 2.0 * N * tokens + lm_head * tokens + attn
    # decode: one token per sequence, attention over full cache
    attn = 0.0
    if cfg.num_heads:
        n_attn = (cfg.num_layers // cfg.shared_attn_period
                  if cfg.family == "hybrid" else L)
        attn = n_attn * 4 * H * Dh * S * B
    return 2.0 * N * B + lm_head * B + attn


def roofline_from_compiled(compiled, cfg, cell, mesh) -> dict:
    n_dev = math.prod(mesh.devices.shape)
    text = compiled.as_text()
    cm = HloCostModel(text)
    flops_dev = cm.dot_flops()
    bytes_dev = cm.hbm_bytes()
    wire_dev, by_kind = cm.collective_wire_bytes(n_dev)

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = wire_dev / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, cell)
    mf_dev = mf / n_dev
    bound = max(terms.values())
    return {
        "hlo_flops_per_dev": flops_dev,
        "hbm_bytes_per_dev": bytes_dev,
        "wire_bytes_per_dev": wire_dev,
        "wire_bytes_by_kind": by_kind,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops_total": mf,
        "model_flops_per_dev": mf_dev,
        "useful_flops_ratio": (mf_dev / flops_dev) if flops_dev else 0.0,
        # fraction of roofline: useful work per chip over the bound time
        "roofline_fraction": (mf_dev / PEAK_FLOPS) / bound if bound else 0.0,
        "step_time_lower_bound_s": bound,
    }

"""Analysis gate (CI): the static verifier must pass real SAVE output clean
and must catch seeded corruption by the exact advertised pass id.

Three fresh archives are produced the way deployments produce them —
``ServingEngine.save_archive`` for the exact (deployment-topology) and
stamped (placeholder capture-mesh) paths, ``TemplateDepot.put_archive`` for
the thin depot-backed path — and ``python -m repro.analysis.check`` must
find nothing in any of them (deep blob verification + IR lint included).
Then each corruption class from docs/architecture.md §11 is seeded into a
copy and must surface as its named finding id with exit code 2:

    truncated v2 header        -> container-structure
    bit-flipped template blob  -> blob-integrity
    unknown CaptureSpec tag    -> tags-schema
    RankDelta missing peer     -> rank-delta-coverage

Exit 0 iff every expectation holds. Runs in-process on CPU; the capture
mesh needs placeholder devices, so XLA_FLAGS is pinned before jax loads.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

import shutil  # noqa: E402
import tempfile  # noqa: E402

import jax  # noqa: E402

from repro.analysis.check import main as check_main  # noqa: E402
from repro.analysis.checker import (check_archive_file,  # noqa: E402
                                    check_container_bytes, check_depot,
                                    verify_for_load)
from repro.configs.registry import get_arch  # noqa: E402
from repro.core import Archive, TemplateDepot  # noqa: E402
from repro.launch.mesh import (ShardCtx, make_capture_mesh,  # noqa: E402
                               make_tp_mesh)
from repro.models.model import Model  # noqa: E402
from repro.serving.engine import ServingEngine  # noqa: E402

failures = []


def gate(name: str, ok: bool, detail: str = ""):
    print(f"[gate] {'ok  ' if ok else 'FAIL'} {name}  {detail}")
    if not ok:
        failures.append(name)


def build(mesh):
    eng = ServingEngine(Model(get_arch("smollm-360m").reduced(),
                              ShardCtx(mesh=mesh)),
                        max_batch=4, max_seq=32, bucket_mode="pow2")
    eng.load_weights(rng=jax.random.PRNGKey(0))
    return eng


tmp = tempfile.mkdtemp(prefix="foundry_analysis_gate_")
try:
    # ---- three real SAVE products must verify completely clean ----------
    exact_path = os.path.join(tmp, "exact.fndry")
    ar_exact, _ = build(None).save_archive(exact_path)
    gate("exact archive clean (CLI, deep+ir)",
         check_main([exact_path]) == 0)

    stamp_path = os.path.join(tmp, "stamped.fndry")
    mesh_cap = make_capture_mesh()
    with mesh_cap:
        build(mesh_cap).save_archive(stamp_path)
    gate("stamped (capture-mesh) archive clean (CLI, deep+ir)",
         check_main([stamp_path]) == 0)

    tp2_path = os.path.join(tmp, "tp2.fndry")
    mesh_tp2 = make_tp_mesh(2)
    with mesh_tp2:
        build(mesh_tp2).save_archive(tp2_path)
    gate("2-rank TP archive clean (CLI, deep+ir)",
         check_main([tp2_path]) == 0)

    depot = TemplateDepot(os.path.join(tmp, "depot"))
    depot.put_archive("exact", ar_exact)
    thin_path = os.path.join(depot.manifest_dir, "exact.fndry")
    gate("depot fsck clean", check_main([depot.root]) == 0)
    gate("thin archive clean through depot (CLI, deep, no-ir dedup)",
         check_main([thin_path, "--depot", depot.root, "--no-ir"]) == 0)
    fs, _ = check_depot(depot.root, deep=True)
    gate("depot deep re-hash clean", fs == [])

    # ---- corruption fixtures: named pass id + exit code 2 ---------------
    raw = open(exact_path, "rb").read()

    p = os.path.join(tmp, "c_trunc.fndry")
    open(p, "wb").write(raw[:12])
    got = {f.pass_id for f in check_archive_file(p)}
    gate("truncated header -> container-structure",
         got == {"container-structure"}, f"got {sorted(got)}")
    gate("truncated header exits 2", check_main([p]) == 2)

    _, info = check_container_bytes(raw, "gate")
    exe_hash = ar_exact.manifest["specs"]["decode"]["groups"][0][
        "executable_blob"]
    off, comp_len, _r = info.index[exe_hash]
    bad = bytearray(raw)
    bad[info.blob_base + off + comp_len // 2] ^= 0xFF
    p = os.path.join(tmp, "c_flip.fndry")
    open(p, "wb").write(bytes(bad))
    got = {f.pass_id for f in check_archive_file(p, ir=False)}
    gate("bit-flipped blob -> blob-integrity",
         "blob-integrity" in got, f"got {sorted(got)}")
    gate("bit-flipped blob exits 2", check_main([p, "--no-ir"]) == 2)

    a = Archive.load(exact_path)
    a.manifest["specs"]["decode"]["tags"]["kv_teleport"] = True
    got = {f.pass_id for f in verify_for_load(a)}
    gate("unknown tag -> tags-schema",
         got == {"tags-schema"}, f"got {sorted(got)}")
    p = os.path.join(tmp, "c_tags.fndry")
    a.save(p)
    gate("unknown tag exits 2", check_main([p, "--no-ir", "--no-deep"]) == 2)

    # the 2-rank TP archive: its RankDelta section has a peer table per
    # mesh axis to lose (the stamped capture is single-rank by design)
    a = Archive.load(tp2_path)
    a.manifest["rank_delta"]["capture_ranks"][1]["peer_groups"].pop("model")
    got = {f.pass_id for f in verify_for_load(a)}
    gate("RankDelta missing peer -> rank-delta-coverage",
         got == {"rank-delta-coverage"}, f"got {sorted(got)}")
    p = os.path.join(tmp, "c_rank.fndry")
    a.save(p)
    gate("RankDelta missing peer exits 2",
         check_main([p, "--no-ir", "--no-deep"]) == 2)

    # ---- telemetry gates: a real LOAD under full observability ----------
    # the exposition must lint clean, the trace must schema-check, and the
    # pipelined LOAD must have emitted its stage spans
    from repro.core import foundry_load, wait_for_background  # noqa: E402
    from repro.obs import metrics as obs_metrics  # noqa: E402
    from repro.obs import trace as obs_trace  # noqa: E402
    from repro.obs import lint_exposition, validate_trace  # noqa: E402
    import json  # noqa: E402

    obs_metrics.enable()
    trace_p = os.path.join(tmp, "load_trace.json")
    _, lrep, _ = foundry_load(Archive.load(exact_path), None,
                              trace_path=trace_p)
    wait_for_background(lrep)
    obs_metrics.disable()

    lint = lint_exposition(obs_metrics.render())
    gate("prometheus exposition lints clean", lint == [],
         f"{lint[:2]}" if lint else "")
    doc = json.load(open(trace_p))
    schema = validate_trace(doc)
    gate("chrome trace schema-checks clean", schema == [],
         f"{schema[:2]}" if schema else "")
    for span_name in ("load.fetch", "load.deserialize", "load.install"):
        gate(f"trace has {span_name} spans",
             bool(obs_trace.spans_named(doc, span_name)))
    obs_metrics.reset()
finally:
    shutil.rmtree(tmp, ignore_errors=True)

if failures:
    print(f"analysis gate: {len(failures)} expectation(s) failed: "
          f"{failures}")
    sys.exit(1)
print("analysis gate: all expectations held")

"""Docs link check (CI): every local markdown link resolves, every referenced
`src/repro/...` / `examples/...` / `benchmarks/...` path exists, and every
`benchmarks/fig*.py` is indexed in README.md."""
from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ["README.md", "docs/architecture.md", "ROADMAP.md", "CHANGES.md"]

failures = []

for doc in DOCS:
    path = os.path.join(ROOT, doc)
    if not os.path.exists(path):
        failures.append(f"{doc}: missing")
        continue
    text = open(path, encoding="utf-8").read()
    base = os.path.dirname(path)
    # markdown links to local files (skip http/anchors)
    for m in re.finditer(r"\[[^\]]*\]\(([^)#h][^)#]*)\)", text):
        target = os.path.normpath(os.path.join(base, m.group(1)))
        if not os.path.exists(target):
            failures.append(f"{doc}: broken link -> {m.group(1)}")
    # inline-code repo paths
    for m in re.finditer(
            r"`((?:src/repro|examples|benchmarks|tests|docs)/[\w./]+?\.(?:py|md))`",
            text):
        if not os.path.exists(os.path.join(ROOT, m.group(1))):
            failures.append(f"{doc}: referenced path missing -> {m.group(1)}")

readme = open(os.path.join(ROOT, "README.md"), encoding="utf-8").read()
for fig in sorted(glob.glob(os.path.join(ROOT, "benchmarks", "fig*.py"))):
    rel = os.path.relpath(fig, ROOT)
    if rel not in readme:
        failures.append(f"README.md: benchmark figure not indexed -> {rel}")

if failures:
    print("\n".join(failures))
    sys.exit(1)
print(f"docs-links: OK ({len(DOCS)} docs checked)")

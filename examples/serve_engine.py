"""End-to-end serving driver: batched requests through the full stack.

    PYTHONPATH=src python examples/serve_engine.py [--requests 24]

Exercises the production path on a small model: Foundry LOAD cold start,
continuous batching across a Poisson-ish arrival pattern, bucket resizing,
background exact-bucket swap-in, a mid-run simulated worker failure with
request re-queue, and a final TTFT/TPOT report.
"""
import argparse
import random
import time

import jax

from repro.configs.registry import get_arch
from repro.core import wait_for_background
from repro.models.model import Model
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--arch", default="smollm-360m")
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    rng = random.Random(0)

    def build():
        eng = ServingEngine(Model(cfg), max_batch=8, max_seq=96,
                            bucket_mode="pow2")
        eng.load_weights(rng=jax.random.PRNGKey(0))
        return eng

    # offline SAVE once
    print("== offline SAVE ==")
    archive, rep = build().save_archive(verbose=True)

    print("\n== online: LOAD + serve ==")
    eng = build()
    t0 = time.perf_counter()
    eng.cold_start_foundry(archive, background_exact=True)
    print(f"cold start: {(time.perf_counter() - t0) * 1e3:.1f} ms "
          f"({eng.programs.coverage()})")

    pending = [
        [rng.randrange(1, cfg.vocab_size) for _ in range(rng.randrange(2, 12))]
        for _ in range(args.requests)
    ]
    submitted = []
    steps = 0
    failed_once = False
    t_start = time.perf_counter()
    while pending or eng.scheduler.pending:
        # staggered arrivals: a couple of new requests per engine step
        for _ in range(min(len(pending), rng.randrange(0, 3))):
            submitted.append(eng.submit(pending.pop(), rng.randrange(4, 16)))
        eng.step()
        steps += 1
        if steps == 12 and not failed_once:
            print("  !! simulating worker failure (re-queue running work)")
            eng.simulate_worker_failure()
            failed_once = True
        if steps % 20 == 0:
            cov = eng.programs.coverage()
            print(f"  step {steps:4d}: running={len(eng.scheduler.running)} "
                  f"queued={len(eng.scheduler.queue)} "
                  f"done={len(eng.scheduler.done)} "
                  f"bucket={eng.pool.cur_bucket} "
                  f"exact_loaded={cov['exact_loaded']}")
        if steps > 5000:
            raise RuntimeError("engine did not drain")
    wall = time.perf_counter() - t_start

    done = eng.scheduler.done
    ttfts = [r.ttft for r in done if r.ttft is not None]
    toks = sum(len(r.generated) for r in done)
    print(f"\nserved {len(done)} requests, {toks} tokens in {wall:.2f}s "
          f"({steps} engine steps)")
    print(f"TTFT p50/p95: {sorted(ttfts)[len(ttfts) // 2] * 1e3:.1f} / "
          f"{sorted(ttfts)[int(len(ttfts) * 0.95)] * 1e3:.1f} ms")
    print(f"dispatch stats: {eng.programs.stats}")
    retried = sum(1 for r in done if r.retries)
    print(f"requests recovered from worker failure: {retried}")
    assert len(done) == args.requests
    wait_for_background(eng._load_report)
    print("background exact buckets:", eng.programs.coverage()["exact_loaded"])


if __name__ == "__main__":
    main()

"""Dynamic parallelism hot-switch via Foundry archives (paper §2.1, §4.2.2).

    PYTHONPATH=src python examples/parallelism_switch.py

Parallelism reconfiguration (EP2 -> EP4 style) normally forces a full graph
recapture; with Foundry, each parallelism config has a pre-materialized
archive and switching costs one LOAD. This example runs on 8 placeholder
devices: it serves on a (2,4) data x model mesh, then hot-switches the same
engine *process* to a (4,2) mesh — in-flight requests keep their generated
prefixes (the thing process-level checkpoint/restore cannot do, §2.3) and
finish on the new mesh.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402

from repro.configs.registry import get_arch  # noqa: E402
from repro.launch.mesh import ShardCtx, make_mesh  # noqa: E402
from repro.models.model import Model  # noqa: E402
from repro.serving.engine import ServingEngine  # noqa: E402


def build_engine(mesh):
    cfg = get_arch("smollm-360m").reduced()
    model = Model(cfg, ShardCtx(mesh=mesh))
    eng = ServingEngine(model, max_batch=8, max_seq=64, bucket_mode="pow2")
    return eng


def main():
    mesh_a = make_mesh((2, 4), ("data", "model"))
    mesh_b = make_mesh((4, 2), ("data", "model"))

    # offline: one archive per parallelism config (single capture host!)
    print("== offline SAVE for both parallelism configs ==")
    archives = {}
    for name, mesh in (("2x4", mesh_a), ("4x2", mesh_b)):
        with mesh:
            eng = build_engine(mesh)
            eng.load_weights(rng=jax.random.PRNGKey(0))
            archives[name], rep = eng.save_archive(verbose=True)
            params = eng.params  # weights shared across configs (resharded)

    print("\n== serve on 2x4, then hot-switch to 4x2 ==")
    with mesh_a:
        eng = build_engine(mesh_a)
        eng.load_weights(rng=jax.random.PRNGKey(0))
        t0 = time.perf_counter()
        eng.cold_start_foundry(archives["2x4"], background_exact=False)
        print(f"cold start (2x4): {(time.perf_counter() - t0) * 1e3:.1f} ms")
        reqs = [eng.submit([3 + i, 5, 7], 10) for i in range(5)]
        for _ in range(4):
            eng.step()
        prefix_lens = {r.req_id: len(r.generated) for r in reqs}
        print(f"in-flight after 4 steps: "
              f"{[(r.req_id, len(r.generated)) for r in reqs]}")

    # ---- the switch: new mesh, new archive, SAME request state ----
    t0 = time.perf_counter()
    with mesh_b:
        eng2 = build_engine(mesh_b)
        eng2.load_weights(rng=jax.random.PRNGKey(0))  # reshard (RDMA-class)
        eng2.cold_start_foundry(archives["4x2"], background_exact=False)
        # migrate scheduler state: requests keep their generated prefixes
        eng2.scheduler = eng.scheduler
        for r in list(eng2.scheduler.running.values()):
            eng2.scheduler.requeue_on_failure(r)
            r.retries = 0  # a planned switch is not a failure
        t_switch = time.perf_counter() - t0
        print(f"parallelism switch to 4x2: {t_switch * 1e3:.1f} ms "
              f"(graph LOAD, no recapture)")
        eng2.run_until_drained()

    done = {r.req_id: r for r in eng2.scheduler.done}
    assert len(done) == 5
    kept = all(len(done[i].generated) >= prefix_lens[i] for i in done)
    print(f"all 5 requests finished on the new mesh; "
          f"prefixes preserved: {kept}")
    for r in sorted(done.values(), key=lambda r: r.req_id):
        print(f"  req {r.req_id}: {len(r.generated)} tokens")


if __name__ == "__main__":
    main()

"""Dynamic parallelism hot-switch from ONE single-capture archive
(paper §2.1, §4.2.2, §4.3).

    PYTHONPATH=src python examples/parallelism_switch.py

Parallelism reconfiguration (EP2 -> EP4 style) normally forces a full graph
recapture. With Foundry rank stamping, a SINGLE archive — captured offline on
a 1-device topology — serves *every* shape-compatible deployment: LOAD
reuses the archived template program byte-identically and stamps only
rank-dependent communication state (peer tables, mesh coordinates,
rank-relative buffer offsets) for the deployment mesh.

This example runs on 8 placeholder devices: one offline SAVE on the
single-device capture mesh, then the same engine *process* serves a (2,4)
data x model mesh and hot-switches to a (4,2) mesh — both cold starts are
rank-stamped LOADs of the one archive (``fallback_compiles == 0``), and
in-flight requests keep their generated prefixes across the switch (the
thing process-level checkpoint/restore cannot do, §2.3).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402

from repro.configs.registry import get_arch  # noqa: E402
from repro.launch.mesh import ShardCtx, make_capture_mesh, make_mesh  # noqa: E402
from repro.models.model import Model  # noqa: E402
from repro.serving.engine import ServingEngine  # noqa: E402


def build_engine(mesh):
    cfg = get_arch("smollm-360m").reduced()
    model = Model(cfg, ShardCtx(mesh=mesh))
    eng = ServingEngine(model, max_batch=8, max_seq=64, bucket_mode="pow2")
    return eng


def main():
    mesh_cap = make_capture_mesh()                 # 1 device, offline
    mesh_a = make_mesh((2, 4), ("data", "model"))  # deployment A
    mesh_b = make_mesh((4, 2), ("data", "model"))  # deployment B

    # offline: ONE capture on ONE device serves every deployment shape
    print("== offline SAVE on the single-device capture mesh ==")
    with mesh_cap:
        eng = build_engine(mesh_cap)
        eng.load_weights(rng=jax.random.PRNGKey(0))
        archive, rep = eng.save_archive(verbose=True)

    print("\n== serve on 2x4 (rank-stamped LOAD), then hot-switch to 4x2 ==")
    with mesh_a:
        eng = build_engine(mesh_a)
        eng.load_weights(rng=jax.random.PRNGKey(0))
        t0 = time.perf_counter()
        cs = eng.cold_start_foundry(archive, background_exact=False)
        print(f"cold start (2x4): {(time.perf_counter() - t0) * 1e3:.1f} ms "
              f"mode={cs.mode} rank_stamped={cs.rank_stamped} "
              f"fallback_compiles={cs.fallback_compiles}")
        assert cs.mode == "foundry-stamped" and cs.fallback_compiles == 0
        reqs = [eng.submit([3 + i, 5, 7], 10) for i in range(5)]
        for _ in range(4):
            eng.step()
        prefix_lens = {r.req_id: len(r.generated) for r in reqs}
        print(f"in-flight after 4 steps: "
              f"{[(r.req_id, len(r.generated)) for r in reqs]}")

    # ---- the switch: new mesh, SAME archive, SAME request state ----
    t0 = time.perf_counter()
    with mesh_b:
        eng2 = build_engine(mesh_b)
        eng2.load_weights(rng=jax.random.PRNGKey(0))  # reshard (RDMA-class)
        cs2 = eng2.cold_start_foundry(archive, background_exact=False)
        assert cs2.mode == "foundry-stamped" and cs2.fallback_compiles == 0
        # migrate scheduler state: requests keep their generated prefixes
        eng2.scheduler = eng.scheduler
        for r in list(eng2.scheduler.running.values()):
            eng2.scheduler.requeue_on_failure(r)
            r.retries = 0  # a planned switch is not a failure
        t_switch = time.perf_counter() - t0
        print(f"parallelism switch to 4x2: {t_switch * 1e3:.1f} ms "
              f"(rank-stamped LOAD of the same archive, no recapture; "
              f"rank_stamped={cs2.rank_stamped})")
        eng2.run_until_drained()

    done = {r.req_id: r for r in eng2.scheduler.done}
    assert len(done) == 5
    kept = all(len(done[i].generated) >= prefix_lens[i] for i in done)
    print(f"all 5 requests finished on the new mesh; "
          f"prefixes preserved: {kept}")
    for r in sorted(done.values(), key=lambda r: r.req_id):
        print(f"  req {r.req_id}: {len(r.generated)} tokens")


if __name__ == "__main__":
    main()

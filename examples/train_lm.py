"""Train a small LM end-to-end with the production training stack.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses the real substrate: Model zoo config (smollm family, width reduced for
CPU), AdamW with fp32 masters, synthetic-but-learnable data pipeline,
async checkpointing every 100 steps, straggler watchdog, and a kill+resume
demonstration (restart is bitwise-identical thanks to counter-based data).
"""
import argparse
import dataclasses
import tempfile

import jax

from repro.configs.registry import get_arch
from repro.launch.mesh import ShardCtx
from repro.models.model import Model
from repro.training.checkpoint import Checkpointer
from repro.training.data import DataConfig, SyntheticLMData
from repro.training.elastic import StragglerWatchdog
from repro.training.optimizer import OptConfig
from repro.training.train_loop import run_train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    # a ~15M-param member of the smollm family (CPU-trainable)
    cfg = dataclasses.replace(
        get_arch("smollm-360m"), name="smollm-cpu", num_layers=4,
        d_model=256, num_heads=4, num_kv_heads=2, head_dim=64, d_ff=768,
        vocab_size=2048, param_dtype="float32", remat=False)
    model = Model(cfg, ShardCtx(mesh=None))
    n_params = sum(l.size for l in jax.tree.leaves(model.param_shapes()))
    print(f"model: {cfg.name}, {n_params / 1e6:.1f}M params")

    opt = OptConfig(lr=3e-3, weight_decay=0.01)
    data = SyntheticLMData(DataConfig(cfg.vocab_size, args.batch, args.seq,
                                      seed=11))
    ckdir = tempfile.mkdtemp(prefix="train_lm_ckpt_")
    ck = Checkpointer(ckdir, keep=2)
    wd = StragglerWatchdog(on_straggler=lambda i, dt, med: print(
        f"  !! step {i} straggler: {dt * 1e3:.0f}ms vs median {med * 1e3:.0f}ms"))

    class CkptShim:
        def save(self, state, step):
            ck.save(state, step, extra={"data": data.state_dict()},
                    async_=True)
            print(f"  -> async checkpoint @ step {step}")

    state, hist = run_train_loop(
        model, opt, iter(data), num_steps=args.steps,
        rng=jax.random.PRNGKey(0), log_every=25,
        checkpointer=CkptShim(), checkpoint_every=100, watchdog=wd)
    ck.wait()

    first, last = hist[0][1], hist[-1][1]
    print(f"\nloss: {first:.3f} -> {last:.3f} "
          f"({'DECREASED' if last < first else 'DID NOT DECREASE'})")

    # kill + resume: restore the latest checkpoint and continue
    step0 = ck.latest_step()
    print(f"\nsimulating preemption; resuming from checkpoint @ {step0}")
    from repro.training.train_loop import train_state_specs
    restored, extra = ck.restore(like=train_state_specs(model, opt))
    data2 = SyntheticLMData(DataConfig(cfg.vocab_size, args.batch, args.seq,
                                       seed=11))
    data2.load_state_dict(extra["data"])
    state2, hist2 = run_train_loop(
        model, opt, iter(data2), num_steps=args.steps, state=restored,
        log_every=25, watchdog=None)
    print(f"resumed loss @ {args.steps}: {hist2[-1][1]:.3f} "
          f"(direct run: {last:.3f})")
    assert abs(hist2[-1][1] - last) < 1e-3, "resume diverged"
    print("restart consistency: OK")


if __name__ == "__main__":
    main()

"""Model zoo: many models, one depot, scale-to-zero serving.

    PYTHONPATH=src python examples/model_zoo.py

SAVEs two reduced models into one content-addressed TemplateDepot (blobs
shared across archives are stored once), then serves both behind a
ModelRouter front door: the hot model rotates, the idle model drains to
ZERO replicas (engine + KV pool released), and the next request for it
reactivates a fresh fleet from the depot in milliseconds — with token
streams identical to a never-deactivated engine.
"""
import argparse
import os
import tempfile
import time

import jax

from repro.configs.registry import get_arch
from repro.core import TemplateDepot
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.fleet import AutoscalePolicy
from repro.serving.router import ModelPolicy, ModelRouter

MODELS = ["smollm-360m", "qwen3-14b"]


def make_factory(arch: str):
    cfg = get_arch(arch).reduced()

    def factory():
        eng = ServingEngine(Model(cfg), max_batch=4, max_seq=48,
                            bucket_mode="pow2")
        eng.load_weights(rng=jax.random.PRNGKey(0))
        return eng
    return factory


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--depot", default=None,
                    help="depot dir (default: fresh temp dir)")
    args = ap.parse_args()
    root = args.depot or os.path.join(tempfile.mkdtemp(), "depot")

    # ---- offline: SAVE each model once, into ONE shared depot ----
    depot = TemplateDepot(root)
    for name in MODELS:
        if name not in depot:
            ar, _ = make_factory(name)().save_archive()
            depot.put_archive(name, ar)
    st = depot.stats()
    print(f"depot {root}: {st['archives']} archives share {st['blobs']} "
          f"blobs ({st['logical_blobs']} referenced), "
          f"dedup {st['dedup_ratio']:.2f}x, "
          f"{st['physical_comp_bytes'] / 1e6:.2f} MB on disk")

    # ---- reference streams from never-deactivated engines ----
    prompt = [5, 9, 2]
    ref = {}
    for name in MODELS:
        eng = make_factory(name)()
        eng.cold_start_foundry(depot.open(name), background_exact=False)
        r = eng.submit(prompt, 6)
        eng.run_until_drained()
        ref[name] = r.generated

    # ---- online: the gateway ----
    router = ModelRouter(verbose=True)
    for name in MODELS:
        router.add_model(
            name, make_factory(name), archive=depot.open(name),
            policy=ModelPolicy(
                autoscale=AutoscalePolicy(min_replicas=1, max_replicas=2,
                                          target_inflight_per_replica=8,
                                          scale_down_idle_ticks=6),
                idle_ticks_to_zero=40))

    # popularity shift: each model is hot twice, with a quiet gap after
    # each phase long enough that the idle model drains to zero — so the
    # second round deterministically reactivates from the depot
    phases = [(name, 6) for _ in range(2) for name in MODELS]
    router.run_phases(phases, seed=0, gap_ticks=60)
    rep = router.report()

    for name in MODELS:
        m = rep.models[name]
        acts = ", ".join(f"{t * 1e3:.0f}ms" for t in m["activation_ready_s"])
        print(f"{name}: {m['activations']} activations "
              f"({m['deactivations']} scale-to-zero) ready in [{acts}]; "
              f"{m['n_done']} served, ttft_p50="
              f"{m['ttft_p50_s'] * 1e3:.0f}ms")
        assert m["activations"] >= 2, f"{name} never reactivated"
        assert m["fallback_compiles"] == 0
        assert m["background_errors"] == 0

    # token identity across the deactivate -> reactivate cycle
    for name in MODELS:
        out = router.submit(name, prompt, 6)
        t0 = time.perf_counter()
        while out.state.value not in ("done", "failed"):
            if router.tick() == 0:
                time.sleep(0.001)
            if time.perf_counter() - t0 > 300:
                raise RuntimeError(f"{name} request wedged "
                                   f"(state={out.state.value})")
        assert out.generated == ref[name], f"{name} diverged after reactivation"
    router.deactivate_all()  # join LOAD background workers: clean teardown
    print(f"peak resident replicas: {rep.peak_resident_replicas} "
          f"(vs {len(MODELS)}+ always-resident)")
    print("token identity across scale-to-zero: OK")


if __name__ == "__main__":
    main()

"""Quickstart: Foundry SAVE -> LOAD -> serve, in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a small LM, captures its decode graphs offline (SAVE), restarts a
fresh engine from the archive (LOAD, ~ms instead of the full capture), and
generates tokens — verifying they match the natively-captured engine.
"""
import time

import jax

from repro.configs.registry import get_arch
from repro.models.model import Model
from repro.serving.engine import ServingEngine


def build_engine():
    cfg = get_arch("qwen3-14b").reduced()   # the paper's model, reduced
    eng = ServingEngine(Model(cfg), max_batch=8, max_seq=64,
                        bucket_mode="pow2")
    eng.load_weights(rng=jax.random.PRNGKey(0))
    return eng


def main():
    # ---- offline: SAVE (one-time, off the serving critical path) ----
    eng = build_engine()
    archive, rep = eng.save_archive("/tmp/quickstart.fndry", verbose=True)
    print(f"archive: {archive.blob_bytes() / 1e6:.2f} MB blobs, "
          f"{rep['specs']['decode']['n_templates']} templates for "
          f"{rep['specs']['decode']['n_buckets']} buckets\n")

    # ---- baseline: vanilla cold start (full capture) ----
    jax.clear_caches()
    eng_v = build_engine()
    t0 = time.perf_counter()
    eng_v.cold_start_vanilla()
    t_vanilla = time.perf_counter() - t0
    for p in ([1, 2, 3], [9, 8]):
        eng_v.submit(p, 8)
    eng_v.run_until_drained()
    ref = [r.generated for r in eng_v.scheduler.done]
    print(f"vanilla cold start: {t_vanilla:.2f}s; generated {ref}")

    # ---- Foundry: LOAD from archive ----
    jax.clear_caches()
    eng_f = build_engine()
    t0 = time.perf_counter()
    eng_f.cold_start_foundry(archive, background_exact=False)
    t_foundry = time.perf_counter() - t0
    for p in ([1, 2, 3], [9, 8]):
        eng_f.submit(p, 8)
    eng_f.run_until_drained()
    got = [r.generated for r in eng_f.scheduler.done]
    print(f"foundry cold start: {t_foundry * 1e3:.1f}ms "
          f"({100 * (1 - t_foundry / t_vanilla):.1f}% reduction); "
          f"generated {got}")
    assert got == ref, "restored engine diverged!"
    print("token identity: OK")


if __name__ == "__main__":
    main()

"""Unit tests for the serving KV/state pool: slot lifecycle, bucket
resize/compaction, structural batch-dim detection across model families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.models.model import Model
from repro.serving.kvcache import KVCachePool


def make_pool(arch="smollm-360m", max_batch=8, max_seq=32):
    cfg = get_arch(arch).reduced()
    m = Model(cfg)
    buckets = [1, 2, 4, 8]

    def bucket_of(n):
        import bisect
        return buckets[min(bisect.bisect_left(buckets, n), len(buckets) - 1)]
    return KVCachePool(m, max_batch, max_seq, bucket_of), m


@pytest.mark.parametrize("arch", ["smollm-360m", "falcon-mamba-7b",
                                  "zamba2-2.7b", "moonshot-v1-16b-a3b"])
def test_batch_dims_detected_structurally(arch):
    pool, m = make_pool(arch)
    specs = jax.tree.leaves(m.cache_specs(3, 32))
    for bd, sd in zip(pool._bdims, specs):
        if bd is not None:
            assert sd.shape[bd] == 3  # the probe batch size


def test_acquire_grows_bucket_release_shrinks():
    pool, _ = make_pool()
    slots = [pool.acquire(i) for i in range(5)]
    assert pool.cur_bucket == 8  # 5 active -> bucket 8
    assert pool.n_active == 5
    for s in sorted(slots[1:], reverse=True):
        pool.release(s)
    assert pool.n_active == 1
    assert pool.cur_bucket <= 2  # hysteresis-shrunk


def test_release_compacts_and_reports_moved():
    pool, _ = make_pool()
    a, b, c = pool.acquire(10), pool.acquire(11), pool.acquire(12)
    pool.release(a)  # last active (req 12) moves into slot a
    assert pool.slots[a] == 12
    assert pool.n_active == 2


def test_lengths_follow_slot_moves():
    pool, m = make_pool()
    a, b = pool.acquire(0), pool.acquire(1)
    pool.cache["lengths"] = pool.cache["lengths"].at[b].set(7)
    pool.release(a)  # b's row moves into slot a
    assert int(pool.cache["lengths"][a]) == 7


def test_resize_preserves_content():
    pool, m = make_pool()
    a = pool.acquire(0)
    pool.cache["lengths"] = pool.cache["lengths"].at[a].set(5)
    for i in range(1, 4):
        pool.acquire(i)  # grows bucket
    assert int(pool.cache["lengths"][a]) == 5


def test_pool_exhaustion_raises():
    pool, _ = make_pool(max_batch=2)
    pool.bucket_of = lambda n: 2
    pool._resize(2)
    pool.acquire(0)
    pool.acquire(1)
    with pytest.raises(RuntimeError):
        pool.acquire(2)

"""TemplateDepot: content-addressed cross-archive dedup, ref-counted GC,
thin (depot-backed) archives, persistence, and the depot-wide fetch-once
guarantee under concurrency (core/depot.py)."""
import os
import threading

import jax
import pytest

from repro.configs.registry import get_arch
from repro.core import Archive, TemplateDepot
from repro.models.model import Model
from repro.serving.engine import ServingEngine


def synth_archive(tag: str, shared: bytes) -> Archive:
    ar = Archive(manifest={"meta": {"tag": tag}})
    ar.add_blob(shared)
    ar.add_blob(f"{tag}-private".encode() * 300)
    return ar


@pytest.fixture()
def depot(tmp_path):
    return TemplateDepot(str(tmp_path / "depot"))


def test_dedup_and_stats(depot):
    shared = b"shared-template" * 500
    depot.put_archive("a", synth_archive("a", shared))
    depot.put_archive("b", synth_archive("b", shared))
    st = depot.stats()
    assert st["archives"] == 2
    assert st["blobs"] == 3           # shared stored once
    assert st["logical_blobs"] == 4   # referenced twice
    assert st["dedup_ratio"] > 1.0
    # blob files actually on disk, one per unique hash
    assert len(os.listdir(depot.blob_dir)) == 3


def test_refcounted_gc(depot):
    shared = b"shared-template" * 500
    a = synth_archive("a", shared)
    depot.put_archive("a", a)
    depot.put_archive("b", synth_archive("b", shared))
    shared_hash = next(h for h in a.blobs
                       if a.get_blob(h) == shared)
    depot.remove_archive("a")
    out = depot.gc()
    assert out["deleted_blobs"] == 1  # only a's private blob
    # the shared blob survives (b still references it) and b loads in full
    reopened = depot.open("b")
    assert reopened.get_blob(shared_hash) == shared
    assert depot.stats()["archives"] == 1
    with pytest.raises(KeyError):
        depot.open("a")
    # removing the last referent frees everything
    depot.remove_archive("b")
    depot.gc()
    assert depot.stats()["blobs"] == 0
    assert os.listdir(depot.blob_dir) == []


def test_thin_archive_roundtrip(tmp_path, depot):
    ar = synth_archive("thin", b"payload" * 1000)
    path = str(tmp_path / "thin.fndry")
    size = ar.save(path, depot=depot)
    # the thin file holds the header only — far smaller than the blobs
    assert size < sum(len(ar.get_blob(h)) for h in ar.blobs)
    back = Archive.load(path, depot=depot)
    assert back.manifest == ar.manifest
    for h in ar.blobs:
        assert back.get_blob(h) == ar.get_blob(h)
    # without the depot the file must refuse loudly, not half-load
    with pytest.raises(ValueError, match="depot"):
        Archive.load(path)


def test_persistence_across_reopen(tmp_path):
    root = str(tmp_path / "depot")
    d1 = TemplateDepot(root)
    d1.put_archive("a", synth_archive("a", b"shared" * 400))
    st1 = d1.stats()
    d2 = TemplateDepot(root)  # fresh object, index re-read from disk
    assert d2.archives() == ["a"]
    st2 = d2.stats()
    assert st2["blobs"] == st1["blobs"]
    assert st2["logical_raw_bytes"] == st1["logical_raw_bytes"]
    a = d2.open("a")
    assert a.manifest["meta"]["tag"] == "a"
    for h in list(d2.store):
        assert d2.store[h]  # every indexed blob fetchable + hash-verified


def test_depot_wide_fetch_once_concurrent(depot):
    """Two archives sharing blobs, opened and hammered by 8 threads: each
    unique blob is read from disk at most once depot-wide (the two-fleet
    shared-depot guarantee rides on this)."""
    shared = b"shared-template" * 500
    depot.put_archive("a", synth_archive("a", shared))
    depot.put_archive("b", synth_archive("b", shared))
    reads = []
    orig = type(depot.store._source).read_hash
    depot.store._source.read_hash = (
        lambda h, _o=orig, _s=depot.store._source: (reads.append(h),
                                                    _o(_s, h))[1])
    a, b = depot.open("a"), depot.open("b")
    errs = []

    def hammer(ar):
        try:
            for h in list(depot.store):
                if h in ar.blobs:
                    ar.get_blob(h)
        except Exception as e:  # pragma: no cover - surfaced via assert
            errs.append(e)

    threads = [threading.Thread(target=hammer, args=(ar,))
               for _ in range(4) for ar in (a, b)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert len(reads) == len(set(reads)) == 3, \
        f"{len(reads)} disk reads for 3 unique blobs (dup fetches)"


def test_engine_save_load_through_depot(tmp_path):
    """Full stack: engine SAVE -> depot -> LOAD -> serve, token-identical
    to a vanilla engine and with zero critical-path compiles."""
    cfg = get_arch("smollm-360m").reduced()

    def factory():
        eng = ServingEngine(Model(cfg), max_batch=2, max_seq=32,
                            bucket_mode="pow2")
        eng.load_weights(rng=jax.random.PRNGKey(3))
        return eng

    depot = TemplateDepot(str(tmp_path / "depot"))
    ar, _ = factory().save_archive()
    depot.put_archive("smol", ar)

    ref_eng = factory()
    ref_eng.cold_start_vanilla()
    ref = ref_eng.submit([4, 4, 1], 5)
    ref_eng.run_until_drained()

    eng = factory()
    rep = eng.cold_start_foundry(depot.open("smol"), background_exact=False)
    assert rep.fallback_compiles == 0
    out = eng.submit([4, 4, 1], 5)
    eng.run_until_drained()
    assert out.generated == ref.generated


def test_canonical_exports_dedup_across_saves(tmp_path):
    """Re-saving the same capture set (fresh engine, different call site)
    must re-use the export blobs: canonical serialization strips the MLIR
    debug locations that otherwise make every save byte-unique
    (core/materialize.py canonical_export_bytes)."""
    cfg = get_arch("smollm-360m").reduced()

    def factory():
        eng = ServingEngine(Model(cfg), max_batch=2, max_seq=32,
                            bucket_mode="pow2")
        eng.load_weights(rng=jax.random.PRNGKey(3))
        return eng

    a1, _ = factory().save_archive()
    jax.clear_caches()
    a2, _ = factory().save_archive()
    shared = set(a1.blobs) & set(a2.blobs)
    # every per-bucket StableHLO export dedups; only the compiled template
    # executable (nondeterministic XLA binary metadata) may differ
    n_buckets = len(factory().buckets)
    assert len(shared) >= n_buckets, \
        f"only {len(shared)} shared blobs across identical saves"

    depot = TemplateDepot(str(tmp_path / "depot"))
    depot.put_archive("v1", a1)
    depot.put_archive("v2", a2)
    assert depot.stats()["dedup_ratio"] > 1.0

"""Per-kernel validation: shape/dtype sweeps in interpret mode vs the pure-jnp
oracles in repro.kernels.ref, plus kernel-catalog behaviour."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernel_catalog import KernelCatalog
from repro.kernels import ops, ref
from repro.kernels.decode_attention import (decode_attention_kernel,
                                            decode_attention_paged_kernel)
from repro.kernels.moe_gemm import moe_grouped_gemm_kernel
from repro.kernels.ssm_scan import mamba1_scan_kernel

RTOL = {jnp.float32: 1e-5, jnp.bfloat16: 2e-2}
ATOL = {jnp.float32: 1e-5, jnp.bfloat16: 2e-2}


def _tols(dtype):
    return dict(rtol=RTOL[dtype], atol=ATOL[dtype])


class TestDecodeAttention:
    @pytest.mark.parametrize("B,S,H,Hkv,Dh,blk", [
        (2, 256, 8, 2, 64, 128),
        (1, 512, 4, 4, 128, 256),   # MHA
        (3, 128, 8, 1, 64, 128),    # MQA
        (2, 256, 16, 4, 128, 256),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, S, H, Hkv, Dh, blk, dtype):
        k = jax.random.PRNGKey(0)
        ks = jax.random.split(k, 4)
        q = jax.random.normal(ks[0], (B, H, Dh), dtype)
        kc = jax.random.normal(ks[1], (B, S, Hkv, Dh), dtype)
        vc = jax.random.normal(ks[2], (B, S, Hkv, Dh), dtype)
        lengths = jax.random.randint(ks[3], (B,), 1, S - 1)
        out = decode_attention_kernel(q, kc, vc, lengths, blk=blk,
                                      interpret=True)
        want = ref.decode_attention_ref(q, kc, vc, lengths)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            **_tols(dtype))

    def test_mask_respects_length(self):
        """Tokens beyond lengths[b] must not affect the output."""
        B, S, H, Hkv, Dh = 1, 128, 4, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (B, H, Dh), jnp.float32)
        kc = jax.random.normal(ks[1], (B, S, Hkv, Dh), jnp.float32)
        vc = jax.random.normal(ks[2], (B, S, Hkv, Dh), jnp.float32)
        lengths = jnp.asarray([40])
        out1 = decode_attention_kernel(q, kc, vc, lengths, blk=64)
        kc2 = kc.at[:, 41:].set(999.0)
        vc2 = vc.at[:, 41:].set(-999.0)
        out2 = decode_attention_kernel(q, kc2, vc2, lengths, blk=64)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=1e-6)


class TestPagedDecodeAttention:
    """Block-table indirected flash-decode vs the gather-then-attend oracle
    and the contiguous kernel (the two must agree on identical logical
    content regardless of physical block placement)."""

    @staticmethod
    def _rand_pool(key, B, MB, bs, Hkv, Dh, dtype, n_spare=3):
        """Pool + per-sequence tables of distinct physical blocks, shuffled
        so logical order != physical order; block 0 reserved scratch."""
        NB = 1 + B * MB + n_spare
        ks = jax.random.split(key, 3)
        kp = jax.random.normal(ks[0], (NB, bs, Hkv, Dh), dtype)
        vp = jax.random.normal(ks[1], (NB, bs, Hkv, Dh), dtype)
        perm = np.asarray(jax.random.permutation(ks[2], NB - 1)) + 1
        tables = jnp.asarray(perm[:B * MB].reshape(B, MB), jnp.int32)
        return kp, vp, tables

    @pytest.mark.parametrize("B,MB,bs,H,Hkv,Dh", [
        (2, 4, 64, 8, 2, 64),
        (1, 2, 256, 4, 4, 128),   # MHA
        (3, 8, 16, 8, 1, 64),     # MQA, small blocks
        (2, 4, 64, 16, 4, 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, MB, bs, H, Hkv, Dh, dtype):
        ks = jax.random.split(jax.random.PRNGKey(10), 3)
        q = jax.random.normal(ks[0], (B, H, Dh), dtype)
        kp, vp, tables = self._rand_pool(ks[1], B, MB, bs, Hkv, Dh, dtype)
        lengths = jax.random.randint(ks[2], (B,), 1, MB * bs - 1)
        out = decode_attention_paged_kernel(q, kp, vp, tables, lengths,
                                            interpret=True)
        want = ref.decode_attention_paged_ref(q, kp, vp, tables, lengths)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            **_tols(dtype))

    def test_matches_contiguous_kernel_on_gathered_cache(self):
        B, MB, bs, H, Hkv, Dh = 2, 4, 64, 8, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(11), 3)
        q = jax.random.normal(ks[0], (B, H, Dh), jnp.float32)
        kp, vp, tables = self._rand_pool(ks[1], B, MB, bs, Hkv, Dh,
                                         jnp.float32)
        lengths = jnp.asarray([100, 255])
        paged = decode_attention_paged_kernel(q, kp, vp, tables, lengths)
        kd = kp[tables].reshape(B, MB * bs, Hkv, Dh)
        vd = vp[tables].reshape(B, MB * bs, Hkv, Dh)
        dense = decode_attention_kernel(q, kd, vd, lengths, blk=bs)
        np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                                   rtol=1e-5, atol=1e-5)

    def test_shared_prefix_blocks_attend_identically(self):
        """Two sequences whose tables alias the SAME physical prefix blocks
        (a radix prefix-cache hit) must each see that prefix exactly as if
        they owned a private copy."""
        B, MB, bs, H, Hkv, Dh = 2, 4, 32, 4, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(12), 3)
        q = jax.random.normal(ks[0], (B, H, Dh), jnp.float32)
        kp, vp, _ = self._rand_pool(ks[1], B, MB, bs, Hkv, Dh, jnp.float32,
                                    n_spare=8)
        # seqs share physical blocks 1,2 for their first two logical blocks
        shared = jnp.asarray([[1, 2, 3, 4], [1, 2, 5, 6]], jnp.int32)
        lengths = jnp.asarray([MB * bs - 1, MB * bs - 1])
        aliased = decode_attention_paged_kernel(q, kp, vp, shared, lengths)
        # private copies of the same content at different physical blocks
        kp2 = kp.at[7].set(kp[1]).at[8].set(kp[2])
        vp2 = vp.at[7].set(vp[1]).at[8].set(vp[2])
        private = jnp.asarray([[1, 2, 3, 4], [7, 8, 5, 6]], jnp.int32)
        copied = decode_attention_paged_kernel(q, kp2, vp2, private, lengths)
        np.testing.assert_allclose(np.asarray(aliased), np.asarray(copied),
                                   rtol=1e-6)

    def test_mask_ignores_scratch_tail_blocks(self):
        """Unallocated table tail entries point at the scratch block 0:
        whatever garbage lives there must not leak into the output."""
        B, MB, bs, H, Hkv, Dh = 1, 4, 32, 4, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(13), 2)
        q = jax.random.normal(ks[0], (B, H, Dh), jnp.float32)
        kp, vp, _ = self._rand_pool(ks[1], B, MB, bs, Hkv, Dh, jnp.float32)
        tables = jnp.asarray([[1, 2, 0, 0]], jnp.int32)  # 2 live blocks
        lengths = jnp.asarray([2 * bs - 1])
        out1 = decode_attention_paged_kernel(q, kp, vp, tables, lengths)
        kp2 = kp.at[0].set(999.0)
        vp2 = vp.at[0].set(-999.0)
        out2 = decode_attention_paged_kernel(q, kp2, vp2, tables, lengths)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=1e-6)


class TestMamba1Scan:
    @pytest.mark.parametrize("B,T,C,N,cb,tc", [
        (2, 32, 128, 16, 128, 8),
        (1, 64, 256, 16, 128, 16),
        (2, 16, 128, 8, 128, 16),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, T, C, N, cb, tc, dtype):
        ks = jax.random.split(jax.random.PRNGKey(2), 4)
        dt = jax.nn.softplus(jax.random.normal(ks[0], (B, T, C))).astype(dtype)
        x = jax.random.normal(ks[1], (B, T, C), dtype)
        Bm = jax.random.normal(ks[2], (B, T, N), dtype)
        Cm = jax.random.normal(ks[3], (B, T, N), dtype)
        A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(5), (C, N))) \
            .astype(jnp.float32)
        out = mamba1_scan_kernel(dt, x, Bm, Cm, A, c_blk=cb, t_chunk=tc,
                                 interpret=True)
        want = ref.mamba1_scan_ref(dt, x, Bm, Cm, A)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
            atol=5e-2 if dtype == jnp.bfloat16 else 1e-4)

    def test_state_carries_across_chunks(self):
        """Splitting time into chunks must equal one long chunk (carry)."""
        B, T, C, N = 1, 32, 128, 16
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        dt = jax.nn.softplus(jax.random.normal(ks[0], (B, T, C)))
        x = jax.random.normal(ks[1], (B, T, C))
        Bm = jax.random.normal(ks[2], (B, T, N))
        Cm = jax.random.normal(ks[3], (B, T, N))
        A = -jnp.ones((C, N), jnp.float32)
        a = mamba1_scan_kernel(dt, x, Bm, Cm, A, t_chunk=8)
        b = mamba1_scan_kernel(dt, x, Bm, Cm, A, t_chunk=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-5)


class TestMoeGemm:
    @pytest.mark.parametrize("E,C,D,F", [
        (4, 128, 128, 256),
        (2, 256, 256, 128),
        (8, 128, 256, 384),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("act", ["none", "silu"])
    def test_matches_ref(self, E, C, D, F, dtype, act):
        ks = jax.random.split(jax.random.PRNGKey(4), 2)
        xe = (jax.random.normal(ks[0], (E, C, D)) / np.sqrt(D)).astype(dtype)
        w = (jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D)).astype(dtype)
        out = moe_grouped_gemm_kernel(xe, w, activation=act, interpret=True)
        want = ref.moe_grouped_gemm_ref(xe, w, activation=act)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            **_tols(dtype))


class TestKernelCatalog:
    def test_autotune_skipped_on_catalog_hit(self):
        cat = KernelCatalog()
        B, S, H, Hkv, Dh = 1, 256, 4, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(6), 3)
        q = jax.random.normal(ks[0], (B, H, Dh), jnp.float32)
        kc = jax.random.normal(ks[1], (B, S, Hkv, Dh), jnp.float32)
        vc = jax.random.normal(ks[2], (B, S, Hkv, Dh), jnp.float32)
        lengths = jnp.asarray([100])
        o1 = ops.decode_attention(q, kc, vc, lengths, catalog=cat)
        assert cat.stats["misses"] == 1 and len(cat.entries) == 1
        o2 = ops.decode_attention(q, kc, vc, lengths, catalog=cat)
        assert cat.stats["autotune_skipped"] == 1
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))

    def test_catalog_roundtrip_through_archive(self):
        from repro.core.archive import Archive
        cat = KernelCatalog()
        cat.record("k1(sig)", b"stablehlo-payload", {"blk": 256})
        ar = Archive()
        cat.add_blobs(ar)
        ar.manifest = {"kernel_catalog": cat.to_manifest()}
        ar2 = Archive.from_bytes(ar.to_bytes())
        cat2 = KernelCatalog()
        cat2.prime(ar2.manifest["kernel_catalog"], ar2)
        e = cat2.resolve("k1(sig)")
        assert e is not None and cat2.payload(e) == b"stablehlo-payload"

"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU; asserts output shapes and no NaNs. Decode smoke for decodable archs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED, EXTRA, get_arch
from repro.models.model import Model

ALL = [c.name for c in ASSIGNED + EXTRA]

B, S = 2, 32


def _batch(cfg, rng):
    c = cfg
    if c.family == "encoder":
        return {"frames": jax.random.normal(rng, (B, S, c.d_model), jnp.float32)}
    if c.family == "vlm":
        sv = c.frontend_seq
        return {"tokens": jax.random.randint(rng, (B, S - sv), 0, c.vocab_size),
                "vision_embeds": jax.random.normal(rng, (B, sv, c.d_model),
                                                   jnp.float32)}
    return {"tokens": jax.random.randint(rng, (B, S), 0, c.vocab_size)}


def _with_labels(cfg, batch, rng):
    lab_s = S - cfg.frontend_seq if cfg.family == "vlm" else S
    return {**batch, "labels": jax.random.randint(rng, (B, lab_s), 0,
                                                  cfg.vocab_size)}


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes_no_nan(name):
    cfg = get_arch(name).reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux, _ = jax.jit(lambda p, b: m.forward(p, b))(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any()), "NaN logits"
    assert not bool(jnp.isnan(aux)), "NaN aux loss"


@pytest.mark.parametrize("name", ALL)
def test_train_step_no_nan(name):
    cfg = get_arch(name).reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _with_labels(cfg, _batch(cfg, jax.random.PRNGKey(1)),
                         jax.random.PRNGKey(2))

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(m.loss_fn, has_aux=True)(p, b)
        p2 = jax.tree.map(lambda w, g: w - 1e-3 * g.astype(w.dtype), p, grads)
        return loss, p2

    loss, p2 = step(params, batch)
    assert np.isfinite(float(loss)), f"loss not finite: {loss}"
    # params moved and are finite
    leaves = jax.tree.leaves(p2)
    assert all(bool(jnp.isfinite(l).all()) for l in leaves)


@pytest.mark.parametrize("name", [n for n in ALL
                                  if get_arch(n).has_decode])
def test_prefill_then_decode(name):
    cfg = get_arch(name).reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    cap = S + 8
    last_logits, cache = jax.jit(
        lambda p, b: m.prefill(p, b, cache_len=cap))(params, batch)
    assert last_logits.shape == (B, cfg.padded_vocab)
    assert not bool(jnp.isnan(last_logits).any())
    assert int(cache["lengths"][0]) == S

    tok = jnp.argmax(last_logits[:, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    step = jax.jit(lambda p, c, t: m.decode_step(p, c, t))
    for _ in range(3):
        cache, logits = step(params, cache, tok)
        assert logits.shape == (B, cfg.padded_vocab)
        assert not bool(jnp.isnan(logits).any())
        tok = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    assert int(cache["lengths"][0]) == S + 3


@pytest.mark.parametrize("name", ["yi-9b", "falcon-mamba-7b", "zamba2-2.7b"])
def test_decode_matches_forward(name):
    """Token-by-token decode logits must match the full forward logits."""
    cfg = get_arch(name).reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = jax.jit(lambda p, b: m.forward(p, b))(
        params, {"tokens": toks})

    # prefill first half, decode second half token by token
    half = S // 2
    _, cache = jax.jit(lambda p, b: m.prefill(p, b, cache_len=S))(
        params, {"tokens": toks[:, :half]})
    step = jax.jit(lambda p, c, t: m.decode_step(p, c, t))
    for t in range(half, S):
        cache, logits = step(params, cache, toks[:, t])
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]),
            rtol=2e-2, atol=2e-2,
            err_msg=f"decode step {t} diverges from forward")

"""Autoscaling fleet: concurrent replica cold starts against one shared
archive, scale-up under a spike, scale-down when idle, and clean rejection
of oversized prompts under load (serving/fleet.py)."""
import time

import jax
import pytest

from repro.configs.registry import get_arch
from repro.core import Archive
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.fleet import (AutoscalePolicy, Fleet, Replica,
                                 ReplicaState, spike_trace)

CFG = get_arch("smollm-360m").reduced()


def factory():
    eng = ServingEngine(Model(CFG), max_batch=4, max_seq=64,
                        bucket_mode="pow2")
    eng.load_weights(rng=jax.random.PRNGKey(7))
    return eng


@pytest.fixture(scope="module")
def archive(tmp_path_factory):
    """One shared on-disk archive, opened lazily (the fleet serving path)."""
    path = str(tmp_path_factory.mktemp("fleet") / "fleet.fndry")
    eng = factory()
    ar, _ = eng.save_archive(path)
    del ar
    return Archive.load(path)  # lazy: blobs fetched on demand, read-shared


def small_policy(**kw):
    base = dict(min_replicas=1, max_replicas=3,
                target_inflight_per_replica=4, scale_down_idle_ticks=5)
    base.update(kw)
    return AutoscalePolicy(**base)


def test_scale_up_under_spike(archive):
    fleet = Fleet(factory, mode="foundry", archive=archive,
                  policy=small_policy())
    rep = fleet.run_trace(spike_trace(warm_ticks=2, spike_ticks=6,
                                      cool_ticks=4, base_rate=1,
                                      spike_rate=5), seed=1)
    fleet.drain_background()
    rep = fleet.report()
    assert rep.peak_alive > 1, "spike did not trigger scale-up"
    assert rep.n_done == len(fleet.requests)
    assert rep.n_failed == 0
    assert rep.ttfts and all(t > 0 for t in rep.ttfts)
    # foundry replicas must never touch the compiler on the critical path,
    # and background compiles must not fail silently
    assert all(r.mode == "foundry" for r in rep.replicas)
    assert rep.summary()["fallback_compiles"] == 0
    assert rep.summary()["background_errors"] == 0
    # every replica that served recorded its scale-out latency
    for r in rep.replicas:
        if r.served_requests:
            assert r.cold_start_to_first_token_s is not None
            assert r.cold_start_to_first_token_s > 0


def test_scale_down_when_idle(archive):
    fleet = Fleet(factory, mode="foundry", archive=archive,
                  policy=small_policy(scale_down_idle_ticks=3))
    fleet.run_trace(spike_trace(warm_ticks=1, spike_ticks=5, cool_ticks=2,
                                base_rate=1, spike_rate=5), seed=2)
    assert fleet.peak_alive > 1
    for _ in range(40):  # idle ticks: autoscaler must shed down to the floor
        fleet.tick()
        if len(fleet._alive()) == fleet.policy.min_replicas:
            break
    assert len(fleet._alive()) == fleet.policy.min_replicas
    stopped = [r for r in fleet.replicas if r.state is ReplicaState.STOPPED]
    assert stopped and all(r.stats.stopped_t is not None for r in stopped)
    assert all(r.load == 0 for r in stopped)


def test_oversized_prompt_rejected_under_load(archive):
    fleet = Fleet(factory, mode="foundry", archive=archive,
                  policy=small_policy())
    normal = [fleet.submit([1 + i, 2, 3], 4) for i in range(6)]
    oversized = fleet.submit(list(range(1, 80)), 4)  # 79 tokens > max_seq=64
    more = [fleet.submit([9, 9, i + 1], 4) for i in range(4)]
    rep = fleet.run_trace([], seed=0)  # no extra arrivals: dispatch + drain
    assert oversized.state.value == "failed"
    assert "max_seq" in oversized.fail_reason
    assert all(r.state.value == "done" for r in normal + more)
    assert rep.n_failed == 1 and rep.n_done == len(normal) + len(more)


def test_shared_lazy_archive_single_fetch(archive):
    """Concurrent LOADs against one lazy archive share fetched blobs: each
    blob is materialized at most once fleet-wide."""
    before = archive.blobs.fetched()
    fleet = Fleet(factory, mode="foundry", archive=archive,
                  policy=small_policy(min_replicas=2, max_replicas=2))
    fleet.start()
    for _ in range(6000):  # both replicas LOAD the same archive concurrently
        for r in fleet.replicas:
            r.poll()
        if len(fleet._ready()) == 2:
            break
        time.sleep(0.01)
    assert len(fleet._ready()) == 2
    reqs = [fleet.submit([5, 9, 2], 4) for _ in range(4)]
    fleet.run_trace([], seed=0)
    fleet.drain_background()
    assert archive.blobs.fetched() <= len(archive.blobs)
    assert archive.blobs.fetched() >= before
    assert all(r.state.value == "done" for r in reqs)


def test_blobstore_concurrent_fetch_once(tmp_path):
    """Single-flight guarantee of the lazy blob store: N threads hammering
    the same blobs cause exactly one source read per blob."""
    import threading

    ar = Archive()
    hashes = [ar.add_blob(bytes([i]) * 20000) for i in range(4)]
    path = str(tmp_path / "sf.fndry")
    ar.save(path)
    lz = Archive.load(path)
    src = lz.blobs._source
    orig_read, reads = src.read, []

    def counting_read(offset, length):
        reads.append(offset)
        time.sleep(0.005)  # widen the race window
        return orig_read(offset, length)

    src.read = counting_read
    errs = []

    def hammer():
        try:
            for h in hashes:
                assert lz.get_blob(h) == bytes([hashes.index(h)]) * 20000
        except Exception as e:  # pragma: no cover - surfaced via assert
            errs.append(e)

    threads = [threading.Thread(target=hammer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert len(reads) == len(hashes), \
        f"{len(reads)} source reads for {len(hashes)} blobs (dup fetches)"


def test_fleet_fails_fast_on_broken_cold_start():
    """A systematically failing provision (bad factory/archive) must stop
    respawning after max_spawn_failures and return, not spawn forever."""
    def broken_factory():
        raise RuntimeError("boom: no such archive")

    fleet = Fleet(broken_factory, mode="vanilla",
                  policy=small_policy(max_spawn_failures=2))
    req = fleet.submit([1, 2, 3], 4)
    rep = fleet.run_trace([1], seed=0)  # must terminate on its own
    assert fleet.spawn_failures == 2
    assert len(fleet.replicas) <= 4  # bounded, not one per tick
    assert all(r.state is ReplicaState.FAILED for r in fleet.replicas)
    assert all("boom" in r.stats.error for r in fleet.replicas)
    assert req.state.value == "waiting"  # never dispatched, never wedged
    assert rep.n_done == 0 and rep.n_failed == 0


def test_join_provision_timeout_resolves_to_failed():
    """A provisioning thread still alive after the join timeout must leave
    the replica FAILED with a distinct timeout error — not PROVISIONING
    forever — and its eventual late engine attach must be reaped."""
    import threading
    gate = threading.Event()
    sentinel = object()

    def gated_factory():
        gate.wait(30.0)  # wedged provision (hung IO / stuck compile)
        return sentinel

    r = Replica(0, gated_factory, lambda eng: None)
    out = r.join_provision(timeout=0.05)
    assert out is ReplicaState.FAILED
    assert "timed out" in r.stats.error
    assert r.discard_engine
    # the thread eventually finishes and attaches its engine; poll() reaps
    # it instead of reviving the replica
    gate.set()
    r._thread.join(30.0)
    assert r.poll() is ReplicaState.FAILED
    assert r.engine is None, "late engine attach must be discarded"


def test_provision_deadline_fails_wedged_replica():
    """AutoscalePolicy.provision_deadline_s: a hung provision past the
    deadline resolves to FAILED on poll() so the supervisor can respawn."""
    import threading
    gate = threading.Event()

    def gated_factory():
        gate.wait(30.0)
        return object()

    r = Replica(1, gated_factory, lambda eng: None, deadline_s=0.05)
    assert r.poll() is ReplicaState.PROVISIONING
    time.sleep(0.08)
    assert r.poll() is ReplicaState.FAILED
    assert "deadline exceeded" in r.stats.error
    assert r.discard_engine
    gate.set()


def test_fleet_foundry_tokens_match_single_engine(archive):
    """A fleet-served request produces the same tokens as a single vanilla
    engine given the same prompt (program provenance must not change
    outputs)."""
    eng = factory()
    eng.cold_start_vanilla()
    ref = eng.submit([5, 9, 2], 6)
    eng.run_until_drained()

    fleet = Fleet(factory, mode="foundry", archive=archive,
                  policy=small_policy(max_replicas=1))
    out = fleet.submit([5, 9, 2], 6)
    fleet.run_trace([], seed=0)
    assert out.state.value == "done"
    assert out.generated == ref.generated

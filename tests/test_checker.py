"""foundry-check: the static verifier (repro.analysis.checker + check CLI).

Fast tests exercise each pass family on synthetic-but-valid artifacts and
their seeded corruptions (no jax compile, no execution). The slow
subprocess test runs the real cycle the CI analysis gate also runs: a
foundry_save archive verifies clean end-to-end (deep + IR passes), then
each of the four corruption classes is caught by its named pass AND makes
``foundry_load(strict=True)`` raise with zero fallback compiles attempted.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import checker
from repro.analysis.check import main as check_main
from repro.analysis.checker import (ArchiveVerificationError, Finding,
                                    check_container_bytes, check_depot,
                                    check_manifest_schema, check_memory_plan,
                                    check_rank_delta_section, check_tags,
                                    exit_code, summarize, verify_for_load)
from repro.core import Archive, MemoryPlan, TemplateDepot
from repro.core.archive import MAGIC2
from repro.core.rank_stamp import build_rank_deltas

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def make_plan() -> MemoryPlan:
    p = MemoryPlan()
    p.alloc("weights", 1000)
    p.alloc("kv_pool", 4096, scope="per_rank")
    p.set_phase("capture")
    p.alloc("capture_tmp", 64)
    return p


def make_archive() -> Archive:
    """Synthetic archive whose manifest satisfies every metadata-level pass
    (blobs are opaque bytes, so only deep/IR passes are out of scope)."""
    ar = Archive()
    h_exe = ar.add_blob(b"template-executable" * 20)
    h_e1 = ar.add_blob(b"export-bucket-1" * 20)
    h_e2 = ar.add_blob(b"export-bucket-2" * 20)
    plan = make_plan()
    ident = {"axes": ["data", "model"], "shape": [1, 2]}
    ar.manifest = {
        "version": 2, "mesh": ident, "meta": {},
        "specs": {"decode": {
            "buckets": [1, 2], "donate_argnums": [1],
            "tags": {"decode_loop": "host", "fused_sampling": False,
                     "kv_layout": "slot"},
            "groups": [{"key": "k1", "buckets": [1, 2],
                        "template_bucket": 2, "executable_blob": h_exe,
                        "bucket_export_blobs": {"1": h_e1, "2": h_e2},
                        "bucket_executable_blobs": {}}],
        }},
        "memory_plan": plan.to_manifest(),
        "kernel_catalog": None,
        "rank_delta": {
            "capture_ranks": [d.to_manifest()
                              for d in build_rank_deltas(ident, plan)],
            "rank_dependent_fields": ["mesh"],
        },
    }
    return ar


def ids(findings):
    return sorted({f.pass_id for f in findings})


# ---------------------------------------------------------------------------
# findings / severity / exit-code contract
# ---------------------------------------------------------------------------
class TestFindingContract:
    def test_every_pass_id_documented(self):
        assert set(checker.PASSES) >= {
            "container-structure", "manifest-schema", "blob-index",
            "blob-integrity", "tags-schema", "ir-parse",
            "donation-aliasing", "ir-determinism", "rank-delta-coverage",
            "memory-plan-overlap", "memory-plan-alignment",
            "memory-plan-extent", "memory-plan-leak", "memory-plan-scope",
            "capture-window-order", "depot-index", "depot-orphan-blob"}

    def test_unknown_pass_id_rejected(self):
        with pytest.raises(AssertionError):
            Finding("no-such-pass", "error", "x", "y")

    def test_exit_codes(self):
        e = Finding("blob-integrity", "error", "a", "m")
        w = Finding("depot-orphan-blob", "warning", "a", "m")
        i = Finding("depot-orphan-blob", "info", "a", "m")
        assert exit_code([]) == 0
        assert exit_code([i]) == 0
        assert exit_code([i, w]) == 1
        assert exit_code([i, w, e]) == 2
        assert summarize([i, w, e]) == {"info": 1, "warning": 1, "error": 1}

    def test_render_includes_fix_hint(self):
        f = Finding("blob-index", "error", "a.fndry:x", "gone",
                    fix_hint="re-run SAVE")
        assert "re-run SAVE" in f.render() and "blob-index" in f.render()


# ---------------------------------------------------------------------------
# pass 1: container / manifest / blob index / tags
# ---------------------------------------------------------------------------
class TestContainerPass:
    def test_clean_v2(self):
        fs, info = check_container_bytes(make_archive().to_bytes(), "t")
        assert fs == [] and info.version == 2 and len(info.index) == 3

    def test_bad_magic(self):
        fs, _ = check_container_bytes(b"not an archive at all", "t")
        assert ids(fs) == ["container-structure"]

    def test_truncated_header(self):
        raw = make_archive().to_bytes()
        fs, _ = check_container_bytes(raw[:len(MAGIC2) + 4], "t")
        assert [(f.pass_id, f.severity) for f in fs] == \
            [("container-structure", "error")]
        fs, _ = check_container_bytes(raw[:len(MAGIC2) + 12], "t")
        assert ids(fs) == ["container-structure"]

    def test_truncated_blob_section(self):
        raw = make_archive().to_bytes()
        fs, _ = check_container_bytes(raw[:-10], "t")
        assert "blob-index" in ids(fs)

    def test_bit_flip_caught_by_deep_pass(self, tmp_path):
        ar = make_archive()
        path = str(tmp_path / "a.fndry")
        ar.save(path)
        raw = bytearray(open(path, "rb").read())
        _, info = check_container_bytes(bytes(raw), "t")
        h = ar.manifest["specs"]["decode"]["groups"][0]["executable_blob"]
        off, comp_len, _ = info.index[h]
        raw[info.blob_base + off + comp_len // 2] ^= 0xFF
        open(path, "wb").write(bytes(raw))
        fs = checker.check_archive_file(path, ir=False)
        assert "blob-integrity" in ids(fs)
        assert exit_code(fs) == 2


class TestManifestPass:
    def test_clean(self):
        assert verify_for_load(make_archive()) == []

    def test_missing_version_and_specs(self):
        ar = make_archive()
        del ar.manifest["version"]
        ar.manifest["specs"] = {}
        assert ids(verify_for_load(ar)) == ["manifest-schema"]

    def test_buckets_must_increase(self):
        ar = make_archive()
        ar.manifest["specs"]["decode"]["buckets"] = [2, 1]
        assert "manifest-schema" in ids(verify_for_load(ar))

    def test_template_bucket_must_be_group_max(self):
        ar = make_archive()
        ar.manifest["specs"]["decode"]["groups"][0]["template_bucket"] = 1
        fs = verify_for_load(ar)
        assert any(f.pass_id == "manifest-schema" and f.severity == "error"
                   and "pad-served" in f.message for f in fs)

    def test_bucket_covered_twice(self):
        ar = make_archive()
        g = dict(ar.manifest["specs"]["decode"]["groups"][0],
                 key="k2", buckets=[2], template_bucket=2)
        ar.manifest["specs"]["decode"]["groups"].append(g)
        assert "manifest-schema" in ids(verify_for_load(ar))

    def test_dangling_blob_reference(self):
        ar = make_archive()
        ar.manifest["specs"]["decode"]["groups"][0]["executable_blob"] = \
            "f" * 32
        fs = verify_for_load(ar)
        assert ids(fs) == ["blob-index"]
        assert all(f.severity == "error" for f in fs)

    def test_missing_export_is_warning(self):
        ar = make_archive()
        del ar.manifest["specs"]["decode"]["groups"][0][
            "bucket_export_blobs"]["1"]
        fs = verify_for_load(ar)
        assert ids(fs) == ["blob-index"]
        assert all(f.severity == "warning" for f in fs)
        assert exit_code(fs) == 1

    def test_manifest_schema_standalone(self):
        fs = check_manifest_schema("not-a-dict", "t")
        assert ids(fs) == ["manifest-schema"]


class TestTagsPass:
    def test_engine_capture_tags_are_clean(self):
        # the convention matrix must accept what the engine itself emits
        for loop in ("host", "device"):
            tags = {"decode_loop": loop, "fused_sampling": loop == "device",
                    "kv_layout": "paged", "kv_block_size": 16, "kv_blocks": 9}
            assert check_tags(tags, "t") == []

    def test_unknown_key(self):
        fs = check_tags({"decode_loop": "host", "fused_sampling": False,
                         "kv_teleport": True}, "t")
        assert ids(fs) == ["tags-schema"]
        assert "kv_teleport" in fs[0].message

    def test_bad_value_domains(self):
        assert ids(check_tags({"decode_loop": "gpu"}, "t")) == ["tags-schema"]
        assert ids(check_tags({"kv_layout": "ring"}, "t")) == ["tags-schema"]
        assert ids(check_tags({"kv_block_size": 0}, "t")) == ["tags-schema"]
        assert ids(check_tags({"kv_blocks": True}, "t")) == ["tags-schema"]

    def test_fused_sampling_cross_field(self):
        fs = check_tags({"decode_loop": "host", "fused_sampling": True}, "t")
        assert ids(fs) == ["tags-schema"]


# ---------------------------------------------------------------------------
# pass 3: memory plan
# ---------------------------------------------------------------------------
class TestMemoryPlanPass:
    def test_clean(self):
        assert check_memory_plan(make_plan().to_manifest(), "t") == []
        assert check_memory_plan(None, "t") == []

    def _mut(self, i, **kw):
        m = make_plan().to_manifest()
        m["allocations"][i] = dict(m["allocations"][i], **kw)
        return m

    def test_overlap(self):
        fs = check_memory_plan(self._mut(1, offset=512), "t")
        assert "memory-plan-overlap" in ids(fs)

    def test_misaligned(self):
        fs = check_memory_plan(self._mut(2, offset=5200), "t")
        assert "memory-plan-alignment" in ids(fs)

    def test_gap_is_leak_warning(self):
        m = make_plan().to_manifest()
        m["allocations"][2] = dict(m["allocations"][2], offset=512 * 20)
        m["extent"] = 512 * 21
        fs = check_memory_plan(m, "t")
        assert ids(fs) == ["memory-plan-leak"]
        assert all(f.severity == "warning" for f in fs)

    def test_short_extent(self):
        m = make_plan().to_manifest()
        m["extent"] = 8
        assert ids(check_memory_plan(m, "t")) == ["memory-plan-extent"]

    def test_init_after_capture_window(self):
        m = make_plan().to_manifest()
        m["allocations"].append(dict(m["allocations"][0], name="late",
                                     offset=m["extent"], phase="init"))
        m["extent"] += 1024
        fs = check_memory_plan(m, "t")
        assert "capture-window-order" in ids(fs)

    def test_unknown_scope(self):
        fs = check_memory_plan(self._mut(1, scope="per_host"), "t")
        assert "memory-plan-scope" in ids(fs)


# ---------------------------------------------------------------------------
# passes 2/3 joint: rank-delta section
# ---------------------------------------------------------------------------
class TestRankDeltaPass:
    def _man(self):
        return make_archive().manifest

    def test_clean(self):
        assert check_rank_delta_section(self._man(), "t") == []

    def test_missing_section_is_warning(self):
        m = self._man()
        del m["rank_delta"]
        fs = check_rank_delta_section(m, "t")
        assert ids(fs) == ["rank-delta-coverage"]
        assert all(f.severity == "warning" for f in fs)

    def test_missing_rank(self):
        m = self._man()
        m["rank_delta"]["capture_ranks"].pop()
        fs = check_rank_delta_section(m, "t")
        assert any(f.pass_id == "rank-delta-coverage"
                   and f.severity == "error" for f in fs)

    def test_missing_peer_axis(self):
        m = self._man()
        del m["rank_delta"]["capture_ranks"][1]["peer_groups"]["model"]
        fs = check_rank_delta_section(m, "t")
        assert any("peer table" in f.message for f in fs)
        assert ids(fs) == ["rank-delta-coverage"]

    def test_wrong_peer_membership(self):
        m = self._man()
        m["rank_delta"]["capture_ranks"][0]["peer_groups"]["model"] = [0, 7]
        fs = check_rank_delta_section(m, "t")
        assert ids(fs) == ["rank-delta-coverage"]

    def test_wrong_coords(self):
        m = self._man()
        m["rank_delta"]["capture_ranks"][1]["coords"] = [5, 5]
        assert ids(check_rank_delta_section(m, "t")) == \
            ["rank-delta-coverage"]

    def test_comm_buffer_drift_vs_plan(self):
        m = self._man()
        m["rank_delta"]["capture_ranks"][0]["comm_buffers"][0]["size"] += 8
        fs = check_rank_delta_section(m, "t")
        assert ids(fs) == ["memory-plan-scope"]


# ---------------------------------------------------------------------------
# strict LOAD wiring (metadata level; the full cycle is in the slow test)
# ---------------------------------------------------------------------------
class TestStrictLoadPreflight:
    def test_verification_error_carries_findings_and_report(self):
        fs = [Finding("tags-schema", "error", "a", "bad tag")]
        err = ArchiveVerificationError(fs, report="REP")
        assert err.findings == fs and err.report == "REP"
        assert isinstance(err, ValueError)
        assert "tags-schema" in str(err)

    def test_foundry_load_strict_rejects_bad_tags(self):
        from repro.core import foundry_load
        ar = make_archive()
        ar.manifest["specs"]["decode"]["tags"]["kv_teleport"] = True
        with pytest.raises(ArchiveVerificationError) as ei:
            foundry_load(ar, None)
        assert "tags-schema" in {f.pass_id for f in ei.value.findings}
        assert ei.value.report.fallback_compiles == 0
        assert "verify_s" in ei.value.report.phases

    def test_foundry_load_strict_false_skips_preflight(self):
        from repro.core import foundry_load
        ar = make_archive()
        ar.manifest["specs"]["decode"]["tags"]["kv_teleport"] = True
        # non-strict: pre-flight skipped; the fake exe blob then degrades to
        # a fallback compile attempt that fails on fake export bytes — which
        # is exactly the silent-degradation mode strict LOAD exists to stop
        with pytest.raises(Exception) as ei:
            foundry_load(ar, None, strict=False)
        assert not isinstance(ei.value, ArchiveVerificationError)


# ---------------------------------------------------------------------------
# pass 4: depot fsck (+ the atomic index.json regression)
# ---------------------------------------------------------------------------
class TestDepotFsck:
    def _depot(self, tmp_path):
        depot = TemplateDepot(str(tmp_path / "depot"))
        depot.put_archive("m1", make_archive())
        ar2 = make_archive()
        ar2.add_blob(b"unique-to-m2" * 30)
        depot.put_archive("m2", ar2)
        return depot

    def test_clean_depot(self, tmp_path):
        depot = self._depot(tmp_path)
        fs, acts = check_depot(depot.root)
        assert fs == [] and acts["gc_removed_blobs"] == 0
        fs, _ = depot.fsck(deep=True)  # deep re-hash also clean
        assert fs == []

    def test_torn_index_write(self, tmp_path):
        depot = self._depot(tmp_path)
        with open(os.path.join(depot.root, "index.json"), "w") as f:
            f.write('{"version": 1, "blobs": {"tru')  # torn mid-write
        fs, _ = check_depot(depot.root)
        assert any(f.pass_id == "depot-index" and f.severity == "error"
                   and "torn" in f.message for f in fs)

    def test_flush_is_atomic_and_tmp_free(self, tmp_path):
        depot = self._depot(tmp_path)
        for _ in range(5):
            depot.register_ref("ref-a", [])
            depot.release_ref("ref-a")
        names = os.listdir(depot.root)
        assert not [n for n in names if ".tmp" in n], names
        with open(os.path.join(depot.root, "index.json")) as f:
            assert json.load(f)["version"] == 1
        assert check_depot(depot.root)[0] == []

    def test_missing_blob_file(self, tmp_path):
        depot = self._depot(tmp_path)
        victim = sorted(os.listdir(depot.blob_dir))[0]
        os.remove(os.path.join(depot.blob_dir, victim))
        fs, _ = check_depot(depot.root)
        assert "depot-missing-blob" in ids(fs)

    def test_blob_size_mismatch(self, tmp_path):
        depot = self._depot(tmp_path)
        victim = sorted(os.listdir(depot.blob_dir))[0]
        with open(os.path.join(depot.blob_dir, victim), "ab") as f:
            f.write(b"xx")
        fs, _ = check_depot(depot.root)
        assert "depot-blob-size" in ids(fs)

    def test_orphan_blob_and_gc(self, tmp_path):
        depot = self._depot(tmp_path)
        orphan = os.path.join(depot.blob_dir, "deadbeef" * 4)
        open(orphan, "wb").write(b"crash residue")
        fs, _ = check_depot(depot.root)
        assert "depot-orphan-blob" in ids(fs)
        assert exit_code(fs) == 1  # warning only
        fs, acts = check_depot(depot.root, gc_orphans=True)
        assert acts["gc_removed_blobs"] == 1
        assert not os.path.exists(orphan)
        assert check_depot(depot.root)[0] == []

    def test_dangling_ref(self, tmp_path):
        depot = self._depot(tmp_path)
        depot.register_ref("/nowhere/stale.fndry",
                           list(depot._index["blobs"]))
        fs, _ = check_depot(depot.root)
        assert "depot-dangling-ref" in ids(fs)
        depot.release_ref("/nowhere/stale.fndry")
        assert check_depot(depot.root)[0] == []

    def test_unheld_reference_refcount(self, tmp_path):
        depot = self._depot(tmp_path)
        entry = depot._index["archives"]["m1"]
        me = os.path.abspath(os.path.join(depot.root, entry["file"]))
        depot.release_ref(me)  # archive alive, refs dropped: gc would eat it
        fs, _ = check_depot(depot.root)
        assert "depot-refcount" in ids(fs)

    def test_orphan_manifest(self, tmp_path):
        depot = self._depot(tmp_path)
        open(os.path.join(depot.manifest_dir, "ghost.fndry"), "wb").write(
            make_archive().to_bytes())
        fs, _ = check_depot(depot.root)
        assert "depot-orphan-manifest" in ids(fs)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
class TestCLI:
    def test_clean_archive_exit_0(self, tmp_path):
        path = str(tmp_path / "a.fndry")
        make_archive().save(path)
        assert check_main([path, "--no-ir", "--no-deep"]) == 0

    def test_warning_exit_1(self, tmp_path):
        ar = make_archive()
        del ar.manifest["rank_delta"]
        path = str(tmp_path / "a.fndry")
        ar.save(path)
        assert check_main([path, "--no-ir", "--no-deep"]) == 1

    def test_error_exit_2_and_json(self, tmp_path, capsys):
        ar = make_archive()
        ar.manifest["specs"]["decode"]["tags"]["bogus"] = 1
        path = str(tmp_path / "a.fndry")
        ar.save(path)
        assert check_main([path, "--no-ir", "--no-deep", "--json"]) == 2
        doc = json.loads(capsys.readouterr().out)
        assert doc["summary"]["error"] >= 1
        assert {f["pass_id"] for f in doc["findings"]} == {"tags-schema"}

    def test_missing_target_exit_3(self):
        assert check_main(["/no/such/file.fndry"]) == 3

    def test_bad_usage_exit_3(self):
        with pytest.raises(SystemExit) as ei:
            check_main([])
        assert ei.value.code == 3

    def test_depot_target_and_thin_without_depot(self, tmp_path):
        depot = TemplateDepot(str(tmp_path / "depot"))
        depot.put_archive("m1", make_archive())
        assert check_main([depot.root]) == 0
        thin = os.path.join(depot.manifest_dir, "m1.fndry")
        # thin archive without --depot: warning (blobs unverifiable)
        assert check_main([thin, "--no-ir"]) == 1
        # with --depot: fully verifiable, clean
        assert check_main([thin, "--no-ir", "--depot", depot.root]) == 0

    def test_module_entrypoint_subprocess(self, tmp_path):
        path = str(tmp_path / "a.fndry")
        make_archive().save(path)
        env = dict(os.environ,
                   PYTHONPATH=SRC + os.pathsep + os.environ.get(
                       "PYTHONPATH", ""))
        r = subprocess.run(
            [sys.executable, "-m", "repro.analysis.check", path,
             "--no-ir", "--no-deep"],
            capture_output=True, text=True, env=env, timeout=120)
        assert r.returncode == 0, r.stdout + r.stderr
        assert "0 error(s)" in r.stdout


# ---------------------------------------------------------------------------
# the real cycle: SAVE -> verify clean -> corrupt -> named pass + strict
# LOAD raises with fallback_compiles == 0 (subprocess: capture topology)
# ---------------------------------------------------------------------------
CORRUPTION_SCRIPT = r"""
import struct
import jax, jax.numpy as jnp
from repro.configs.registry import get_arch
from repro.core import Archive, CaptureSpec, MemoryPlan, foundry_save, foundry_load
from repro.core.archive import MAGIC2
from repro.launch.mesh import ShardCtx, make_mesh
from repro.models.model import Model
from repro.analysis.checker import (ArchiveVerificationError,
                                    check_archive_file, check_container_bytes,
                                    verify_for_load)

mesh = make_mesh((2,), ("model",))
ctx = ShardCtx(mesh=mesh)
m = Model(get_arch("smollm-360m").reduced(), ctx)
S = 32

def make_args(b):
    return (m.param_specs(), m.cache_specs(b, S),
            jax.ShapeDtypeStruct((b,), jnp.int32,
                                 sharding=ctx.sharding(("batch",), (b,))))

plan = MemoryPlan()
plan.alloc("params", 4096)
plan.alloc("kv", 8192, scope="per_rank")
plan.set_phase("capture")
plan.alloc("tmp", 64)
spec = CaptureSpec("decode", lambda p, c, t: m.decode_step(p, c, t),
                   make_args, [1, 2], donate_argnums=(1,),
                   tags={"decode_loop": "host", "fused_sampling": False,
                         "kv_layout": "slot"})
with mesh:
    ar, _ = foundry_save([spec], mesh, memory_plan=plan)
ar.save("/tmp/checker_e2e.fndry")
raw = open("/tmp/checker_e2e.fndry", "rb").read()

# clean: full pass set (deep + IR) finds nothing
fs = check_archive_file("/tmp/checker_e2e.fndry", deep=True, ir=True)
assert fs == [], [f.render() for f in fs]
print("CLEAN_OK")

def strict_raises(archive, want_pass):
    try:
        with mesh:
            foundry_load(archive, mesh)
    except ArchiveVerificationError as e:
        assert e.report.fallback_compiles == 0, "fallback attempted"
        assert want_pass in {f.pass_id for f in e.findings}, e.findings
        return
    raise AssertionError(f"strict LOAD did not raise for {want_pass}")

# 1. truncated v2 header -> container-structure
open("/tmp/c1.fndry", "wb").write(raw[:12])
fs = check_archive_file("/tmp/c1.fndry")
assert {f.pass_id for f in fs} == {"container-structure"}
print("TRUNC_OK")

# 2. bit-flipped template executable blob -> blob-integrity (deep pass AND
#    the strict fetch stage)
_, info = check_container_bytes(raw, "t")
exe_hash = ar.manifest["specs"]["decode"]["groups"][0]["executable_blob"]
off, comp_len, _r = info.index[exe_hash]
bad = bytearray(raw)
bad[info.blob_base + off + comp_len // 2] ^= 0xFF
open("/tmp/c2.fndry", "wb").write(bytes(bad))
fs = check_archive_file("/tmp/c2.fndry", ir=False)
assert "blob-integrity" in {f.pass_id for f in fs}
strict_raises(Archive.load("/tmp/c2.fndry"), "blob-integrity")
print("BITFLIP_OK")

# 3. unknown tags key -> tags-schema
a3 = Archive.load("/tmp/checker_e2e.fndry")
a3.manifest["specs"]["decode"]["tags"]["kv_teleport"] = True
assert {f.pass_id for f in verify_for_load(a3)} == {"tags-schema"}
strict_raises(a3, "tags-schema")
print("TAGS_OK")

# 4. RankDelta missing peer entry -> rank-delta-coverage
a4 = Archive.load("/tmp/checker_e2e.fndry")
a4.manifest["rank_delta"]["capture_ranks"][1]["peer_groups"].pop("model")
assert {f.pass_id for f in verify_for_load(a4)} == {"rank-delta-coverage"}
strict_raises(a4, "rank-delta-coverage")
print("RANKDELTA_OK")

# the clean archive still strict-LOADs with zero fallbacks + verify_s timed
with mesh:
    _, rep, _ = foundry_load(Archive.load("/tmp/checker_e2e.fndry"), mesh)
assert rep.fallback_compiles == 0
assert 0 < rep.phases["verify_s"] < rep.critical_path_s
from repro.core import wait_for_background
wait_for_background(rep)
print("STRICT_CLEAN_OK")
"""


@pytest.mark.slow
def test_corruption_classes_end_to_end():
    from repro.core.collective_stub import run_in_capture_process
    r = run_in_capture_process(CORRUPTION_SCRIPT, 2, timeout=900,
                               pythonpath=SRC)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    for marker in ("CLEAN_OK", "TRUNC_OK", "BITFLIP_OK", "TAGS_OK",
                   "RANKDELTA_OK", "STRICT_CLEAN_OK"):
        assert marker in r.stdout

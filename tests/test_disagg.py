"""Phase-disaggregated serving (serving/pool.py + Fleet pools; docs §14):

  * wide-prefill/narrow-decode token identity vs a colocated oracle — every
    stream byte-identical across the prefill->decode KV handoff, including
    a request admitted via a radix prefix-cache hit on the prefill pool,
    with zero fallback compiles (prefill LOADs the shared archive via the
    rank-stamped path, decode via the exact path);
  * decode-capacity overflow: a handoff with no free decode slot requeues
    onto the decode pool with its prefix kept — zero drops, zero retries
    charged, identical tokens;
  * a prefill replica crashing MID-FILL salvages its rows cross-pool onto
    decode replicas (the adopter resumes the fill — the request simply
    never needs a handoff);
  * per-pool reshard: the prefill pool switches topology live while the
    decode pool keeps serving, and the other pool is never touched.
"""
import time

import jax
import pytest

from repro.configs.registry import get_arch
from repro.core import Archive
from repro.launch.mesh import MeshSpec, ShardCtx, make_host_mesh, resolve_mesh
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.faults import FaultPlan, FaultSpec, fault_plan
from repro.serving.fleet import AutoscalePolicy, Fleet, PoolSpec
from repro.serving.scheduler import ReqState

CFG = get_arch("smollm-360m").reduced()
# 12-token shared system prompt (three full blocks at block_size=4): the
# follow-up request must be admitted on the prefill pool via a radix hit
SYS = [9, 4, 7, 7, 1, 3, 8, 2, 6, 6, 2, 5]
REQ_A, REQ_B = SYS + [5, 1], SYS + [2, 8, 4]
PROMPTS = [[5, 9, 2, 4], [11, 3, 6, 1], [7, 7, 7, 1], [2, 9], [13, 4, 9, 2]]
N_NEW = 8


def mk(mesh=None, max_batch=8):
    eng = ServingEngine(Model(CFG, ShardCtx(mesh=resolve_mesh(mesh))),
                        max_batch=max_batch, max_seq=64, bucket_mode="pow2",
                        kv_block_size=4)
    eng.load_weights(rng=jax.random.PRNGKey(7))
    return eng


@pytest.fixture(scope="module")
def archive():
    """One shared lazy archive captured un-meshed: exact LOAD for the
    un-meshed decode pool, rank-stamped LOAD for the (1,1) prefill pool."""
    ar, _ = mk(None).save_archive()
    return Archive.from_bytes(ar.to_bytes(), lazy=True)


@pytest.fixture(scope="module")
def reference():
    """prompt -> token tuple from cold colocated oracles (one fresh engine
    per prompt, so no prefix cache and no handoff are involved)."""
    out = {}
    for p in PROMPTS + [REQ_A, REQ_B]:
        eng = mk(None)
        eng.cold_start_vanilla()
        r = eng.submit(p, N_NEW)
        eng.run_until_drained()
        out[tuple(p)] = tuple(r.generated)
    return out


def pol(**kw):
    base = dict(min_replicas=1, max_replicas=1,
                target_inflight_per_replica=64, scale_down_idle_ticks=500)
    base.update(kw)
    return AutoscalePolicy(**base)


def disagg(archive, *, prefill_mesh=None, decode_mesh=None, factory=mk,
           prefill_pol=None, decode_pol=None, mode="foundry"):
    return Fleet(factory_for_mesh=factory, mode=mode, archive=archive,
                 pools=[PoolSpec("prefill", prefill_pol or pol(),
                                 prefill_mesh),
                        PoolSpec("decode", decode_pol or pol(),
                                 decode_mesh)])


def drain(fleet, reqs, budget_s=300.0):
    t0 = time.perf_counter()
    while any(q.state not in (ReqState.DONE, ReqState.FAILED) for q in reqs):
        if fleet.tick() == 0:
            time.sleep(0.001)
        assert time.perf_counter() - t0 < budget_s, "fleet wedged"


# ---------------------------------------------------------------------------
# the tentpole: wide prefill, narrow decode, byte-identical streams
# ---------------------------------------------------------------------------
def test_disagg_identity_with_prefix_hit(archive, reference):
    fleet = disagg(archive, prefill_mesh=MeshSpec((1, 1)), decode_mesh=None)
    fleet.start()
    assert fleet.disaggregated
    reqs = [fleet.submit(REQ_A, N_NEW)]
    drain(fleet, reqs)  # REQ_A's fill commits SYS into the prefill radix tree
    reqs.append(fleet.submit(REQ_B, N_NEW))  # admitted via a prefix hit
    reqs += [fleet.submit(p, N_NEW) for p in PROMPTS]
    drain(fleet, reqs)
    fleet.drain_background()
    rep = fleet.report()
    s = rep.summary()

    assert rep.n_failed == 0 and rep.n_done == len(reqs)
    for r in reqs:
        assert tuple(r.generated) == reference[tuple(r.prompt)], \
            f"req {r.req_id} diverged across the prefill->decode handoff"
    # every request crossed pools exactly once, nothing fell back
    assert fleet.handoffs == len(reqs) and fleet.handoff_requeued == 0
    assert s["fallback_compiles"] == 0 and s["background_errors"] == 0
    assert s["handoff_wait_p50_s"] is not None
    assert s["handoff_wait_p95_s"] >= s["handoff_wait_p50_s"]
    # both phases show up in the per-phase queue-wait breakdown
    assert set(s["phase_queue_wait_p50_s"]) == {"prefill", "decode"}
    # the prefill pool's radix tree survived the handoffs and served REQ_B
    pre = fleet.pools["prefill"]._ready()[0].engine
    assert pre.prefill_stats["prefix_hits"] >= 1
    # one capture, two topologies: the wide pool LOADed via stamping, the
    # narrow one via the exact path — and both phases are in the report
    modes = {r.mode for r in rep.replicas if r.mode}
    assert modes == {"foundry", "foundry-stamped"}, modes
    assert [p["phase"] for p in s["pools"]] == ["prefill", "decode"]
    assert all(p["steps"] > 0 for p in s["pools"])
    # requests were stamped with the phase they ended on
    assert all(r.phase == "decode" for r in reqs)
    assert all(r.handoff_wait_s is not None for r in reqs)


def test_decode_capacity_overflow_requeues_with_prefix(archive, reference):
    """More finished fills than free decode slots: the overflow handoff
    requeues onto the decode pool (prefix kept, no retry charged) and every
    stream still matches the oracle."""
    fleet = Fleet(
        factory_for_mesh=lambda m: mk(m, max_batch=2), mode="vanilla",
        pools=[PoolSpec("prefill", pol()), PoolSpec("decode", pol())])
    fleet.start()
    # max_batch=2 everywhere: the prefill pool finishes fills two at a time
    # while the decode pool is still mid-stream on the previous pair
    ref = {}
    for p in PROMPTS:
        eng = mk(None, max_batch=2)
        eng.cold_start_vanilla()
        r = eng.submit(p, 10)
        eng.run_until_drained()
        ref[tuple(p)] = tuple(r.generated)
    reqs = [fleet.submit(PROMPTS[i % len(PROMPTS)], 10) for i in range(6)]
    drain(fleet, reqs)
    rep = fleet.report()
    assert rep.n_failed == 0 and rep.n_done == len(reqs)
    assert fleet.handoff_requeued > 0, \
        "6 requests through a 2-slot decode pool must overflow a handoff"
    assert fleet.handoffs + fleet.handoff_requeued >= len(reqs)
    assert all(q.retries == 0 for q in reqs), \
        "capacity overflow is a resource shortfall, not a worker failure"
    for r in reqs:
        assert tuple(r.generated) == ref[tuple(r.prompt)], \
            f"req {r.req_id} diverged across the requeued handoff"


def test_prefill_crash_salvages_onto_decode_pool(archive, reference):
    """A prefill replica dying MID-FILL: supervision exports its rows and
    the decode pool adopts them cross-pool — the adopter re-derives the fill
    target and finishes the fill, so the stream never diverges."""
    fleet = disagg(archive)
    fleet.start()
    t0 = time.perf_counter()
    while len(fleet._ready()) < 2:
        fleet.tick()
        time.sleep(0.001)
        assert time.perf_counter() - t0 < 300, "provision wedged"
    reqs = [fleet.submit(p, N_NEW) for p in PROMPTS[:4]]
    fleet.tick()  # fills are in flight on the prefill replica
    tgt = fleet.pools["prefill"]._ready()[0]
    assert tgt.load > 0
    with fault_plan(FaultPlan(
            FaultSpec(site="engine.decode_step",
                      tag=f"replica{tgt.stats.replica_id}", times=1,
                      message="prefill chaos"))):
        while fleet.crashes == 0:
            fleet.tick()
            assert time.perf_counter() - t0 < 300, "crash never fired"
    assert fleet.pools["prefill"].crashes == 1
    assert fleet.pools["decode"].crashes == 0
    drain(fleet, reqs)
    rep = fleet.report()
    assert rep.n_failed == 0 and rep.n_done == len(reqs)
    assert rep.salvaged_requests + rep.crash_requeued_requests > 0
    for r in reqs:
        assert tuple(r.generated) == reference[tuple(r.prompt)], \
            f"req {r.req_id} diverged across the prefill crash"
    assert rep.summary()["fallback_compiles"] == 0  # respawn = warm LOAD


def test_per_pool_reshard_does_not_wedge_the_other_pool(archive, reference):
    """The prefill pool reshards live (un-meshed -> (1,1) stamped) while the
    decode pool keeps completing handoffs; the decode pool's topology and
    reshard history are untouched."""
    fleet = disagg(archive)
    fleet.start()
    with pytest.raises(ValueError, match="pass pool="):
        fleet.reshard(make_host_mesh())  # multi-pool fleet: must name one
    reqs = [fleet.submit(p, N_NEW) for p in PROMPTS[:3]]
    t0 = time.perf_counter()
    while len(fleet._ready()) < 2:
        fleet.tick()
        time.sleep(0.001)
        assert time.perf_counter() - t0 < 300, "provision wedged"
    for _ in range(2):
        fleet.tick()
    rep = fleet.reshard(make_host_mesh(), pool="prefill")
    assert rep.pool == "prefill"
    k = 0
    while fleet._reshard is not None:
        reqs.append(fleet.submit(PROMPTS[k % len(PROMPTS)], N_NEW))
        k += 1
        if fleet.tick() == 0:
            time.sleep(0.001)
        assert time.perf_counter() - t0 < 300, "reshard wedged"
    assert rep.done and rep.aborted is None
    drain(fleet, reqs)
    fleet.drain_background()
    frep = fleet.report()
    assert frep.n_failed == 0 and frep.n_done == len(reqs)
    for r in reqs:
        assert tuple(r.generated) == reference[tuple(r.prompt)], \
            f"req {r.req_id} diverged across the per-pool reshard"
    # the switch was scoped to the prefill pool
    assert fleet.pools["prefill"].mesh is not None
    assert fleet.pools["decode"].mesh is None
    assert not fleet.pools["decode"].reshard_reports
    assert [s["pool"] for s in frep.summary()["reshards"]] == ["prefill"]
    # decode replicas were serving (not wedged) during and after the switch
    assert fleet.pools["decode"].step_walls
    assert fleet.handoffs > 0
    assert frep.summary()["fallback_compiles"] == 0

"""Foundry core: topology keys, memory plan, archive, SAVE->LOAD round trip.

Multi-device pieces run in a subprocess with placeholder devices (jax pins
the device count at first init; see core.collective_stub).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Archive, MemoryPlan, PlanMismatch, content_hash,
                        group_buckets, jaxpr_topology_key, topology_key)


# ---------------------------------------------------------------------------
# topology keys
# ---------------------------------------------------------------------------
class TestTopologyKeys:
    def _key(self, fn, *shapes):
        args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
        return topology_key(fn, *args)

    def test_same_structure_different_batch_same_key(self):
        f = lambda x, w: jnp.tanh(x @ w).sum(-1)
        k1 = self._key(f, (8, 64), (64, 32))
        k2 = self._key(f, (128, 64), (64, 32))
        assert k1 == k2

    def test_different_structure_different_key(self):
        f = lambda x, w: jnp.tanh(x @ w).sum(-1)
        g = lambda x, w: jnp.sin(x @ w).sum(-1)
        assert self._key(f, (8, 64), (64, 32)) != self._key(g, (8, 64), (64, 32))

    def test_dtype_changes_key(self):
        f = lambda x, w: (x @ w).sum(-1)
        a1 = [jax.ShapeDtypeStruct((8, 64), jnp.float32),
              jax.ShapeDtypeStruct((64, 32), jnp.float32)]
        a2 = [jax.ShapeDtypeStruct((8, 64), jnp.bfloat16),
              jax.ShapeDtypeStruct((64, 32), jnp.bfloat16)]
        assert topology_key(f, *a1) != topology_key(f, *a2)

    def test_scan_length_is_structural(self):
        def f(x, n):
            return jax.lax.scan(lambda c, _: (c * 2, ()), x,
                                None, length=n)[0]
        k4 = self._key(lambda x: f(x, 4), (8,))
        k8 = self._key(lambda x: f(x, 8), (8,))
        assert k4 != k8  # layer count IS topology

    def test_model_decode_buckets_share_key(self):
        from repro.configs.registry import get_arch
        from repro.models.model import Model
        cfg = get_arch("smollm-360m").reduced()
        m = Model(cfg)

        def key_for(bucket):
            specs = m.cache_specs(bucket, 64)
            tok = jax.ShapeDtypeStruct((bucket,), jnp.int32)
            return topology_key(lambda p, c, t: m.decode_step(p, c, t),
                                m.param_shapes(), specs, tok)

        assert key_for(4) == key_for(16)

    def test_model_layer_count_changes_key(self):
        import dataclasses
        from repro.configs.registry import get_arch
        from repro.models.model import Model
        cfg = get_arch("smollm-360m").reduced()
        cfg2 = dataclasses.replace(cfg, num_layers=cfg.num_layers + 1)

        def key_for(cfg):
            m = Model(cfg)
            specs = m.cache_specs(4, 64)
            tok = jax.ShapeDtypeStruct((4,), jnp.int32)
            return topology_key(lambda p, c, t: m.decode_step(p, c, t),
                                m.param_shapes(), specs, tok)

        assert key_for(cfg) != key_for(cfg2)


# ---------------------------------------------------------------------------
# memory plan
# ---------------------------------------------------------------------------
class TestMemoryPlan:
    def test_determinism(self):
        def build():
            p = MemoryPlan()
            p.alloc("weights", 1 << 20)
            p.alloc("kv_pool", 1 << 22)
            p.set_phase("capture")
            p.alloc("scratch", 12345)
            return p
        assert build().layout_equal(build())

    def test_offsets_monotonic_aligned(self):
        p = MemoryPlan(align=512)
        a = p.alloc("a", 100)
        b = p.alloc("b", 200)
        assert a == p.base and b == p.base + 512
        assert p.extent == 512 + 200 + (512 - 200 % 512)

    def test_load_replay_and_verify(self):
        save = MemoryPlan()
        save.alloc("weights", 1000)
        save.alloc("kv", 5000)
        save.set_phase("capture")
        save.alloc("tmp0", 64)
        save.alloc("tmp1", 64)

        load = MemoryPlan.for_load(save.to_manifest())
        base, extent = load.preallocate()
        assert extent == save.extent
        assert load.verify_alloc("weights", 1000) == save.base + 0
        assert load.verify_alloc("kv", 5000) == save.allocations[1].offset + save.base
        replayed = load.replay_capture_window()
        assert [a.name for a in replayed] == ["tmp0", "tmp1"]
        assert load.layout_equal(save)

    def test_mismatch_detected(self):
        save = MemoryPlan()
        save.alloc("weights", 1000)
        load = MemoryPlan.for_load(save.to_manifest())
        with pytest.raises(PlanMismatch):
            load.verify_alloc("weights", 2000)  # different size -> diverged

    def test_roundtrip_manifest(self):
        p = MemoryPlan()
        p.alloc("x", 77)
        q = MemoryPlan.from_manifest(p.to_manifest())
        assert q.layout_equal(p)

    def test_scoped_extent(self):
        p = MemoryPlan()
        p.alloc("weights", 1000)
        p.alloc("kv_paged/k", 4096, scope="per_rank")
        p.alloc("kv_paged/v", 4096, scope="per_rank")
        assert p.scoped_extent("global") == 1000
        assert p.scoped_extent("per_rank") == 8192
        with pytest.raises(ValueError, match="scope"):
            p.scoped_extent("per_host")


# ---------------------------------------------------------------------------
# archive
# ---------------------------------------------------------------------------
class TestArchive:
    def test_roundtrip(self, tmp_path):
        ar = Archive(manifest={"hello": [1, 2, 3]})
        h = ar.add_blob(b"payload-bytes" * 100)
        path = str(tmp_path / "a.fndry")
        size = ar.save(path)
        assert size > 0
        ar2 = Archive.load(path)
        assert ar2.manifest == {"hello": [1, 2, 3]}
        assert ar2.get_blob(h) == b"payload-bytes" * 100

    def test_corruption_detected(self, tmp_path):
        ar = Archive()
        h = ar.add_blob(b"data")
        ar.blobs[h] = b"tampered"
        with pytest.raises(ValueError):
            Archive.from_bytes(ar.to_bytes())

    def test_dedup_by_content(self):
        ar = Archive()
        h1 = ar.add_blob(b"same")
        h2 = ar.add_blob(b"same")
        assert h1 == h2 and len(ar.blobs) == 1


def test_group_buckets():
    keys = {1: "a", 2: "a", 3: "b", 4: "a", 8: "b"}
    groups = group_buckets(keys)
    by_key = {g.key: g for g in groups}
    assert by_key["a"].buckets == [1, 2, 4]
    assert by_key["a"].template_bucket == 4
    assert by_key["b"].template_bucket == 8


# ---------------------------------------------------------------------------
# SAVE -> LOAD round trip on a 8-placeholder-device mesh (subprocess)
# ---------------------------------------------------------------------------
SAVE_LOAD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.registry import get_arch
from repro.core import (Archive, CaptureSpec, MemoryPlan, foundry_save,
                        foundry_load, wait_for_background, pad_batch_arg)
from repro.launch.mesh import ShardCtx, make_mesh
from repro.models.model import Model

mesh = make_mesh((2, 4), ("data", "model"))
ctx = ShardCtx(mesh=mesh)
cfg = get_arch("smollm-360m").reduced()
m = Model(cfg, ctx)
S = 64

def decode_step(params, cache, tokens):
    return m.decode_step(params, cache, tokens)

def make_args(bucket):
    return (m.param_specs(), m.cache_specs(bucket, S),
            jax.ShapeDtypeStruct((bucket,), jnp.int32,
                                 sharding=ctx.sharding(("batch",), (bucket,))))

buckets = [1, 2, 4, 8, 16]
plan = MemoryPlan()
plan.alloc("params", 123456)
plan.set_phase("capture")
plan.alloc("capture_tmp", 999)

spec = CaptureSpec("decode", decode_step, make_args, buckets,
                   donate_argnums=(1,))
with mesh:
    ar, save_rep = foundry_save([spec], mesh, memory_plan=plan,
                                meta={"arch": cfg.name})
    n_templates = len(ar.manifest["specs"]["decode"]["groups"])
    print("TEMPLATES", n_templates)
    assert 1 <= n_templates < len(buckets), "templating must compress buckets"

    # LOAD
    progs, load_rep, lplan = foundry_load(ar, mesh)
    ps = progs["decode"]
    print("CRITPATH_MS", round(load_rep.critical_path_s * 1e3, 2))
    assert load_rep.fallback_compiles == 0, "same-topology load must not compile"

    # correctness: restored template output == natively compiled output
    params = m.init(jax.random.PRNGKey(0))
    bucket = ps.pick_bucket(3)
    exec_bucket, exe, path = ps.lookup(3)
    cache = m.init_cache(exec_bucket, S)
    toks = jnp.arange(exec_bucket, dtype=jnp.int32) % cfg.vocab_size
    native = jax.jit(decode_step, donate_argnums=(1,)).lower(
        *make_args(exec_bucket)).compile()
    c1, l1 = native(params, m.init_cache(exec_bucket, S), toks)
    c2, l2 = exe(params, cache, toks)
    assert (np.asarray(l1) == np.asarray(l2)).all(), "restored != native"
    print("BITWISE_OK")

    # background exact buckets eventually land
    wait_for_background(load_rep)
    cov = ps.coverage()
    print("EXACT", cov["exact_loaded"])
print("DONE")
"""


@pytest.mark.slow
def test_save_load_roundtrip_multidevice():
    from repro.core.collective_stub import run_in_capture_process
    r = run_in_capture_process(SAVE_LOAD_SCRIPT, 8, timeout=900,
                               pythonpath=os.path.join(os.path.dirname(__file__), "..", "src"))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "BITWISE_OK" in r.stdout
    assert "DONE" in r.stdout

"""Hypothesis property tests for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests require the hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import Archive, MemoryPlan, group_buckets, topology_key
from repro.models.layers import _moe_row, flash_attention

# hypothesis sweeps are long; the CI push job runs -m "not slow"
pytestmark = pytest.mark.slow

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# memory plan
# ---------------------------------------------------------------------------
alloc_seq = st.lists(
    st.tuples(st.sampled_from(["w", "kv", "io", "tmp"]),
              st.integers(min_value=0, max_value=1 << 20)),
    min_size=1, max_size=40)


@given(seq=alloc_seq)
@settings(**SETTINGS)
def test_memory_plan_deterministic_and_disjoint(seq):
    def build():
        p = MemoryPlan()
        for i, (name, size) in enumerate(seq):
            if i == len(seq) // 2:
                p.set_phase("capture")
            p.alloc(f"{name}{i}", size)
        return p

    p1, p2 = build(), build()
    assert p1.layout_equal(p2)
    # allocations are disjoint and ordered
    allocs = p1.allocations
    for a, b in zip(allocs, allocs[1:]):
        assert a.offset + a.size <= b.offset
    # LOAD replay reproduces the exact layout
    load = MemoryPlan.for_load(p1.to_manifest())
    load.preallocate()
    for a in allocs:
        if a.phase == "capture":
            break
        assert load.verify_alloc(a.name, a.size) == p1.base + a.offset
    load.replay_capture_window()
    assert load.layout_equal(p1)


# ---------------------------------------------------------------------------
# archive
# ---------------------------------------------------------------------------
I64 = st.integers(min_value=-(2**63), max_value=2**63 - 1)  # container contract


@given(blobs=st.lists(st.binary(min_size=0, max_size=2048), max_size=10),
       manifest=st.dictionaries(
           st.text(min_size=1, max_size=8),
           st.one_of(I64, st.text(max_size=16), st.lists(I64, max_size=4)),
           max_size=6))
@settings(**SETTINGS)
def test_archive_roundtrip(blobs, manifest):
    ar = Archive(manifest=dict(manifest))
    hashes = [ar.add_blob(b) for b in blobs]
    ar2 = Archive.from_bytes(ar.to_bytes())
    assert ar2.manifest == manifest
    for h, b in zip(hashes, blobs):
        assert ar2.get_blob(h) == b


# ---------------------------------------------------------------------------
# topology keys / grouping
# ---------------------------------------------------------------------------
@given(b1=st.integers(min_value=1, max_value=64),
       b2=st.integers(min_value=1, max_value=64),
       width=st.sampled_from([8, 16, 32]))
@settings(max_examples=15, deadline=None)
def test_topology_key_batch_invariant(b1, b2, width):
    f = lambda x, w: jax.nn.relu(x @ w).sum()
    k1 = topology_key(f, jax.ShapeDtypeStruct((b1, width), jnp.float32),
                      jax.ShapeDtypeStruct((width, width), jnp.float32))
    k2 = topology_key(f, jax.ShapeDtypeStruct((b2, width), jnp.float32),
                      jax.ShapeDtypeStruct((width, width), jnp.float32))
    assert k1 == k2


@given(keys=st.dictionaries(st.integers(min_value=1, max_value=512),
                            st.sampled_from(["a", "b", "c"]),
                            min_size=1, max_size=64))
@settings(**SETTINGS)
def test_group_buckets_partition(keys):
    groups = group_buckets(keys)
    seen = []
    for g in groups:
        assert g.template_bucket == max(g.buckets)
        assert all(keys[b] == g.key for b in g.buckets)
        seen += g.buckets
    assert sorted(seen) == sorted(keys)  # exact partition


# ---------------------------------------------------------------------------
# MoE routing
# ---------------------------------------------------------------------------
@given(t=st.integers(min_value=1, max_value=48),
       e=st.sampled_from([4, 8]),
       k=st.integers(min_value=1, max_value=3),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_moe_lossless_capacity_matches_dense(t, e, k, seed):
    """capacity=T must reproduce the dense top-k mixture exactly."""
    d, f = 16, 32
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (t, d), jnp.float32)
    wr = jax.random.normal(ks[1], (d, e)) * 0.1
    wg = jax.random.normal(ks[2], (e, d, f)) * 0.1
    wu = jax.random.normal(ks[3], (e, d, f)) * 0.1
    wd = jax.random.normal(ks[4], (e, f, d)) * 0.1

    out, _ = _moe_row(x, wr, wg, wu, wd, top_k=k, capacity=t)

    # dense reference: run every expert on every token, mix top-k
    probs = jax.nn.softmax((x @ wr).astype(jnp.float32), -1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    hs = jax.vmap(lambda g, u, dn: (jax.nn.silu(x @ g) * (x @ u)) @ dn,
                  in_axes=(0, 0, 0))(wg, wu, wd)  # [E, T, D]
    picked = jnp.stack([hs[top_i[:, i], jnp.arange(t)]
                        for i in range(k)], axis=1)  # [T, k, D]
    mix = jnp.einsum("tk,tkd->td", top_p, picked)
    np.testing.assert_allclose(np.asarray(out), np.asarray(mix),
                               rtol=2e-4, atol=2e-4)


@given(t=st.integers(min_value=2, max_value=32),
       cap=st.integers(min_value=1, max_value=4),
       seed=st.integers(min_value=0, max_value=1000))
@settings(max_examples=20, deadline=None)
def test_moe_capacity_drop_is_bounded(t, cap, seed):
    """With tight capacity, each expert processes <= capacity tokens and the
    output stays finite (dropped tokens contribute zero, never NaN)."""
    d, f, e, k = 8, 16, 4, 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (t, d), jnp.float32)
    wr = jax.random.normal(ks[1], (d, e)) * 0.1
    wg = jax.random.normal(ks[2], (e, d, f)) * 0.1
    wu = jax.random.normal(ks[3], (e, d, f)) * 0.1
    wd = jax.random.normal(ks[4], (e, f, d)) * 0.1
    out, aux = _moe_row(x, wr, wg, wu, wd, top_k=k, capacity=cap)
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(float(aux))


# ---------------------------------------------------------------------------
# flash attention == naive attention
# ---------------------------------------------------------------------------
@given(b=st.integers(min_value=1, max_value=3),
       sq=st.integers(min_value=1, max_value=40),
       skv=st.integers(min_value=1, max_value=40),
       h=st.sampled_from([2, 4]), g=st.sampled_from([1, 2]),
       causal=st.booleans(),
       seed=st.integers(min_value=0, max_value=1000))
@settings(max_examples=25, deadline=None)
def test_flash_matches_naive(b, sq, skv, h, g, causal, seed):
    if causal and sq != skv:
        skv = sq  # causal masks assume aligned positions
    dh = 8
    hkv = h // g
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, sq, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, hkv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, hkv, dh), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, q_block=16, kv_block=16)

    kf = jnp.repeat(k, g, axis=2)
    vf = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kf) / np.sqrt(dh)
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------
@given(n=st.integers(min_value=1, max_value=20),
       cap=st.integers(min_value=1, max_value=8))
@settings(**SETTINGS)
def test_scheduler_admissions_capacity(n, cap):
    from repro.serving.scheduler import Scheduler
    s = Scheduler()
    for i in range(n):
        s.submit([1, 2], 4)
    admitted = s.admissions(cap)
    assert len(admitted) == min(n, cap)
    assert len(s.running) == len(admitted)
    # failure requeue preserves generated prefixes and order
    for r in admitted:
        s.record_token(r, 7)
        s.requeue_on_failure(r)
    readmitted = s.admissions(cap)
    assert all(r.generated == [7] for r in readmitted)

"""Property tests for the paged KV block allocator and radix prefix cache
(serving/blockpool.py): random alloc/extend/release/fork sequences must
preserve the block/prefix invariants the serving engine relies on — no
double-allocated block, ref counts matching reachable references, eviction
never freeing a live request's block, and full release returning the pool
to its initial free-list state.

Runs under hypothesis when installed (shrinking, example database); in
environments without it, a seeded-random fallback harness draws the same
example distribution so the sweeps still execute rather than skip."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback: deterministic seeded sweeps, no shrinking
    import random

    class _Strat:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 - mirrors the hypothesis namespace
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strat(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strat(lambda r: r.choice(seq))

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            return _Strat(lambda r: [elem.draw(r) for _ in
                                     range(r.randint(min_size, max_size))])

        @staticmethod
        def tuples(*elems):
            return _Strat(lambda r: tuple(e.draw(r) for e in elems))

    def settings(**kw):
        def deco(fn):
            fn._max_examples = kw.get("max_examples", 50)
            return fn
        return deco

    def given(**strats):
        def deco(fn):
            def run():
                for seed in range(getattr(fn, "_max_examples", 50)):
                    rng = random.Random(0xB10C + seed)
                    fn(**{k: s.draw(rng) for k, s in strats.items()})
            run.__name__, run.__doc__ = fn.__name__, fn.__doc__
            return run
        return deco

from repro.serving.blockpool import BlockAllocator, RadixPrefixCache  # noqa: E402

# hypothesis sweeps are long; the CI push job runs -m "not slow"
pytestmark = pytest.mark.slow

SETTINGS = dict(max_examples=50, deadline=None)

BS = 4  # block size in tokens for radix tests


def tree_blocks(cache):
    """Every block currently referenced by a radix node."""
    out, stack = [], list(cache.root.children.values())
    while stack:
        n = stack.pop()
        out.append(n.block)
        stack.extend(n.children.values())
    return out


def check_allocator_invariants(alloc, holders):
    """``holders``: block -> number of non-tree references (request tables);
    cross-checked against the allocator's refs and free list."""
    free = set(alloc._free)
    # no double-allocated block: free list entries are unique and disjoint
    # from anything referenced
    assert len(free) == len(alloc._free), "free list holds duplicates"
    assert BlockAllocator.SCRATCH not in free, "scratch block leaked to free"
    for b in free:
        assert alloc.refs[b] == 0, f"free block {b} has refcount"
    for b, n in holders.items():
        if n > 0:
            assert b not in free, f"live block {b} also on the free list"
    # every non-free block's refcount equals the reachable references
    assert alloc.refs[BlockAllocator.SCRATCH] == 1


# ---------------------------------------------------------------------------
# allocator alone: random alloc / incref / decref interleavings
# ---------------------------------------------------------------------------
@given(n_blocks=st.integers(min_value=2, max_value=40),
       ops=st.lists(st.tuples(st.sampled_from(["alloc", "inc", "dec"]),
                              st.integers(min_value=0, max_value=1000)),
                    max_size=120))
@settings(**SETTINGS)
def test_allocator_refcounts_match_model(n_blocks, ops):
    alloc = BlockAllocator(n_blocks)
    model = {}  # block -> refcount we maintain independently
    held = []   # blocks with refs, for targeting inc/dec
    for op, pick in ops:
        if op == "alloc":
            if alloc.n_free == 0:
                with pytest.raises(RuntimeError):
                    alloc.alloc()
                continue
            b = alloc.alloc()
            assert b != BlockAllocator.SCRATCH
            assert model.get(b, 0) == 0, f"block {b} double-allocated"
            model[b] = 1
            held.append(b)
        elif op == "inc" and held:
            b = held[pick % len(held)]
            alloc.incref(b)
            model[b] += 1
        elif op == "dec" and held:
            b = held[pick % len(held)]
            alloc.decref(b)
            model[b] -= 1
            if model[b] == 0:
                del model[b]
                held = [x for x in held if x != b]
    for b, n in model.items():
        assert alloc.refs[b] == n
    assert alloc.n_free == alloc.n_blocks - 1 - len(model)
    # releasing everything returns the pool to its initial free-list state
    for b in list(model):
        for _ in range(model[b]):
            alloc.decref(b)
    assert sorted(alloc._free) == list(range(1, n_blocks))
    assert all(r == 0 for i, r in enumerate(alloc.refs) if i != 0)


def test_allocator_guards():
    alloc = BlockAllocator(4)
    b = alloc.alloc()
    alloc.decref(b)
    with pytest.raises(ValueError):
        alloc.decref(b)  # decref of a free block
    with pytest.raises(ValueError):
        alloc.incref(b)  # incref of a free block
    alloc.decref(BlockAllocator.SCRATCH)  # no-op, scratch pinned
    assert alloc.refs[BlockAllocator.SCRATCH] == 1
    with pytest.raises(ValueError):
        BlockAllocator(1)


# ---------------------------------------------------------------------------
# radix tree driven by request-like lifecycles
# ---------------------------------------------------------------------------
token = st.integers(min_value=0, max_value=5)  # tiny alphabet: forced shares
prompt = st.lists(token, min_size=1, max_size=5 * BS)


class _Sim:
    """Drives RadixPrefixCache the way PagedKVCachePool does: requests
    match a prefix (incref adopted blocks), allocate private blocks for the
    rest, commit full prompt chunks on fill completion (with dedupe swaps),
    and release by decref'ing their whole table."""

    def __init__(self, n_blocks):
        self.alloc = BlockAllocator(n_blocks)
        self.cache = RadixPrefixCache(self.alloc, BS)
        self.live = {}  # req key -> (prompt, table)

    def begin(self, key, toks):
        cap = max(0, len(toks) - 1)
        matched = self.cache.match(toks[:cap])
        table = []
        for node in matched:
            self.alloc.incref(node.block)
            table.append(node.block)
        # private blocks for the uncached remainder (incl. write headroom)
        n_need = -(-(len(toks)) // BS) - len(table)
        try:
            for _ in range(n_need):
                table.append(self._alloc_evicting())
        except RuntimeError:
            for b in table:
                self.alloc.decref(b)
            return False
        self.live[key] = (toks, table)
        return True

    def _alloc_evicting(self):
        while True:
            try:
                return self.alloc.alloc()
            except RuntimeError:
                if not self.cache.evict_lru():
                    raise

    def commit(self, key):
        toks, table = self.live[key]
        swaps = self.cache.insert(toks, table)
        for idx, shared in swaps:
            self.alloc.incref(shared)
            self.alloc.decref(table[idx])
            table[idx] = shared

    def release(self, key):
        _, table = self.live.pop(key)
        for b in table:
            self.alloc.decref(b)

    def holders(self):
        out = {}
        for _, table in self.live.values():
            for b in table:
                out[b] = out.get(b, 0) + 1
        return out


@given(prompts=st.lists(prompt, min_size=1, max_size=12),
       script=st.lists(st.tuples(st.sampled_from(["begin", "commit",
                                                  "release", "evict"]),
                                 st.integers(min_value=0, max_value=11)),
                       max_size=60),
       n_blocks=st.integers(min_value=4, max_value=24))
@settings(**SETTINGS)
def test_radix_lifecycle_preserves_invariants(prompts, script, n_blocks):
    sim = _Sim(n_blocks)
    begun, committed = set(), set()
    for op, i in script:
        key = i % len(prompts)
        if op == "begin" and key not in begun:
            if sim.begin(key, prompts[key]):
                begun.add(key)
        elif op == "commit" and key in begun and key not in committed:
            sim.commit(key)
            committed.add(key)
        elif op == "release" and key in begun:
            sim.release(key)
            begun.discard(key)
            committed.discard(key)
        elif op == "evict":
            sim.cache.evict_lru()

        # --- invariants after every operation -------------------------
        holders = sim.holders()
        check_allocator_invariants(sim.alloc, holders)
        tb = tree_blocks(sim.cache)
        assert len(tb) == len(set(tb)), "two radix nodes share a block"
        # refcount == live-table references + tree references, exactly
        tree_refs = {}
        for b in tb:
            tree_refs[b] = tree_refs.get(b, 0) + 1
        for b in range(1, sim.alloc.n_blocks):
            want = holders.get(b, 0) + tree_refs.get(b, 0)
            assert sim.alloc.refs[b] == want, \
                f"block {b}: refs {sim.alloc.refs[b]} != reachable {want}"
        # eviction candidates never include a block a live request holds
        for node in sim.cache.evictable():
            assert holders.get(node.block, 0) == 0, \
                "evictable node backs a live request's block"

    # full teardown: release every request, evict the whole tree
    for key in list(begun):
        sim.release(key)
    while sim.cache.evict_lru():
        pass
    assert sim.cache.n_nodes == 0
    assert sorted(sim.alloc._free) == list(range(1, n_blocks)), \
        "full release must return the pool to its initial free-list state"


@given(toks=st.lists(token, min_size=2 * BS, max_size=4 * BS))
@settings(**SETTINGS)
def test_radix_match_is_longest_prefix(toks):
    sim = _Sim(64)
    assert sim.begin("a", toks)
    sim.commit("a")
    # full re-match of the same prompt (capped at len-1 like the pool)
    cap = len(toks) - 1
    matched = sim.cache.match(toks[:cap])
    assert len(matched) == cap // BS
    for i, node in enumerate(matched):
        assert node.chunk == tuple(toks[i * BS:(i + 1) * BS])
    # a diverging suffix matches only the shared chunks
    forked = toks[:BS] + [t + 1 for t in toks[BS:]]
    assert len(sim.cache.match(forked[:len(forked) - 1])) == 1
    sim.release("a")


@given(toks=st.lists(token, min_size=2 * BS, max_size=3 * BS),
       n_extra=st.integers(min_value=1, max_value=6))
@settings(**SETTINGS)
def test_radix_dedupe_swaps_converge(toks, n_extra):
    """Concurrent cold fills of the same prompt commit in sequence; dedupe
    swaps must collapse them all onto one chain of shared blocks."""
    sim = _Sim(128)
    keys = [f"r{i}" for i in range(n_extra + 1)]
    for k in keys:
        # all begin before anyone commits: every fill is cold and private
        assert sim.begin(k, toks)
    for k in keys:
        sim.commit(k)
    chains = {tuple(sim.live[k][1][: len(toks) // BS]) for k in keys}
    assert len(chains) == 1, "dedupe swaps did not converge tables"
    n_full = len(toks) // BS
    for b in next(iter(chains)):
        assert sim.alloc.refs[b] == len(keys) + 1  # every table + the tree
    for k in keys:
        sim.release(k)
    while sim.cache.evict_lru():
        pass
    assert sorted(sim.alloc._free) == list(range(1, 128))
    assert n_full >= 2  # strategy sanity: the chain was non-trivial

"""Unit tests for the trip-count-aware HLO cost analyzer — the §Roofline
measurement tool itself (synthetic HLO fixtures + a live compiled module)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.roofline import (HloCostModel, _parse_shape, _shape_bytes,
                                     model_flops)

SYNTH = """\
HloModule jit_f

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[4,4]<=[16], use_global_device_ids=true, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[8,16]) -> f32[8,16] {
  %x = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,16]) tuple(%zero, %x)
  %w2 = f32[16,16]{1,0} constant({...})
  %dot.0 = f32[8,16]{1,0} dot(%x, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %wh = (s32[], f32[8,16]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%wh), index=1
}
"""


class TestSyntheticHlo:
    def setup_method(self, _):
        self.cm = HloCostModel(SYNTH)

    def test_trip_count_extracted(self):
        assert self.cm.trips.get("body") == 12

    def test_dot_flops_multiplied_by_trips(self):
        # dot: 2*8*16*16 = 4096 flops; f32-sourced -> x4 penalty
        per_dot = 2 * 8 * 16 * 16 * self.cm.F32_DOT_PENALTY
        # one dot at top level + one dot x12 in the body
        assert self.cm.dot_flops() == pytest.approx(per_dot * 13)

    def test_collective_ring_model(self):
        wire, by_kind = self.cm.collective_wire_bytes(16)
        # all-reduce of 8*16*4B in groups of 4, ring: 2*S*(g-1)/g, x12 trips
        s = 8 * 16 * 4
        assert by_kind["all-reduce"] == pytest.approx(2 * s * 3 / 4 * 12)

    def test_entry_found(self):
        assert self.cm.entry == "main"


def test_shape_parsing():
    assert _parse_shape("f32[8,16]{1,0}") == ("f32", (8, 16))
    assert _parse_shape("bf16[2,3,4]") == ("bf16", (2, 3, 4))
    assert _parse_shape("pred[]")[1] == ()
    assert _shape_bytes("(f32[8,16]{1,0}, bf16[4]{0})") == 8 * 16 * 4 + 4 * 2


class TestLiveModule:
    """Against a real compiled scan program: the analyzer must out-count
    cost_analysis by ~the trip factor (the while-body undercount)."""

    def test_scan_trip_correction(self):
        L, D = 16, 64

        def f(ws, x):
            def body(c, w):
                return jnp.tanh(c @ w), ()
            y, _ = jax.lax.scan(body, x, ws)
            return y

        ws = jnp.zeros((L, D, D), jnp.float32)
        x = jnp.zeros((8, D), jnp.float32)
        compiled = jax.jit(f).lower(ws, x).compile()
        cm = HloCostModel(compiled.as_text())
        raw = compiled.cost_analysis()
        if isinstance(raw, (list, tuple)):  # jax<=0.4.x returns [dict]
            raw = raw[0]
        raw = raw["flops"]
        ours = cm.dot_flops()
        per_layer = 2 * 8 * D * D
        # our count must cover all L layers (within the f32 penalty factor)
        assert ours >= per_layer * L
        # XLA's raw count misses the trip multiplication
        assert raw < per_layer * L

    def test_convert_only_fusion_free(self):
        hlo = """\
HloModule m

%fused_convert (p0: bf16[128,128]) -> f32[128,128] {
  %p0 = bf16[128,128]{1,0} parameter(0)
  ROOT %c = f32[128,128]{1,0} convert(%p0)
}

ENTRY %main (x: bf16[128,128]) -> f32[128,128] {
  %x = bf16[128,128]{1,0} parameter(0)
  ROOT %f = f32[128,128]{1,0} fusion(%x), kind=kLoop, calls=%fused_convert
}
"""
        cm = HloCostModel(hlo)
        assert cm.hbm_bytes() == 0.0  # convert-only: fuses into a dot on TPU

    def test_dus_fusion_counts_slice_only(self):
        hlo = """\
HloModule m

%fused_dus (p0: s32[], p1: f32[1,64], p2: f32[16,64]) -> f32[16,64] {
  %p2 = f32[16,64]{1,0} parameter(2)
  %p1 = f32[1,64]{1,0} parameter(1)
  %p0 = s32[] parameter(0)
  %z = s32[] constant(0)
  ROOT %dus = f32[16,64]{1,0} dynamic-update-slice(%p2, %p1, %p0, %z)
}

ENTRY %main (i: s32[], u: f32[1,64], buf: f32[16,64]) -> f32[16,64] {
  %i = s32[] parameter(0)
  %u = f32[1,64]{1,0} parameter(1)
  %buf = f32[16,64]{1,0} parameter(2)
  ROOT %f = f32[16,64]{1,0} fusion(%i, %u, %buf), kind=kLoop, calls=%fused_dus
}
"""
        cm = HloCostModel(hlo)
        # 2x the update slice (read-modify-write) + scalar index,
        # not the full buffer
        assert cm.hbm_bytes() == pytest.approx(2 * 1 * 64 * 4 + 4)


def test_model_flops_formulas():
    from repro.configs.base import SHAPE_CELLS
    from repro.configs.registry import get_arch
    yi = get_arch("yi-9b")
    mf_train = model_flops(yi, SHAPE_CELLS["train_4k"])
    # 6*N*D dominates: N~8.8e9 params, D=256*4096 tokens
    assert mf_train == pytest.approx(6 * 8.3e9 * 256 * 4096, rel=0.25)
    mf_dec = model_flops(yi, SHAPE_CELLS["decode_32k"])
    assert mf_dec < mf_train / 1000  # one token per sequence
    moe = get_arch("moonshot-v1-16b-a3b")
    # MoE uses ACTIVE params only
    assert model_flops(moe, SHAPE_CELLS["train_4k"]) < \
        6 * moe.param_count() * 256 * 4096

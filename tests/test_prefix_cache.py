"""Radix prefix cache on the paged KV pool: token identity for prefix hits
(vs cold prefill, across an archive SAVE->LOAD round trip), the prefill-
savings regression the TTFT win rests on, and admission accounting that
charges only the uncached suffix (ISSUE 6 satellites)."""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.core import Archive
from repro.models.model import Model
from repro.serving.engine import ServingEngine

# 12-token shared system prompt: three full blocks at block_size=4, so a
# follow-up request hits cached blocks AND forks copy-on-write mid-block
SYS = [9, 4, 7, 7, 1, 3, 8, 2, 6, 6, 2, 5]
REQ_A = SYS + [5, 1]
REQ_B = SYS + [2, 8, 4]


def make_engine(**kw):
    cfg = get_arch("smollm-360m").reduced()
    m = Model(cfg)
    kw.setdefault("kv_block_size", 4)
    eng = ServingEngine(m, max_batch=8, max_seq=64, bucket_mode="pow2", **kw)
    eng.load_weights(rng=jax.random.PRNGKey(7))
    return eng


def serve_one(eng, prompt, n_new=6):
    r = eng.submit(prompt, n_new)
    eng.run_until_drained()
    assert r.state.value == "done", r.fail_reason
    return tuple(r.generated)


def test_engine_defaults_to_paged_layout():
    eng = make_engine()
    assert eng.kv_layout == "paged"
    eng.cold_start_vanilla()
    from repro.serving.blockpool import PagedKVCachePool
    assert isinstance(eng.pool, PagedKVCachePool)


def test_prefix_hit_matches_cold_prefill():
    """A request whose prompt shares a cached prefix must produce a
    byte-identical token stream to a cold engine that never cached it."""
    warm = make_engine()
    warm.cold_start_vanilla()
    serve_one(warm, REQ_A)  # populates the radix tree with SYS blocks
    hit = serve_one(warm, REQ_B)
    assert warm.prefill_stats["prefix_hits"] == 1
    assert warm.prefill_stats["cached_tokens"] > 0

    cold = make_engine()
    cold.cold_start_vanilla()
    miss = serve_one(cold, REQ_B)
    assert cold.prefill_stats["prefix_hits"] == 0
    assert hit == miss, "prefix-cache hit diverged from cold prefill"


def test_prefix_hit_identity_across_archive_roundtrip():
    """SAVE on one engine, LOAD on a fresh one: the restored engine's
    prefix-cache hits stay byte-identical, with zero fallback compiles."""
    eng1 = make_engine()
    archive, save_rep = eng1.save_archive()
    assert archive.manifest["specs"]["decode"]["tags"]["kv_layout"] == "paged"
    eng1.cold_start_vanilla()
    ref_a = serve_one(eng1, REQ_A)
    ref_b = serve_one(eng1, REQ_B)  # hit

    eng2 = make_engine()
    rep = eng2.cold_start_foundry(Archive.from_bytes(archive.to_bytes()),
                                  background_exact=False)
    assert rep.fallback_compiles == 0
    assert eng2.kv_layout == "paged"
    assert serve_one(eng2, REQ_A) == ref_a
    assert serve_one(eng2, REQ_B) == ref_b
    assert eng2.prefill_stats["prefix_hits"] == 1


def test_prefill_savings_regression():
    """The TTFT-win mechanism without wall-clock flakiness: the second
    request with a shared system prompt prefills strictly fewer tokens and
    takes strictly fewer decode-fill steps than the first."""
    eng = make_engine()
    eng.cold_start_vanilla()

    r1 = eng.submit(REQ_A, 4)
    eng.run_until_drained()
    first_prefilled = eng.prefill_stats["prefilled_tokens"]
    first_steps = eng.decode_steps - len(r1.generated) + 1  # steps to token 1

    r2 = eng.submit(REQ_B, 4)
    steps0 = eng.decode_steps
    eng.run_until_drained()
    second_prefilled = (eng.prefill_stats["prefilled_tokens"]
                        - first_prefilled)
    second_steps = (eng.decode_steps - steps0) - len(r2.generated) + 1

    assert second_prefilled < first_prefilled, \
        (f"shared-prefix request prefilled {second_prefilled} tokens, "
         f"first prefilled {first_prefilled}")
    assert second_steps < first_steps
    assert eng.prefill_stats["cached_tokens"] >= 8  # >= two full blocks


def test_cow_fork_does_not_corrupt_donor():
    """Copy-on-write divergence: serving the forked request must not
    perturb the cached donor chain — the original stream stays identical
    when re-served after the fork."""
    eng = make_engine()
    eng.cold_start_vanilla()
    ref_a = serve_one(eng, REQ_A)
    serve_one(eng, REQ_B)  # forks COW off REQ_A's chain
    again = serve_one(eng, REQ_A)  # re-serve the donor's prompt (full hit)
    assert again == ref_a, "COW fork corrupted the donor's cached blocks"
    assert eng.prefill_stats["prefix_hits"] == 2


def test_lru_eviction_under_pressure_keeps_serving():
    """A pool too small to cache every distinct prompt chain must keep
    serving correctly by evicting unreferenced radix nodes LRU."""
    eng = make_engine(kv_blocks=13)  # 12 usable blocks of 4 tokens
    eng.cold_start_vanilla()
    streams = {}
    prompts = {i: [i + 1] * 9 + [i + 2, i + 3] for i in range(6)}
    for i, p in prompts.items():
        streams[i] = serve_one(eng, p, 3)
    assert eng.pool.prefix.stats["evictions"] > 0
    # every stream matches a cold engine's (eviction never served garbage)
    cold = make_engine()
    cold.cold_start_vanilla()
    for i, p in prompts.items():
        assert serve_one(cold, p, 3) == streams[i], f"prompt {i} diverged"


# ---------------------------------------------------------------------------
# admission accounting: charge the uncached suffix, not the full prompt
# ---------------------------------------------------------------------------
def test_admission_counts_only_uncached_suffix():
    """Boundary: a pool with room for ONE cold request's end-to-end blocks
    but not two. Cold, the second submission defers until the first
    completes. With the shared prefix already cached, both requests'
    uncached need fits and they are admitted concurrently."""
    # blocks_needed(prompt=14, max_new=2) = ceil(16/4) = 4; two cold
    # requests reserve 8 > 7 usable; warm, the tree pins 3 shared blocks
    # and each request needs 4 - 3 = 1 fresh: 3 + 1 + 1 = 5 <= 7.
    a = SYS + [5, 1]
    b = SYS + [2, 8]

    cold = make_engine(kv_blocks=8)
    cold.cold_start_vanilla()
    ra, rb = cold.submit(a, 2), cold.submit(b, 2)
    cold.step()
    states = sorted(r.state.value for r in (ra, rb))
    assert states == ["running", "waiting"], \
        f"cold pool admitted both over-budget requests: {states}"
    cold.run_until_drained()
    assert ra.state.value == rb.state.value == "done"

    warm = make_engine(kv_blocks=8)
    warm.cold_start_vanilla()
    serve_one(warm, SYS + [1], 2)  # caches SYS's three full blocks
    ra, rb = warm.submit(a, 2), warm.submit(b, 2)
    warm.step()
    assert ra.state.value == rb.state.value == "running", \
        "cached prefix must admit both: only the uncached suffix counts"
    warm.run_until_drained()
    assert ra.state.value == rb.state.value == "done"


def test_admission_rejects_impossible_request_cleanly():
    """A request whose end-to-end table exceeds every usable block can
    never be served — terminal failure, not an eternal deferral."""
    eng = make_engine(kv_blocks=4)  # 3 usable blocks = 12 positions
    eng.cold_start_vanilla()
    doomed = eng.submit(list(range(1, 15)), 4)  # needs ceil(18/4)=5 blocks
    ok = eng.submit([1, 2, 3], 2)
    eng.run_until_drained()
    assert doomed.state.value == "failed"
    assert "KV blocks" in doomed.fail_reason
    assert ok.state.value == "done"
    assert eng.scheduler.pending == 0

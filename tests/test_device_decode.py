"""Device-resident decode loop: token identity vs the pre-fusion host loop,
O(B) transfer regression, donation feedback fast path, and lookup memoization.

The device loop (serving/engine.py docstring) keeps decode state on the
device end to end: the captured step fuses greedy sampling and donates the
KV cache, sampled ids feed back device-to-device, and the host reads only B
int32 ids per token. These tests pin the two load-bearing claims: the token
streams are byte-identical to the host loop on every restore path, and the
per-step host traffic is O(B), not O(B x padded_vocab).
"""
import os
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.core import Archive, ProgramSet, ReshardingExecutable, group_buckets
from repro.models.model import Model
from repro.serving.engine import ServingEngine

PROMPTS = [[5, 9, 2], [11, 3], [7, 7, 7, 1], [2], [13, 4, 9, 9, 1, 2]]


def make_engine(loop="device", **kw):
    cfg = get_arch("smollm-360m").reduced()
    m = Model(cfg)
    eng = ServingEngine(m, max_batch=8, max_seq=64, bucket_mode="pow2",
                        decode_loop=loop, **kw)
    eng.load_weights(rng=jax.random.PRNGKey(7))
    return eng


def serve_tokens(eng, prompts=PROMPTS, n_new=6, stagger=False):
    # staggered lengths force completions/compaction mid-stream, which is
    # exactly what invalidates the device-resident token vector
    reqs = [eng.submit(p, n_new + (i % 3 if stagger else 0))
            for i, p in enumerate(prompts)]
    eng.run_until_drained()
    assert all(r.state.value == "done" for r in reqs)
    return [tuple(r.generated) for r in reqs]


# ---------------------------------------------------------------------------
# token identity: device loop vs pre-refactor host loop
# ---------------------------------------------------------------------------
def test_device_loop_matches_host_loop_vanilla():
    eng_h = make_engine("host")
    eng_h.cold_start_vanilla()
    ref = serve_tokens(eng_h, stagger=True)
    eng_d = make_engine("device")
    eng_d.cold_start_vanilla()
    out = serve_tokens(eng_d, stagger=True)
    assert out == ref, "fused-sampling loop diverged from host argmax loop"
    # the device loop must not have re-packed tokens every step: rebuilds
    # happen only on scheduling events (admission batches + completions)
    assert eng_d.transfer_stats["token_rebuilds"] < eng_d.decode_steps
    assert eng_h.transfer_stats["token_rebuilds"] == eng_h.decode_steps


def test_device_loop_exact_restore_identity():
    """exact restore path: archive save -> byte round trip -> LOAD."""
    eng = make_engine("device")
    archive, _ = eng.save_archive()
    assert archive.manifest["specs"]["decode"]["tags"]["fused_sampling"]
    eng.cold_start_vanilla()
    ref = serve_tokens(eng)

    eng2 = make_engine("device")
    rep = eng2.cold_start_foundry(Archive.from_bytes(archive.to_bytes()),
                                  background_exact=False)
    assert rep.mode == "foundry" and rep.fallback_compiles == 0
    assert serve_tokens(eng2) == ref

    # and with background exact swaps hot-swapping mid-serve
    eng3 = make_engine("device")
    rep3 = eng3.cold_start_foundry(Archive.from_bytes(archive.to_bytes()),
                                   background_exact=True)
    from repro.core import wait_for_background
    wait_for_background(eng3._load_report)
    assert eng3._load_report.background_errors == 0
    assert serve_tokens(eng3) == ref


def test_device_loop_fallback_compile_identity():
    """A template whose executable blob cannot be deserialized must degrade
    to the compile-from-StableHLO fallback and still emit identical tokens."""
    eng = make_engine("device")
    archive, _ = eng.save_archive()
    eng.cold_start_vanilla()
    ref = serve_tokens(eng)

    broken = Archive.from_bytes(archive.to_bytes())
    junk = broken.add_blob(pickle.dumps("not an executable payload"))
    spec_m = broken.manifest["specs"]["decode"]
    for g in spec_m["groups"]:
        if g["executable_blob"]:
            g["executable_blob"] = junk
    eng2 = make_engine("device")
    rep = eng2.cold_start_foundry(broken, background_exact=False)
    assert rep.fallback_compiles > 0, "junk template must force the fallback"
    assert serve_tokens(eng2) == ref


def test_archive_without_tags_served_with_host_loop():
    """Pre-fusion archives (no spec tags) carry logits-returning programs;
    a LOADing engine must bind the host loop, whatever its default. They
    also predate the paged KV layout, so the SAVE side is pinned to the
    slot pool — and the LOADing engine must adopt it (untagged archives
    default to kv_layout='slot', the pre-paged calling convention)."""
    eng = make_engine("host", kv_layout="slot")
    archive, _ = eng.save_archive()
    del archive.manifest["specs"]["decode"]["tags"]
    eng2 = make_engine("device")
    eng2.cold_start_foundry(archive, background_exact=False)
    assert eng2.decode_loop == "host"
    assert eng2.kv_layout == "slot"
    serve_tokens(eng2, PROMPTS[:2])


# ---------------------------------------------------------------------------
# transfer regression: steady-state decode moves O(B), not O(B x vocab)
# ---------------------------------------------------------------------------
def _steady_d2h_bytes_per_step(eng, monkeypatch, steps=6):
    """Externally measured device->host bytes per steady decode step (counts
    numpy.asarray materializations of jax arrays, the readback transport)."""
    for _ in range(4):
        eng.submit([3, 1, 4], steps + 8)
    # admissions + prefill: the paged layout decode-fills the 3-token
    # prompts over the first 3 steps (each a scheduled token rebuild), so
    # the steady window starts after the fill completes
    for _ in range(3):
        eng.step()
    moved = {"d2h": 0}
    real_asarray = np.asarray

    def counting(a, *args, **kw):
        out = real_asarray(a, *args, **kw)
        if isinstance(a, jax.Array):
            moved["d2h"] += out.nbytes
        return out

    h2d0 = eng.transfer_stats["h2d_bytes"]
    rebuilds0 = eng.transfer_stats["token_rebuilds"]
    monkeypatch.setattr(np, "asarray", counting)
    try:
        for _ in range(steps):
            eng.step()
    finally:
        monkeypatch.undo()
    h2d = eng.transfer_stats["h2d_bytes"] - h2d0
    rebuilds = eng.transfer_stats["token_rebuilds"] - rebuilds0
    return moved["d2h"] / steps, h2d, rebuilds


def test_steady_state_transfer_is_O_batch(monkeypatch):
    eng = make_engine("device")
    eng.cold_start_vanilla()
    per_step, h2d, rebuilds = _steady_d2h_bytes_per_step(eng, monkeypatch)
    bucket = eng.pool.cur_bucket
    vocab_p = eng.cfg.padded_vocab
    assert per_step <= bucket * 4, \
        f"device loop read back {per_step} B/step, expected <= {bucket * 4}"
    assert per_step < bucket * vocab_p * 4 / 8, "readback is not O(B)"
    # nothing crossed host->device and no token re-pack happened mid-window
    assert h2d == 0 and rebuilds == 0


def test_host_loop_transfer_is_O_batch_times_vocab(monkeypatch):
    """The control: the pre-fusion loop really does move the logits matrix,
    so the O(B) assertion above is measuring what it claims to measure."""
    eng = make_engine("host")
    eng.cold_start_vanilla()
    per_step, h2d, rebuilds = _steady_d2h_bytes_per_step(eng, monkeypatch)
    bucket = eng.pool.cur_bucket
    assert per_step >= bucket * eng.cfg.vocab_size * 4
    assert rebuilds > 0  # host loop re-packs tokens every step


# ---------------------------------------------------------------------------
# donation feedback fast path (ReshardingExecutable extension)
# ---------------------------------------------------------------------------
def test_resharding_executable_feedback_donation():
    """Caller buffers are copied before donation (the XLA-CPU deserialized-
    donation crash workaround), but the wrapper's own fed-back outputs are
    donated in place — the steady-state decode contract."""
    def f(cache, x):
        return {"v": cache["v"] + x}, cache["v"].sum()

    compiled = jax.jit(f, donate_argnums=(0,)).lower(
        {"v": jax.ShapeDtypeStruct((8,), jnp.float32)},
        jax.ShapeDtypeStruct((), jnp.float32)).compile()
    wrap = ReshardingExecutable(compiled, donate_argnums=(0,))

    c0 = {"v": jax.device_put(np.ones(8, np.float32))}  # host-origin buffer
    out1, _ = wrap(c0, jnp.float32(1.0))
    assert not c0["v"].is_deleted(), \
        "host-origin donated arg must be copied, not donated"
    out2, _ = wrap(out1, jnp.float32(1.0))
    assert out1["v"].is_deleted(), \
        "fed-back wrapper output should be donated in place (no copy)"
    assert not out2["v"].is_deleted()
    np.testing.assert_allclose(np.asarray(out2["v"]), 3.0)

    # a host-mutated leaf inside an otherwise-owned tree is re-materialized
    out3, _ = wrap({"v": jax.device_put(np.asarray(out2["v"]))},
                   jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(out3["v"]), 4.0)


# ---------------------------------------------------------------------------
# ProgramSet.lookup memoization
# ---------------------------------------------------------------------------
def test_lookup_memoized_and_invalidated():
    groups = group_buckets({1: "k", 2: "k", 4: "k", 8: "k8"})
    ps = ProgramSet(groups)
    tmpl = object()
    ps.set_template("k", tmpl)
    assert ps.lookup(1) == (4, tmpl, "template")  # pad to template bucket
    assert 1 in ps._lookup_cache
    assert ps.lookup(1) == (4, tmpl, "template")  # dict-hit path
    assert ps.stats["pad_dispatches"] == 2

    exact = object()
    ps.set_exact(1, exact)  # hot-swap must invalidate the memo
    assert ps._lookup_cache == {}
    assert ps.lookup(1) == (1, exact, "exact")
    assert ps.lookup(1) == (1, exact, "exact")
    assert ps.stats["exact_dispatches"] == 2


# ---------------------------------------------------------------------------
# stamped + fallback restore paths (multi-device, subprocess)
# ---------------------------------------------------------------------------
DEVICE_STAMP_SCRIPT = r"""
import numpy as np
import jax
from repro.configs.registry import get_arch
from repro.launch.mesh import ShardCtx, make_capture_mesh, make_tp_mesh
from repro.models.model import Model
from repro.serving.engine import ServingEngine

def build(mesh, loop):
    cfg = get_arch("smollm-360m").reduced()
    eng = ServingEngine(Model(cfg, ShardCtx(mesh=mesh)), max_batch=4,
                        max_seq=32, bucket_mode="pow2", decode_loop=loop)
    eng.load_weights(rng=jax.random.PRNGKey(0))
    return eng

archives = {}
mesh_cap = make_capture_mesh()
with mesh_cap:
    for loop in ("device", "host"):
        archives[loop] = build(mesh_cap, loop).save_archive()[0]
assert archives["device"].manifest["specs"]["decode"]["tags"]["fused_sampling"]

def serve(loop, allow_stamping):
    jax.clear_caches()
    mesh = make_tp_mesh(2)
    with mesh:
        e = build(mesh, loop)
        rep = e.cold_start_foundry(archives[loop], background_exact=False,
                                   allow_stamping=allow_stamping)
        assert e.decode_loop == loop
        for p in ([1, 2, 3], [9, 8]):
            e.submit(p, 6)
        e.run_until_drained()
        toks = sorted((r.req_id, tuple(r.generated))
                      for r in e.scheduler.done)
        return rep, toks, dict(e.transfer_stats)

rep_s, toks_s, xfer = serve("device", True)
assert rep_s.mode == "foundry-stamped", rep_s.mode
assert rep_s.fallback_compiles == 0, "stamped rebind must not compile"
# the stamped device loop reads back only O(B) ids per step
assert xfer["d2h_bytes"] <= 6 * 2 * 4 * 4, xfer
print("STAMPED_DEVICE_OK")

rep_f, toks_f, _ = serve("device", False)
assert rep_f.mode == "foundry" and rep_f.fallback_compiles > 0
assert toks_s == toks_f, f"stamped {toks_s} != fallback {toks_f}"
print("FALLBACK_MATCHES")

rep_h, toks_h, _ = serve("host", True)
assert rep_h.mode == "foundry-stamped"
assert toks_s == toks_h, f"device {toks_s} != host {toks_h}"
print("HOST_LOOP_MATCHES")
print("DONE")
"""


@pytest.mark.slow
def test_device_loop_stamped_and_fallback_identity():
    from repro.core.collective_stub import run_in_capture_process
    r = run_in_capture_process(
        DEVICE_STAMP_SCRIPT, 2, timeout=900,
        pythonpath=os.path.join(os.path.dirname(__file__), "..", "src"))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    for marker in ("STAMPED_DEVICE_OK", "FALLBACK_MATCHES",
                   "HOST_LOOP_MATCHES", "DONE"):
        assert marker in r.stdout

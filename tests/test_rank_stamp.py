"""Rank-stamping LOAD (paper §4.3): peer state, rank-relative extents,
archive codec fallback, and the stamped-vs-fallback restore equivalence.

The multi-device stamped restore runs in a subprocess with placeholder
devices (jax pins the device count at first init; core/collective_stub.py).
"""
import os

import pytest

from repro.core import (Archive, MemoryPlan, RankDelta, build_rank_deltas,
                        peer_groups, rank_coords, stamp_compatible)


# ---------------------------------------------------------------------------
# peer state (collective_stub)
# ---------------------------------------------------------------------------
class TestPeerState:
    def test_rank_coords_row_major(self):
        assert rank_coords([2, 2]) == [(0, 0), (0, 1), (1, 0), (1, 1)]
        assert rank_coords([]) == [()]

    def test_peer_groups_2x4(self):
        g = peer_groups([2, 4], ["data", "model"])
        # model-axis collectives: the 4 ranks of each data row
        assert g["model"] == [[0, 1, 2, 3], [4, 5, 6, 7]]
        # data-axis collectives: column peers across rows
        assert g["data"] == [[0, 4], [1, 5], [2, 6], [3, 7]]

    def test_peer_groups_partition(self):
        # every axis's groups partition the full rank set
        g = peer_groups([2, 3, 4], ["pod", "data", "model"])
        for rows in g.values():
            flat = sorted(r for row in rows for r in row)
            assert flat == list(range(24))

    def test_stamp_compatibility(self):
        import numpy as np

        class FakeMesh:
            def __init__(self, n):
                self.devices = np.empty(n, dtype=object)

        one = {"axes": ["data", "model"], "shape": [1, 1]}
        eight = {"axes": ["data", "model"], "shape": [2, 4]}
        # 1-rank capture stamps onto anything
        assert stamp_compatible(one, FakeMesh(4))
        assert stamp_compatible(one, FakeMesh(1))
        # same rank count: axis re-arrangement is stampable
        assert stamp_compatible(eight, FakeMesh(8))
        # true scale change of a multi-rank capture is not
        assert not stamp_compatible(eight, FakeMesh(2))
        assert not stamp_compatible(eight, FakeMesh(16))
        assert not stamp_compatible(one, None)


# ---------------------------------------------------------------------------
# rank deltas
# ---------------------------------------------------------------------------
class TestRankDelta:
    def test_build_and_roundtrip(self):
        plan = MemoryPlan()
        plan.alloc("weights", 1 << 12)
        plan.alloc("kv_pool", 1 << 14, scope="per_rank")
        deltas = build_rank_deltas(
            {"axes": ["data", "model"], "shape": [2, 2]}, plan)
        assert len(deltas) == 4
        d2 = deltas[2]
        assert d2.rank == 2 and d2.coords == (1, 0)
        assert d2.peer_groups["model"] == [2, 3]
        assert d2.peer_groups["data"] == [0, 2]
        back = RankDelta.from_manifest(d2.to_manifest())
        assert back == d2

    def test_single_rank_capture(self):
        deltas = build_rank_deltas({"axes": [], "shape": []})
        assert len(deltas) == 1 and deltas[0].rank == 0

    def test_rank_relative_buffers(self):
        plan = MemoryPlan(align=256)
        plan.alloc("weights", 1024)
        plan.alloc("kv_pool", 4096, scope="per_rank")
        deltas = build_rank_deltas(
            {"axes": ["data", "model"], "shape": [1, 4]}, plan)
        kv = next(b for b in deltas[0].comm_buffers if b["name"] == "kv_pool")
        assert kv["size"] == 1024  # 4096 / 4 ranks
        assert kv["scope"] == "per_rank"
        w = next(b for b in deltas[0].comm_buffers if b["name"] == "weights")
        assert w["size"] == 1024  # global: full size on every rank


# ---------------------------------------------------------------------------
# memory plan rank extents + manifest v2 compat
# ---------------------------------------------------------------------------
class TestRankExtents:
    def test_per_rank_sharding_shrinks_extent(self):
        plan = MemoryPlan(align=256)
        plan.alloc("weights", 1024)
        plan.alloc("kv", 8192, scope="per_rank")
        assert plan.rank_extent_total(1) > plan.rank_extent_total(4)
        ext4 = plan.rank_extents(4)
        assert [e["size"] for e in ext4] == [1024, 2048]
        # offsets are deterministic and aligned
        assert ext4[1]["offset"] % 256 == 0

    def test_bad_scope_rejected(self):
        plan = MemoryPlan()
        with pytest.raises(ValueError):
            plan.alloc("x", 16, scope="per_pod")

    def test_v1_manifest_without_scope_loads(self):
        plan = MemoryPlan()
        plan.alloc("a", 100)
        m = plan.to_manifest()
        for a in m["allocations"]:
            a.pop("scope")  # simulate a v1 archive
        back = MemoryPlan.from_manifest(m)
        assert back.allocations[0].scope == "global"

    def test_scope_survives_roundtrip_and_verify(self):
        plan = MemoryPlan()
        plan.alloc("kv", 512, scope="per_rank")
        load = MemoryPlan.for_load(plan.to_manifest())
        load.preallocate()
        load.verify_alloc("kv", 512)
        assert load.allocations[0].scope == "per_rank"


# ---------------------------------------------------------------------------
# archive codec fallback (zstd <-> zlib)
# ---------------------------------------------------------------------------
class TestArchiveCodec:
    def test_zlib_roundtrip(self, monkeypatch):
        import repro.core.archive as archive_mod
        monkeypatch.setattr(archive_mod, "zstandard", None)
        ar = Archive(manifest={"v": 2})
        h = ar.add_blob(b"blob" * 500)
        raw = ar.to_bytes()
        back = Archive.from_bytes(raw)
        assert back.get_blob(h) == b"blob" * 500

    def test_zlib_archive_readable_with_zstd_present(self, monkeypatch):
        import repro.core.archive as archive_mod
        ar = Archive(manifest={"v": 2})
        h = ar.add_blob(b"payload")
        monkeypatch.setattr(archive_mod, "zstandard", None)
        raw = ar.to_bytes()  # zlib-compressed
        monkeypatch.undo()
        back = Archive.from_bytes(raw)  # codec sniffed from stream magic
        assert back.get_blob(h) == b"payload"


# ---------------------------------------------------------------------------
# stamped restore == fallback restore, TP=1 capture -> TP=2 deployment
# (the paper's single-capture / many-ranks result, acceptance criterion)
# ---------------------------------------------------------------------------
STAMP_SCRIPT = r"""
import numpy as np
import jax
from repro.configs.registry import get_arch
from repro.launch.mesh import ShardCtx, make_capture_mesh, make_tp_mesh
from repro.models.model import Model
from repro.serving.engine import ServingEngine

def build(mesh):
    cfg = get_arch("smollm-360m").reduced()
    eng = ServingEngine(Model(cfg, ShardCtx(mesh=mesh)), max_batch=4,
                        max_seq=32, bucket_mode="pow2")
    eng.load_weights(rng=jax.random.PRNGKey(0))
    return eng

mesh_cap = make_capture_mesh()
with mesh_cap:
    eng = build(mesh_cap)
    archive, _ = eng.save_archive()
assert archive.manifest["version"] == 2
assert len(archive.manifest["rank_delta"]["capture_ranks"]) == 1

def serve(allow_stamping):
    jax.clear_caches()
    mesh = make_tp_mesh(2)
    with mesh:
        e = build(mesh)
        rep = e.cold_start_foundry(archive, background_exact=False,
                                   allow_stamping=allow_stamping)
        for p in ([1, 2, 3], [9, 8]):
            e.submit(p, 6)
        e.run_until_drained()
        toks = sorted((r.req_id, tuple(r.generated))
                      for r in e.scheduler.done)
        return rep, toks, dict(e.programs.stats)

rep_s, toks_s, stats_s = serve(True)
assert rep_s.mode == "foundry-stamped", rep_s.mode
assert rep_s.fallback_compiles == 0, "shape-compatible rebind must not compile"
assert rep_s.rank_stamped > 0
assert stats_s["stamped_dispatches"] > 0
print("STAMPED_OK", rep_s.rank_stamped)

rep_f, toks_f, _ = serve(False)
assert rep_f.mode == "foundry"
assert rep_f.fallback_compiles > 0

# greedy decode is argmax over logits: token identity across the two restore
# paths is the integer-level witness of fp-tolerance logit agreement
assert toks_s == toks_f, f"stamped {toks_s} != fallback {toks_f}"
print("OUTPUTS_MATCH")

# TP<->EP-style axis re-arrangement at fixed rank count is also stampable
from repro.launch.mesh import make_mesh
jax.clear_caches()
mesh_tp = make_mesh((1, 2), ("data", "model"))
with mesh_tp:
    e = build(mesh_tp)
    ar2, _ = e.save_archive()
jax.clear_caches()
mesh_dp = make_mesh((2, 1), ("data", "model"))
with mesh_dp:
    e2 = build(mesh_dp)
    rep2 = e2.cold_start_foundry(ar2, background_exact=False)
assert rep2.mode == "foundry-stamped" and rep2.fallback_compiles == 0
print("REARRANGE_OK", rep2.rank_stamped)
print("DONE")
"""


@pytest.mark.slow
def test_rank_stamped_restore_matches_fallback():
    from repro.core.collective_stub import run_in_capture_process
    r = run_in_capture_process(
        STAMP_SCRIPT, 2, timeout=900,
        pythonpath=os.path.join(os.path.dirname(__file__), "..", "src"))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "STAMPED_OK" in r.stdout
    assert "OUTPUTS_MATCH" in r.stdout
    assert "REARRANGE_OK" in r.stdout
    assert "DONE" in r.stdout

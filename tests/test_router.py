"""ModelRouter: routing by model name, scale-to-zero round trips with token
identity, keep-resident policy, and two fleets concurrently reading one
shared depot (serving/router.py + core/depot.py)."""
import threading
import time

import jax
import pytest

from repro.configs.registry import get_arch
from repro.core import TemplateDepot
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.fleet import AutoscalePolicy
from repro.serving.router import (ModelPolicy, ModelRouter, ModelState,
                                  popularity_trace)

CFG = get_arch("smollm-360m").reduced()
PROMPT = [5, 9, 2]


def factory():
    eng = ServingEngine(Model(CFG), max_batch=4, max_seq=32,
                        bucket_mode="pow2")
    eng.load_weights(rng=jax.random.PRNGKey(0))
    return eng


@pytest.fixture(scope="module")
def depot(tmp_path_factory):
    """One depot holding the same capture set under two model names (the
    two-model zoo; 100% blob sharing by construction)."""
    d = TemplateDepot(str(tmp_path_factory.mktemp("zoo") / "depot"))
    ar, _ = factory().save_archive()
    d.put_archive("model-a", ar)
    d.put_archive("model-b", ar)
    return d


@pytest.fixture(scope="module")
def reference():
    """Token stream of a never-deactivated engine for PROMPT."""
    eng = factory()
    eng.cold_start_vanilla()
    ref = eng.submit(PROMPT, 6)
    eng.run_until_drained()
    return list(ref.generated)


def policy(**kw):
    base = dict(
        autoscale=AutoscalePolicy(min_replicas=1, max_replicas=2,
                                  target_inflight_per_replica=8,
                                  scale_down_idle_ticks=5),
        scale_to_zero=True, idle_ticks_to_zero=10)
    base.update(kw)
    return ModelPolicy(**base)


def drive(router, req, max_s=300.0):
    t0 = time.perf_counter()
    while req.state.value not in ("done", "failed"):
        if router.tick() == 0:
            time.sleep(0.001)
        assert time.perf_counter() - t0 < max_s, "router wedged"
    return req


def test_scale_to_zero_round_trip(depot, reference):
    """The ISSUE acceptance test: deactivate under load-drain, reactivate
    from the depot, token streams byte-identical to a never-deactivated
    engine, zero critical-path compiles across both activations."""
    router = ModelRouter()
    router.add_model("model-a", factory, archive=depot.open("model-a"),
                     policy=policy())
    r1 = drive(router, router.submit("model-a", PROMPT, 6))
    assert r1.state.value == "done" and list(r1.generated) == reference

    # load drains -> idle ticks accumulate -> the model scales to ZERO
    for _ in range(5000):
        router.tick()
        if router.state_of("model-a") is ModelState.COLD:
            break
        time.sleep(0.001)
    assert router.state_of("model-a") is ModelState.COLD
    assert router.entries["model-a"].fleet is None  # replicas+KV released

    # a queued request reactivates it from the (now warm) depot
    r2 = drive(router, router.submit("model-a", PROMPT, 6))
    assert r2.state.value == "done"
    assert list(r2.generated) == reference, \
        "token stream diverged across deactivate->reactivate"
    rep = router.report().summary()
    assert rep["models"]["model-a"]["activations"] == 2
    assert rep["models"]["model-a"]["deactivations"] >= 1
    assert rep["fallback_compiles"] == 0
    assert rep["background_errors"] == 0
    assert len(rep["models"]["model-a"]["activation_ready_s"]) == 2
    router.deactivate_all()


def test_routing_and_unknown_model(depot):
    router = ModelRouter()
    for name in ("model-a", "model-b"):
        router.add_model(name, factory, archive=depot.open(name),
                         policy=policy())
    ra = router.submit("model-a", PROMPT, 4)
    rb = router.submit("model-b", [7, 7], 4)
    for r in (ra, rb):
        drive(router, r)
    assert ra.state.value == rb.state.value == "done"
    # requests landed on their own model's fleet, not each other's
    assert ra in router.entries["model-a"].requests
    assert rb in router.entries["model-b"].requests
    assert ra not in router.entries["model-b"].requests
    with pytest.raises(KeyError, match="unknown model"):
        router.submit("model-c", PROMPT, 4)
    router.deactivate_all()


def test_concurrent_two_fleets_one_depot(depot):
    """Two models' fleets cold-start CONCURRENTLY against one shared depot:
    every blob is read from disk at most once depot-wide (single-flight
    through the shared BlobStore), and both models serve correctly."""
    store = depot.store
    reads = []
    lock = threading.Lock()
    orig = type(store._source).read_hash

    def counting(h):
        with lock:
            reads.append(h)
        return orig(store._source, h)
    store._source.read_hash = counting
    try:
        router = ModelRouter()
        for name in ("model-a", "model-b"):
            router.add_model(name, factory, archive=depot.open(name),
                             policy=policy())
        # trigger both activations in the same tick: two provisioning
        # threads LOAD from the depot at the same time
        ra = router.submit("model-a", PROMPT, 4)
        rb = router.submit("model-b", PROMPT, 4)
        for r in (ra, rb):
            drive(router, r)
        assert ra.state.value == rb.state.value == "done"
        assert list(ra.generated) == list(rb.generated)  # same weights+seed
        dup = len(reads) - len(set(reads))
        assert dup == 0, f"{dup} duplicate depot reads across fleets"
        rep = router.report().summary()
        assert rep["fallback_compiles"] == 0
        assert rep["background_errors"] == 0
        router.deactivate_all()
    finally:
        store._source.read_hash = orig.__get__(store._source)


def test_keep_resident_never_deactivates(depot):
    router = ModelRouter()
    router.add_model("model-a", factory, archive=depot.open("model-a"),
                     policy=policy(scale_to_zero=False, idle_ticks_to_zero=2))
    drive(router, router.submit("model-a", PROMPT, 4))
    for _ in range(50):
        router.tick()
    assert router.state_of("model-a") is ModelState.ACTIVE
    assert router.entries["model-a"].fleet is not None
    router.deactivate_all()
    assert router.state_of("model-a") is ModelState.COLD


def test_popularity_trace_shape():
    tr = popularity_trace(["a", "b"], phase_ticks=3, hot_rate=2,
                          cold_rate=0, rounds=2, gap_ticks=1)
    assert len(tr) == 2 * 2 * (3 + 1)
    assert tr[0] == {"a": 2, "b": 0}
    assert tr[3] == {}                      # gap tick
    assert tr[4] == {"a": 0, "b": 2}

"""End-to-end behaviour tests for the full system (the paper's claims on a
reduced scale, as pass/fail invariants):

  1. cold-start reduction: Foundry LOAD is >=10x faster than vanilla capture
     (paper: 95-99% reduction),
  2. templating compresses buckets (paper Fig 11),
  3. token identity between natively-captured and restored engines
     (paper §6.3),
  4. the dry-run entrypoint works end-to-end for a reduced multi-device cell.
"""
import os
import time

import jax
import pytest

from repro.configs.registry import get_arch
from repro.core.collective_stub import run_in_capture_process
from repro.models.model import Model
from repro.serving.engine import ServingEngine

# whole-system claims take minutes; the CI push job runs -m "not slow"
pytestmark = pytest.mark.slow


def _engine():
    cfg = get_arch("qwen3-14b").reduced()
    eng = ServingEngine(Model(cfg), max_batch=8, max_seq=64,
                        bucket_mode="all")
    eng.load_weights(rng=jax.random.PRNGKey(3))
    return eng


def test_cold_start_reduction_and_token_identity():
    eng = _engine()
    archive, save_rep = eng.save_archive()
    n_templates = save_rep["specs"]["decode"]["n_templates"]
    assert n_templates < len(eng.buckets), "templating must compress buckets"

    jax.clear_caches()
    eng_v = _engine()
    t0 = time.perf_counter()
    eng_v.cold_start_vanilla()
    t_vanilla = time.perf_counter() - t0

    jax.clear_caches()
    eng_f = _engine()
    t0 = time.perf_counter()
    eng_f.cold_start_foundry(archive, background_exact=False)
    t_foundry = time.perf_counter() - t0

    assert t_foundry < t_vanilla / 10, \
        f"expected >=10x cold-start reduction, got {t_vanilla / t_foundry:.1f}x"

    prompts = [[2, 7, 1], [9], [4, 4, 8, 1]]
    for p in prompts:
        eng_v.submit(p, 6)
        eng_f.submit(p, 6)
    eng_v.run_until_drained()
    eng_f.run_until_drained()
    ref = sorted(tuple(r.generated) for r in eng_v.scheduler.done)
    got = sorted(tuple(r.generated) for r in eng_f.scheduler.done)
    assert ref == got, "restored engine must generate identical tokens"


@pytest.mark.slow
def test_dryrun_entrypoint_reduced_cell():
    script = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_mesh
rec = run_cell("smollm-360m-reduced", "train_4k", make_mesh((2, 4), ("data", "model")))
assert rec["status"] == "ok", rec
print("DRYRUN_OK", rec["roofline"]["dominant"])
"""
    r = run_in_capture_process(
        script, 8, timeout=900,
        pythonpath=os.path.join(os.path.dirname(__file__), "..", "src"))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-2000:]}"
    assert "DRYRUN_OK" in r.stdout

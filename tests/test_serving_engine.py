"""Serving engine end-to-end on CPU: vanilla vs foundry vs eager cold starts
produce identical tokens; continuous batching; failure re-queue."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.core import wait_for_background
from repro.models.model import Model
from repro.serving.engine import ServingEngine


def make_engine(**kw):
    cfg = get_arch("smollm-360m").reduced()
    m = Model(cfg)
    eng = ServingEngine(m, max_batch=8, max_seq=64, bucket_mode="pow2", **kw)
    eng.load_weights(rng=jax.random.PRNGKey(7))
    return eng


def serve_tokens(eng, prompts, n_new=6):
    reqs = [eng.submit(p, n_new) for p in prompts]
    eng.run_until_drained()
    assert all(r.state.value == "done" for r in reqs)
    return [tuple(r.generated) for r in reqs]


PROMPTS = [[5, 9, 2], [11, 3], [7, 7, 7, 1], [2], [13, 4, 9, 9, 1, 2]]


def test_vanilla_serving_and_batching():
    eng = make_engine()
    rep = eng.cold_start_vanilla()
    assert rep.n_templates >= 1
    outs = serve_tokens(eng, PROMPTS)
    assert all(len(o) == 6 for o in outs)
    assert eng.scheduler.pending == 0


def test_foundry_cold_start_token_identity(tmp_path):
    # SAVE with one engine, LOAD with a fresh one; tokens must be identical
    eng1 = make_engine()
    archive, save_rep = eng1.save_archive()
    assert save_rep["specs"]["decode"]["n_templates"] < len(eng1.buckets)
    eng1.cold_start_vanilla()
    ref = serve_tokens(eng1, PROMPTS)

    eng2 = make_engine()
    rep = eng2.cold_start_foundry(archive)
    assert rep.n_templates == save_rep["specs"]["decode"]["n_templates"]
    out = serve_tokens(eng2, PROMPTS)
    assert out == ref, "foundry-restored engine diverged from vanilla"

    # foundry cold start must be much cheaper than vanilla capture
    assert rep.phases["templates_s"] >= 0


def test_eager_matches_vanilla():
    eng1 = make_engine()
    eng1.cold_start_vanilla()
    ref = serve_tokens(eng1, PROMPTS[:3])
    eng2 = make_engine()
    eng2.cold_start_eager()
    out = serve_tokens(eng2, PROMPTS[:3])
    assert out == ref


def test_failure_requeue_completes():
    eng = make_engine()
    eng.cold_start_vanilla()
    reqs = [eng.submit(p, 6) for p in PROMPTS]
    eng.step()
    eng.step()
    eng.simulate_worker_failure()  # drops running work, keeps prefixes
    eng.run_until_drained()
    assert all(r.state.value == "done" for r in reqs)
    assert all(len(r.generated) >= 6 for r in reqs)
    assert any(r.retries > 0 for r in reqs)


def test_background_exact_swap(tmp_path):
    eng = make_engine()
    archive, _ = eng.save_archive()
    eng2 = make_engine()
    eng2.cold_start_foundry(archive, background_exact=True)
    wait_for_background(eng2._load_report)
    cov = eng2.programs.coverage()
    assert cov["exact_loaded"] > 0
    serve_tokens(eng2, PROMPTS[:2])

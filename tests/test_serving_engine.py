"""Serving engine end-to-end on CPU: vanilla vs foundry vs eager cold starts
produce identical tokens; continuous batching; failure re-queue."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.core import wait_for_background
from repro.models.model import Model
from repro.serving.engine import ServingEngine


def make_engine(**kw):
    cfg = get_arch("smollm-360m").reduced()
    m = Model(cfg)
    eng = ServingEngine(m, max_batch=8, max_seq=64, bucket_mode="pow2", **kw)
    eng.load_weights(rng=jax.random.PRNGKey(7))
    return eng


def serve_tokens(eng, prompts, n_new=6):
    reqs = [eng.submit(p, n_new) for p in prompts]
    eng.run_until_drained()
    assert all(r.state.value == "done" for r in reqs)
    return [tuple(r.generated) for r in reqs]


PROMPTS = [[5, 9, 2], [11, 3], [7, 7, 7, 1], [2], [13, 4, 9, 9, 1, 2]]


def test_vanilla_serving_and_batching():
    eng = make_engine()
    rep = eng.cold_start_vanilla()
    assert rep.n_templates >= 1
    outs = serve_tokens(eng, PROMPTS)
    assert all(len(o) == 6 for o in outs)
    assert eng.scheduler.pending == 0


def test_foundry_cold_start_token_identity(tmp_path):
    # SAVE with one engine, LOAD with a fresh one; tokens must be identical
    eng1 = make_engine()
    archive, save_rep = eng1.save_archive()
    assert save_rep["specs"]["decode"]["n_templates"] < len(eng1.buckets)
    eng1.cold_start_vanilla()
    ref = serve_tokens(eng1, PROMPTS)

    eng2 = make_engine()
    rep = eng2.cold_start_foundry(archive)
    assert rep.n_templates == save_rep["specs"]["decode"]["n_templates"]
    out = serve_tokens(eng2, PROMPTS)
    assert out == ref, "foundry-restored engine diverged from vanilla"

    # foundry cold start must be much cheaper than vanilla capture
    assert rep.phases["templates_s"] >= 0


def test_eager_matches_vanilla():
    eng1 = make_engine()
    eng1.cold_start_vanilla()
    ref = serve_tokens(eng1, PROMPTS[:3])
    eng2 = make_engine()
    eng2.cold_start_eager()
    out = serve_tokens(eng2, PROMPTS[:3])
    assert out == ref


def test_failure_requeue_completes():
    eng = make_engine()
    eng.cold_start_vanilla()
    reqs = [eng.submit(p, 6) for p in PROMPTS]
    eng.step()
    eng.step()
    eng.simulate_worker_failure()  # drops running work, keeps prefixes
    eng.run_until_drained()
    assert all(r.state.value == "done" for r in reqs)
    assert all(len(r.generated) >= 6 for r in reqs)
    assert any(r.retries > 0 for r in reqs)


def test_background_exact_swap(tmp_path):
    eng = make_engine()
    archive, _ = eng.save_archive()
    eng2 = make_engine()
    eng2.cold_start_foundry(archive, background_exact=True)
    wait_for_background(eng2._load_report)
    cov = eng2.programs.coverage()
    assert cov["exact_loaded"] > 0
    # a systematically failing background compile must be visible, not
    # swallowed: the happy path reports zero errors
    assert eng2._load_report.background_errors == 0
    assert eng2._load_report.background_first_error is None
    serve_tokens(eng2, PROMPTS[:2])


def test_oversized_prompt_rejected_cleanly():
    """A prompt that cannot fit max_seq used to raise a broadcast ValueError
    inside step() and wedge the request in `running` forever; it must fail
    cleanly through the scheduler while other traffic proceeds."""
    eng = make_engine()
    eng.cold_start_vanilla()
    ok = eng.submit([1, 2, 3], 4)
    too_long = eng.submit(list(range(1, 80)), 4)       # 79 tokens > max_seq=64
    exactly_max = eng.submit(list(range(1, 65)), 4)    # 64 == max_seq: no room
    eng.run_until_drained()
    assert too_long.state.value == "failed"
    assert "max_seq" in too_long.fail_reason
    assert exactly_max.state.value == "failed"
    assert too_long.req_id not in eng.scheduler.running
    assert too_long in eng.scheduler.failed
    assert ok.state.value == "done" and len(ok.generated) == 4
    assert eng.scheduler.pending == 0


def test_boundary_prompt_still_served():
    """max_seq - 1 prompt tokens leaves room for exactly one generated token
    and must be admitted, not rejected."""
    eng = make_engine()
    eng.cold_start_vanilla()
    edge = eng.submit(list(range(1, 64)), 4)  # 63 == max_seq - 1
    eng.run_until_drained()
    assert edge.state.value == "done"
    assert len(edge.generated) >= 1


def test_multi_completion_slot_compaction():
    """Two+ requests finishing in the same step(): after release+compaction
    every surviving request's slot must still point at its own KV row (the
    moved_id repair in ServingEngine.step). Pinned to the slot layout whose
    device row-compaction it exercises (and whose one-shot prefill the step
    counts assume); the paged layout's compaction is covered by
    tests/test_prefix_cache.py and the blockpool property suite."""
    eng = make_engine(kv_layout="slot")
    eng.cold_start_vanilla()
    short = [eng.submit(p, 3) for p in ([5, 9, 2], [11, 3], [7, 7, 7, 1])]
    long = [eng.submit(p, 8) for p in ([2, 4], [13, 4, 9])]
    for _ in range(3):  # all 5 admitted at once; short ones finish together
        eng.step()
    assert all(r.state.value == "done" for r in short)
    for r in long:
        assert r.state.value == "running"
        assert eng.pool.slots[r.slot] == r.req_id, \
            f"request {r.req_id} slot {r.slot} points at someone else's row"
    eng.run_until_drained()
    assert all(r.state.value == "done" and len(r.generated) == 8 for r in long)


def test_pool_shrink_during_release_keeps_slots_valid():
    """A mass completion shrinks the pool bucket (hysteresis) while a
    survivor is still decoding; its slot must survive the shrink. Slot
    layout pinned — the step counts assume one-shot prefill."""
    eng = make_engine(kv_layout="slot")
    eng.cold_start_vanilla()
    many = [eng.submit([3, 1, 4], 2) for _ in range(5)]
    survivor = eng.submit([2, 7], 9)
    for _ in range(2):
        eng.step()
    assert all(r.state.value == "done" for r in many)
    assert eng.pool.cur_bucket < 8  # pool shrank under the survivor
    assert survivor.state.value == "running"
    assert eng.pool.slots[survivor.slot] == survivor.req_id
    eng.run_until_drained()
    assert survivor.state.value == "done" and len(survivor.generated) == 9

"""Unified telemetry (obs/): registry semantics, exposition lint, trace
spans, and the instrumentation seams the serving stack feeds.

The registry/trace primitives are pure stdlib, so most tests here are fast
and engine-free; the LOAD-span integration tests at the bottom build one
small engine archive per module.
"""
import json
import threading
import time

import jax
import pytest

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs import (LabelCardinalityError, MetricsRegistry, span,
                       lint_exposition, validate_trace)


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts with collection off and zeroed metrics."""
    obs_metrics.disable()
    obs_metrics.reset()
    if obs_trace.active():
        obs_trace.stop()
    yield
    obs_metrics.disable()
    obs_metrics.reset()
    if obs_trace.active():
        obs_trace.stop()


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------
class TestRegistry:
    def test_disabled_mutators_record_nothing(self):
        r = MetricsRegistry()
        c = r.counter("c_total", "c")
        g = r.gauge("g", "g")
        h = r.histogram("h_seconds", "h")
        c.inc()
        g.set(5)
        h.observe(0.1)
        assert c.value() == 0.0
        assert g.value() == 0.0
        assert h.snapshot() == ([0] * (len(h.buckets) + 1), 0.0, 0)
        # no children were even allocated
        assert not c.samples() and not g.samples()

    def test_disabled_path_is_cheap(self):
        """The disabled mutator is one global read + return. The bound here
        is deliberately generous (CI jitter); it exists to catch a rewrite
        that starts allocating label tuples or taking locks when off."""
        c = obs_metrics.counter("cheap_total", "c", ("k",))
        n = 20000
        t0 = time.perf_counter()
        for _ in range(n):
            c.inc(k="v")
        per_op = (time.perf_counter() - t0) / n
        assert per_op < 50e-6, f"disabled inc() costs {per_op * 1e9:.0f}ns"

    def test_enable_disable_scope(self):
        c = obs_metrics.counter("scoped_total", "c")
        with obs_metrics.enabled_scope():
            c.inc()
            assert obs_metrics.enabled()
        assert not obs_metrics.enabled()
        c.inc()  # off again: dropped
        assert c.value() == 1.0

    def test_label_cardinality_cap(self):
        r = MetricsRegistry()
        c = r.counter("explode_total", "c", ("req",), max_label_sets=8)
        obs_metrics.enable()
        for i in range(8):
            c.inc(req=str(i))
        with pytest.raises(LabelCardinalityError):
            c.inc(req="one-too-many")
        # existing label sets still usable after the cap trips
        c.inc(req="3")
        assert c.value(req="3") == 2.0

    def test_undeclared_label_rejected(self):
        c = obs_metrics.counter("strict_total", "c", ("a",))
        obs_metrics.enable()
        with pytest.raises(ValueError):
            c.inc(b="nope")

    def test_redeclare_is_idempotent_but_kind_checked(self):
        r = MetricsRegistry()
        c1 = r.counter("twice_total", "c")
        c2 = r.counter("twice_total", "c")
        assert c1 is c2
        with pytest.raises(ValueError):
            r.gauge("twice_total", "now a gauge")

    def test_counter_rejects_negative(self):
        c = obs_metrics.counter("mono_total", "c")
        obs_metrics.enable()
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_histogram_buckets(self):
        r = MetricsRegistry()
        h = r.histogram("lat_seconds", "h", buckets=(0.1, 1.0, 10.0))
        obs_metrics.enable()
        for v in (0.05, 0.1, 0.5, 2.0, 100.0):
            h.observe(v)
        cum, total, count = h.snapshot()
        # le=0.1 holds 0.05 and the boundary 0.1; le=1.0 adds 0.5;
        # le=10.0 adds 2.0; +Inf adds 100.0
        assert cum == [2, 3, 4, 5]
        assert count == 5
        assert total == pytest.approx(102.65)


# ---------------------------------------------------------------------------
# exposition
# ---------------------------------------------------------------------------
class TestExposition:
    def test_render_parses_clean(self):
        obs_metrics.enable()
        c = obs_metrics.counter("exp_total", "with \"quotes\" and \\slash",
                                ("mode",))
        g = obs_metrics.gauge("exp_gauge", "g", ("fleet",))
        h = obs_metrics.histogram("exp_seconds", "h")
        c.inc(mode="a")
        c.inc(2, mode='we"ird\nvalue')
        g.set(-3.5, fleet="f")
        h.observe(0.01)
        h.observe(999.0)
        text = obs_metrics.render()
        assert lint_exposition(text) == []
        assert 'exp_total{mode="a"} 1' in text
        assert "# TYPE exp_seconds histogram" in text

    def test_lint_catches_corruption(self):
        good = ("# HELP x_total x\n# TYPE x_total counter\n"
                "x_total 1\n")
        assert lint_exposition(good) == []
        assert lint_exposition("x_total 1\nx_total 2\n")  # duplicate series
        assert lint_exposition("junk line !!!\n")
        # histogram without +Inf bucket
        bad_hist = ("# TYPE h histogram\n"
                    'h_bucket{le="1.0"} 1\nh_sum 0.5\nh_count 1\n')
        assert any("+Inf" in f for f in lint_exposition(bad_hist))
        # non-cumulative buckets
        bad_cum = ("# TYPE h histogram\n"
                   'h_bucket{le="1.0"} 5\nh_bucket{le="+Inf"} 3\n'
                   "h_sum 0.5\nh_count 3\n")
        assert any("non-decreasing" in f or "cumulative" in f
                   for f in lint_exposition(bad_cum))

    def test_value_accessor(self):
        obs_metrics.enable()
        c = obs_metrics.counter("acc_total", "c", ("k",))
        c.inc(3, k="x")
        assert obs_metrics.value("acc_total", {"k": "x"}) == 3.0
        assert obs_metrics.value("acc_total", {"k": "never"}) == 0.0
        with pytest.raises(KeyError):
            obs_metrics.value("no_such_metric")


# ---------------------------------------------------------------------------
# trace spans
# ---------------------------------------------------------------------------
class TestTrace:
    def test_span_measures_even_when_off(self):
        with span("work", cat="t") as sp:
            time.sleep(0.001)
        assert sp.seconds >= 0.001
        assert not obs_trace.active()

    def test_span_records_when_on(self):
        obs_trace.start()
        obs_trace.set_thread_name("test.main")
        with span("work", cat="t", tag="x"):
            pass
        obs_trace.instant("marker", cat="t")
        doc = obs_trace.stop().to_dict()
        assert validate_trace(doc) == []
        names = [e["name"] for e in doc["traceEvents"]]
        assert "work" in names and "marker" in names
        work = obs_trace.spans_named(doc, "work")[0]
        assert work["args"]["tag"] == "x"
        assert work["dur"] >= 0

    def test_span_records_exception(self):
        obs_trace.start()
        with pytest.raises(RuntimeError):
            with span("boom", cat="t"):
                raise RuntimeError("no")
        doc = obs_trace.stop().to_dict()
        ev = obs_trace.spans_named(doc, "boom")[0]
        assert "error" in ev["args"]

    def test_concurrent_spans_thread_safe(self):
        obs_trace.start()
        n_threads, n_spans = 8, 200
        # hold every worker at the line until all are alive: get_ident()
        # values are only unique among concurrently-live threads
        gate = threading.Barrier(n_threads)

        def worker(i):
            gate.wait()
            obs_trace.set_thread_name(f"w{i}")
            for j in range(n_spans):
                with span("tick", cat="t", i=i, j=j):
                    pass

        ts = [threading.Thread(target=worker, args=(i,))
              for i in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        doc = obs_trace.stop().to_dict()
        assert validate_trace(doc) == []
        ticks = obs_trace.spans_named(doc, "tick")
        assert len(ticks) == n_threads * n_spans
        assert len({e["tid"] for e in ticks}) == n_threads

    def test_bounded_buffer_drops_not_grows(self):
        col = obs_trace.start(max_events=10)
        for i in range(50):
            obs_trace.instant(f"e{i}")
        assert len(col.events()) == 10
        assert col.dropped == 40
        doc = obs_trace.stop().to_dict()
        assert doc["otherData"]["dropped_events"] == 40

    def test_save_round_trips(self, tmp_path):
        obs_trace.start()
        with span("disk", cat="t"):
            pass
        p = str(tmp_path / "trace.json")
        obs_trace.save(p)
        obs_trace.stop()
        doc = json.loads(open(p).read())
        assert validate_trace(doc) == []
        assert obs_trace.spans_named(doc, "disk")


# ---------------------------------------------------------------------------
# integration: the serving stack feeds the same numbers it reports
# ---------------------------------------------------------------------------
from repro.configs.registry import get_arch  # noqa: E402
from repro.models.model import Model  # noqa: E402
from repro.serving.engine import ServingEngine  # noqa: E402


def make_engine(**kw):
    cfg = get_arch("smollm-360m").reduced()
    eng = ServingEngine(Model(cfg), max_batch=4, max_seq=64,
                        bucket_mode="pow2", **kw)
    eng.load_weights(rng=jax.random.PRNGKey(7))
    return eng


@pytest.fixture(scope="module")
def saved_archive(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("obs") / "obs.fndry")
    eng = make_engine()
    eng.save_archive(path)
    from repro.core import Archive
    return Archive.load(path)


class TestServingIntegration:
    def test_load_spans_on_distinct_threads(self, saved_archive):
        """A cold start under tracing must show the pipelined LOAD: fetch
        and deserialize spans live on their own stage threads, distinct
        from the install thread."""
        obs_trace.start()
        eng = make_engine()
        eng.cold_start_foundry(saved_archive)
        doc = obs_trace.stop().to_dict()
        assert validate_trace(doc) == []
        fetch = obs_trace.spans_named(doc, "load.fetch")
        deser = obs_trace.spans_named(doc, "load.deserialize")
        install = obs_trace.spans_named(doc, "load.install")
        assert fetch and deser and install
        tids = ({e["tid"] for e in fetch} | {e["tid"] for e in deser}
                | {e["tid"] for e in install})
        assert len(tids) >= 2, "LOAD stages all ran on one thread"

    def test_registry_matches_load_report(self, saved_archive):
        obs_metrics.enable()
        eng = make_engine()
        eng.cold_start_foundry(saved_archive)
        load_rep = eng._load_report  # the LoadReport the registry was fed
        busy = obs_metrics.REGISTRY.get(
            "foundry_load_pipeline_busy_seconds_total")
        for stage in ("fetch", "deserialize", "install"):
            assert busy.value(stage=stage) == pytest.approx(
                load_rep.pipeline[f"{stage}_s"]), stage
        assert obs_metrics.value("engine_cold_starts_total",
                                 {"mode": "foundry"}) == 1.0

    def test_queue_wait_below_ttft_and_observed(self, saved_archive):
        obs_metrics.enable()
        eng = make_engine()
        eng.cold_start_foundry(saved_archive)
        reqs = [eng.submit([5, 9, 2], 4), eng.submit([3, 1], 4)]
        eng.run_until_drained()
        for r in reqs:
            assert r.queue_wait_s is not None
            assert r.ttft is not None
            assert 0 <= r.queue_wait_s <= r.ttft
        h = obs_metrics.REGISTRY.get("serving_queue_wait_seconds")
        assert h.snapshot()[2] == len(reqs)
        tpot = obs_metrics.REGISTRY.get("serving_tpot_seconds")
        assert tpot.snapshot()[2] > 0, "no decode-step TPOT observed"

"""Live parallelism switching (Fleet.reshard, paper §4.3):

  * token identity across a mid-stream unmeshed -> (1,1) -> unmeshed round
    trip with traffic flowing through both cutovers (in-process; the
    1-device analogue of the TP1 -> TP2 -> TP1 switch the subprocess test
    runs on 2 ranks);
  * in-flight KV rows really migrate (and the capacity-overflow tail
    requeues with its prefix kept) with zero dropped requests and zero
    fallback compiles;
  * the drain-and-restart baseline strategy also drops nothing;
  * the router's ReshardPolicy flips a loaded model between mesh levels
    instead of scaling replicas out;
  * scheduler/KV-pool failure-path regressions (requeue_on_failure terminal
    accounting, ttft-at-0.0, double release, release after drain).
"""
import itertools
import os
import time

import jax
import pytest

from repro.configs.registry import get_arch
from repro.core import Archive
from repro.launch.mesh import MeshSpec, ShardCtx, make_host_mesh, resolve_mesh
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.fleet import AutoscalePolicy, Fleet, ReplicaState
from repro.serving.router import ModelPolicy, ModelRouter, ReshardPolicy
from repro.serving.scheduler import ReqState, Request, Scheduler

CFG = get_arch("smollm-360m").reduced()
PROMPTS = [[5, 9, 2], [11, 3], [7, 7, 7, 1], [2], [13, 4, 9]]
N_NEW = 10


def build(mesh=None):
    eng = ServingEngine(Model(CFG, ShardCtx(mesh=resolve_mesh(mesh))),
                        max_batch=8, max_seq=64, bucket_mode="pow2")
    eng.load_weights(rng=jax.random.PRNGKey(7))
    return eng


@pytest.fixture(scope="module")
def archive():
    """One shared lazy archive captured un-meshed: serves the un-meshed
    deployment on the exact path and the (1,1) mesh on the stamped path."""
    ar, _ = build(None).save_archive()
    return Archive.from_bytes(ar.to_bytes(), lazy=True)


@pytest.fixture(scope="module")
def reference():
    """prompt -> token tuple from a never-resharded vanilla engine."""
    eng = build(None)
    eng.cold_start_vanilla()
    out = {}
    for p in PROMPTS:
        r = eng.submit(p, N_NEW)
        eng.run_until_drained()
        out[tuple(p)] = tuple(r.generated)
    return out


def policy(**kw):
    base = dict(min_replicas=1, max_replicas=2,
                target_inflight_per_replica=64, scale_down_idle_ticks=50)
    base.update(kw)
    return AutoscalePolicy(**base)


def drive_through_switch(fleet, reqs, cycle, max_s=300.0):
    """Tick until the in-flight reshard completes, submitting one request
    per tick so traffic keeps flowing through the cutover."""
    t0 = time.perf_counter()
    while fleet._reshard is not None:
        reqs.append(fleet.submit(next(cycle), N_NEW))
        if fleet.tick() == 0:
            time.sleep(0.001)
        assert time.perf_counter() - t0 < max_s, "reshard wedged"
    return reqs


# ---------------------------------------------------------------------------
# the tentpole: mid-stream round trip, token identity, zero drops
# ---------------------------------------------------------------------------
def test_live_reshard_round_trip_identity(archive, reference):
    fleet = Fleet(factory_for_mesh=build, mode="foundry", archive=archive,
                  policy=policy(), mesh=None)
    fleet.start()
    cycle = itertools.cycle(PROMPTS)
    reqs = [fleet.submit(next(cycle), N_NEW) for _ in range(4)]
    while not fleet._ready():
        fleet.tick()
        time.sleep(0.001)
    for _ in range(3):
        fleet.tick()  # requests are mid-stream when the switch starts

    rep_up = fleet.reshard(make_host_mesh())
    drive_through_switch(fleet, reqs, cycle)
    assert rep_up.done and rep_up.aborted is None
    assert rep_up.time_to_new_topology_s > 0
    for _ in range(2):
        fleet.tick()
    rep_down = fleet.reshard(None)
    drive_through_switch(fleet, reqs, cycle)
    assert rep_down.done and rep_down.aborted is None

    fleet.run_trace([], seed=0)  # drain
    fleet.drain_background()
    frep = fleet.report()
    # zero dropped requests, all token streams byte-identical to the
    # never-resharded engine — including the ones that spanned a cutover
    assert frep.n_failed == 0 and frep.n_done == len(reqs)
    for r in reqs:
        assert tuple(r.generated) == reference[tuple(r.prompt)], \
            f"req {r.req_id} diverged across the switch"
    # in-flight KV rows actually moved across both topology changes
    assert rep_up.migrated_requests > 0
    assert rep_down.migrated_requests > 0
    assert rep_up.released_replicas >= 1
    # zero compiles anywhere: exact path un-meshed, stamped path on (1,1)
    s = frep.summary()
    assert s["fallback_compiles"] == 0
    assert s["background_errors"] == 0
    assert len(s["reshards"]) == 2
    # the fleet now serves the original topology again
    assert fleet.mesh is None
    old = [r for r in fleet.replicas if r.state is ReplicaState.STOPPED]
    assert all(r.engine is None for r in old), "old replicas must release"


def test_restart_strategy_drops_nothing(archive, reference):
    """The drain-and-restart baseline loses KV rows (requests re-prefill
    from their kept prefixes) but must not lose requests or tokens."""
    fleet = Fleet(factory_for_mesh=build, mode="foundry", archive=archive,
                  policy=policy(), mesh=None)
    fleet.start()
    cycle = itertools.cycle(PROMPTS)
    reqs = [fleet.submit(next(cycle), N_NEW) for _ in range(6)]
    while not fleet._ready():
        fleet.tick()
        time.sleep(0.001)
    for _ in range(3):
        fleet.tick()
    rep = fleet.reshard(make_host_mesh(), strategy="restart")
    drive_through_switch(fleet, reqs, cycle)
    assert rep.done and rep.aborted is None
    assert rep.requeued_requests > 0 and rep.migrated_requests == 0
    fleet.run_trace([], seed=0)
    fleet.drain_background()
    frep = fleet.report()
    assert frep.n_failed == 0 and frep.n_done == len(reqs)
    for r in reqs:
        assert tuple(r.generated) == reference[tuple(r.prompt)]
    assert frep.summary()["fallback_compiles"] == 0


def test_reshard_rejects_concurrent_and_unknown_strategy(archive):
    fleet = Fleet(factory_for_mesh=build, mode="foundry", archive=archive,
                  policy=policy(), mesh=None)
    with pytest.raises(ValueError, match="strategy"):
        fleet.reshard(None, strategy="teleport")
    fleet.reshard(make_host_mesh())
    with pytest.raises(RuntimeError, match="already in progress"):
        fleet.reshard(None)
    while fleet._reshard is not None:
        if fleet.tick() == 0:
            time.sleep(0.001)
    fleet.run_trace([], seed=0)


def test_reshard_needs_a_factory(archive):
    fleet = Fleet(lambda: build(None), mode="foundry", archive=archive,
                  policy=policy())
    with pytest.raises(ValueError, match="factory"):
        fleet.reshard(make_host_mesh())


def test_abort_reshard_recovers_the_fleet(archive, reference):
    """A wedged replacement generation must be cancellable: after
    abort_reshard the old topology keeps serving, autoscaling resumes, and
    a later reshard attempt is allowed (code-review regression: the stuck
    op used to block both forever)."""
    import threading
    gate = threading.Event()

    def blocked_build(mesh):
        if mesh is not None:
            gate.wait(60.0)  # simulate wedged provisioning on the new mesh
        return build(mesh)

    fleet = Fleet(factory_for_mesh=blocked_build, mode="foundry",
                  archive=archive, policy=policy(), mesh=None)
    fleet.start()
    reqs = [fleet.submit(p, N_NEW) for p in PROMPTS[:2]]
    while not fleet._ready():
        fleet.tick()
        time.sleep(0.001)
    rep = fleet.reshard(make_host_mesh())
    for _ in range(5):
        fleet.tick()
    assert fleet._reshard is not None  # DUAL, replacement wedged
    out = fleet.abort_reshard("test wedge")
    assert out is rep and rep.aborted == "test wedge"
    assert fleet._reshard is None
    assert fleet.mesh is None, "aborted live switch must keep the old mesh"
    # old generation serves on as if nothing happened…
    frep = fleet.run_trace([], seed=0)
    assert frep.n_failed == 0 and frep.n_done == len(reqs)
    for r in reqs:
        assert tuple(r.generated) == reference[tuple(r.prompt)]
    # …and the fleet is not wedged: a new switch can start
    gate.set()
    rep2 = fleet.reshard(make_host_mesh())
    cycle = itertools.cycle(PROMPTS)
    drive_through_switch(fleet, reqs, cycle)
    assert rep2.aborted is None and rep2.done
    fleet.run_trace([], seed=0)
    # the wedged replica's late engine is never dispatched to
    dead = [r for r in fleet.replicas
            if r.state is ReplicaState.STOPPED and r.stats.ready_t is None]
    assert dead and all(r not in fleet._ready() for r in dead)


def test_cutover_fault_aborts_switch_and_keeps_serving(archive, reference):
    """An exception at the cutover boundary (injected at the
    ``reshard.cutover`` fault site, which fires BEFORE any KV migration)
    must abort the switch through ``abort_reshard``: the old generation
    keeps serving, nothing is dropped, and a later switch succeeds."""
    from repro.serving.faults import FaultPlan, FaultSpec, fault_plan

    fleet = Fleet(factory_for_mesh=build, mode="foundry", archive=archive,
                  policy=policy(), mesh=None)
    fleet.start()
    cycle = itertools.cycle(PROMPTS)
    reqs = [fleet.submit(next(cycle), N_NEW) for _ in range(4)]
    while not fleet._ready():
        fleet.tick()
        time.sleep(0.001)
    for _ in range(3):
        fleet.tick()  # requests are mid-stream when the switch starts
    with fault_plan(FaultPlan(FaultSpec(site="reshard.cutover", times=1,
                                        message="cutover chaos"))) as plan:
        rep = fleet.reshard(make_host_mesh())
        drive_through_switch(fleet, reqs, cycle)
        assert plan.fired("reshard.cutover") == 1
    assert rep.aborted is not None and "cutover failed" in rep.aborted
    assert "cutover chaos" in rep.aborted
    assert fleet._reshard is None
    assert fleet.mesh is None, "aborted cutover must keep the old mesh"
    assert rep.migrated_requests == 0, "fault fires before any migration"
    # old generation serves every request to completion, tokens identical
    fleet.run_trace([], seed=0)
    fleet.drain_background()
    frep = fleet.report()
    assert frep.n_failed == 0 and frep.n_done == len(reqs)
    for r in reqs:
        assert tuple(r.generated) == reference[tuple(r.prompt)]
    assert frep.summary()["fallback_compiles"] == 0
    # the fleet is not wedged: the next switch (no fault armed) completes
    rep2 = fleet.reshard(make_host_mesh())
    drive_through_switch(fleet, reqs, cycle)
    assert rep2.aborted is None and rep2.done
    assert fleet.mesh is not None
    fleet.run_trace([], seed=0)
    frep = fleet.report()
    assert frep.n_failed == 0 and frep.n_done == len(reqs)


# ---------------------------------------------------------------------------
# router policy: a load spike triggers reshard instead of scale-out
# ---------------------------------------------------------------------------
def test_router_policy_reshards_instead_of_scaling_out(archive):
    pol = ModelPolicy(
        autoscale=policy(max_replicas=3, target_inflight_per_replica=2),
        scale_to_zero=False,
        reshard=ReshardPolicy(high_mesh=MeshSpec((1, 1)),
                              low_mesh=MeshSpec(()),
                              up_inflight=6, down_inflight=0,
                              sustain_ticks=3, cooldown_ticks=10))
    router = ModelRouter()
    router.add_model("m", archive=archive, policy=pol,
                     factory_for_mesh=build)
    reqs = [router.submit("m", PROMPTS[i % len(PROMPTS)], 6)
            for i in range(12)]
    fleet = router.entries["m"].fleet
    t0 = time.perf_counter()
    while (any(q.state not in (ReqState.DONE, ReqState.FAILED) for q in reqs)
           or fleet._reshard is not None):
        if len(reqs) < 40:  # keep the spike sustained
            reqs.append(router.submit("m", [2, 4], 6))
        if router.tick() == 0:
            time.sleep(0.001)
        assert time.perf_counter() - t0 < 300, "router wedged"
    rep = router.report().summary()
    m = rep["models"]["m"]
    assert m["mesh_level"] == "high"
    assert len(m["reshards"]) >= 1
    assert m["reshards"][0]["strategy"] == "live"
    assert m["fallback_compiles"] == 0
    assert rep["n_failed"] == 0 and rep["n_done"] == len(reqs)
    # the policy answered load with a bigger mesh for the SAME replica
    # count, not with more replicas (prefer_reshard_over_scale_out)
    ready = [r for r in fleet.replicas if r.state is ReplicaState.READY]
    assert len(ready) == 1, "spike must reshard, not scale out"
    assert fleet.mesh is not None  # serving on the high mesh now
    router.deactivate_all()


def test_router_aborted_reshard_keeps_mesh_level(archive):
    """code-review regression: mesh_level must flip only when the switch
    completes. If every replacement replica fails to provision, the fleet
    aborts back onto the old topology — and the policy's recorded level
    must still say 'low', not wedge at a topology the fleet never reached."""
    def flaky_build(mesh):
        if mesh is not None:
            raise RuntimeError("boom: high mesh unavailable")
        return build(None)

    pol = ModelPolicy(
        autoscale=policy(max_replicas=3, target_inflight_per_replica=2),
        scale_to_zero=False,
        reshard=ReshardPolicy(high_mesh=MeshSpec((1, 1)),
                              low_mesh=MeshSpec(()),
                              up_inflight=4, down_inflight=0,
                              sustain_ticks=2, cooldown_ticks=100000))
    router = ModelRouter()
    router.add_model("m", archive=archive, policy=pol,
                     factory_for_mesh=flaky_build)
    reqs = [router.submit("m", PROMPTS[i % len(PROMPTS)], 6)
            for i in range(10)]
    fleet = router.entries["m"].fleet
    t0 = time.perf_counter()
    while (any(q.state not in (ReqState.DONE, ReqState.FAILED) for q in reqs)
           or fleet._reshard is not None
           or router.entries["m"].pending_reshard is not None):
        if router.tick() == 0:
            time.sleep(0.001)
        assert time.perf_counter() - t0 < 300, "router wedged"
    m = router.report().summary()["models"]["m"]
    assert m["mesh_level"] == "low", \
        "aborted switch must not record the level it never reached"
    aborted = [r for r in m["reshards"] if r["aborted"]]
    assert aborted, "the failed switch must be visible in the report"
    assert m["n_done"] == len(reqs) and m["n_failed"] == 0
    assert fleet.mesh is None  # still serving the low topology
    router.deactivate_all()


def test_router_control_without_policy_scales_out(archive):
    """The control for the test above: same spike, no ReshardPolicy —
    the fleet answers with replicas, never with a topology switch."""
    pol = ModelPolicy(
        autoscale=policy(max_replicas=3, target_inflight_per_replica=2),
        scale_to_zero=False)
    router = ModelRouter()
    router.add_model("m", lambda: build(None), archive=archive, policy=pol)
    reqs = [router.submit("m", PROMPTS[i % len(PROMPTS)], 6)
            for i in range(12)]
    t0 = time.perf_counter()
    while any(q.state not in (ReqState.DONE, ReqState.FAILED) for q in reqs):
        if len(reqs) < 40:
            reqs.append(router.submit("m", [2, 4], 6))
        if router.tick() == 0:
            time.sleep(0.001)
        assert time.perf_counter() - t0 < 300, "router wedged"
    fleet = router.entries["m"].fleet
    assert fleet.peak_alive > 1, "control fleet should have scaled out"
    assert not fleet.reshard_reports
    m = router.report().summary()["models"]["m"]
    assert m["mesh_level"] == "low" and not m["reshards"]
    router.deactivate_all()


# ---------------------------------------------------------------------------
# engine-level migration primitives
# ---------------------------------------------------------------------------
def test_export_adopt_between_engines(archive, reference):
    """Direct engine-to-engine migration: export mid-stream, adopt into a
    fresh engine on a different topology, finish there — identical tokens."""
    src = build(None)
    src.cold_start_foundry(archive, background_exact=False)
    reqs = [src.submit(p, N_NEW) for p in PROMPTS[:3]]
    for _ in range(4):
        src.step()
    prefix = {r.req_id: len(r.generated) for r in reqs}
    assert all(v > 0 for v in prefix.values())

    running, bundle, queued = src.export_inflight()
    assert len(running) == 3 and bundle.n == 3 and not queued
    assert src.scheduler.pending == 0
    assert all(r.slot is None and r.state is ReqState.WAITING
               for r in running)

    mesh = make_host_mesh()
    with mesh:
        dst = build(mesh)
        rep = dst.cold_start_foundry(archive, background_exact=False,
                                     warm=True)
        assert rep.mode == "foundry-stamped"
        assert rep.fallback_compiles == 0
        adopted = dst.adopt_inflight(running, bundle)
        assert adopted == 3
        dst.run_until_drained()
    for r in reqs:
        assert r.state is ReqState.DONE
        assert len(r.generated) >= prefix[r.req_id]
        assert tuple(r.generated) == reference[tuple(r.prompt)], \
            "tokens diverged across the engine migration"


def test_prefix_hit_rows_survive_reshard():
    """Paged leg: a request admitted via a radix prefix-cache hit migrates
    mid-stream to a different topology and finishes byte-identical. The
    radix tree itself is per-pool state and does not migrate — only the
    request's KV rows do — so the adopted engine must keep decoding from
    rows that originated in shared cached blocks."""
    SYS = [9, 4, 7, 7, 1, 3, 8, 2, 6, 6, 2, 5]
    A, B = SYS + [5, 1], SYS + [2, 8, 4]

    def mk(mesh=None):
        eng = ServingEngine(Model(CFG, ShardCtx(mesh=resolve_mesh(mesh))),
                            max_batch=8, max_seq=64, bucket_mode="pow2",
                            kv_block_size=4)
        eng.load_weights(rng=jax.random.PRNGKey(7))
        return eng

    ref = {}
    for p in (A, B):  # cold oracle: one fresh engine per prompt, no cache
        e = mk()
        e.cold_start_vanilla()
        r = e.submit(p, N_NEW)
        e.run_until_drained()
        ref[tuple(p)] = tuple(r.generated)

    src = mk()
    src.cold_start_vanilla()
    assert src.kv_layout == "paged"
    ra = src.submit(A, N_NEW)
    src.run_until_drained()      # caches SYS's chain in the radix tree
    rb = src.submit(B, N_NEW)    # admitted via a prefix hit
    for _ in range(5):
        src.step()               # mid-stream: some tokens, not all
    assert src.prefill_stats["prefix_hits"] == 1
    assert 0 < len(rb.generated) < N_NEW

    running, bundle, queued = src.export_inflight()
    assert len(running) == 1 and bundle.n == 1 and not queued
    mesh = make_host_mesh()
    with mesh:
        dst = mk(mesh)
        dst.cold_start_vanilla()
        assert dst.adopt_inflight(running, bundle) == 1
        assert dst.prefill_stats["prefix_hits"] == 0  # tree did not migrate
        dst.run_until_drained()
    assert rb.state is ReqState.DONE
    assert tuple(ra.generated) == ref[tuple(A)]
    assert tuple(rb.generated) == ref[tuple(B)], \
        "prefix-hit request diverged across the topology switch"


def test_adopt_partial_when_capacity_short(archive):
    src = build(None)
    src.cold_start_foundry(archive, background_exact=False)
    reqs = [src.submit([1 + i, 2], 6) for i in range(6)]
    for _ in range(2):
        src.step()
    running, bundle, _ = src.export_inflight()
    dst = build(None)
    dst.cold_start_foundry(archive, background_exact=False, warm=True)
    for i in range(5):  # eat 5 of dst's 8 slots
        dst.pool.acquire(1000 + i)
    adopted = dst.adopt_inflight(running, bundle)
    assert adopted == 3  # free capacity, not the full population
    rest = running[adopted:]
    assert all(r.state is ReqState.WAITING and r.slot is None for r in rest)
    tail = bundle.select(range(adopted, bundle.n))
    assert tail.n == len(rest)


# ---------------------------------------------------------------------------
# satellite regressions: scheduler + KV pool failure paths
# ---------------------------------------------------------------------------
def test_requeue_on_failure_terminal_sets_done_accounting():
    """ISSUE satellite: the retries-exhausted branch must complete the
    request like reject does — fail_reason + done_t set — so latency
    summaries never see a FAILED request with done_t=None."""
    s = Scheduler(max_retries=1)
    r = s.submit([1, 2, 3], 4)
    s.admissions(1)
    s.requeue_on_failure(r)           # retry 1: back on the queue
    assert r.state is ReqState.WAITING
    assert r.done_t is None and r.fail_reason is None
    s.admissions(1)
    s.requeue_on_failure(r)           # retry 2: terminal
    assert r.state is ReqState.FAILED
    assert r.done_t is not None, "terminal requeue must set done_t"
    assert "retries exhausted" in r.fail_reason
    assert r in s.failed and r.req_id not in s.running


def test_ttft_survives_zero_timestamp():
    """ISSUE satellite: ttft must test `is not None`, not truthiness —
    perf_counter's epoch is unspecified, so 0.0 is a legal timestamp."""
    r = Request(0, [1], 4, arrival_t=0.0)
    assert r.ttft is None
    r.first_token_t = 0.0
    assert r.ttft == 0.0, "first token at t=0.0 must not be dropped"


def test_pool_release_guards():
    """ISSUE satellite: empty-pool release and double release must raise a
    clear ValueError instead of a bare max() error / silent compaction
    corruption."""
    eng = build(None)
    eng.cold_start_eager()
    pool = eng.pool
    with pytest.raises(ValueError, match="not an active slot"):
        pool.release(0)  # release-after-drain / empty pool
    a = pool.acquire(10)
    b = pool.acquire(11)
    pool.release(a)
    # slot a now holds request 11 (compacted); b is free
    with pytest.raises(ValueError, match="not an active slot"):
        pool.release(b)  # double release of the already-freed slot
    assert pool.slots[a] == 11, "double release must not corrupt live rows"
    with pytest.raises(ValueError, match="out of range"):
        pool.release(10_000)


def test_pool_export_import_rows_roundtrip():
    eng_a = build(None)
    eng_a.cold_start_eager()
    eng_b = build(None)
    eng_b.cold_start_eager()
    a0, a1 = eng_a.pool.acquire(0), eng_a.pool.acquire(1)
    # layout-neutral accessors: the slot pool keeps lengths in the device
    # cache, the paged pool in host metadata + block tables
    eng_a.pool.seed_length(a0, 5)
    eng_a.pool.seed_length(a1, 9)
    bundle = eng_a.pool.export_rows([a0, a1])
    slots = eng_b.pool.import_rows(bundle, [100, 101])
    assert eng_b.pool.slots[slots[0]] == 100
    assert eng_b.pool.row_length(slots[0]) == 5
    assert eng_b.pool.row_length(slots[1]) == 9
    with pytest.raises(ValueError, match="not an active slot"):
        eng_a.pool.export_rows([a0, 7])  # inactive slot
    with pytest.raises(ValueError):
        eng_b.pool.import_rows(bundle, [1, 2, 3])  # count mismatch


# ---------------------------------------------------------------------------
# TP1 -> TP2 -> TP1 on real placeholder ranks (subprocess)
# ---------------------------------------------------------------------------
RESHARD_SCRIPT = r"""
import itertools, time
import jax
from repro.configs.registry import get_arch
from repro.core import Archive
from repro.launch.mesh import ShardCtx, make_capture_mesh, make_tp_mesh
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.fleet import AutoscalePolicy, Fleet

CFG = get_arch("smollm-360m").reduced()
PROMPTS = [[5, 9, 2], [11, 3], [7, 7, 7, 1], [2]]
N_NEW = 8

def build(mesh):
    eng = ServingEngine(Model(CFG, ShardCtx(mesh=mesh)), max_batch=4,
                        max_seq=32, bucket_mode="pow2")
    eng.load_weights(rng=jax.random.PRNGKey(0))
    return eng

mesh_cap = make_capture_mesh()
with mesh_cap:
    ar = Archive.from_bytes(build(mesh_cap).save_archive()[0].to_bytes(),
                            lazy=True)

ref_eng = build(None)
ref_eng.cold_start_vanilla()
reference = {}
for p in PROMPTS:
    r = ref_eng.submit(p, N_NEW)
    ref_eng.run_until_drained()
    reference[tuple(p)] = tuple(r.generated)

tp1, tp2 = make_tp_mesh(1), make_tp_mesh(2)
fleet = Fleet(factory_for_mesh=build, mode="foundry", archive=ar,
              policy=AutoscalePolicy(min_replicas=1, max_replicas=1,
                                     target_inflight_per_replica=64),
              mesh=tp1)
fleet.start()
cycle = itertools.cycle(PROMPTS)
reqs = [fleet.submit(next(cycle), N_NEW) for _ in range(3)]
while not fleet._ready():
    fleet.tick(); time.sleep(0.001)
for _ in range(2):
    fleet.tick()

legs = []
for tgt in (tp2, tp1):
    rep = fleet.reshard(tgt)
    while fleet._reshard is not None:
        reqs.append(fleet.submit(next(cycle), N_NEW))
        if fleet.tick() == 0:
            time.sleep(0.001)
    legs.append(rep)
    for _ in range(2):
        fleet.tick()

frep = fleet.run_trace([], seed=0)
fleet.drain_background()
frep = fleet.report()
assert frep.n_failed == 0 and frep.n_done == len(reqs), \
    f"dropped requests: {frep.n_failed} failed / {frep.n_done} done"
for r in reqs:
    assert tuple(r.generated) == reference[tuple(r.prompt)], \
        f"req {r.req_id} diverged: {r.generated}"
print("IDENTITY_OK", len(reqs))
assert legs[0].migrated_requests > 0, "TP1->TP2 moved no KV rows"
assert legs[1].migrated_requests > 0, "TP2->TP1 moved no KV rows"
print("MIGRATED_OK", legs[0].migrated_requests, legs[1].migrated_requests)
s = frep.summary()
assert s["fallback_compiles"] == 0, "reshard must not compile"
assert s["background_errors"] == 0
# every LOAD came from the ONE single-capture archive: exact on the
# capture-shaped TP1 mesh, rank-stamped on TP2 — never a recompile
modes = {r.mode for r in frep.replicas if r.mode}
assert modes == {"foundry", "foundry-stamped"}, modes
print("STAMPED_OK", sorted(modes))
print("DONE")
"""


@pytest.mark.slow
def test_reshard_tp1_tp2_round_trip_subprocess():
    from repro.core.collective_stub import run_in_capture_process
    r = run_in_capture_process(
        RESHARD_SCRIPT, 2, timeout=900,
        pythonpath=os.path.join(os.path.dirname(__file__), "..", "src"))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    for marker in ("IDENTITY_OK", "MIGRATED_OK", "STAMPED_OK", "DONE"):
        assert marker in r.stdout

"""Fault injection + fleet supervision (serving/faults.py; fleet crash
recovery, KV salvage, graceful degradation — docs/architecture.md §12).

Includes the ISSUE acceptance regression test: a decode-step exception must
NOT propagate out of ``Fleet.tick()`` — the crashed replica is salvaged and
respawned while the others keep serving.
"""
import glob
import os
import re
import time

import jax
import pytest

from repro.configs.registry import get_arch
from repro.core import Archive
from repro.models.model import Model
from repro.serving import faults
from repro.serving.engine import ServingEngine
from repro.serving.faults import (FAULT_SITES, FaultPlan, FaultSpec,
                                  InjectedFault, InjectedIOError, fault_plan,
                                  fault_point)
from repro.serving.fleet import (AutoscalePolicy, Fleet, PoolSpec,
                                 ReplicaState)
from repro.serving.scheduler import ReqState, Scheduler

CFG = get_arch("smollm-360m").reduced()
PROMPTS = [[5, 9, 2], [11, 3], [7, 7, 7, 1], [2], [13, 4, 9], [6, 2, 8]]
N_NEW = 6


def factory():
    eng = ServingEngine(Model(CFG), max_batch=4, max_seq=64,
                        bucket_mode="pow2")
    eng.load_weights(rng=jax.random.PRNGKey(7))
    return eng


@pytest.fixture(autouse=True)
def _no_plan_leaks():
    """A plan leaking out of one test would chaos-inject every later test."""
    faults.deactivate_all()
    yield
    faults.deactivate_all()


@pytest.fixture(scope="module")
def archive_path(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("faults") / "faults.fndry")
    factory().save_archive(path)
    return path


@pytest.fixture(scope="module")
def reference(archive_path):
    """Token streams from a never-crashed engine, one request at a time."""
    eng = factory()
    eng.cold_start_foundry(Archive.load(archive_path))
    out = {}
    for p in PROMPTS:
        r = eng.submit(p, N_NEW)
        eng.run_until_drained()
        out[tuple(p)] = tuple(r.generated)
    return out


def small_policy(**kw):
    base = dict(min_replicas=1, max_replicas=3,
                target_inflight_per_replica=64, scale_down_idle_ticks=500)
    base.update(kw)
    return AutoscalePolicy(**base)


def _tick_until(fleet, cond, what, budget=8000):
    for k in range(budget):
        if cond():
            return k
        if fleet.tick() == 0:
            time.sleep(0.001)
    raise AssertionError(f"{what}: not reached in {budget} ticks")


# -- the hook and its triggers ------------------------------------------
def test_fault_point_is_passthrough_without_plan():
    payload = b"untouched"
    assert fault_point("depot.fetch", payload=payload) is payload
    assert fault_point("engine.decode_step") is None
    # unregistered sites are only validated when a plan is live (the hook
    # must stay zero-cost in production), and rejected when one is
    assert fault_point("not.a.site", payload=1) == 1
    with fault_plan(FaultPlan()):
        with pytest.raises(ValueError, match="unregistered site"):
            fault_point("not.a.site")


def test_unknown_site_and_kind_rejected_at_spec_time():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec(site="depot.fetchh")
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(site="depot.fetch", kind="explode")


def test_nth_tag_times_triggers():
    spec = FaultSpec(site="engine.decode_step", nth=3, times=1,
                     tag="replica1")
    with fault_plan(FaultPlan(spec)) as plan:
        # wrong tag never matches, right tag fires exactly on its 3rd call
        for _ in range(5):
            fault_point("engine.decode_step", tag="replica0")
        fault_point("engine.decode_step", tag="replica1")
        fault_point("engine.decode_step", tag="replica1")
        with pytest.raises(InjectedFault, match=r"\[fault:engine.decode_step\]"):
            fault_point("engine.decode_step", tag="replica1")
        # times=1: exhausted, later matching calls pass through
        fault_point("engine.decode_step", tag="replica1")
        assert plan.fired() == 1
        # only tag-matching calls count toward the spec's nth counter
        assert plan.calls("engine.decode_step") == 4


def test_seeded_probability_is_deterministic():
    def run():
        spec = FaultSpec(site="depot.fetch", p=0.3, seed=11, times=None)
        fired = []
        with fault_plan(FaultPlan(spec)):
            for k in range(50):
                try:
                    fault_point("depot.fetch", payload=b"x")
                except InjectedFault:
                    fired.append(k)
        return fired
    a, b = run(), run()
    assert a == b and 0 < len(a) < 50


def test_corrupt_and_hang_kinds():
    payload = bytes(range(100))
    with fault_plan(FaultPlan(FaultSpec(site="depot.fetch", kind="corrupt"))):
        out = fault_point("depot.fetch", payload=payload)
    assert len(out) == len(payload) and out != payload
    assert out[64:] == payload[64:]  # a flipped head, not a truncation
    # corrupt at a payload-less site degenerates to raising
    with fault_plan(FaultPlan(FaultSpec(site="reshard.cutover",
                                        kind="corrupt"))):
        with pytest.raises(InjectedFault):
            fault_point("reshard.cutover")
    with fault_plan(FaultPlan(FaultSpec(site="restore.install", kind="hang",
                                        hang_s=0.05))):
        t0 = time.perf_counter()
        fault_point("restore.install")
        assert time.perf_counter() - t0 >= 0.05


def test_fault_sites_registry_matches_code():
    """Lint guard: every ``fault_point("site")`` call in src/ names a
    registered site, and every registered site has at least one call."""
    root = os.path.join(os.path.dirname(__file__), "..", "src")
    in_code = set()
    for path in glob.glob(os.path.join(root, "**", "*.py"), recursive=True):
        if path.endswith(os.path.join("serving", "faults.py")):
            continue
        with open(path) as f:
            in_code |= set(re.findall(r'fault_point\(\s*"([^"]+)"', f.read()))
    assert in_code == set(FAULT_SITES), (
        f"fault_point sites and FAULT_SITES diverged: "
        f"unregistered={sorted(in_code - set(FAULT_SITES))}, "
        f"uncalled={sorted(set(FAULT_SITES) - in_code)}")


# -- retries around storage IO ------------------------------------------
def test_transient_fetch_fault_healed_by_retry(archive_path):
    ar = Archive.load(archive_path)  # fresh store: nothing fetched yet
    h = next(iter(ar.blobs))
    clean = Archive.load(archive_path).blobs[h]
    plan = FaultPlan(
        FaultSpec(site="depot.fetch", nth=1, times=1, exc=InjectedIOError,
                  message="flaky mount"))
    with fault_plan(plan):
        assert ar.blobs[h] == clean  # retried + verified, caller never sees it
    assert plan.fired() == 1
    assert plan.calls() >= 2  # the retry re-entered the fault point


def test_corrupted_fetch_healed_by_retry(archive_path):
    """A torn/bit-rotted read fails content verification and is re-read."""
    ar = Archive.load(archive_path)
    h = next(iter(ar.blobs))
    clean = Archive.load(archive_path).blobs[h]
    plan = FaultPlan(FaultSpec(site="depot.fetch", kind="corrupt", nth=1,
                               times=1))
    with fault_plan(plan):
        assert ar.blobs[h] == clean
    assert plan.fired() == 1


def test_persistent_corruption_surfaces_after_retries(archive_path):
    ar = Archive.load(archive_path)
    h = next(iter(ar.blobs))
    with fault_plan(FaultPlan(FaultSpec(site="depot.fetch", kind="corrupt",
                                        times=None))):
        with pytest.raises(ValueError, match="corrupt"):
            ar.blobs[h]


# -- LOAD-side faults ----------------------------------------------------
def test_deserialize_fault_degrades_to_fallback_compile(archive_path):
    eng = factory()
    with fault_plan(FaultPlan(FaultSpec(site="archive.deserialize",
                                        times=1))) as plan:
        rep = eng.cold_start_foundry(Archive.load(archive_path),
                                     background_exact=False)
        assert plan.fired() == 1
    assert rep.fallback_compiles >= 1  # degraded, not dead
    r = eng.submit(PROMPTS[0], 4)
    eng.run_until_drained()
    assert r.state is ReqState.DONE


def test_install_fault_fails_the_cold_start(archive_path):
    eng = factory()
    with fault_plan(FaultPlan(FaultSpec(site="restore.install", times=1))):
        with pytest.raises(InjectedFault):
            eng.cold_start_foundry(Archive.load(archive_path),
                                   background_exact=False)


# -- fleet supervision (THE acceptance regression test) ------------------
def test_decode_crash_is_supervised_not_fatal(archive_path, reference):
    """A decode-step exception must not unwind ``Fleet.tick()``: the
    crashed replica is salvaged (KV rows migrated / prefixes requeued) and
    respawned while the surviving replica keeps serving; every request
    completes with byte-identical tokens."""
    fleet = Fleet(factory, mode="foundry", archive=Archive.load(archive_path),
                  policy=small_policy(min_replicas=2, max_replicas=2))
    fleet.start()
    _tick_until(fleet, lambda: len(fleet._ready()) == 2, "provision")
    reqs = [fleet.submit(p, N_NEW) for p in PROMPTS]
    for _ in range(2):
        fleet.tick()  # put work in flight on both replicas
    tgt = max(fleet._ready(), key=lambda r: r.load)
    assert tgt.load > 0
    spec = FaultSpec(site="engine.decode_step",
                     tag=f"replica{tgt.stats.replica_id}", times=1,
                     message="chaos kill")
    with fault_plan(FaultPlan(spec)):
        _tick_until(fleet, lambda: fleet.crashes > 0, "crash", budget=200)
    assert tgt.state is ReplicaState.CRASHED
    assert tgt.engine is None, "crashed replica's engine not released"
    assert "chaos kill" in tgt.stats.error
    # the survivor serves while the replacement provisions
    survivors_served = 0
    for _ in range(10):
        survivors_served += fleet.tick()
    assert survivors_served > 0, "fleet stopped serving during recovery"
    _tick_until(fleet, lambda: len(fleet._ready()) == 2, "respawn")
    _tick_until(fleet, lambda: fleet._unresolved() == 0, "drain")
    fleet.drain_background()
    rep = fleet.report()
    assert rep.n_failed == 0 and rep.n_done == len(reqs)
    assert rep.crashes == 1 and rep.respawns == 1
    assert rep.salvaged_requests + rep.crash_requeued_requests > 0
    assert rep.summary()["fallback_compiles"] == 0  # respawn = warm LOAD
    for q in reqs:
        assert tuple(q.generated) == reference[tuple(q.prompt)], \
            f"req {q.req_id} diverged across crash recovery"


def test_kv_import_fault_falls_back_to_requeue(archive_path, reference):
    """Salvage whose ``adopt_inflight`` raises excludes that target and
    requeues from kept prefixes — still zero lost requests."""
    fleet = Fleet(factory, mode="foundry", archive=Archive.load(archive_path),
                  policy=small_policy(min_replicas=2, max_replicas=2))
    fleet.start()
    _tick_until(fleet, lambda: len(fleet._ready()) == 2, "provision")
    reqs = [fleet.submit(p, N_NEW) for p in PROMPTS[:4]]
    for _ in range(2):
        fleet.tick()
    tgt = max(fleet._ready(), key=lambda r: r.load)
    assert tgt.load > 0
    plan = FaultPlan(
        FaultSpec(site="engine.decode_step",
                  tag=f"replica{tgt.stats.replica_id}", times=1),
        FaultSpec(site="kv.import_rows", times=None))  # every adopt refused
    with fault_plan(plan):
        _tick_until(fleet, lambda: fleet.crashes > 0, "crash", budget=200)
    assert fleet.salvaged_requests == 0
    assert fleet.crash_requeued_requests > 0
    _tick_until(fleet, lambda: fleet._unresolved() == 0, "drain")
    rep = fleet.report()
    assert rep.n_failed == 0 and rep.n_done == len(reqs)
    for q in reqs:
        assert tuple(q.generated) == reference[tuple(q.prompt)]


def test_crash_budget_exhaustion_degrades_and_sheds(archive_path):
    """Crash-looping fleet: the sliding-window budget stops the respawn
    churn, the fleet degrades, and load sheds cheaply at admission (and
    off the backlog) via ``Scheduler.reject`` — no KV touched, callers see
    terminal FAILED instead of a hang."""
    fleet = Fleet(factory, mode="foundry", archive=Archive.load(archive_path),
                  policy=small_policy(min_replicas=1, max_replicas=1,
                                      max_crashes_in_window=1,
                                      crash_window_s=600.0))
    fleet.start()
    _tick_until(fleet, lambda: len(fleet._ready()) == 1, "provision")
    stuck = fleet.submit(PROMPTS[0], 4)
    with fault_plan(FaultPlan(FaultSpec(site="engine.decode_step",
                                        times=None))):  # every step dies
        _tick_until(fleet,
                    lambda: fleet.crash_budget_exhausted
                    and not fleet._alive(), "budget exhaustion")
    assert fleet.crashes == 2 and fleet.respawns == 1
    assert fleet.degraded and not fleet._can_spawn()
    fleet.tick()  # backlog shed happens on the tick after terminal incapacity
    assert stuck.state is ReqState.FAILED
    assert "degraded" in stuck.fail_reason
    late = fleet.submit(PROMPTS[1], 4)  # shed at admission, never queued
    assert late.state is ReqState.FAILED and "degraded" in late.fail_reason
    assert late not in fleet.backlog
    rep = fleet.report()
    assert rep.degraded and rep.shed_requests == 2
    assert rep.degraded_ticks > 0
    assert rep.n_failed == 2 and rep.n_done == 0


def test_verify_failure_on_respawn_degrades_to_nonstrict(archive_path,
                                                         monkeypatch,
                                                         reference):
    """Strict pre-flight verify failing on a RESPAWN falls back to a
    non-strict LOAD (one degraded replica beats a dead supervisor)."""
    import repro.analysis.checker as checker

    fleet = Fleet(factory, mode="foundry", archive=Archive.load(archive_path),
                  policy=small_policy(min_replicas=1, max_replicas=1))
    fleet.start()
    _tick_until(fleet, lambda: len(fleet._ready()) == 1, "provision")
    reqs = [fleet.submit(p, N_NEW) for p in PROMPTS[:3]]
    fleet.tick()
    monkeypatch.setattr(
        checker, "verify_for_load",
        lambda archive, loc="archive": [checker.Finding(
            "manifest-schema", "error", "test:injected",
            "injected verify failure for the respawn-degrade test")])
    with fault_plan(FaultPlan(FaultSpec(site="engine.decode_step",
                                        times=1))):
        _tick_until(fleet, lambda: fleet.crashes > 0, "crash", budget=200)
    _tick_until(fleet, lambda: len(fleet._ready()) == 1, "degraded respawn")
    _tick_until(fleet, lambda: fleet._unresolved() == 0, "drain")
    rep = fleet.report()
    assert fleet.verify_degraded_loads == 1
    assert rep.summary()["verify_degraded_loads"] == 1
    assert rep.n_failed == 0 and rep.n_done == len(reqs)
    assert rep.respawns == 1
    for q in reqs:
        assert tuple(q.generated) == reference[tuple(q.prompt)]


def test_handoff_fault_requeues_onto_decode_pool(archive_path, reference):
    """A fault in the prefill->decode handoff window (the request exists
    only as a detached RowBundle) must requeue the request onto the DECODE
    pool with its prefix kept — no retry charged, no token divergence."""
    fleet = Fleet(factory, mode="foundry", archive=Archive.load(archive_path),
                  pools=[PoolSpec("prefill", small_policy(max_replicas=1)),
                         PoolSpec("decode", small_policy(max_replicas=1))])
    fleet.start()
    _tick_until(fleet, lambda: len(fleet._ready()) == 2, "provision")
    reqs = [fleet.submit(p, N_NEW) for p in PROMPTS[:3]]
    with fault_plan(FaultPlan(FaultSpec(site="kv.handoff", nth=1, times=1,
                                        message="handoff chaos"))) as plan:
        _tick_until(fleet, lambda: fleet.handoff_requeued > 0,
                    "handoff fault", budget=2000)
        assert plan.fired("kv.handoff") == 1
    _tick_until(fleet, lambda: fleet._unresolved() == 0, "drain")
    rep = fleet.report()
    assert rep.n_failed == 0 and rep.n_done == len(reqs)
    assert fleet.handoff_requeued == 1
    assert fleet.handoffs == len(reqs) - 1  # the faulted one never adopted
    assert all(q.retries == 0 for q in reqs), \
        "a failed handoff is not a worker failure; no retry may be charged"
    # the requeued request still crossed phases and completed on decode
    assert all(q.phase == "decode" for q in reqs)
    assert all(q.handoff_wait_s is not None for q in reqs)
    for q in reqs:
        assert tuple(q.generated) == reference[tuple(q.prompt)], \
            f"req {q.req_id} diverged across the faulted handoff"
    s = rep.summary()
    assert s["handoffs"] == len(reqs) - 1 and s["handoff_requeued"] == 1
    assert s["fallback_compiles"] == 0


# -- scheduler retry accounting (satellite) ------------------------------
def test_requeue_on_failure_retry_accounting():
    sched = Scheduler(max_retries=2)
    req = sched.submit([4, 5, 6], 8)
    sched.admissions(1)
    req.generated = [7, 8]  # mid-decode prefix that must survive requeues
    for k in range(2):  # exactly max_retries requeues survive
        sched.requeue_on_failure(req)
        assert req.state is ReqState.WAITING
        assert req.retries == k + 1
        assert req.generated == [7, 8]
        assert sched.queue[0] is req and not sched.failed
        sched.admissions(1)
    sched.requeue_on_failure(req)  # retries > max_retries: terminal
    assert req.state is ReqState.FAILED
    assert req.retries == 3
    assert "retries exhausted" in req.fail_reason
    assert req.done_t is not None
    assert sched.failed == [req] and req.req_id not in sched.running
    assert req.generated == [7, 8]

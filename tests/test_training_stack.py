"""Training substrate: optimizer semantics, loop convergence-ish behavior,
checkpoint atomicity/restart, elastic resharding, straggler watchdog,
data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.core.collective_stub import run_in_capture_process
from repro.models.model import Model
from repro.training.checkpoint import Checkpointer
from repro.training.data import DataConfig, SyntheticLMData
from repro.training.elastic import StragglerWatchdog
from repro.training.optimizer import OptConfig
from repro.training.train_loop import (init_train_state, make_train_step,
                                       run_train_loop)


def small_setup():
    cfg = get_arch("smollm-360m").reduced()
    model = Model(cfg)
    opt = OptConfig(lr=1e-2, weight_decay=0.0)
    data = SyntheticLMData(DataConfig(cfg.vocab_size, 8, 32, seed=3))
    return cfg, model, opt, data


def test_loss_decreases():
    cfg, model, opt, data = small_setup()
    state, hist = run_train_loop(model, opt, iter(data), num_steps=30,
                                 rng=jax.random.PRNGKey(0), log_every=10,
                                 log=lambda *_: None)
    first, last = hist[0][1], hist[-1][1]
    assert last < first - 0.3, f"loss did not decrease: {first} -> {last}"


def test_train_step_deterministic():
    cfg, model, opt, data = small_setup()
    step = jax.jit(make_train_step(model, opt))
    s1 = init_train_state(model, opt, jax.random.PRNGKey(1))
    s2 = init_train_state(model, opt, jax.random.PRNGKey(1))
    b = data.batch_at(0)
    o1, m1 = step(s1, b)
    o2, m2 = step(s2, b)
    for l1, l2 in zip(jax.tree.leaves(o1), jax.tree.leaves(o2)):
        assert (np.asarray(l1) == np.asarray(l2)).all()


def test_grad_accum_matches_full_batch():
    cfg, model, opt, data = small_setup()
    s = init_train_state(model, opt, jax.random.PRNGKey(1))
    b = data.batch_at(0)
    full = jax.jit(make_train_step(model, opt, microbatches=1))
    accum = jax.jit(make_train_step(model, opt, microbatches=2))
    (_, mf), (_, ma) = full(s, b), accum(
        init_train_state(model, opt, jax.random.PRNGKey(1)), b)
    # mean-of-means == full mean for equal microbatch sizes
    np.testing.assert_allclose(float(mf["loss"]), float(ma["loss"]),
                               rtol=1e-4)


def test_data_deterministic_and_resumable():
    d1 = SyntheticLMData(DataConfig(101, 4, 16, seed=9))
    next(d1); next(d1)
    saved = d1.state_dict()
    b_expect = next(d1)
    d2 = SyntheticLMData(DataConfig(101, 4, 16, seed=9))
    d2.load_state_dict(saved)
    b_got = next(d2)
    assert (np.asarray(b_expect["tokens"]) == np.asarray(b_got["tokens"])).all()


class TestCheckpoint:
    def test_save_restore_bitwise_resume(self, tmp_path):
        cfg, model, opt, data = small_setup()
        ck = Checkpointer(str(tmp_path), keep=2)
        step = jax.jit(make_train_step(model, opt))
        state = init_train_state(model, opt, jax.random.PRNGKey(0))
        for i in range(3):
            state, _ = step(state, data.batch_at(i))
        ck.save(state, step=3, extra={"data": data.state_dict()})
        # continue 2 more steps -> reference
        ref = state
        for i in range(3, 5):
            ref, _ = step(ref, data.batch_at(i))
        # restart from checkpoint
        restored, extra = ck.restore(like=state)
        assert extra["data"]["step"] == data.state_dict()["step"] or True
        re_state = restored
        for i in range(3, 5):
            re_state, _ = step(re_state, data.batch_at(i))
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(re_state)):
            assert (np.asarray(a) == np.asarray(b)).all(), \
                "restart is not bitwise-identical"

    def test_async_save_and_gc(self, tmp_path):
        cfg, model, opt, data = small_setup()
        ck = Checkpointer(str(tmp_path), keep=2)
        state = init_train_state(model, opt, jax.random.PRNGKey(0))
        for s in (1, 2, 3, 4):
            ck.save(state, step=s, async_=True)
        ck.wait()
        assert ck.all_steps() == [3, 4]  # keep=2

    def test_corruption_detected(self, tmp_path):
        cfg, model, opt, data = small_setup()
        ck = Checkpointer(str(tmp_path))
        state = init_train_state(model, opt, jax.random.PRNGKey(0))
        ck.save(state, step=1)
        d = os.path.join(str(tmp_path), "step_00000001")
        victim = sorted(os.listdir(d))[1]
        with open(os.path.join(d, victim), "r+b") as f:
            f.seek(100)
            f.write(b"\xff\xff\xff")
        with pytest.raises(ValueError):
            ck.restore(like=state)

    def test_partial_checkpoint_invisible(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
        assert ck.latest_step() is None  # incomplete save never visible


def test_straggler_watchdog():
    events = []
    wd = StragglerWatchdog(threshold=3.0, warmup_steps=3,
                           on_straggler=lambda i, dt, med: events.append(i))
    for _ in range(10):
        wd.observe(0.10)
    wd.observe(0.55)  # 5.5x median
    assert wd.flagged and events, "straggler not detected"
    wd.observe(0.11)
    assert len(wd.flagged) == 1


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from repro.configs.registry import get_arch
from repro.launch.mesh import ShardCtx, make_mesh
from repro.models.model import Model
from repro.training.checkpoint import Checkpointer
from repro.training.data import DataConfig, SyntheticLMData
from repro.training.elastic import ElasticController
from repro.training.optimizer import OptConfig
from repro.training.train_loop import init_train_state, make_train_step

cfg = get_arch("smollm-360m").reduced()
opt = OptConfig(lr=1e-2, weight_decay=0.0)
data = SyntheticLMData(DataConfig(cfg.vocab_size, 8, 32, seed=5))

# train 3 steps on a (2,4) mesh
mesh_a = make_mesh((2, 4), ("data", "model"))
with mesh_a:
    model_a = Model(cfg, ShardCtx(mesh=mesh_a))
    step_a = jax.jit(make_train_step(model_a, opt))
    state = init_train_state(model_a, opt, jax.random.PRNGKey(0))
    for i in range(3):
        state, _ = step_a(state, data.batch_at(i))
    ck = Checkpointer("/tmp/elastic_ckpt_test", keep=1)
    ck.save(state, step=3, extra={"data": {"seed": 5, "step": 3}})
    ref = state
    for i in range(3, 5):
        ref, _ = step_a(ref, data.batch_at(i))
    ref_loss_leaf = np.asarray(jax.tree.leaves(ref)[0])

# elastic restart on a DIFFERENT mesh (4,2): node-count change survival
mesh_b = make_mesh((4, 2), ("data", "model"))
with mesh_b:
    ec = ElasticController(cfg, opt, ck)
    model_b, state_b, extra = ec.resume(mesh_b)
    assert extra["data"]["step"] == 3
    step_b = jax.jit(make_train_step(model_b, opt))
    for i in range(3, 5):
        state_b, _ = step_b(state_b, data.batch_at(i))
    got = np.asarray(jax.tree.leaves(state_b)[0])

np.testing.assert_allclose(ref_loss_leaf.astype(np.float32),
                           got.astype(np.float32), rtol=2e-2, atol=2e-2)
print("ELASTIC_OK")
"""


@pytest.mark.slow
def test_elastic_mesh_reshape_resume():
    r = run_in_capture_process(
        ELASTIC_SCRIPT, 8, timeout=900,
        pythonpath=os.path.join(os.path.dirname(__file__), "..", "src"))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "ELASTIC_OK" in r.stdout

"""[Fig 15] Live parallelism switching under load: in-place fleet reshard
vs drain-and-restart (paper §4.3 "dynamic parallelism switching").

A fleet serving steady traffic on TP1 is told to move to TP2 mid-stream.
Two strategies, identical in everything but the switch mechanics:

  reshard   (``Fleet.reshard(strategy="live")``) replacement replicas stand
            up on TP2 via warm stamped-template LOAD of the SAME
            single-capture archive while the TP1 generation keeps serving;
            at cutover every in-flight request's KV rows are exported from
            the old pool and device_put-resharded into the TP2 pool, the
            backlog flips, and the old replicas are released. Requests that
            arrive during the switch are served throughout.

  restart   (``strategy="restart"``) the drain-and-restart baseline every
            system without graph-context materialization is stuck with: the
            TP1 generation is torn down first, in-flight requests requeue
            from their kept prefixes, and the backlog stalls until TP2
            provisions.

Measured per leg: time-to-new-topology (reshard() call -> old generation
fully released and the new one serving) and the TTFT distribution of the
requests that arrived DURING the switch window — the user-visible cost of a
parallelism change. Hard assertions, not just prints: zero dropped
requests, zero fallback compiles, zero background errors on both legs;
token streams byte-identical to a never-resharded engine (including the
requests that spanned the cutover); in-flight KV rows actually migrated on
the reshard leg; and the reshard leg's switch-window p99 TTFT beats the
restart baseline's.

The TP2 leg needs 2 placeholder ranks, so the whole comparison runs in a
subprocess with ``--xla_force_host_platform_device_count`` (the harness
process has its device count pinned at jax init; core/collective_stub.py).

CLI: ``python -m benchmarks.fig15_reshard [--quick]``. ``--quick`` is the
CI smoke mode (wired into the test-fast job next to the fig9/fig13 gates):
fewer requests, same hard assertions — a regression exits nonzero.
"""
from __future__ import annotations

_INNER = r"""
import itertools
import time

import jax
from repro.configs.registry import get_arch
from repro.core import Archive
from repro.launch.mesh import ShardCtx, make_capture_mesh, make_tp_mesh
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.fleet import AutoscalePolicy, Fleet, FleetReport

QUICK = __QUICK__
CFG = get_arch("smollm-360m").reduced()
PROMPTS = [[5, 9, 2], [11, 3], [7, 7, 7, 1], [2], [13, 4, 9]]
N_NEW = 6 if QUICK else 10
N_BEFORE = 3 if QUICK else 4        # requests in flight when the switch starts
MAX_INFLIGHT = 8                     # arrival gate during the switch window
POLICY = dict(min_replicas=1, max_replicas=1,
              target_inflight_per_replica=64)

def build(mesh):
    eng = ServingEngine(Model(CFG, ShardCtx(mesh=mesh)), max_batch=4,
                        max_seq=32, bucket_mode="pow2")
    eng.load_weights(rng=jax.random.PRNGKey(0))
    return eng

# offline SAVE (not on the clock): ONE single-device capture serves both
# topologies — exact on the capture-shaped TP1 mesh, rank-stamped on TP2
mesh_cap = make_capture_mesh()
with mesh_cap:
    archive_bytes = build(mesh_cap).save_archive()[0].to_bytes()

# reference token streams from a never-resharded engine
ref_eng = build(None)
ref_eng.cold_start_vanilla()
reference = {}
for p in PROMPTS:
    r = ref_eng.submit(p, N_NEW)
    ref_eng.run_until_drained()
    reference[tuple(p)] = tuple(r.generated)

def run_leg(strategy):
    jax.clear_caches()
    ar = Archive.from_bytes(archive_bytes, lazy=True)  # fresh caches per leg
    tp1, tp2 = make_tp_mesh(1), make_tp_mesh(2)
    fleet = Fleet(factory_for_mesh=build, mode="foundry", archive=ar,
                  policy=AutoscalePolicy(**POLICY), mesh=tp1)
    fleet.start()
    cycle = itertools.cycle(PROMPTS)
    reqs = [fleet.submit(next(cycle), N_NEW) for _ in range(N_BEFORE)]
    while not fleet._ready():
        fleet.tick(); time.sleep(0.001)
    for _ in range(2):
        fleet.tick()

    rep = fleet.reshard(tp2, strategy=strategy)
    switch_reqs = []
    while fleet._reshard is not None:
        # steady arrivals through the switch, gated so the backlog stays
        # bounded while the restart baseline stalls
        if fleet.inflight() < MAX_INFLIGHT:
            q = fleet.submit(next(cycle), N_NEW)
            reqs.append(q); switch_reqs.append(q)
        if fleet.tick() == 0:
            time.sleep(0.001)
    assert rep.aborted is None, f"{strategy}: {rep.aborted}"
    fleet.run_trace([], seed=0)   # drain the tail
    fleet.drain_background()
    frep = fleet.report()

    # -- hard invariants (the ISSUE acceptance criteria) -----------------
    assert frep.n_failed == 0 and frep.n_done == len(reqs), \
        f"{strategy}: dropped requests ({frep.n_failed} failed)"
    for q in reqs:
        assert tuple(q.generated) == reference[tuple(q.prompt)], \
            f"{strategy}: req {q.req_id} tokens diverged across the switch"
    s = frep.summary()
    assert s["fallback_compiles"] == 0, f"{strategy}: compiled on switch"
    assert s["background_errors"] == 0, f"{strategy}: background failures"
    if strategy == "live":
        assert rep.migrated_requests > 0, "live switch moved no KV rows"

    ttfts = sorted(q.ttft for q in switch_reqs if q.ttft is not None)
    assert ttfts, f"{strategy}: no requests arrived during the switch"
    pct = FleetReport._pct
    return {
        "topology_s": rep.time_to_new_topology_s,
        "ttft_p50_s": pct(ttfts, 0.50),
        "ttft_p99_s": pct(ttfts, 0.99),
        "n_switch": len(switch_reqs),
        "migrated": rep.migrated_requests,
        "requeued": rep.requeued_requests,
        "dual_ticks": rep.dual_ticks,
        "n_total": len(reqs),
    }

results = {}
for label, strategy in (("reshard", "live"), ("restart", "restart")):
    r = results[label] = run_leg(strategy)
    print(f"ROW,fig15.{label}.time_to_new_topology_s,"
          f"{r['topology_s'] * 1e6:.1f},dual_ticks={r['dual_ticks']}")
    print(f"ROW,fig15.{label}.switch_ttft_p50_s,{r['ttft_p50_s'] * 1e6:.1f},"
          f"n={r['n_switch']}")
    print(f"ROW,fig15.{label}.switch_ttft_p99_s,{r['ttft_p99_s'] * 1e6:.1f},"
          f"p50={r['ttft_p50_s']:.3f}s")
    print(f"ROW,fig15.{label}.served,{r['n_total']},"
          f"migrated={r['migrated']};requeued={r['requeued']}")

# the paper's flexibility claim, enforced: requests arriving during a live
# reshard are served by the old generation (ms TTFTs), while the restart
# baseline stalls them behind a full re-provision
assert (results["reshard"]["ttft_p99_s"]
        < results["restart"]["ttft_p99_s"]), \
    "live reshard's switch-window p99 TTFT not better than drain-and-restart"
print("ROW,fig15.reshard_beats_restart,"
      f"{results['restart']['ttft_p99_s'] / results['reshard']['ttft_p99_s']:.1f},"
      "p99_ttft_ratio_asserted")
"""


def run(quick: bool = False):
    from repro.core.collective_stub import run_in_capture_process
    inner = _INNER.replace("__QUICK__", repr(bool(quick)))
    r = run_in_capture_process(inner, 2, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"fig15 subprocess failed:\n{r.stdout}\n{r.stderr}")
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("ROW,"):
            _, name, us, derived = line.split(",", 3)
            rows.append((name, float(us), derived))
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer requests, same zero-drop / "
                         "zero-compile / identity / faster-than-restart "
                         "assertions")
    args = ap.parse_args()
    emit(run(quick=args.quick), figure="fig15_reshard")

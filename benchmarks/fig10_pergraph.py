"""[Fig 10] Per-graph cost of the three construction paths.

Paper: stream capture 59-199 ms/graph; explicit-API construction 2-3x
faster; in-place template update another 24-32x faster. JAX analogues:
  capture   = Python trace + lower + compile (per bucket)
  construct = compile from archived StableHLO (no Python trace)
  update    = template dispatch (pad-to-bucket lookup; amortized zero)
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import BENCH_ARCHS, fresh_jax_caches, make_engine, timed
from repro.core.restore import _compile_from_export


def run():
    rows = []
    arch = BENCH_ARCHS[0]
    eng = make_engine(arch, bucket_mode="pow2")
    archive, _ = eng.save_archive()
    spec_m = archive.manifest["specs"]["decode"]
    buckets = eng.buckets

    # 1) capture: trace+lower+compile per bucket
    fresh_jax_caches()
    step = eng._decode_fn()
    t0 = time.perf_counter()
    for b in buckets:
        jax.jit(step, donate_argnums=(1,)).lower(*eng._decode_args(b)).compile()
    t_capture = (time.perf_counter() - t0) / len(buckets)

    # 2) construct: compile from pre-lowered StableHLO (no model re-trace)
    fresh_jax_caches()
    blobs = []
    for g in spec_m["groups"]:
        blobs += list(g["bucket_export_blobs"].values())
    t0 = time.perf_counter()
    for blob in blobs:
        _compile_from_export(archive, blob, None,
                             donate_argnums=spec_m["donate_argnums"])
    t_construct = (time.perf_counter() - t0) / len(blobs)

    # 3) materialized-context restore: deserialize template executables
    #    (the actual LOAD path — zero trace, zero compile)
    from repro.core.restore import _deserialize_template
    tmpl_blobs = [g["executable_blob"] for g in spec_m["groups"]
                  if g["executable_blob"]]
    t0 = time.perf_counter()
    for blob in tmpl_blobs:
        _deserialize_template(archive.get_blob(blob))
    t_deser = (time.perf_counter() - t0) / len(tmpl_blobs)

    # 4) update: template dispatch (the pad path)
    eng2 = make_engine(arch, bucket_mode="pow2")
    eng2.cold_start_foundry(archive, background_exact=False)
    t0 = time.perf_counter()
    n = 2000
    for i in range(n):
        eng2.programs.lookup(1 + (i % eng2.max_batch))
    t_update = (time.perf_counter() - t0) / n

    rows.append(("fig10.capture_per_graph", t_capture * 1e6, ""))
    rows.append(("fig10.construct_per_graph", t_construct * 1e6,
                 f"speedup={t_capture / t_construct:.2f}x"))
    rows.append(("fig10.restore_template_per_graph", t_deser * 1e6,
                 f"speedup_vs_capture={t_capture / max(t_deser, 1e-9):.0f}x"))
    rows.append(("fig10.update_per_graph", t_update * 1e6,
                 f"speedup_vs_construct={t_construct / max(t_update, 1e-9):.0f}x"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), figure="fig10_pergraph")

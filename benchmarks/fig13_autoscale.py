"""[Fig 13] Autoscaling fleet scale-out latency under a load spike:
vanilla vs foundry vs foundry-stamped replica cold starts.

The paper's motivating scenario (§1-2): traffic spikes, the autoscaler adds
replicas, and every request admitted during scale-out eats the new
replica's cold start in its TTFT. Here one spike trace is replayed against
three fleets that differ ONLY in replica cold-start provenance:

  vanilla          every replica trace+lower+compiles its capture set;
  foundry          every replica LOADs one shared archive captured on the
                   deployment topology (exact path, zero compile);
  foundry-stamped  every replica LOADs one shared single-device capture and
                   rank-stamps it onto the (1,2) TP deployment mesh
                   (stamped path, zero compile).

Reported per mode: the fleet's scale-out latency (max replica
cold-start-to-first-token), mean replica cold start, and fleet-wide TTFT
percentiles. The foundry paths must reach first token faster than vanilla
and must never touch the compiler on the critical path
(``fallback_compiles == 0``) nor fail background compiles silently
(``background_errors == 0``) — both asserted, not just printed.

The stamped leg needs 2 placeholder ranks, so the whole comparison runs in
a subprocess with ``--xla_force_host_platform_device_count`` (the harness
process has its device count pinned at jax init; core/collective_stub.py).

CLI: ``python -m benchmarks.fig13_autoscale [--quick]``. ``--quick`` is the
CI smoke mode (wired into the test-fast job next to the fig9 gate): a
shorter spike and fewer replicas, with the same hard assertions — foundry
faster than vanilla, ``fallback_compiles == 0``,
``background_errors == 0`` — so a regression exits nonzero.
"""
from __future__ import annotations

_INNER = r"""
import jax
from repro.configs.registry import get_arch
from repro.core.archive import Archive
from repro.launch.mesh import ShardCtx, make_capture_mesh, make_tp_mesh
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.fleet import AutoscalePolicy, Fleet, spike_trace

QUICK = __QUICK__
CFG = get_arch("smollm-360m").reduced()
TRACE = (spike_trace(warm_ticks=1, spike_ticks=5, cool_ticks=4,
                     base_rate=1, spike_rate=4) if QUICK else
         spike_trace(warm_ticks=2, spike_ticks=8, cool_ticks=6,
                     base_rate=1, spike_rate=5))
POLICY = dict(min_replicas=1, max_replicas=2 if QUICK else 3,
              target_inflight_per_replica=4, scale_down_idle_ticks=8)

def build(mesh):
    eng = ServingEngine(Model(CFG, ShardCtx(mesh=mesh)), max_batch=4,
                        max_seq=32, bucket_mode="pow2")
    eng.load_weights(rng=jax.random.PRNGKey(0))
    return eng

# offline SAVEs (not on the clock): one archive per capture topology,
# round-tripped through bytes so the fleets LOAD the lazy v2 container
mesh_cap = make_capture_mesh()
with mesh_cap:
    ar_stamp = Archive.from_bytes(build(mesh_cap).save_archive()[0].to_bytes(),
                                  lazy=True)
ar_exact = Archive.from_bytes(build(None).save_archive()[0].to_bytes(),
                              lazy=True)

legs = (
    ("vanilla",         "vanilla", None,     None),
    ("foundry",         "foundry", ar_exact, None),
    ("foundry_stamped", "foundry", ar_stamp, make_tp_mesh(2)),
)
results = {}
for label, mode, archive, mesh in legs:
    jax.clear_caches()
    fleet = Fleet(lambda m=mesh: build(m), mode=mode, archive=archive,
                  policy=AutoscalePolicy(**POLICY), mesh=mesh)
    rep = fleet.run_trace(TRACE, seed=0)
    fleet.drain_background()
    rep = fleet.report()
    s = rep.summary()
    assert rep.n_failed == 0 and rep.n_done == len(fleet.requests), \
        f"{label}: {rep.n_failed} failed / {rep.n_done} done"
    assert rep.peak_alive > 1, f"{label}: spike never triggered scale-up"
    results[label] = s
    cold = s["cold_start_to_first_token_s"]
    print(f"ROW,fig13.{label}.scaleout_first_token_s,"
          f"{s['cold_start_to_first_token_max_s'] * 1e6:.1f},"
          f"replicas={s['replicas_spawned']};peak={s['peak_alive']}")
    print(f"ROW,fig13.{label}.cold_start_mean_s,"
          f"{sum(cold) / len(cold) * 1e6:.1f},n={len(cold)}")
    print(f"ROW,fig13.{label}.ttft_p50_s,{s['ttft_p50_s'] * 1e6:.1f},"
          f"p95={s['ttft_p95_s']:.3f}s")
    modes = {r.mode for r in rep.replicas}
    print(f"ROW,fig13.{label}.done,{rep.n_done},modes={'|'.join(sorted(modes))}")

# the paper's claim, enforced: foundry cold starts reach first token faster
# than vanilla, without compiling on the critical path
for label in ("foundry", "foundry_stamped"):
    s = results[label]
    assert s["fallback_compiles"] == 0, f"{label}: compiled on critical path"
    assert s["background_errors"] == 0, f"{label}: background compiles failed"
    assert (s["cold_start_to_first_token_max_s"]
            < results["vanilla"]["cold_start_to_first_token_max_s"]), \
        f"{label} scale-out not faster than vanilla"
print("ROW,fig13.foundry_faster_than_vanilla,1.0,asserted")

# strict-LOAD verification budget: the static pre-flight that
# foundry_load(strict=True) runs (repro.analysis.checker.verify_for_load)
# must cost < 5% of the LOAD critical path — measured on a fresh LOAD (no
# template-cache reuse) so verify_s is weighed against real restore work
from repro.core import foundry_load, wait_for_background
_, lrep, _ = foundry_load(
    Archive.from_bytes(ar_exact.to_bytes(), lazy=True), None,
    reuse_templates=False)
wait_for_background(lrep)
verify = lrep.phases["verify_s"]
assert lrep.fallback_compiles == 0
assert verify < 0.05 * lrep.critical_path_s, \
    f"strict verification {verify * 1e3:.2f}ms exceeds 5% of LOAD " \
    f"critical path {lrep.critical_path_s * 1e3:.2f}ms"
print(f"ROW,fig13.strict_verify_s,{verify * 1e6:.1f},"
      f"pct={100 * verify / lrep.critical_path_s:.2f}%_of_load")
"""


def run(quick: bool = False):
    from repro.core.collective_stub import run_in_capture_process
    inner = _INNER.replace("__QUICK__", repr(bool(quick)))
    r = run_in_capture_process(inner, 2, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"fig13 subprocess failed:\n{r.stdout}\n{r.stderr}")
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("ROW,"):
            _, name, us, derived = line.split(",", 3)
            rows.append((name, float(us), derived))
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: shorter spike, fewer replicas, same "
                         "fallback/background/faster-than-vanilla asserts")
    args = ap.parse_args()
    emit(run(quick=args.quick), figure="fig13_autoscale")

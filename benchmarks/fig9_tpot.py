"""[Fig 9] Serving-throughput preservation: TPOT with natively-captured vs
Foundry-restored programs, across batch sizes — plus the paper's token-
identity check (§6.3: "the tokens generated are identical").
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_ARCHS, fresh_jax_caches, make_engine, timed


def _tpot(eng, bucket: int, steps: int = 20):
    """Mean decode-step time at a given active batch (pad path included)."""
    m = eng.model
    exec_bucket, exe, path = eng.programs.lookup(bucket)
    cache = m.init_cache(exec_bucket, eng.max_seq)
    cache = {**cache, "lengths": jnp.full((exec_bucket,), 4, jnp.int32)}
    toks = jnp.ones((exec_bucket,), jnp.int32)
    # warmup
    cache, logits = exe(eng.params, cache, toks)
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(steps):
        cache, logits = exe(eng.params, cache, toks)
    jax.block_until_ready(logits)
    return (time.perf_counter() - t0) / steps, path


def run():
    rows = []
    arch = BENCH_ARCHS[0]
    eng = make_engine(arch, bucket_mode="pow2")
    archive, _ = eng.save_archive()
    eng.cold_start_vanilla()

    eng_f = make_engine(arch, bucket_mode="pow2")
    eng_f.cold_start_foundry(archive, background_exact=True)

    # transient: right after LOAD every bucket pad-serves via its template
    t_pad, path0 = _tpot(eng_f, 1)
    rows.append((f"fig9.{arch}.b1.foundry_tpot_transient", t_pad * 1e6,
                 f"path={path0}(pad-to-template)"))

    # steady state: background exact-bucket compiles have swapped in
    from repro.core import wait_for_background
    wait_for_background(eng_f._load_report)

    for bucket in (1, 4, 16):
        t_v, _ = _tpot(eng, bucket)
        t_f, path = _tpot(eng_f, bucket)
        rows.append((f"fig9.{arch}.b{bucket}.vanilla_tpot", t_v * 1e6, ""))
        rows.append((f"fig9.{arch}.b{bucket}.foundry_tpot", t_f * 1e6,
                     f"path={path},ratio={t_f / t_v:.3f}"))

    # token identity (greedy decode through both engines)
    prompts = [[3, 1, 4], [1, 5, 9, 2], [6, 5]]
    eng2 = make_engine(arch, bucket_mode="pow2")
    eng2.cold_start_vanilla()
    for p in prompts:
        eng2.submit(p, 5)
    eng2.run_until_drained()
    ref = [tuple(r.generated) for r in eng2.scheduler.done]

    eng3 = make_engine(arch, bucket_mode="pow2")
    eng3.cold_start_foundry(archive, background_exact=False)
    for p in prompts:
        eng3.submit(p, 5)
    eng3.run_until_drained()
    got = [tuple(r.generated) for r in eng3.scheduler.done]
    identical = sorted(ref) == sorted(got)
    rows.append((f"fig9.{arch}.token_identity", 1.0 if identical else 0.0,
                 "identical" if identical else "MISMATCH"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())

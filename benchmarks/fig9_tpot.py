"""[Fig 9] Serving-throughput preservation: TPOT with natively-captured vs
Foundry-restored programs, across batch sizes — plus the paper's token-
identity check (§6.3: "the tokens generated are identical").

This figure also carries the decode-hot-path comparison: the device-resident
loop (fused sampling, donated cache, O(B)-id readback; ``decode_loop=
"device"``) against the pre-fusion host loop (per-step token re-pack +
O(B x padded_vocab) logits readback + numpy argmax). The loop comparison is
run at a serving-scale vocab (32768) because the host loop's per-token cost
is dominated by the logits matrix it drags across the host boundary — the
reduced configs' 256-token vocab would hide exactly the overhead the fused
step removes.

CLI: ``python benchmarks/fig9_tpot.py [--quick]``. ``--quick`` is the CI
smoke mode: fewer steps/buckets, and it acts as a regression gate — nonzero
exit if BENCH_results.json was not written or the foundry TPOT regresses
past the vanilla path by more than REGRESSION_MARGIN.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_ARCHS, make_engine, read_results

# foundry TPOT may not exceed vanilla TPOT by more than this factor (the two
# run the *same* program on the exact path, so the true ratio is ~1.0; the
# margin absorbs CI timer noise)
REGRESSION_MARGIN = 1.5
LOOP_VOCAB = 32768


def _tpot(eng, bucket: int, steps: int = 20):
    """Mean decode-step time at a given active batch (pad path included)."""
    m = eng.model
    exec_bucket, exe, path = eng.programs.lookup(bucket)
    if getattr(eng, "kv_layout", "slot") == "paged":
        cache = m.init_cache_paged(exec_bucket, eng.max_seq, eng.kv_blocks,
                                   eng.kv_block_size)
    else:
        cache = m.init_cache(exec_bucket, eng.max_seq)
    cache = {**cache, "lengths": jnp.full((exec_bucket,), 4, jnp.int32)}
    toks = jnp.ones((exec_bucket,), jnp.int32)
    # warmup
    cache, out = exe(eng.params, cache, toks)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(steps):
        cache, out = exe(eng.params, cache, toks)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / steps, path


def _loop_steps_per_s(arch: str, *, batch: int, steps: int, reps: int):
    """Steady-state engine steps/sec through the full serving loop (the
    number the host-vs-device comparison is about: scheduling + dispatch +
    readback, not just kernel time). The two loops are measured in
    interleaved repetitions and reported as medians — this box's wall clock
    is noisy enough that back-to-back single shots can swing 2x. max_seq is
    kept moderate: decode attention cost is O(max_seq) per step regardless
    of lengths, and an oversized window buries the per-step loop overhead
    (the thing the two loops differ in) under padded-cache compute."""
    engs, xfers = {}, {}
    for loop in ("host", "device"):
        eng = make_engine(arch, bucket_mode="pow2", max_batch=max(batch, 8),
                          max_seq=steps * reps + 32,
                          decode_loop=loop, vocab_size=LOOP_VOCAB)
        eng.cold_start_vanilla()
        for _ in range(batch):
            eng.submit([3, 1, 4], 10 ** 6)  # nothing completes in the window
        eng.step()  # admissions + prefill compile + first token: off clock
        eng.transfer_stats = {k: 0 for k in eng.transfer_stats}
        engs[loop] = eng
    samples = {"host": [], "device": []}
    for _ in range(reps):
        for loop in ("host", "device"):
            eng = engs[loop]
            t0 = time.perf_counter()
            for _ in range(steps):
                eng.step()
            samples[loop].append(steps / (time.perf_counter() - t0))
    for loop, eng in engs.items():
        n = eng.decode_steps - 1
        xfers[loop] = {k: v / n for k, v in eng.transfer_stats.items()}
    import statistics
    return ({k: statistics.median(v) for k, v in samples.items()}, xfers)


def run(quick: bool = False):
    rows = []
    arch = BENCH_ARCHS[0]
    steps = 10 if quick else 40
    batch = 8

    # --- decode hot path: host loop vs device-resident loop ---------------
    sps, xfers = _loop_steps_per_s(arch, batch=batch,
                                   steps=10 if quick else 16,
                                   reps=3 if quick else 8)
    for loop in ("host", "device"):
        rows.append((f"fig9.{arch}.loop_{loop}.steps_per_s", sps[loop],
                     f"b={batch},vocab={LOOP_VOCAB},"
                     f"d2h_bytes_per_step={xfers[loop]['d2h_bytes']:.0f},"
                     f"h2d_bytes_per_step={xfers[loop]['h2d_bytes']:.0f}"))
    speedup = sps["device"] / sps["host"]
    rows.append((f"fig9.{arch}.device_loop_speedup", speedup,
                 f"device_vs_host_steps_per_s,b={batch}"))

    # --- TPOT preservation: vanilla capture vs foundry restore ------------
    eng = make_engine(arch, bucket_mode="pow2", max_batch=8 if quick else 16)
    archive, _ = eng.save_archive()
    eng.cold_start_vanilla()

    eng_f = make_engine(arch, bucket_mode="pow2",
                        max_batch=8 if quick else 16)
    rep_f = eng_f.cold_start_foundry(archive, background_exact=True)
    rows.append((f"fig9.{arch}.load_fallback_compiles",
                 float(rep_f.fallback_compiles),
                 "must_be_0_on_exact_path"))

    # transient: right after LOAD every bucket pad-serves via its template
    t_pad, path0 = _tpot(eng_f, 1, steps=steps)
    rows.append((f"fig9.{arch}.b1.foundry_tpot_transient", t_pad * 1e6,
                 f"path={path0}(pad-to-template)"))

    # steady state: background exact-bucket compiles have swapped in
    from repro.core import wait_for_background
    wait_for_background(eng_f._load_report)

    import statistics
    ratios = []
    for bucket in (1, 4) if quick else (1, 4, 16):
        tv, tf = [], []
        path = "?"
        for _ in range(3 if quick else 5):  # interleaved medians (noise)
            tv.append(_tpot(eng, bucket, steps=steps)[0])
            t, path = _tpot(eng_f, bucket, steps=steps)
            tf.append(t)
        t_v, t_f = statistics.median(tv), statistics.median(tf)
        ratios.append(t_f / t_v)
        rows.append((f"fig9.{arch}.b{bucket}.vanilla_tpot", t_v * 1e6, ""))
        rows.append((f"fig9.{arch}.b{bucket}.foundry_tpot", t_f * 1e6,
                     f"path={path},ratio={t_f / t_v:.3f}"))
    tpot_ratio = sum(ratios) / len(ratios)
    rows.append((f"fig9.{arch}.foundry_vs_vanilla_tpot_ratio", tpot_ratio,
                 f"mean_over_{len(ratios)}_buckets"))

    # --- token identity across an archive save -> load round trip ---------
    # (device loop, greedy: byte-identical streams are the acceptance bar)
    prompts = [[3, 1, 4], [1, 5, 9, 2], [6, 5]]
    eng2 = make_engine(arch, bucket_mode="pow2")
    eng2.cold_start_vanilla()
    for p in prompts:
        eng2.submit(p, 5)
    eng2.run_until_drained()
    ref = [tuple(r.generated) for r in eng2.scheduler.done]

    from repro.core import Archive
    eng3 = make_engine(arch, bucket_mode="pow2")
    eng3.cold_start_foundry(Archive.from_bytes(archive.to_bytes()),
                            background_exact=False)
    for p in prompts:
        eng3.submit(p, 5)
    eng3.run_until_drained()
    got = [tuple(r.generated) for r in eng3.scheduler.done]
    identical = sorted(ref) == sorted(got)
    rows.append((f"fig9.{arch}.token_identity", 1.0 if identical else 0.0,
                 "identical" if identical else "MISMATCH"))

    headline = {
        "device_steps_per_s": sps["device"],
        "host_steps_per_s": sps["host"],
        "device_loop_speedup": speedup,
        "foundry_vs_vanilla_tpot_ratio": tpot_ratio,
        "fallback_compiles": rep_f.fallback_compiles,
        "token_identity": bool(identical),
    }
    return rows, headline


def check_regression(verbose: bool = True) -> list:
    """CI gate: BENCH_results.json must exist and fig9's headline must show
    foundry TPOT within REGRESSION_MARGIN of vanilla, zero fallback
    compiles, and token identity. Returns a list of failure strings."""
    doc = read_results()
    failures = []
    fig = doc.get("figures", {}).get("fig9_tpot")
    if not fig:
        return [f"BENCH_results.json missing or has no fig9_tpot entry"]
    head = fig.get("headline", {})
    ratio = head.get("foundry_vs_vanilla_tpot_ratio")
    if ratio is None or ratio > REGRESSION_MARGIN:
        failures.append(f"foundry TPOT regressed past vanilla: ratio={ratio} "
                        f"(margin {REGRESSION_MARGIN})")
    if head.get("fallback_compiles", 1) != 0:
        failures.append("exact-path LOAD performed fallback compiles")
    if not head.get("token_identity", False):
        failures.append("token identity lost across save->load round trip")
    if verbose:
        for f in failures:
            print(f"[fig9 REGRESSION] {f}")
    return failures


if __name__ == "__main__":
    import argparse
    import sys

    from benchmarks.common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: fewer steps/buckets + regression "
                         "gate on BENCH_results.json")
    args = ap.parse_args()
    rows, headline = run(quick=args.quick)
    emit(rows, figure="fig9_tpot", headline=headline)
    if args.quick and check_regression():
        sys.exit(1)

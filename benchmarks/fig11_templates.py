"""[Fig 11] Unique templates vs captured graphs per model.

Paper: 512 captured graphs collapse to 12-25 unique topologies (95-98%
served via on-demand update). Here topology keys are computed over jaxprs
traced against the production (16,16) mesh shape (AbstractMesh: no devices
needed for tracing) for buckets 1..512 — topology transitions come from
sharding-divisibility classes of the batch axis, the JAX counterpart of the
paper's "nearby batch sizes share a topology".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.core import group_buckets, topology_key
from repro.core.templates import default_bucket_ladder
from repro.launch.mesh import ShardCtx
from repro.models.model import Model

ARCHS = ["qwen3-14b", "smollm-360m", "yi-9b", "moonshot-v1-16b-a3b"]


def _abstract_production_mesh():
    AM = jax.sharding.AbstractMesh
    try:  # jax<=0.4.x: AbstractMesh(shape_tuple=((name, size), ...))
        return AM((("data", 16), ("model", 16)))
    except TypeError:  # jax>=0.5: AbstractMesh(axis_sizes, axis_names)
        return AM((16, 16), ("data", "model"))


def template_count(arch: str, n_buckets: int = 512, max_seq: int = 64):
    mesh = _abstract_production_mesh()
    ctx = ShardCtx(mesh=mesh)
    cfg = get_arch(arch).reduced()
    m = Model(cfg, ctx)

    def step(p, c, t):
        return m.decode_step(p, c, t)

    keys = {}
    for b in default_bucket_ladder(n_buckets, "all"):
        cache = m.cache_specs(b, max_seq)
        tok = jax.ShapeDtypeStruct((b,), jnp.int32)
        keys[b] = topology_key(step, m.param_shapes(), cache, tok,
                               extra=("(16,16)",))
    groups = group_buckets(keys)
    return len(groups), len(keys)


def run():
    rows = []
    for arch in ARCHS:
        n_templates, n_buckets = template_count(arch, n_buckets=512)
        pct = 100.0 * (n_buckets - n_templates) / n_buckets
        rows.append((f"fig11.{arch}.templates", n_templates,
                     f"of_{n_buckets}_graphs,{pct:.1f}%_via_update"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), figure="fig11_templates")

"""[Fig 12] Rank-stamped LOAD vs fallback-compile LOAD across deployment
sizes (paper §4.3).

One single-device offline capture is loaded onto 1-, 2-, and 4-rank
deployment meshes. The stamped path reuses the archived template program
byte-identically and patches only rank-dependent state, so its critical path
stays flat in the rank count and never touches the compiler
(``fallback_compiles == 0``); the no-stamping ablation pays a
compile-from-StableHLO per topology group at every new shape. The 1-rank
deployment IS the capture topology, so both of its rows take the exact
restore path (``path=exact``) — it is the same-shape baseline, not a
stamped-vs-fallback comparison; the ablation bites from 2 ranks up. Each
row's ``derived`` column carries the restore path taken so the figure is
self-describing.

Placeholder ranks are simulated with ``--xla_force_host_platform_device_count``
in a subprocess (the benchmark harness process has its device count pinned
at jax init; core/collective_stub.py documents the constraint).
"""
from __future__ import annotations

RANKS = (1, 2, 4)

_INNER = r"""
import time
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.registry import get_arch
from repro.core.archive import Archive
from repro.launch.mesh import ShardCtx, make_capture_mesh, make_tp_mesh
from repro.models.model import Model
from repro.serving.engine import ServingEngine

def build(mesh):
    cfg = get_arch("smollm-360m").reduced()
    eng = ServingEngine(Model(cfg, ShardCtx(mesh=mesh)), max_batch=4,
                        max_seq=32, bucket_mode="pow2")
    eng.load_weights(rng=jax.random.PRNGKey(0))
    return eng

mesh_cap = make_capture_mesh()
with mesh_cap:
    eng = build(mesh_cap)
    archive_bytes = eng.save_archive()[0].to_bytes()

for n in (%(ranks)s):
    mesh = make_tp_mesh(n)
    tokens = {}
    for mode, allow in (("stamped", True), ("fallback", False)):
        jax.clear_caches()
        with mesh:
            e = build(mesh)
            # fresh Archive object per leg: each cold start models a fresh
            # process, so the per-Archive deserialized-template cache and
            # blob cache must not carry over between measured LOADs
            archive = Archive.from_bytes(archive_bytes, lazy=True)
            t0 = time.perf_counter()
            rep = e.cold_start_foundry(archive, background_exact=False,
                                       allow_stamping=allow)
            dt = time.perf_counter() - t0
            e.submit([1, 2, 3], 4)
            e.run_until_drained()
            tokens[mode] = [tuple(r.generated) for r in e.scheduler.done]
            print(f"ROW,fig12.r{n}.{mode}_load_s,{dt * 1e6:.1f},"
                  f"path={e._load_report.restore_path};"
                  f"rank_stamped={rep.rank_stamped};"
                  f"fallback_compiles={rep.fallback_compiles}")
    assert tokens["stamped"] == tokens["fallback"], \
        f"rank {n}: stamped and fallback outputs diverged"
    print(f"ROW,fig12.r{n}.outputs_match,1.0,token_identical")
"""


def run():
    from repro.core.collective_stub import run_in_capture_process
    script = _INNER % {"ranks": ", ".join(str(r) for r in RANKS)}
    r = run_in_capture_process(script, max(RANKS), timeout=1200)
    if r.returncode != 0:
        raise RuntimeError(f"fig12 subprocess failed:\n{r.stdout}\n{r.stderr}")
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("ROW,"):
            _, name, us, derived = line.split(",", 3)
            rows.append((name, float(us), derived))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), figure="fig12_rank_stamp")

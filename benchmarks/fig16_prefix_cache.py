"""[Fig 16] Radix prefix caching over the paged KV pool: TTFT and prefill
work for shared-system-prompt traffic, paged+radix vs the slot-pool baseline.

The workload every serving deployment sees: N requests share one long system
prompt and differ only in a short user suffix. Three engines serve the same
trace:

  paged     (``kv_layout="paged"``) block-table pool + radix prefix cache.
            Request 1 is cold and decode-fills the whole prompt; requests
            2..N hit the radix tree, attach the cached prefix blocks by
            reference (no copy, no recompute) and fill only the suffix —
            the TTFT win measured here.
  slot      (``kv_layout="slot"``) the row-per-request baseline: every
            request re-prefills the full prompt into its private row; no
            sharing is possible because rows are monolithic.

Measured: wall TTFT cold vs warm on the paged engine, decode-fill steps to
first token, prefix hit rate, prefilled-token totals for both layouts, and
the paged pool's MemoryPlan per-rank footprint (§5.4 — the deterministic
extent LOAD pins before restore).

Hard assertions, not just prints: every request after the first is a prefix
hit; warm fill-steps and warm wall TTFT are strictly below cold; the paged
engine prefills < 60% of the slot baseline's tokens on the same trace; and
warm token streams are byte-identical to a cold engine serving the same
prompts (identity is re-checked here, not only in tests, because this is
the configuration the figure ships).

CLI: ``python -m benchmarks.fig16_prefix_cache [--quick]``. ``--quick`` is
the CI smoke mode (wired into the test-fast job next to the fig9/fig13/
fig15 gates): fewer requests, same hard assertions — a regression exits
nonzero.
"""
from __future__ import annotations

import time

import jax

from repro.configs.registry import get_arch
from repro.models.model import Model
from repro.serving.engine import ServingEngine

CFG = get_arch("smollm-360m").reduced()
BLOCK = 8
MAX_SEQ = 64
N_NEW = 6
# 40-token shared system prompt (5 full blocks), 3-token user suffixes
SYSTEM = [((7 * i) % 96) + 1 for i in range(40)]
P50 = 0.50


def make_engine(kv_layout: str) -> ServingEngine:
    eng = ServingEngine(Model(CFG), max_batch=8, max_seq=MAX_SEQ,
                        bucket_mode="pow2", kv_layout=kv_layout,
                        kv_block_size=BLOCK)
    eng.load_weights(rng=jax.random.PRNGKey(0))
    eng.cold_start_vanilla()
    return eng


def prompts(n: int):
    return [SYSTEM + [100 + i, 3, ((11 * i) % 96) + 1] for i in range(n)]


def serve_trace(eng, trace):
    """One request at a time (the prefix-cache steady state: later arrivals
    find earlier prompts' chains committed). Returns per-request records."""
    out = []
    for p in trace:
        r = eng.submit(p, N_NEW)
        t0 = time.perf_counter()
        steps = 0
        while not r.generated:
            eng.step()
            steps += 1
        ttft = time.perf_counter() - t0
        eng.run_until_drained()
        assert r.state.value == "done", r.fail_reason
        out.append({"ttft_s": ttft, "fill_steps": steps,
                    "tokens": tuple(r.generated)})
    return out


def _pct(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))]


def run(quick: bool = False):
    n_reqs = 6 if quick else 16
    trace = prompts(n_reqs)

    paged = make_engine("paged")
    recs = serve_trace(paged, trace)
    cold, warm = recs[0], recs[1:]
    stats = paged.prefill_stats
    hit_rate = stats["prefix_hits"] / max(
        1, stats["prefix_hits"] + stats["prefix_misses"])
    paged_prefilled = stats["prefilled_tokens"]

    slot = make_engine("slot")
    slot_recs = serve_trace(slot, trace)
    # the slot pool re-prefills every prompt in full: its token work is the
    # trace itself (the engine's prefill path has no cache to skip any)
    slot_prefilled = sum(len(p) for p in trace)

    # ---- hard invariants (the ISSUE acceptance criteria) ----------------
    assert stats["prefix_hits"] == n_reqs - 1, \
        f"expected every warm request to hit, got {stats['prefix_hits']}"
    warm_steps = _pct([w["fill_steps"] for w in warm], P50)
    assert warm_steps < cold["fill_steps"], \
        f"warm fill {warm_steps} steps !< cold {cold['fill_steps']}"
    warm_ttft = _pct([w["ttft_s"] for w in warm], P50)
    assert warm_ttft < cold["ttft_s"], \
        f"warm TTFT {warm_ttft:.4f}s !< cold {cold['ttft_s']:.4f}s"
    assert paged_prefilled < 0.6 * slot_prefilled, \
        (f"paged prefilled {paged_prefilled} tokens, slot baseline "
         f"{slot_prefilled}: prefix cache saved too little")
    # identity: warm streams must match a fresh paged engine serving the
    # same prompt cold (the slot baseline uses a different fill convention,
    # so the oracle is paged-cold, not slot)
    oracle = make_engine("paged")
    check = 1 if quick else 3  # cold-serve a few warm prompts, compare
    for i in range(1, 1 + check):
        o = oracle.submit(trace[i], N_NEW)
        oracle.run_until_drained()
        assert tuple(o.generated) == recs[i]["tokens"], \
            f"warm stream {i} diverged from its cold oracle"

    kv_bytes = paged.memory_plan.scoped_extent("per_rank")
    return [
        ("fig16.paged.cold_ttft_s", cold["ttft_s"] * 1e6,
         f"fill_steps={cold['fill_steps']}"),
        ("fig16.paged.warm_ttft_p50_s", warm_ttft * 1e6,
         f"fill_steps_p50={warm_steps};n={len(warm)}"),
        ("fig16.paged.ttft_speedup", cold["ttft_s"] / max(warm_ttft, 1e-9),
         "cold_over_warm_asserted_gt_1"),
        ("fig16.paged.prefix_hit_rate", hit_rate,
         f"hits={stats['prefix_hits']};misses={stats['prefix_misses']}"),
        ("fig16.paged.prefilled_tokens", paged_prefilled,
         f"cached={stats['cached_tokens']}"),
        ("fig16.slot.prefilled_tokens", slot_prefilled,
         "full_prompt_every_request"),
        ("fig16.slot.ttft_p50_s",
         _pct([s["ttft_s"] for s in slot_recs], P50) * 1e6,
         "one_shot_prefill_baseline"),
        ("fig16.prefill_work_saved_frac",
         1.0 - paged_prefilled / slot_prefilled, "asserted_gt_0.4"),
        ("fig16.kv_plan_bytes_per_rank", kv_bytes,
         f"blocks={paged.kv_blocks};block_size={BLOCK}"),
    ]


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer requests, same hit-rate / "
                         "TTFT-win / prefill-savings / identity assertions")
    args = ap.parse_args()
    rows = run(quick=args.quick)
    emit(rows, figure="fig16_prefix_cache",
         headline={"ttft_speedup": rows[2][1],
                   "prefix_hit_rate": rows[3][1],
                   "prefill_work_saved_frac": rows[7][1]})

"""[Fig 18] Unified telemetry: registry overhead, trace timelines, chaos.

Three legs, all in-process (unmeshed, so no placeholder-rank subprocess):

  A. **Overhead gate.** The same engine serves the same decode workload
     with telemetry off and on, interleaved (off/on/off/on...) so drift
     hits both arms equally. Hard gate: median TPOT with the registry +
     tracer live must stay within 5% (plus a fixed epsilon for µs-scale
     steps) of the disabled path — the one-global-read discipline
     (``obs/metrics.py``) is a perf claim, so it is asserted, not eyeballed.

  B. **Cold-start timeline.** A multi-spec archive is LOADed with tracing
     on; the emitted Chrome/Perfetto trace must show the pipelined LOAD:
     ``load.fetch`` / ``load.deserialize`` spans on their own stage
     threads, at least one of them overlapping an install-thread span.
     The registry's pipeline busy-seconds must equal ``LoadReport``'s to
     the float — both are fed from the same ``span`` measurement.

  C. **Fleet lifecycle + chaos.** A two-replica fleet serves traffic,
     survives one chaos kill (salvage + respawn), then live-reshards
     unmeshed -> (1,1). Registry counters must match ``FleetReport``
     (crashes, respawns, salvaged, reshard outcome), the report summary
     must carry the new ``queue_wait_p50_s``/``queue_wait_p95_s`` keys,
     and the saved trace must validate and contain the
     ``replica.provision`` / ``reshard.dual`` / ``reshard.cutover``
     windows.

Every leg also feeds the shared exposition gate: ``lint_exposition`` over
the final ``render()`` must come back clean, and every trace document must
pass ``validate_trace``.

CLI: ``python -m benchmarks.fig18_observability [--quick]``. ``--quick``
is the CI smoke mode: fewer requests and fewer overhead rounds, same hard
gates — a telemetry perf or well-formedness regression exits nonzero.
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import statistics
import tempfile
import time

import jax

from repro.configs.registry import get_arch
from repro.core import (Archive, CaptureSpec, foundry_load, foundry_save,
                        wait_for_background)
from repro.launch.mesh import ShardCtx, make_host_mesh, resolve_mesh
from repro.models.model import Model
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs import lint_exposition, validate_trace
from repro.serving.engine import ServingEngine
from repro.serving.faults import FaultPlan, FaultSpec, deactivate_all
from repro.serving.fleet import AutoscalePolicy, Fleet

CFG = get_arch("smollm-360m").reduced()
PROMPTS = [[5, 9, 2], [11, 3], [7, 7, 7, 1], [2], [13, 4, 9]]

# Leg A gate: 5% relative plus a fixed floor — reduced-config CPU decode
# steps are tens of µs, where one scheduler hiccup exceeds any relative
# bound. The epsilon is far below anything a real lock/allocation on the
# step path would cost.
TPOT_REL_BUDGET = 1.05
TPOT_ABS_EPS_S = 25e-6


def build(mesh=None):
    eng = ServingEngine(Model(CFG, ShardCtx(mesh=resolve_mesh(mesh))),
                        max_batch=4, max_seq=32, bucket_mode="pow2")
    eng.load_weights(rng=jax.random.PRNGKey(7))
    return eng


# ---------------------------------------------------------------------------
# leg A: overhead gate
# ---------------------------------------------------------------------------
def measure_tpot(eng, n_steps):
    """Median seconds/step over a drained batch of short requests."""
    cycle = itertools.cycle(PROMPTS)
    for _ in range(4):
        eng.submit(next(cycle), n_steps)
    times = []
    while eng.scheduler.pending:
        t0 = time.perf_counter()
        n = eng.step()
        if n:
            times.append((time.perf_counter() - t0) / 1)
    return statistics.median(times)


def leg_overhead(quick):
    eng = build(None)
    eng.cold_start_vanilla()
    measure_tpot(eng, 8)  # warm every bucket before either arm times it
    rounds, n_steps = (3, 8) if quick else (6, 16)
    off, on = [], []
    obs_trace.start()
    obs_trace.stop()  # collector exists; arms below toggle recording only
    for _ in range(rounds):  # interleave: drift lands on both arms
        obs_metrics.disable()
        off.append(measure_tpot(eng, n_steps))
        obs_metrics.enable()
        obs_trace.start(fresh=False)
        on.append(measure_tpot(eng, n_steps))
        obs_trace.stop()
    obs_metrics.disable()
    tpot_off, tpot_on = statistics.median(off), statistics.median(on)
    budget = tpot_off * TPOT_REL_BUDGET + TPOT_ABS_EPS_S
    assert tpot_on <= budget, (
        f"telemetry overhead gate: TPOT {tpot_on * 1e6:.1f}us with obs on "
        f"vs {tpot_off * 1e6:.1f}us off (budget {budget * 1e6:.1f}us)")
    return [
        ("fig18.tpot_obs_off", tpot_off * 1e6, "median_us_per_step"),
        ("fig18.tpot_obs_on", tpot_on * 1e6,
         f"gate=off*{TPOT_REL_BUDGET}+{TPOT_ABS_EPS_S * 1e6:.0f}us"),
        ("fig18.tpot_overhead_pct",
         max(0.0, (tpot_on / tpot_off - 1.0)) * 100.0, "asserted_lt_5pct"),
    ]


# ---------------------------------------------------------------------------
# leg B: cold-start timeline
# ---------------------------------------------------------------------------
def _multi_spec_archive():
    """An archive with several topology groups so the LOAD stage graph has
    a real pipeline to overlap (a single-template archive degenerates to
    fetch -> deserialize -> install in sequence)."""
    m = Model(CFG, ShardCtx(mesh=None))
    specs = []
    for name, seq in (("decode_s32", 32), ("decode_s48", 48),
                      ("decode_s64", 64)):
        def make_args(bucket, seq=seq):
            import jax.numpy as jnp
            return (m.param_specs(), m.cache_specs(bucket, seq),
                    jax.ShapeDtypeStruct((bucket,), jnp.int32))
        specs.append(CaptureSpec(name, m.decode_step, make_args, [1, 2, 4],
                                 donate_argnums=(1,)))
    ar, _ = foundry_save(specs, None, meta={"arch": CFG.name})
    return Archive.from_bytes(ar.to_bytes(), lazy=True)


def leg_coldstart_trace(tmpdir):
    ar = _multi_spec_archive()
    trace_path = os.path.join(tmpdir, "coldstart_trace.json")
    obs_metrics.enable()
    _, rep, _ = foundry_load(ar, None, trace_path=trace_path)
    wait_for_background(rep)
    obs_metrics.disable()

    doc = json.load(open(trace_path))
    problems = validate_trace(doc)
    assert problems == [], f"cold-start trace invalid: {problems[:3]}"
    fetch = obs_trace.spans_named(doc, "load.fetch")
    deser = obs_trace.spans_named(doc, "load.deserialize")
    install = obs_trace.spans_named(doc, "load.install")
    assert fetch and deser and install, "missing LOAD pipeline spans"
    stage_tids = ({e["tid"] for e in fetch} | {e["tid"] for e in deser}
                  | {e["tid"] for e in install})
    assert len(stage_tids) >= 2, "LOAD stages all ran on one thread"
    overlaps = sum(1 for a in fetch + deser for b in install
                   if a["tid"] != b["tid"] and obs_trace.overlapping(a, b))
    assert overlaps > 0, \
        "no fetch/deserialize span overlapped an install span"

    # one measurement, two consumers: registry == LoadReport to the float
    busy = obs_metrics.REGISTRY.get("foundry_load_pipeline_busy_seconds_total")
    for stage in ("fetch", "deserialize", "install"):
        got, want = busy.value(stage=stage), rep.pipeline[f"{stage}_s"]
        assert abs(got - want) < 1e-9, \
            f"registry {stage} busy {got} != LoadReport {want}"
    return [
        ("fig18.load_pipeline_spans", float(len(fetch) + len(deser)
                                            + len(install)),
         f"threads={len(stage_tids)}"),
        ("fig18.load_stage_overlaps", float(overlaps),
         "fetch_or_deser_x_install"),
    ]


# ---------------------------------------------------------------------------
# leg C: fleet lifecycle + chaos under full telemetry
# ---------------------------------------------------------------------------
def leg_fleet_chaos(tmpdir, quick):
    ar, _ = build(None).save_archive()
    ar = Archive.from_bytes(ar.to_bytes(), lazy=True)
    trace_path = os.path.join(tmpdir, "fleet_trace.json")
    n_reqs = 8 if quick else 16
    obs_metrics.enable()
    fleet = Fleet(factory_for_mesh=build, mode="foundry", archive=ar,
                  policy=AutoscalePolicy(min_replicas=2, max_replicas=2,
                                         target_inflight_per_replica=64,
                                         scale_down_idle_ticks=10_000),
                  mesh=None, name="fig18", trace_path=trace_path)
    plan = FaultPlan().activate()
    try:
        fleet.start()
        cycle = itertools.cycle(PROMPTS)
        reqs = []

        def tick_until(cond, what, budget=8000):
            for _ in range(budget):
                if cond():
                    return
                if len(reqs) < n_reqs:
                    reqs.append(fleet.submit(next(cycle), 5))
                if fleet.tick() == 0:
                    time.sleep(0.001)
            raise AssertionError(f"fig18: {what} not reached")

        tick_until(lambda: len(fleet._ready()) >= 2, "initial provision")
        tick_until(lambda: fleet.inflight() > 0, "traffic in flight")

        # chaos: kill the busiest replica, expect salvage + respawn
        tgt = max(fleet._ready(), key=lambda r: r.load)
        plan.add(FaultSpec(site="engine.decode_step",
                           tag=f"replica{tgt.stats.replica_id}", times=1,
                           message="fig18 chaos kill"))
        tick_until(lambda: fleet.crashes >= 1, "chaos kill")
        tick_until(lambda: len(fleet._ready()) >= 2, "respawn recovery")

        # live reshard to the (1,1) mesh with traffic still flowing
        rrep = fleet.reshard(make_host_mesh())
        tick_until(lambda: fleet._reshard is None, "reshard completion")
        assert rrep.done and rrep.aborted is None

        tick_until(lambda: len(reqs) >= n_reqs
                   and fleet._unresolved() == 0, "drain")
        fleet.drain_background()
        frep = fleet.report()
    finally:
        deactivate_all()
    obs_metrics.disable()

    s = frep.summary()
    assert frep.n_failed == 0, f"lost requests: {frep.n_failed}"
    # the new queue-wait measurement is populated and ordered below TTFT
    assert s["queue_wait_p50_s"] is not None
    assert s["queue_wait_p95_s"] is not None
    assert s["queue_wait_p50_s"] <= s["ttft_p50_s"] + 1e-9

    # registry == FleetReport, fed at the same code points
    v = obs_metrics.value
    assert v("fleet_crashes_total") == float(frep.crashes)
    assert v("fleet_respawns_total") == float(frep.respawns)
    assert v("fleet_salvaged_requests_total") == float(
        frep.salvaged_requests)
    assert v("fleet_crash_requeued_requests_total") == float(
        frep.crash_requeued_requests)
    assert v("fleet_reshard_total", {"outcome": "completed"}) == 1.0

    doc = json.load(open(trace_path))
    problems = validate_trace(doc)
    assert problems == [], f"fleet trace invalid: {problems[:3]}"
    for name in ("replica.provision", "reshard.dual", "reshard.cutover"):
        assert obs_trace.spans_named(doc, name), f"missing {name} span"
    dual = obs_trace.spans_named(doc, "reshard.dual")[0]
    cut = obs_trace.spans_named(doc, "reshard.cutover")[0]
    assert dual["ts"] + dual["dur"] <= cut["ts"] + 1, \
        "DUAL window must end where CUTOVER begins"
    return [
        ("fig18.fleet_crash_contained", float(frep.crashes),
         f"salvaged={frep.salvaged_requests};"
         f"requeued={frep.crash_requeued_requests}"),
        ("fig18.fleet_queue_wait_p95_us", s["queue_wait_p95_s"] * 1e6,
         "separate_from_ttft"),
        ("fig18.fleet_trace_events", float(len(doc["traceEvents"])),
         "validated_chrome_trace"),
    ]


def run(quick: bool = False):
    obs_metrics.reset()
    rows = []
    with tempfile.TemporaryDirectory() as tmpdir:
        rows += leg_overhead(quick)
        rows += leg_coldstart_trace(tmpdir)
        rows += leg_fleet_chaos(tmpdir, quick)
    # the accumulated exposition from all three legs must parse clean
    obs_metrics.enable()
    text = obs_metrics.render()
    obs_metrics.disable()
    problems = lint_exposition(text)
    assert problems == [], f"exposition lint: {problems[:3]}"
    rows.append(("fig18.exposition_series",
                 float(sum(1 for ln in text.splitlines()
                           if ln and not ln.startswith("#"))),
                 "lint_clean"))
    obs_metrics.reset()
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer requests/rounds, same overhead "
                         "and well-formedness gates")
    args = ap.parse_args()
    emit(run(quick=args.quick), figure="fig18_observability")

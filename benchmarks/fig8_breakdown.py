"""[Fig 8] Engine-initialization phase breakdown: vanilla vs checkpoint-image
vs Foundry.

The "CUDA-checkpoint" analogue bundles EVERY bucket's instantiated executable
into the archive (no templating, no on-demand work) — restore deserializes
them all; Foundry deserializes only templates. Phases are reported
separately, mirroring the paper's stacked bars.
"""
from __future__ import annotations

import pickle

from benchmarks.common import BENCH_ARCHS, fresh_jax_caches, make_engine, timed
from repro.core import foundry_load


def run():
    rows = []
    arch = BENCH_ARCHS[0]
    eng = make_engine(arch)
    archive_t, _ = eng.save_archive()                     # templated
    archive_all, _ = eng.save_archive(serialize_all_executables=True)

    # vanilla phases
    fresh_jax_caches()
    eng_v = make_engine(arch)
    rep = eng_v.cold_start_vanilla()
    for phase, s in rep.phases.items():
        rows.append((f"fig8.vanilla.{phase}", s * 1e6, ""))

    # checkpoint-image analogue: deserialize every bucket executable
    fresh_jax_caches()
    eng_c = make_engine(arch)

    def restore_all():
        from repro.core.restore import _deserialize_template
        spec_m = archive_all.manifest["specs"]["decode"]
        n = 0
        for g in spec_m["groups"]:
            for blob in g["bucket_executable_blobs"].values():
                _deserialize_template(archive_all.get_blob(blob))
                n += 1
        return n

    t_ckpt, n = timed(restore_all)
    rows.append(("fig8.ckpt_image.restore_all", t_ckpt * 1e6,
                 f"{n}_executables"))

    # foundry phases
    fresh_jax_caches()
    eng_f = make_engine(arch)
    rep_f = eng_f.cold_start_foundry(archive_t, background_exact=False)
    for phase, s in rep_f.phases.items():
        rows.append((f"fig8.foundry.{phase}", s * 1e6, ""))
    rows.append(("fig8.foundry.total", rep_f.total_s * 1e6,
                 f"vs_vanilla_{rep.total_s:.2f}s"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(), figure="fig8_breakdown")

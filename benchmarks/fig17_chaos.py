"""[Fig 17] Supervised fleet under chaos: crash recovery with KV salvage.

A two-replica fleet serves steady traffic while a chaos schedule kills
decode steps out from under it (``serving/faults.py``: one-shot
``engine.decode_step`` faults targeted at specific replicas via their
``fault_tag``). Three crashes minimum, one of them landing in the middle of
a live TP1->TP2 reshard's DUAL window. The supervisor
(``Fleet._on_replica_crash``) must contain every one: the crashed replica's
in-flight KV rows migrate into survivors' pools (same ``export_inflight`` /
``adopt_inflight`` path the reshard cutover uses), overflow requeues from
kept prefixes, and a replacement respawns from the shared archive at
warm-LOAD speed.

Hard assertions, not just prints (the ISSUE acceptance criteria):

  * zero lost requests — every submitted request resolves DONE, none FAILED;
  * token streams byte-identical to a never-crashed vanilla engine,
    including requests whose KV rows were salvaged mid-decode;
  * the fleet returns to its target replica count within a bounded number
    of ticks after each crash (recovery-to-full-capacity);
  * ``fallback_compiles == 0`` — the happy respawn path is a warm foundry
    LOAD, never a recompile;
  * the mid-reshard crash neither aborts the switch nor drops requests.

Needs 2 placeholder ranks for the TP2 leg, so everything runs in a
subprocess with ``--xla_force_host_platform_device_count`` (same harness as
fig15; core/collective_stub.py).

CLI: ``python -m benchmarks.fig17_chaos [--quick]``. ``--quick`` is the CI
smoke mode (wired into the test-fast job): fewer requests, same hard
assertions — a regression exits nonzero.
"""
from __future__ import annotations

_INNER = r"""
import itertools
import time

import jax
from repro.configs.registry import get_arch
from repro.core import Archive
from repro.launch.mesh import ShardCtx, make_capture_mesh, make_tp_mesh
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.faults import FaultPlan, FaultSpec, deactivate_all
from repro.serving.fleet import AutoscalePolicy, Fleet

QUICK = __QUICK__
CFG = get_arch("smollm-360m").reduced()
PROMPTS = [[5, 9, 2], [11, 3], [7, 7, 7, 1], [2], [13, 4, 9]]
N_NEW = 5 if QUICK else 8
N_REQS = 10 if QUICK else 24
MAX_INFLIGHT = 6                 # arrival gate: keeps salvage overflow small
RECOVERY_TICK_BUDGET = 8000      # ticks allowed to get back to full capacity
POLICY = dict(min_replicas=2, max_replicas=2,
              target_inflight_per_replica=64,
              max_crashes_in_window=10, crash_window_s=600.0)

def build(mesh):
    eng = ServingEngine(Model(CFG, ShardCtx(mesh=mesh)), max_batch=4,
                        max_seq=32, bucket_mode="pow2")
    eng.load_weights(rng=jax.random.PRNGKey(0))
    return eng

# offline SAVE: one single-device capture serves both topologies
mesh_cap = make_capture_mesh()
with mesh_cap:
    archive_bytes = build(mesh_cap).save_archive()[0].to_bytes()

# reference token streams from a never-crashed vanilla engine
ref_eng = build(None)
ref_eng.cold_start_vanilla()
reference = {}
for p in PROMPTS:
    r = ref_eng.submit(p, N_NEW)
    ref_eng.run_until_drained()
    reference[tuple(p)] = tuple(r.generated)

jax.clear_caches()
ar = Archive.from_bytes(archive_bytes, lazy=True)
tp1, tp2 = make_tp_mesh(1), make_tp_mesh(2)
fleet = Fleet(factory_for_mesh=build, mode="foundry", archive=ar,
              policy=AutoscalePolicy(**POLICY), mesh=tp1)
plan = FaultPlan().activate()

reqs = []
cycle = itertools.cycle(PROMPTS)
# phase the arrivals: hold half the trace back for the reshard window so
# the mid-reshard kill lands on a generation with real in-flight work
N_PRE = max(6, N_REQS // 2)
cap = [N_PRE]

def pump():
    if len(reqs) < cap[0] and fleet.inflight() < MAX_INFLIGHT:
        reqs.append(fleet.submit(next(cycle), N_NEW))

def arm_kill(exclude=()):
    # kill the busiest READY replica not in `exclude`: the salvage then has
    # real in-flight KV rows to migrate, not an idle scheduler
    cands = [r for r in fleet._ready() if r.stats.replica_id not in exclude]
    tgt = max(cands, key=lambda r: r.load)
    rid = tgt.stats.replica_id
    plan.add(FaultSpec(site="engine.decode_step", tag=f"replica{rid}",
                       times=1, message=f"chaos kill replica {rid}"))
    return rid

def tick_until(cond, what, budget=RECOVERY_TICK_BUDGET):
    for k in range(budget):
        if cond():
            return k
        pump()
        if fleet.tick() == 0:
            time.sleep(0.001)
    raise AssertionError(f"{what}: not reached in {budget} ticks")

# -- warm up to full capacity, put traffic in flight ---------------------
fleet.start()
tick_until(lambda: len(fleet._ready()) >= 2, "initial provision")
tick_until(lambda: fleet.inflight() > 0 or len(reqs) >= cap[0], "traffic")

recovery_ticks = []
for kill in range(2):
    # -- steady-state crash: salvage + respawn back to the floor ---------
    arm_kill()
    c0 = fleet.crashes
    tick_until(lambda: fleet.crashes > c0, f"crash #{kill + 1}")
    t = tick_until(lambda: len(fleet._ready()) >= 2,
                   f"recovery #{kill + 1} to full capacity")
    recovery_ticks.append(t)

# -- crash #3: mid-reshard, against the old generation -------------------
c0 = fleet.crashes
cap[0] = N_REQS  # release the held-back arrivals into the switch window
rep = fleet.reshard(tp2)
armed = mid_reshard_crash = False
while fleet._reshard is not None:
    old_ready = [r for r in fleet._reshard.old
                 if r in fleet._ready()]
    if not armed and len(old_ready) >= 2 and any(r.load for r in old_ready):
        arm_kill(exclude={r.stats.replica_id for r in fleet._reshard.new})
        armed = True
    if armed and fleet.crashes > c0:
        mid_reshard_crash = True
    pump()
    if fleet.tick() == 0:
        time.sleep(0.001)
assert armed, "chaos schedule never armed the mid-reshard kill"
assert mid_reshard_crash, "mid-reshard kill never fired inside the DUAL window"
assert rep.aborted is None, f"mid-reshard crash aborted the switch: {rep.aborted}"

# -- drain the remaining traffic on the new topology ---------------------
tick_until(lambda: len(reqs) >= N_REQS and fleet._unresolved() == 0, "drain")
fleet.drain_background()
frep = fleet.report()
s = frep.summary()

# -- hard invariants (the ISSUE acceptance criteria) ---------------------
assert len(reqs) == N_REQS
assert frep.n_failed == 0 and frep.n_done == N_REQS, \
    f"lost requests: {frep.n_failed} failed, {frep.n_done}/{N_REQS} done"
for q in reqs:
    assert tuple(q.generated) == reference[tuple(q.prompt)], \
        f"req {q.req_id} tokens diverged across crash recovery"
assert frep.crashes >= 3, f"chaos schedule only landed {frep.crashes} crashes"
assert frep.respawns >= 2, f"supervisor respawned only {frep.respawns}"
assert frep.salvaged_requests + frep.crash_requeued_requests > 0, \
    "no in-flight requests were recovered from any crash"
assert s["fallback_compiles"] == 0, "respawn path compiled instead of LOADing"
assert s["background_errors"] == 0, "background failures"
assert s["shed_requests"] == 0, "load shed despite available respawn budget"
assert len(fleet._ready()) >= POLICY["min_replicas"], \
    "fleet did not return to full capacity"
deactivate_all()

print(f"ROW,fig17.crashes,{frep.crashes},"
      f"salvaged={frep.salvaged_requests};requeued={frep.crash_requeued_requests}")
print(f"ROW,fig17.respawns,{frep.respawns},warm_load_respawn")
print(f"ROW,fig17.recovery_ticks_max,{max(recovery_ticks)},"
      f"budget={RECOVERY_TICK_BUDGET}")
print(f"ROW,fig17.served,{frep.n_done},zero_lost_identity_asserted")
print(f"ROW,fig17.mid_reshard_crash,1,"
      f"migrated={rep.migrated_requests};requeued={rep.requeued_requests}")
print(f"ROW,fig17.fallback_compiles,{s['fallback_compiles']},asserted_zero")
"""


def run(quick: bool = False):
    from repro.core.collective_stub import run_in_capture_process
    inner = _INNER.replace("__QUICK__", repr(bool(quick)))
    r = run_in_capture_process(inner, 2, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"fig17 subprocess failed:\n{r.stdout}\n{r.stderr}")
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("ROW,"):
            _, name, us, derived = line.split(",", 3)
            rows.append((name, float(us), derived))
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer requests, same zero-lost / "
                         "identity / bounded-recovery / zero-compile "
                         "assertions")
    args = ap.parse_args()
    emit(run(quick=args.quick), figure="fig17_chaos")

"""[Fig 19] Phase-disaggregated serving: decode TPOT isolation + scaling.

Splitting a fleet into a prefill pool and a decode pool (serving/pool.py;
``Fleet(pools=[...])``; HydraServe/ParaServe in PAPERS.md) buys two things
this figure measures on the cooperative single-threaded fleet loop:

  1. **Decode isolation.** Long-prompt, prefill-heavy traffic lands on the
     prefill pool, so the decode pool's batch bucket stays sized for the
     decode-bound requests: its step wall time over 8-token windows (the
     honest per-pool TPOT proxy — what dedicated decode hardware would
     see) stays within 1.2x of a no-prefill-load baseline at p99, while a
     colocated fleet serving the same mix degrades (fills inflate every
     replica's batch bucket).
  2. **Independent prefill scaling.** A burst of long prompts drains in
     ~half the ticks with 2 prefill replicas vs 1, with the decode pool
     unchanged — the knob the colocated fleet does not have.

And the correctness table stakes ride along as hard assertions: every
stream byte-identical across the prefill->decode KV handoff (requeued
overflow handoffs included), zero dropped requests, zero fallback compiles
(both pools LOAD the ONE shared archive).

The TPOT section runs FIRST: its latency windows are single-milliseconds,
and running the identity/scaling fleets beforehand leaves enough heap and
allocator churn behind to inflate the under-load tail by 2x+.

CLI: ``python -m benchmarks.fig19_disagg [--quick]``. ``--quick`` is the CI
smoke mode: smaller trace, deterministic assertions only (identity, zero
drops, handoffs observed, zero compiles, prefill tick-scaling); the
wall-clock p99 gates additionally run in the full mode.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from repro.configs.registry import get_arch
from repro.core import Archive
from repro.launch.mesh import ShardCtx, resolve_mesh
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.fleet import AutoscalePolicy, Fleet, FleetReport, PoolSpec
from repro.serving.scheduler import ReqState

CFG = get_arch("smollm-360m").reduced()
MAX_BATCH = 8
SHORTS = [[5, 9, 2], [11, 3], [7, 7, 7, 1], [2, 9]]


def _build(cfg, mesh=None):
    eng = ServingEngine(Model(cfg, ShardCtx(mesh=resolve_mesh(mesh))),
                        max_batch=MAX_BATCH, max_seq=64, bucket_mode="pow2",
                        kv_block_size=4)
    eng.load_weights(rng=jax.random.PRNGKey(0))
    return eng


def build(mesh=None):
    return _build(CFG, mesh)


def build_tpot(mesh=None):
    """Serving-scale logits head for the TPOT section: at the reduced
    256-token vocab a decode step is overhead-bound and its wall time is
    dominated by shared-CPU cache noise, not by the work the batch bucket
    actually buys (see fig9's LOOP_VOCAB note)."""
    return _build(dataclasses.replace(CFG, vocab_size=4096), mesh)


def pol(n):
    return AutoscalePolicy(min_replicas=n, max_replicas=n,
                           target_inflight_per_replica=64,
                           scale_down_idle_ticks=10**6)


def disagg_fleet(ar, n_prefill=1, n_decode=1, factory=build):
    return Fleet(factory, mode="foundry", archive=ar,
                 pools=[PoolSpec("prefill", pol(n_prefill)),
                        PoolSpec("decode", pol(n_decode))])


def long_prompt(j, plen):
    """Deterministic long prompt #j, unique for j < 2500: the leading two
    tokens spell out j, so no two prompts share even one radix block and
    the prefill prefix cache is not a variable here — fig16 owns that
    axis. (A simple ``f(i, j) % k`` body aliases whenever j wraps mod k,
    which silently turns "fresh" load into cached no-op fills.)"""
    return ([j % 50 + 1, j // 50 + 1]
            + [(7 * i + 3 * j + 5) % 50 + 1 for i in range(plen - 2)])


def wait_ready(fleet, n, budget_s=600.0):
    t0 = time.perf_counter()
    while len(fleet._ready()) < n:
        fleet.tick()
        time.sleep(0.001)
        assert time.perf_counter() - t0 < budget_s, "provision wedged"


def drain(fleet, reqs, budget_s=900.0):
    """Tick until every request resolves; returns the tick count."""
    t0 = time.perf_counter()
    ticks = 0
    while any(q.state not in (ReqState.DONE, ReqState.FAILED) for q in reqs):
        if fleet.tick() == 0:
            time.sleep(0.001)
        ticks += 1
        assert time.perf_counter() - t0 < budget_s, "fleet wedged"
    return ticks


def _tpot_section(quick: bool):
    """Decode TPOT isolation: disagg vs colocated under prefill load.

    Measured at a serving-scale vocab (fig9 idiom) so decode steps are
    bandwidth-bound and the shared-CPU cache pollution from interleaved
    fills is small relative to the step cost — the per-pool step wall is
    the honest proxy for what dedicated decode hardware would see.
    Longs get max_new=1: their whole token budget comes out of the fill,
    so they load the prefill pool without ever occupying decode — the
    purest version of "prefill load must not touch decode latency"."""
    ar_t, _ = build_tpot().save_archive()
    ar_t = Archive.from_bytes(ar_t.to_bytes(), lazy=True)
    shorts = [(p, 34) for p in SHORTS]

    def longs_batch(base):
        return [(long_prompt(base + j, 40), 1) for j in range(8)]

    # Each fleet measures its OWN load ratio: alternating rounds of
    # shorts-only passes and shorts+longs passes on the SAME pool, p99
    # over windows per half, then min over rounds PER HALF and the ratio
    # of the two minima. Within-fleet + interleaved means both halves
    # sample the same ambient noise (a separate baseline fleet measured
    # ~30s earlier drifts with whatever else the machine is doing, and
    # with ~15 windows a p99 is the single worst window). Minimum per
    # half is the estimator because noise only ever INFLATES a window:
    # each half's min round is the closest observation of its true cost,
    # so a transient burst cannot fail the disagg gate by landing in a
    # loaded round NOR fake a pass of the colocated gate by landing in
    # an unloaded round (min over the round RATIOS would keep exactly
    # those inflated-baseline rounds). The colocated fleet's batch-bucket
    # inflation is systematic, hits every loaded round, and survives the
    # min — it gets the identical statistic, fairly.
    n_rep, n_pass = (1, 1) if quick else (3, 2)
    WIN = 8

    def win_pcts(walls):
        """p50/p99 of mean inter-token time over disjoint 8-step windows.
        A single-step p99 on a time-shared CPU measures OS scheduling
        jitter (±1-3ms spikes land on whichever pool's step is running);
        the 8-token window mean is what a reader of the stream perceives
        and is the level at which isolation is actually claimable."""
        means = [sum(walls[i:i + WIN]) / len(walls[i:i + WIN])
                 for i in range(0, len(walls), WIN)]
        return FleetReport._pct(means, 0.50), FleetReport._pct(means, 0.99)

    def load_ratio(fleet_, pool):
        """(unloaded (p50, p99), loaded (p50, p99), p99 ratio): each half
        is its min-p99 round, the ratio divides the two minima."""
        fleet_.start()
        wait_ready(fleet_, sum(p.policy.min_replicas
                               for p in fleet_.pools.values()))
        # identical warmup for every fleet, run TWICE: the first round
        # touches every batch-bucket and fill shape, the second (same
        # prompts, now sitting in the radix tree) touches the prefix-hit
        # admission path — both first-touch host jits would otherwise land
        # as a 100ms..3s outlier inside a measured step
        served = 0
        for _ in range(2):
            rs = [fleet_.submit(p, n) for p, n in shorts + longs_batch(200)]
            drain(fleet_, rs)
            served += len(rs)
        walls = fleet_.pools[pool].step_walls
        rounds = []
        for rep in range(n_rep):
            halves = []
            for with_longs in (False, True):
                walls.clear()
                for i in range(n_pass):
                    # FRESH long prompts each pass: the warmup batch sits
                    # in the prefill radix cache, and a cached fill is no
                    # load at all
                    subs = shorts + (
                        longs_batch(300 + 100 * (rep * n_pass + i))
                        if with_longs else [])
                    rs = [fleet_.submit(p, n) for p, n in subs]
                    drain(fleet_, rs)
                    served += len(subs)
                halves.append(win_pcts(walls))
            rounds.append(halves)
        rep_ = fleet_.report()
        assert rep_.n_failed == 0 and rep_.n_done == served
        assert rep_.summary()["fallback_compiles"] == 0
        unloaded = min((r[0] for r in rounds), key=lambda h: h[1])
        loaded = min((r[1] for r in rounds), key=lambda h: h[1])
        return unloaded, loaded, loaded[1] / unloaded[1]

    (d0_p50, d0_p99), (d1_p50, d1_p99), ratio_disagg = load_ratio(
        disagg_fleet(ar_t, factory=build_tpot), "decode")
    colo = Fleet(build_tpot, mode="foundry", archive=ar_t, policy=pol(2))
    (c0_p50, c0_p99), (c1_p50, c1_p99), ratio_colo = load_ratio(
        colo, "serve")
    if not quick:
        assert ratio_disagg <= 1.2, \
            (f"prefill load leaked into the decode pool: p99 TPOT "
             f"{d1_p99 * 1e6:.0f}us vs baseline {d0_p99 * 1e6:.0f}us "
             f"({ratio_disagg:.2f}x)")
        assert ratio_colo > 1.5 and ratio_colo > ratio_disagg, \
            (f"colocated fleet did not degrade under the same mix: "
             f"{ratio_colo:.2f}x vs disaggregated {ratio_disagg:.2f}x")
    return [
        ("fig19.decode_p99_baseline", d0_p99 * 1e6,
         f"disagg_shorts_only_win{WIN}_p50={d0_p50 * 1e6:.0f}us"),
        ("fig19.decode_p99_disagg", d1_p99 * 1e6,
         f"under_prefill_load_ratio={ratio_disagg:.2f}"
         f"_p50_ratio={d1_p50 / d0_p50:.2f}"),
        ("fig19.decode_p99_colocated", c1_p99 * 1e6,
         f"own_baseline={c0_p99 * 1e6:.0f}us_ratio={ratio_colo:.2f}"
         f"_p50_ratio={c1_p50 / c0_p50:.2f}"),
    ], ratio_disagg, ratio_colo


def run(quick: bool = False):
    plen = 24 if quick else 32
    n_long = 4 if quick else 8
    short_new = 8 if quick else 12
    rows = []

    # TPOT isolation runs first in a quiet heap (see module docstring)
    tpot_rows, ratio_disagg, ratio_colo = _tpot_section(quick)

    ar, _ = build().save_archive()
    ar = Archive.from_bytes(ar.to_bytes(), lazy=True)

    # oracle token streams from a colocated single engine, one at a time
    workload = ([(p, short_new) for p in SHORTS]
                + [(long_prompt(j, plen), 3) for j in range(n_long)])
    oracle_eng = build()
    oracle_eng.cold_start_foundry(ar, background_exact=False)
    oracle = {}
    for p, n_new in workload:
        r = oracle_eng.submit(p, n_new)
        oracle_eng.run_until_drained()
        oracle[(tuple(p), n_new)] = tuple(r.generated)

    # -- correctness: byte identity across the handoff, zero drops --------
    fleet = disagg_fleet(ar)
    fleet.start()
    wait_ready(fleet, 2)
    reqs = [fleet.submit(p, n_new) for p, n_new in workload]
    drain(fleet, reqs)
    fleet.drain_background()
    rep = fleet.report()
    s = rep.summary()
    assert rep.n_failed == 0 and rep.n_done == len(reqs), \
        f"dropped requests: {rep.n_failed} failed / {rep.n_done} done"
    for r in reqs:
        assert tuple(r.generated) == oracle[(tuple(r.prompt),
                                             r.max_new_tokens)], \
            f"req {r.req_id} diverged across the prefill->decode handoff"
    assert fleet.handoffs > 0, "no request ever crossed the pools"
    assert s["fallback_compiles"] == 0, "a pool compiled instead of LOADing"
    assert s["background_errors"] == 0
    assert s["handoff_wait_p50_s"] is not None
    n_handoffs = fleet.handoffs
    rows.append(("fig19.served", rep.n_done, "byte_identity_asserted"))
    rows.append(("fig19.handoffs", n_handoffs,
                 f"requeued={fleet.handoff_requeued}"))
    rows.append(("fig19.handoff_wait_p50", s["handoff_wait_p50_s"] * 1e6,
                 f"p95={s['handoff_wait_p95_s'] * 1e6:.1f}us"))

    # -- prefill scaling: ticks to drain a long burst, 1 vs 2 replicas ----
    # decode stays at 2 replicas in BOTH configs (enough slots to absorb
    # all 16 handoffs without a requeue-and-refill) so the only variable
    # is prefill capacity — the axis the colocated fleet cannot scale alone
    burst = [(long_prompt(100 + j, plen), 2) for j in range(16)]
    ticks = {}
    for n_pre in (1, 2):
        f = disagg_fleet(ar, n_prefill=n_pre, n_decode=2)
        f.start()
        wait_ready(f, n_pre + 2)
        rs = [f.submit(p, n_new) for p, n_new in burst]
        ticks[n_pre] = drain(f, rs)
        frep = f.report()
        assert frep.n_failed == 0 and frep.n_done == len(rs)
        assert frep.summary()["fallback_compiles"] == 0
    ratio = ticks[1] / max(1, ticks[2])
    assert ratio > 1.3, \
        (f"2 prefill replicas must drain the burst substantially faster: "
         f"{ticks[1]} vs {ticks[2]} ticks (ratio {ratio:.2f})")
    rows.append(("fig19.prefill_burst_ticks_1p", ticks[1], "16_long_fills"))
    rows.append(("fig19.prefill_burst_ticks_2p", ticks[2],
                 f"scaling_ratio={ratio:.2f}_gt_1.3_asserted"))

    rows.extend(tpot_rows)
    headline = {"decode_p99_ratio_disagg": ratio_disagg,
                "decode_p99_ratio_colocated": ratio_colo,
                "prefill_scaling_ratio": ratio,
                "handoffs": float(n_handoffs)}
    return rows, headline


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: smaller trace; identity / zero-drop / "
                         "zero-compile / prefill-scaling assertions only "
                         "(wall-clock p99 gates run in full mode)")
    args = ap.parse_args()
    rows, headline = run(quick=args.quick)
    emit(rows, figure="fig19_disagg", headline=headline)

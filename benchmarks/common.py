"""Shared benchmark helpers.

Wall-clock benchmarks run reduced-width configs on CPU (full-size configs are
exercised shape-only by the dry-run); the quantities compared are the ones the
paper claims — ratios and phase structure, not absolute GPU seconds.

Machine-readable results — ``BENCH_results.json``
-------------------------------------------------
Every figure/table module persists its headline metrics with
``write_results(figure, rows, headline=...)`` (``emit`` does it when given a
``figure``), merged per-figure into one repo-root JSON file so successive PRs
accumulate a perf trajectory. Schema (version 1):

    {
      "schema_version": 1,
      "updated_utc": "<iso8601 of the last merge>",
      "figures": {
        "<figure>": {                      # e.g. "fig9_tpot"
          "updated_utc": "<iso8601>",
          "rows": {                        # every emitted CSV row
            "<row name>": {"value": <float>, "derived": "<free-form str>"}
          },
          "headline": { ... }              # optional: the few numbers a
        }                                  # regression gate should look at
      }
    }

Row values keep the CSV meaning (microseconds for timing rows unless the row
name says otherwise). The file is overwritten figure-by-figure, never
whole-file, so partial benchmark runs refresh only what they measured. Path
override: ``BENCH_RESULTS=/path/file.json``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from datetime import datetime, timezone
from typing import Callable, Optional

import jax

from repro.configs.registry import get_arch
from repro.models.model import Model
from repro.serving.engine import ServingEngine

# the paper's primary model (qwen3-14b) + a second family, reduced
BENCH_ARCHS = ["qwen3-14b", "smollm-360m"]

RESULTS_SCHEMA_VERSION = 1
RESULTS_PATH = os.environ.get(
    "BENCH_RESULTS",
    os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                 "BENCH_results.json"))


def make_engine(arch: str, *, max_batch: int = 16, max_seq: int = 64,
                bucket_mode: str = "all", decode_loop: str = "device",
                vocab_size: Optional[int] = None) -> ServingEngine:
    """Reduced-config engine. ``vocab_size`` overrides the reduced config's
    tiny vocab (256) when a benchmark needs the serving-scale logits matrix
    that the paper's decode numbers assume."""
    cfg = get_arch(arch).reduced()
    if vocab_size is not None:
        cfg = dataclasses.replace(cfg, vocab_size=vocab_size)
    model = Model(cfg)
    eng = ServingEngine(model, max_batch=max_batch, max_seq=max_seq,
                        bucket_mode=bucket_mode, decode_loop=decode_loop)
    eng.load_weights(rng=jax.random.PRNGKey(0))
    return eng


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return time.perf_counter() - t0, out


def fresh_jax_caches():
    """Clear jit caches between cold-start measurements so 'vanilla' really
    retraces/recompiles (a fresh process is the honest baseline; clearing
    caches is the in-process approximation)."""
    jax.clear_caches()


def read_results(path: Optional[str] = None) -> dict:
    """Parse BENCH_results.json ({} when absent/corrupt)."""
    p = path or RESULTS_PATH
    try:
        with open(p) as f:
            data = json.load(f)
        return data if isinstance(data, dict) else {}
    except (OSError, ValueError):
        return {}


def write_results(figure: str, rows, headline: Optional[dict] = None,
                  path: Optional[str] = None) -> dict:
    """Merge one figure's metrics into BENCH_results.json (module docstring
    documents the schema). Returns the merged document."""
    p = path or RESULTS_PATH
    now = datetime.now(timezone.utc).isoformat(timespec="seconds")
    doc = read_results(p)
    doc.setdefault("schema_version", RESULTS_SCHEMA_VERSION)
    doc["updated_utc"] = now
    figures = doc.setdefault("figures", {})
    entry = {"updated_utc": now,
             "rows": {name: {"value": float(value), "derived": str(derived)}
                      for name, value, derived in rows}}
    if headline:
        entry["headline"] = headline
    figures[figure] = entry
    tmp = p + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, p)
    return doc


def emit(rows, figure: Optional[str] = None, headline: Optional[dict] = None):
    """Print the CSV rows; when ``figure`` is given, also merge them into
    BENCH_results.json."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if figure is not None:
        write_results(figure, rows, headline=headline)

"""Shared benchmark helpers.

Wall-clock benchmarks run reduced-width configs on CPU (full-size configs are
exercised shape-only by the dry-run); the quantities compared are the ones the
paper claims — ratios and phase structure, not absolute GPU seconds.
"""
from __future__ import annotations

import time
from typing import Callable

import jax

from repro.configs.registry import get_arch
from repro.models.model import Model
from repro.serving.engine import ServingEngine

# the paper's primary model (qwen3-14b) + a second family, reduced
BENCH_ARCHS = ["qwen3-14b", "smollm-360m"]


def make_engine(arch: str, *, max_batch: int = 16, max_seq: int = 64,
                bucket_mode: str = "all") -> ServingEngine:
    cfg = get_arch(arch).reduced()
    model = Model(cfg)
    eng = ServingEngine(model, max_batch=max_batch, max_seq=max_seq,
                        bucket_mode=bucket_mode)
    eng.load_weights(rng=jax.random.PRNGKey(0))
    return eng


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return time.perf_counter() - t0, out


def fresh_jax_caches():
    """Clear jit caches between cold-start measurements so 'vanilla' really
    retraces/recompiles (a fresh process is the honest baseline; clearing
    caches is the in-process approximation)."""
    jax.clear_caches()


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
